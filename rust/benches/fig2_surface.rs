//! FIG2 — regenerate the paper's Figure 2: WordCount running time over
//! `mapreduce.job.reduces` × `mapreduce.task.io.sort.mb` via exhaustive
//! search (16 × 16 grid = 256 cluster runs), plus timing of the sweep.
//!
//! Emits `history/fig2_surface.csv`, a gnuplot script, a terminal heat
//! map, and the paper's qualitative checks (fluctuations + corner trend).
//!
//! Run: `cargo bench --bench fig2_surface` (CATLA_BENCH_QUICK=1 to shorten)

use catla::catla::visualize::{gnuplot_fig2, surface_heatmap};
use catla::config::params::{HadoopConfig, P_IO_SORT_MB, P_REDUCES};
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::{ClusterObjective, Driver, GridSearch, ParamSpace};
use catla::util::bench::Bench;
use catla::util::csv::Csv;
use catla::workloads::wordcount;

fn main() {
    let workload = wordcount(10_240.0);
    let spec = TuningSpec::fig2();
    let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
    println!(
        "# FIG2: exhaustive search, {} grid points, WordCount {} MB on {} nodes",
        spec.grid_size(),
        workload.input_mb,
        ClusterSpec::default().nodes
    );

    // ---- the experiment: the whole grid is ONE ask-batch ---------------
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let outcome = {
        let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
        Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .expect("grid sweep")
    };

    let reduces_axis = spec.ranges[0].grid();
    let sortmb_axis = spec.ranges[1].grid();
    let mut z = vec![vec![0.0f64; sortmb_axis.len()]; reduces_axis.len()];
    let mut csv = Csv::new(&["mapreduce.job.reduces", "mapreduce.task.io.sort.mb", "runtime_s"]);
    for rec in &outcome.records {
        let r = rec.config.get(P_REDUCES);
        let s = rec.config.get(P_IO_SORT_MB);
        let ri = reduces_axis.iter().position(|&v| v == r).unwrap();
        let si = sortmb_axis.iter().position(|&v| v == s).unwrap();
        z[ri][si] = rec.value;
        csv.push(&[r.to_string(), s.to_string(), format!("{:.3}", rec.value)]);
    }
    std::fs::create_dir_all("history").unwrap();
    csv.save(std::path::Path::new("history/fig2_surface.csv")).unwrap();
    std::fs::write("history/fig2.gnuplot", gnuplot_fig2("fig2_surface.csv", "fig2.png")).unwrap();

    println!(
        "\n{}",
        surface_heatmap(
            "Fig. 2 — WordCount running time (simulated)",
            "reduces",
            &reduces_axis,
            "io.sort.mb",
            &sortmb_axis,
            &z
        )
    );

    // ---- the paper's qualitative observations ---------------------------
    let flat: Vec<f64> = z.iter().flatten().copied().collect();
    let zmin = flat.iter().cloned().fold(f64::MAX, f64::min);
    let zmax = flat.iter().cloned().fold(f64::MIN, f64::max);
    let corner_bad = z[0][0]; // reduces=2, sort.mb=50
    let corner_good = z[reduces_axis.len() - 1][sortmb_axis.len() - 1];
    println!("## paper-shape checks");
    println!("| check | paper | measured |");
    println!("|---|---|---|");
    println!(
        "| huge fluctuations over the surface | yes | max/min = {:.2}x ({zmin:.1}s .. {zmax:.1}s) |",
        zmax / zmin
    );
    println!(
        "| larger reduces+sort.mb reduce runtime | yes | corner(2,50)={corner_bad:.1}s vs corner(32,800)={corner_good:.1}s ({}) |",
        if corner_good < corner_bad { "holds" } else { "VIOLATED" }
    );
    println!(
        "| best grid point | n/a | {:.1}s at {} |",
        outcome.best_value,
        outcome.best_config.summary()
    );

    // ---- timing ----------------------------------------------------------
    let mut bench = Bench::new();
    let sweep_cluster = std::cell::RefCell::new(SimCluster::new(ClusterSpec::default()));
    bench.run_throughput("fig2 full 256-point sweep (batched)", 256.0, "jobs", || {
        let mut c = sweep_cluster.borrow_mut();
        let mut obj = ClusterObjective::new(&mut c, &workload, 1);
        Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .expect("grid sweep")
            .best_value
    });
    bench.print_table("FIG2 harness timing");
    println!("wrote history/fig2_surface.csv + history/fig2.gnuplot");
}

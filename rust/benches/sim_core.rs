//! PERF — the DES engine itself: simulated jobs/second across
//! {wordcount, terasort, grep} × {small, large cluster}, measured
//! through three engine paths that must agree bit-for-bit:
//!
//! * **arena**  — `simulate_runtime_in` with one reused [`SimArena`]
//!   (reset-not-reallocate: the production hot path),
//! * **fresh**  — `simulate_runtime`, same optimized engine but fresh
//!   buffers every call (arena-off),
//! * **baseline** — `simulate_runtime_baseline`, the pre-PR decision
//!   structures (linear YARN scan, clone-and-sort straggler median,
//!   full-state straggler scan, no saturation latch, fresh buffers).
//!
//! The headline metric is the **DFO-singleton** case: batch=1 evals
//! through `ClusterObjective` — the shape every sequential method
//! (bobyqa, hooke-jeeves, …) drives — arena engine vs the pre-PR
//! baseline. Records `BENCH_sim_core.json`; the CI bench smoke
//! regenerates it and fails if the arena-on DFO-singleton sims/s
//! regresses more than 30% below the committed value.
//!
//! Run: `cargo bench --bench sim_core` (CATLA_BENCH_QUICK=1 shortens)

use catla::config::params::{HadoopConfig, P_REDUCES};
use catla::config::spec::TuningSpec;
use catla::hadoop::mapreduce::simulate_runtime_baseline;
use catla::hadoop::{
    simulate_runtime, simulate_runtime_in, ClusterSpec, SimArena, SimCluster,
};
use catla::optim::core::BatchObjective;
use catla::optim::{ClusterObjective, ParamSpace};
use catla::util::bench::Bench;
use catla::util::json::Json;
use catla::workloads::{grep, terasort, wordcount, WorkloadSpec};

fn throughput(stats: &catla::util::bench::BenchStats) -> f64 {
    stats.throughput.map(|(v, _)| v).unwrap_or(0.0)
}

fn main() {
    let quick = std::env::var("CATLA_BENCH_QUICK").is_ok();
    let mut bench = Bench::new();

    let small = ClusterSpec::default(); // 16 nodes x 2 racks
    let large = ClusterSpec {
        nodes: 64,
        racks: 4,
        ..ClusterSpec::default()
    };
    let mut cfg = HadoopConfig::default();
    cfg.set(P_REDUCES, 16.0);

    // one arena for the whole bench — exactly how a tuning run holds it
    let mut arena = SimArena::new();
    let mut cases = Json::obj();
    let clusters: [(&str, &ClusterSpec); 2] = [("small16", &small), ("large64", &large)];
    let input_mb = if quick { 1024.0 } else { 2048.0 };
    for (cl_name, cl) in clusters {
        let workloads: [WorkloadSpec; 3] =
            [wordcount(input_mb), terasort(input_mb), grep(input_mb)];
        for wl in workloads {
            // ---- identity first: all three paths, bit-equal ------------
            for seed in 0..8u64 {
                let a = simulate_runtime_in(&mut arena, cl, &wl, &cfg, seed);
                let f = simulate_runtime(cl, &wl, &cfg, seed);
                let b = simulate_runtime_baseline(cl, &wl, &cfg, seed);
                assert_eq!(
                    a.to_bits(),
                    f.to_bits(),
                    "arena vs fresh diverged ({} on {cl_name}, seed {seed})",
                    wl.name
                );
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "optimized vs baseline engine diverged ({} on {cl_name}, seed {seed})",
                    wl.name
                );
            }

            // ---- throughput per path ----------------------------------
            let mut seed = 1_000u64;
            let arena_sims = throughput(bench.run_throughput(
                &format!("{} on {cl_name}, arena engine", wl.name),
                1.0,
                "sims",
                || {
                    seed += 1;
                    simulate_runtime_in(&mut arena, cl, &wl, &cfg, seed)
                },
            ));
            let mut seed = 1_000u64;
            let fresh_sims = throughput(bench.run_throughput(
                &format!("{} on {cl_name}, fresh buffers", wl.name),
                1.0,
                "sims",
                || {
                    seed += 1;
                    simulate_runtime(cl, &wl, &cfg, seed)
                },
            ));
            let mut seed = 1_000u64;
            let baseline_sims = throughput(bench.run_throughput(
                &format!("{} on {cl_name}, pre-PR baseline", wl.name),
                1.0,
                "sims",
                || {
                    seed += 1;
                    simulate_runtime_baseline(cl, &wl, &cfg, seed)
                },
            ));
            let mut case = Json::obj();
            case.set("arena_sims_per_s", Json::Num(arena_sims));
            case.set("fresh_sims_per_s", Json::Num(fresh_sims));
            case.set("baseline_sims_per_s", Json::Num(baseline_sims));
            case.set(
                "arena_speedup_vs_baseline",
                Json::Num(if baseline_sims > 0.0 { arena_sims / baseline_sims } else { 0.0 }),
            );
            cases.set(&format!("{}@{cl_name}", wl.name), case);
        }
    }

    // ---- the acceptance case: DFO-singleton (batch=1) evals ------------
    // sequential methods ask one candidate at a time; each eval_batch of
    // size 1 takes the serial path with the slot-0 arena
    let wl = wordcount(input_mb);
    let sp = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let points: Vec<HadoopConfig> = (0..16)
        .map(|i| sp.decode(&vec![i as f64 / 16.0; sp.dims()]))
        .collect();

    let dfo_arena = {
        let mut cluster = SimCluster::new(small.clone());
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        let mut k = 0usize;
        throughput(bench.run_throughput(
            "DFO singleton (batch=1), arena engine",
            1.0,
            "sims",
            || {
                k += 1;
                obj.eval_batch(std::slice::from_ref(&points[k % points.len()]))
                    .expect("eval")[0]
            },
        ))
    };
    let dfo_baseline = {
        // the pre-PR singleton path: baseline engine, fresh buffers, one
        // simulation per eval (seeds advanced the same way)
        let mut cluster = SimCluster::new(small.clone());
        let mut k = 0usize;
        throughput(bench.run_throughput(
            "DFO singleton (batch=1), pre-PR baseline engine",
            1.0,
            "sims",
            || {
                k += 1;
                let seed = cluster.reserve_seeds(1);
                simulate_runtime_baseline(
                    &cluster.spec,
                    &wl,
                    &points[k % points.len()],
                    seed,
                )
            },
        ))
    };
    let speedup = if dfo_baseline > 0.0 { dfo_arena / dfo_baseline } else { 0.0 };

    let mut dfo = Json::obj();
    dfo.set("sims_per_s", Json::Num(dfo_arena));
    dfo.set("pre_pr_baseline_sims_per_s", Json::Num(dfo_baseline));
    dfo.set("speedup_vs_baseline", Json::Num(speedup));

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("sim_core".into()));
    doc.set("quick", Json::from(quick));
    doc.set("input_mb", Json::Num(input_mb));
    doc.set("identity", Json::Str("bitwise-ok".into()));
    doc.set("workloads", cases);
    doc.set("dfo_singleton", dfo);
    std::fs::write("BENCH_sim_core.json", doc.to_string() + "\n").unwrap();
    println!("wrote BENCH_sim_core.json");
    println!(
        "DFO singleton: arena {dfo_arena:.0} sims/s vs pre-PR baseline {dfo_baseline:.0} sims/s \
         ({speedup:.2}x)"
    );

    bench.print_table("PERF — simulator core (arena / fresh / pre-PR baseline)");
}

//! PERF2 — parameter-space decode throughput: `ParamSpace::decode` is on
//! every optimizer's hot path (each candidate crosses unit-cube →
//! `HadoopConfig` exactly once), so its cost bounds ask-batch overhead.
//! Measures legacy linear specs against the typed redesign's categorical
//! + log + constraint specs and records results to
//! `BENCH_space_decode.json` (CI asserts the file is regenerated).
//!
//! Run: `cargo bench --bench space_decode` (CATLA_BENCH_QUICK=1 to shorten)

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::optim::ParamSpace;
use catla::util::bench::Bench;
use catla::util::json::Json;
use catla::util::rng::Rng;

fn points(dims: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dims).map(|_| rng.f64()).collect())
        .collect()
}

fn main() {
    let mut bench = Bench::new();
    let specs: Vec<(&str, TuningSpec)> = vec![
        ("fig2 2-param linear", TuningSpec::fig2()),
        ("fig3 4-param linear", TuningSpec::fig3()),
        (
            "typed 4-param cat+log+constraint",
            TuningSpec::parse(
                "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
                 param mapreduce.task.io.sort.mb int 64 1024 log\n\
                 param mapreduce.map.memory.mb int 512 4096 log\n\
                 param mapreduce.map.output.compress bool\n\
                 constraint io.sort.mb <= 0.7*map.memory.mb\n",
            )
            .unwrap(),
        ),
    ];

    let mut results = Vec::new();
    for (label, spec) in &specs {
        let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
        let xs = points(space.dims(), 4096, 42);
        let mut i = 0usize;
        let mean_ns = bench
            .run_throughput(&format!("decode {label}"), 1.0, "decodes", || {
                i = (i + 1) % xs.len();
                space.decode(&xs[i]).values.len()
            })
            .mean_ns;
        let mut row = Json::obj();
        row.set("spec", Json::Str(label.to_string()));
        row.set("dims", Json::Num(space.dims() as f64));
        row.set("constraints", Json::Num(spec.constraints.len() as f64));
        row.set("mean_ns", Json::Num(mean_ns));
        row.set("decodes_per_sec", Json::Num(1e9 / mean_ns));
        results.push(row);

        // encode/decode round-trip (resume replay's path)
        let cfgs: Vec<HadoopConfig> = xs[..256].iter().map(|x| space.decode(x)).collect();
        let mut j = 0usize;
        bench.run_throughput(&format!("encode {label}"), 1.0, "encodes", || {
            j = (j + 1) % cfgs.len();
            space.encode(&cfgs[j]).len()
        });
    }

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("space_decode".into()));
    doc.set("results", Json::Arr(results));
    std::fs::write("BENCH_space_decode.json", doc.to_string() + "\n").unwrap();
    println!("wrote BENCH_space_decode.json");

    bench.print_table("PERF2 — ParamSpace decode/encode throughput");
}

//! PERF1b — batched config scoring through the AOT JAX/Pallas artifacts
//! on XLA PJRT vs the native rust mirror: configs/second across batch
//! sizes. This is the surrogate-prescreening hot path (L1+L2+runtime).
//!
//! Run: `make artifacts && cargo bench --bench runtime_batch_eval`

use catla::config::params::{HadoopConfig, PARAMS};
use catla::hadoop::{costmodel, ClusterSpec};
use catla::runtime::{CostModelExec, QuadraticExec, Runtime};
use catla::util::bench::Bench;
use catla::util::rng::Rng;
use catla::workloads::wordcount;

fn random_configs(n: usize, seed: u64) -> Vec<HadoopConfig> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut c = HadoopConfig::default();
            for p in PARAMS.iter() {
                c.set(p.index, rng.range_f64(p.lo, p.hi));
            }
            c
        })
        .collect()
}

fn main() {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime_batch_eval: {e}");
            return;
        }
    };
    let wl = wordcount(10_240.0);
    let cl = ClusterSpec::default();
    let mut exec = CostModelExec::load(&rt, &wl, &cl).expect("compile artifacts");
    let mut bench = Bench::new();

    for n in [128usize, 1024, 4096] {
        let cfgs = random_configs(n, n as u64);
        bench.run_throughput(
            &format!("PJRT cost model, batch {n}"),
            n as f64,
            "configs",
            || exec.predict(&cfgs).unwrap().len(),
        );
        bench.run_throughput(
            &format!("native rust mirror, batch {n}"),
            n as f64,
            "configs",
            || {
                cfgs.iter()
                    .map(|c| costmodel::predict_runtime(c, &wl, &cl))
                    .sum::<f64>()
            },
        );
    }

    // quadratic surrogate evaluation (BOBYQA prescreen inner op)
    let mut quad = QuadraticExec::load(&rt).expect("compile quadratic artifact");
    let mut rng = Rng::new(5);
    let d = 8;
    let xs: Vec<Vec<f64>> = (0..256)
        .map(|_| (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();
    let g: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let mut h = vec![vec![0.0; d]; d];
    for i in 0..d {
        for j in 0..=i {
            let v = rng.range_f64(-1.0, 1.0);
            h[i][j] = v;
            h[j][i] = v;
        }
    }
    bench.run_throughput("PJRT quadratic surrogate, batch 256", 256.0, "points", || {
        quad.eval(&xs, &g, &h, 0.5).unwrap().len()
    });

    bench.print_table("PERF1b — batched scoring throughput");
    println!(
        "note: PJRT wins on accelerator hardware; on this CPU-PJRT testbed the\n\
         native mirror bounds the achievable speedup — see EXPERIMENTS.md §Perf."
    );
}

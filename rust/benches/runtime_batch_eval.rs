//! PERF1b — batched scoring throughput, two layers:
//!
//!   (a) ask/tell batch evaluation: grid/random/latin propose their whole
//!       budget as one ask-batch; `ClusterObjective` fans it out over the
//!       thread pool with reserved seeds. Compared against forced serial
//!       per-config evaluation at EQUAL eval counts — same seeds, byte-
//!       identical results, the wall-clock difference is pure batching.
//!   (b) the batched cost-model scorer across batch sizes (AOT
//!       JAX/Pallas artifacts on XLA PJRT with `--features pjrt`, the
//!       native f32 mirror otherwise) — the prescreening hot path.
//!
//! Run: `cargo bench --bench runtime_batch_eval`

use catla::config::params::HadoopConfig;
use catla::config::space::ParamRegistry;
use catla::config::spec::TuningSpec;
use catla::hadoop::{costmodel, ClusterSpec, SimCluster};
use catla::optim::{ClusterObjective, Driver, Method, ParamSpace};
use catla::runtime::{CostModelExec, QuadraticExec, Runtime};
use catla::util::bench::Bench;
use catla::util::rng::Rng;
use catla::workloads::wordcount;

fn random_configs(n: usize, seed: u64) -> Vec<HadoopConfig> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut c = HadoopConfig::default();
            for (i, d) in ParamRegistry::builtin().defs().iter().enumerate() {
                c.set(i, rng.range_f64(d.lo, d.hi));
            }
            c
        })
        .collect()
}

fn main() {
    let mut bench = Bench::new();

    // ---- (a) ask/tell batched vs serial cluster evaluation --------------
    const EVALS: usize = 192;
    let wl = wordcount(10_240.0);
    let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    println!(
        "# PERF1b(a) — population methods, {EVALS} evals each, serial vs batched\n"
    );
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for name in ["grid", "random", "latin"] {
        let run = |serial: bool| -> f64 {
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
            if serial {
                obj = obj.serial();
            }
            let mut opt = Method::from_name(name, 11).unwrap().build();
            Driver::new(EVALS)
                .run(opt.as_mut(), &space, &mut obj)
                .expect("tuning run")
                .best_value
        };
        // results must be byte-identical: batching may not change science
        assert_eq!(
            run(true).to_bits(),
            run(false).to_bits(),
            "{name}: batched eval changed the outcome"
        );
        let s = bench
            .run_throughput(
                &format!("{name}: serial per-config eval"),
                EVALS as f64,
                "evals",
                || run(true),
            )
            .mean_secs();
        let b = bench
            .run_throughput(
                &format!("{name}: batched ask-batch eval"),
                EVALS as f64,
                "evals",
                || run(false),
            )
            .mean_secs();
        rows.push((name.to_string(), s, b));
    }
    println!("| method | serial | batched | speedup |");
    println!("|---|---|---|---|");
    for (name, s, b) in &rows {
        println!(
            "| {name} | {:.1} ms | {:.1} ms | {:.2}x |",
            s * 1e3,
            b * 1e3,
            s / b
        );
    }

    // ---- (b) batched cost-model scorer ----------------------------------
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            bench.print_table("PERF1b — batched scoring throughput");
            eprintln!("skipping scorer section: {e}");
            return;
        }
    };
    println!("\n# PERF1b(b) — cost-model scorer ({} backend)\n", rt.backend());
    let cl = ClusterSpec::default();
    let mut exec = CostModelExec::load(&rt, &wl, &cl).expect("load cost model");

    for n in [128usize, 1024, 4096] {
        let cfgs = random_configs(n, n as u64);
        bench.run_throughput(
            &format!("{} cost model, batch {n}", rt.backend()),
            n as f64,
            "configs",
            || exec.predict(&cfgs).unwrap().len(),
        );
        bench.run_throughput(
            &format!("f64 analytic model loop, batch {n}"),
            n as f64,
            "configs",
            || {
                cfgs.iter()
                    .map(|c| costmodel::predict_runtime(c, &wl, &cl))
                    .sum::<f64>()
            },
        );
    }

    // quadratic surrogate evaluation (BOBYQA prescreen inner op)
    let mut quad = QuadraticExec::load(&rt).expect("load quadratic");
    let mut rng = Rng::new(5);
    let d = 8;
    let xs: Vec<Vec<f64>> = (0..256)
        .map(|_| (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();
    let g: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
    let mut h = vec![vec![0.0; d]; d];
    for i in 0..d {
        for j in 0..=i {
            let v = rng.range_f64(-1.0, 1.0);
            h[i][j] = v;
            h[j][i] = v;
        }
    }
    bench.run_throughput("quadratic surrogate, batch 256", 256.0, "points", || {
        quad.eval(&xs, &g, &h, 0.5).unwrap().len()
    });

    bench.print_table("PERF1b — batched scoring throughput");
    println!(
        "note: with `--features pjrt` section (b) exercises the AOT artifacts on\n\
         XLA PJRT; PJRT wins on accelerator hardware, while on a CPU testbed the\n\
         native mirror bounds the achievable speedup — see EXPERIMENTS.md §Perf."
    );
}

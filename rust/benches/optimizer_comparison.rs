//! ABL1 + ABL2 — the comparisons the paper's design implies but does not
//! tabulate:
//!
//!   ABL1  direct search vs DFO: every optimizer, equal budget, on the
//!         Fig. 2 two-parameter space; metric = best runtime found and
//!         evaluations-to-within-5%-of-the-grid-optimum.
//!   ABL2  surrogate prescreening: BOBYQA vs BOBYQA seeded through the
//!         analytic cost model (native mirror and, when artifacts exist,
//!         the AOT JAX/Pallas model on PJRT).
//!
//! Run: `cargo bench --bench optimizer_comparison`

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::surrogate::{NativeScorer, Prescreen};
use catla::optim::{ClusterObjective, Driver, GridSearch, Method, ParamSpace, ALL_METHODS};
use catla::runtime::{CostModelExec, Runtime};
use catla::util::csv::Csv;
use catla::workloads::wordcount;

const BUDGET: usize = 40;
const SEEDS: [u64; 5] = [2, 9, 23, 41, 77];

fn main() {
    let workload = wordcount(10_240.0);
    let spec = TuningSpec::fig2();
    let space = ParamSpace::new(spec.clone(), HadoopConfig::default());

    // ---- reference: the full-grid optimum (256 evals, one ask-batch) ----
    let grid_best = {
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
        Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .expect("grid sweep")
    };
    println!(
        "# ABL1/ABL2: budget {BUDGET} vs grid optimum {:.1}s (256 evals), {} seeds\n",
        grid_best.best_value,
        SEEDS.len()
    );

    let mut csv = Csv::new(&["optimizer", "seed", "best_runtime_s", "evals_to_5pct"]);

    // ---- ABL1: every method --------------------------------------------
    println!("## ABL1 — direct search vs DFO (mean over seeds)\n");
    println!("| optimizer | family | best found (s) | evals to 5% of grid-opt |");
    println!("|---|---|---|---|");
    for name in ALL_METHODS {
        if name == "grid" {
            continue; // the reference itself
        }
        let mut bests = Vec::new();
        let mut hits = Vec::new();
        for &seed in &SEEDS {
            let method = Method::from_name(name, seed).unwrap();
            let mut cluster = SimCluster::new(ClusterSpec {
                seed,
                ..ClusterSpec::default()
            });
            let out = {
                let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
                let mut opt = method.build();
                Driver::new(BUDGET)
                    .run(opt.as_mut(), &space, &mut obj)
                    .expect("tuning run")
            };
            let hit = out.evals_to_within(grid_best.best_value, 0.05);
            csv.push(&[
                name.to_string(),
                seed.to_string(),
                format!("{:.3}", out.best_value),
                hit.map(|h| h.to_string()).unwrap_or_else(|| "-".into()),
            ]);
            bests.push(out.best_value);
            if let Some(h) = hit {
                hits.push(h as f64);
            }
        }
        let mean_best = bests.iter().sum::<f64>() / bests.len() as f64;
        let family = if Method::from_name(name, 0).unwrap().is_direct_search() {
            "direct"
        } else {
            "DFO"
        };
        let hit_str = if hits.is_empty() {
            format!("never (in {BUDGET})")
        } else {
            format!("{:.1} ({}/{} seeds)", hits.iter().sum::<f64>() / hits.len() as f64, hits.len(), SEEDS.len())
        };
        println!("| {name} | {family} | {mean_best:.1} | {hit_str} |");
    }
    println!(
        "| grid (reference) | direct | {:.1} | 256 evals always |",
        grid_best.best_value
    );

    // ---- ABL2: prescreening ---------------------------------------------
    println!("\n## ABL2 — surrogate prescreening (BOBYQA, mean over seeds)\n");
    println!("| variant | best found (s) | evals to 5% of grid-opt |");
    println!("|---|---|---|");

    let mut run_variant = |label: &str, prescreen: Option<&str>| {
        let mut bests = Vec::new();
        let mut hits: Vec<f64> = Vec::new();
        for &seed in &SEEDS {
            let mut cluster = SimCluster::new(ClusterSpec {
                seed,
                ..ClusterSpec::default()
            });
            let out = {
                let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
                match prescreen {
                    None => {
                        let mut opt = Method::Bobyqa { seed }.build();
                        Driver::new(BUDGET)
                            .run(opt.as_mut(), &space, &mut obj)
                            .expect("tuning run")
                    }
                    Some("native") => {
                        let scorer = NativeScorer {
                            workload: workload.clone(),
                            cluster: ClusterSpec::default(),
                        };
                        let mut p = Prescreen::new(scorer);
                        p.seed = seed;
                        p.run_bobyqa(&space, &mut obj, BUDGET).unwrap()
                    }
                    Some("runtime") => {
                        let rt = Runtime::open_default().expect("artifacts dir missing");
                        let scorer =
                            CostModelExec::load(&rt, &workload, &ClusterSpec::default()).unwrap();
                        let mut p = Prescreen::new(scorer);
                        p.seed = seed;
                        p.run_bobyqa(&space, &mut obj, BUDGET).unwrap()
                    }
                    _ => unreachable!(),
                }
            };
            csv.push(&[
                label.to_string(),
                seed.to_string(),
                format!("{:.3}", out.best_value),
                out.evals_to_within(grid_best.best_value, 0.05)
                    .map(|h| h.to_string())
                    .unwrap_or_else(|| "-".into()),
            ]);
            bests.push(out.best_value);
            if let Some(h) = out.evals_to_within(grid_best.best_value, 0.05) {
                hits.push(h as f64);
            }
        }
        let mean_best = bests.iter().sum::<f64>() / bests.len() as f64;
        let hit_str = if hits.is_empty() {
            format!("never (in {BUDGET})")
        } else {
            format!(
                "{:.1} ({}/{} seeds)",
                hits.iter().sum::<f64>() / hits.len() as f64,
                hits.len(),
                SEEDS.len()
            )
        };
        println!("| {label} | {mean_best:.1} | {hit_str} |");
    };

    run_variant("bobyqa (no prescreen)", None);
    run_variant("bobyqa + native prescreen", Some("native"));
    match Runtime::open_default() {
        Ok(rt) => run_variant(
            &format!("bobyqa + runtime prescreen ({} backend)", rt.backend()),
            Some("runtime"),
        ),
        Err(_) => println!("| bobyqa + runtime prescreen | skipped (no artifacts dir) | - |"),
    }

    std::fs::create_dir_all("history").unwrap();
    csv.save(std::path::Path::new("history/optimizer_comparison.csv"))
        .unwrap();
    println!("\nwrote history/optimizer_comparison.csv");
}

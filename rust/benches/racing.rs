//! PERF — multi-fidelity racing: the wide-sweep shape racing exists
//! for (random search, one wide ask-slice, `repeats` seeds per config)
//! with racing off vs on, across several optimizer seeds. Measures DES
//! runs, full-fidelity evaluations and wall time, and re-asserts the
//! PR's acceptance bar in-run: racing spends >= 3x fewer full-fidelity
//! evaluations while the mean best-value regression stays <= 2%.
//! Records `BENCH_racing.json` for the CI bench smoke.
//!
//! Run: `cargo bench --bench racing` (CATLA_BENCH_QUICK=1 shortens)

use std::time::Instant;

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::surrogate::{CandidateScorer, NativeScorer};
use catla::optim::{
    ClusterObjective, Driver, Fidelity, Method, ParamSpace, RacingObjective, RacingSettings,
    TuningOutcome,
};
use catla::util::json::Json;
use catla::workloads::wordcount;

const METHOD: &str = "random";
const REPEATS: usize = 3;

fn run(seed: u64, budget: usize, racing: Option<RacingSettings>) -> (TuningOutcome, usize, f64) {
    let wl = wordcount(2048.0);
    let sp = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let cluster_spec = cluster.spec.clone();
    let mut opt = Method::from_name(METHOD, seed).unwrap().build();
    let t0 = Instant::now();
    let (out, sims) = match racing {
        None => {
            let mut obj = ClusterObjective::new(&mut cluster, &wl, REPEATS);
            let out = Driver::new(budget).run(opt.as_mut(), &sp, &mut obj).unwrap();
            let sims = budget * REPEATS;
            (out, sims)
        }
        Some(settings) => {
            let inner = ClusterObjective::new(&mut cluster, &wl, REPEATS);
            let scorer: Option<Box<dyn CandidateScorer>> = Some(Box::new(NativeScorer {
                workload: wl.clone(),
                cluster: cluster_spec,
            }));
            let mut obj = RacingObjective::new(inner, settings, scorer);
            let out = Driver::new(budget).run(opt.as_mut(), &sp, &mut obj).unwrap();
            let sims = obj.stats().sims;
            (out, sims)
        }
    };
    let wall = t0.elapsed().as_secs_f64();
    (out, sims, wall)
}

fn full_evals(out: &TuningOutcome) -> usize {
    out.records.iter().filter(|r| r.fidelity == Fidelity::Full).count()
}

fn main() {
    let quick = std::env::var("CATLA_BENCH_QUICK").is_ok();
    let budget: usize = if quick { 48 } else { 96 };
    let seeds: &[u64] = if quick { &[23, 61] } else { &[11, 23, 47, 61, 89] };
    let racing = RacingSettings {
        enabled: true,
        ..RacingSettings::default()
    };

    let mut full_off = 0usize;
    let mut full_on = 0usize;
    let mut sims_off = 0usize;
    let mut sims_on = 0usize;
    let mut wall_off = 0.0f64;
    let mut wall_on = 0.0f64;
    let mut regressions: Vec<f64> = Vec::new();

    for &seed in seeds {
        let (off, s_off, w_off) = run(seed, budget, None);
        let (on, s_on, w_on) = run(seed, budget, Some(racing));
        assert_eq!(off.evals(), on.evals(), "seed {seed}: racing changed the eval count");
        // monotone promotion: a finalist's value is the exact
        // racing-off measurement of the same candidate (random's ask
        // stream ignores tells, so the candidate streams are identical)
        for (a, b) in off.records.iter().zip(&on.records) {
            if b.fidelity == Fidelity::Full {
                assert_eq!(
                    a.value.to_bits(),
                    b.value.to_bits(),
                    "seed {seed} iter {}: finalist diverged from racing-off",
                    a.iter
                );
            }
        }
        full_off += full_evals(&off);
        full_on += full_evals(&on);
        sims_off += s_off;
        sims_on += s_on;
        wall_off += w_off;
        wall_on += w_on;
        regressions.push(100.0 * (on.best_value - off.best_value) / off.best_value);
        println!(
            "seed {seed}: full evals {} -> {}, DES runs {} -> {}, best {:.3} -> {:.3}",
            full_evals(&off),
            full_evals(&on),
            s_off,
            s_on,
            off.best_value,
            on.best_value
        );
    }

    let full_reduction = full_off as f64 / full_on.max(1) as f64;
    let sims_reduction = sims_off as f64 / sims_on.max(1) as f64;
    let mean_regression = regressions.iter().sum::<f64>() / regressions.len() as f64;

    println!(
        "{} seeds, budget {budget}, {METHOD}, repeats {REPEATS}, eta {} (min keep {}):",
        seeds.len(),
        racing.eta,
        racing.min_tier_evals
    );
    println!(
        "full-fidelity evals {full_off} -> {full_on} ({full_reduction:.1}x), \
         DES runs {sims_off} -> {sims_on} ({sims_reduction:.1}x)"
    );
    println!(
        "mean best-value regression {mean_regression:.3}% over {:?}; wall {wall_off:.2}s -> {wall_on:.2}s",
        regressions
    );

    // the PR's acceptance bar, asserted in-run so `cargo bench` itself
    // fails loudly, not just the CI smoke gate over the JSON
    assert!(
        full_reduction >= 3.0,
        "racing spent too many full-fidelity evals: {full_reduction:.2}x < 3x"
    );
    assert!(
        mean_regression <= 2.0,
        "racing regressed the best value by {mean_regression:.2}% (> 2%)"
    );

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("racing".into()));
    doc.set("quick", Json::Bool(quick));
    doc.set("method", Json::Str(METHOD.into()));
    doc.set("budget", Json::Num(budget as f64));
    doc.set("repeats", Json::Num(REPEATS as f64));
    doc.set("seeds", Json::Num(seeds.len() as f64));
    doc.set("eta", Json::Num(racing.eta as f64));
    doc.set("min_tier_evals", Json::Num(racing.min_tier_evals as f64));
    doc.set("full_evals_off", Json::Num(full_off as f64));
    doc.set("full_evals_on", Json::Num(full_on as f64));
    doc.set("full_eval_reduction", Json::Num(full_reduction));
    doc.set("des_runs_off", Json::Num(sims_off as f64));
    doc.set("des_runs_on", Json::Num(sims_on as f64));
    doc.set("des_run_reduction", Json::Num(sims_reduction));
    doc.set("mean_best_regression_pct", Json::Num(mean_regression));
    doc.set("wall_off_s", Json::Num(wall_off));
    doc.set("wall_on_s", Json::Num(wall_on));
    doc.set("finalists_bitwise_identical", Json::Bool(true));
    std::fs::write("BENCH_racing.json", doc.to_string() + "\n").unwrap();
    println!("wrote BENCH_racing.json");
}

//! PERF + ABL — deterministic fault injection:
//!
//! * **identity first** (in-bench asserts): a disabled fault model
//!   (mttf 0, whatever the other knobs) is bit-identical to the default
//!   spec, and an enabled seeded schedule replays bit-identically;
//! * **throughput**: sims/s with faults off vs on (mttf 400s on the
//!   default 16-node cluster) — what the NodeDown/NodeUp machinery and
//!   lost-shuffle re-execution cost the DES hot path;
//! * **ranking**: extending the `noise_robustness` pattern, how each
//!   optimizer family degrades as the node-failure rate grows — each
//!   method tunes on a flaky cluster and its chosen config is re-measured
//!   on a clean noiseless one, so lucky fault draws can't flatter a
//!   method.
//!
//! Records `BENCH_faults.json` for the CI bench smoke.
//!
//! Run: `cargo bench --bench faults` (CATLA_BENCH_QUICK=1 shortens)

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::noise::NoiseModel;
use catla::hadoop::{simulate_runtime_in, ClusterSpec, FaultModel, SimArena, SimCluster};
use catla::optim::{ClusterObjective, Driver, Method, ParamSpace};
use catla::util::bench::Bench;
use catla::util::json::Json;
use catla::workloads::wordcount;

fn flaky(mttf_s: f64, seed: u64) -> ClusterSpec {
    ClusterSpec {
        seed,
        fault: FaultModel {
            mttf_s,
            recovery_s: 60.0,
            max_concurrent: 2,
        },
        ..ClusterSpec::default()
    }
}

fn throughput(stats: &catla::util::bench::BenchStats) -> f64 {
    stats.throughput.map(|(v, _)| v).unwrap_or(0.0)
}

fn main() {
    let quick = std::env::var("CATLA_BENCH_QUICK").is_ok();
    let mut bench = Bench::new();
    let input_mb = if quick { 1024.0 } else { 2048.0 };
    let wl = wordcount(input_mb);
    let cfg = HadoopConfig::default();
    let mut arena = SimArena::new();

    // ---- identity first --------------------------------------------------
    let off_spec = ClusterSpec {
        fault: FaultModel {
            mttf_s: 0.0,
            recovery_s: 7.0,
            max_concurrent: 5,
        },
        ..ClusterSpec::default()
    };
    for seed in 0..8u64 {
        let a = simulate_runtime_in(&mut arena, &ClusterSpec::default(), &wl, &cfg, seed);
        let b = simulate_runtime_in(&mut arena, &off_spec, &wl, &cfg, seed);
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "disabled fault model drifted the timeline (seed {seed})"
        );
        let f1 = simulate_runtime_in(&mut arena, &flaky(400.0, 42), &wl, &cfg, seed);
        let f2 = simulate_runtime_in(&mut arena, &flaky(400.0, 42), &wl, &cfg, seed);
        assert_eq!(
            f1.to_bits(),
            f2.to_bits(),
            "seeded fault schedule did not replay bit-identically (seed {seed})"
        );
    }

    // ---- throughput: faults off vs on ------------------------------------
    let clean = ClusterSpec::default();
    let on_spec = flaky(400.0, 42);
    let mut seed = 1_000u64;
    let off_sims = throughput(bench.run_throughput(
        "wordcount, faults off (default spec, no injection)",
        1.0,
        "sims",
        || {
            seed += 1;
            simulate_runtime_in(&mut arena, &clean, &wl, &cfg, seed)
        },
    ));
    let mut seed = 1_000u64;
    let on_sims = throughput(bench.run_throughput(
        "wordcount, faults on (mttf 400s, recovery 60s)",
        1.0,
        "sims",
        || {
            seed += 1;
            simulate_runtime_in(&mut arena, &on_spec, &wl, &cfg, seed)
        },
    ));
    let overhead = if on_sims > 0.0 { off_sims / on_sims } else { 0.0 };

    // ---- ranking under increasing node-failure rate ----------------------
    let budget = if quick { 12 } else { 25 };
    let seeds: &[u64] = if quick { &[5, 19] } else { &[5, 19, 33] };
    let methods = ["bobyqa", "hooke-jeeves", "random"];
    let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
    println!(
        "# optimizer ranking vs node-failure rate (budget {budget}, {} seeds)\n",
        seeds.len()
    );
    println!("| mttf_s | {} |", methods.join(" | "));
    println!("|{}|", "---|".repeat(methods.len() + 1));
    let mut ranking = Json::obj();
    for mttf in [0.0, 600.0, 300.0, 150.0] {
        let mut row = format!("| {mttf:.0} ");
        let mut by_method = Json::obj();
        for m in methods {
            let mut bests = Vec::new();
            for &seed in seeds {
                let mut cluster = SimCluster::new(flaky(mttf, seed));
                let out = {
                    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
                    let mut opt = Method::from_name(m, seed).unwrap().build();
                    Driver::new(budget)
                        .run(opt.as_mut(), &space, &mut obj)
                        .expect("tuning run")
                };
                // re-measure the chosen config on a clean, noiseless,
                // fault-free cluster: the score is the config's true
                // quality, not the fault draws it happened to see
                let mut verify = SimCluster::new(ClusterSpec {
                    seed: seed + 999,
                    noise: NoiseModel::noiseless(),
                    speculative: false,
                    ..ClusterSpec::default()
                });
                let truth = verify
                    .run_job(&catla::hadoop::JobSubmission {
                        name: "verify".into(),
                        workload: wl.clone(),
                        config: out.best_config.clone(),
                    })
                    .runtime_s;
                bests.push(truth);
            }
            let mean = bests.iter().sum::<f64>() / bests.len() as f64;
            by_method.set(m, Json::Num(mean));
            row.push_str(&format!("| {mean:.1} "));
        }
        ranking.set(&format!("mttf{mttf:.0}"), by_method);
        println!("{row}|");
    }
    println!("\n(cells: clean-cluster runtime of the config each optimizer picked under that failure rate — lower is better)");

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("faults".into()));
    doc.set("quick", Json::from(quick));
    doc.set("input_mb", Json::Num(input_mb));
    doc.set("identity", Json::Str("bitwise-ok".into()));
    doc.set("sims_per_s_faults_off", Json::Num(off_sims));
    doc.set("sims_per_s_faults_on", Json::Num(on_sims));
    doc.set("fault_overhead_x", Json::Num(overhead));
    doc.set("ranking_clean_runtime_s", ranking);
    std::fs::write("BENCH_faults.json", doc.to_string() + "\n").unwrap();
    println!("\nwrote BENCH_faults.json");
    println!("faults off {off_sims:.0} sims/s, on {on_sims:.0} sims/s ({overhead:.2}x overhead)");

    bench.print_table("PERF — fault injection (off / on, identity-checked)");
}

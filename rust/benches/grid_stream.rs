//! PERF — streaming grid enumeration over a >10^6-point constrained
//! space: points/second off the lazy `GridCursor`, the O(dims) cursor
//! memory vs what materializing the cross product would cost, a
//! budget-capped constrained sweep through the `Driver` (the acceptance
//! scenario: the grid is never materialized), and the striped-shard
//! partition. Records `BENCH_grid_stream.json` for the CI bench smoke.
//!
//! Run: `cargo bench --bench grid_stream` (CATLA_BENCH_QUICK=1 shortens)

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::optim::core::{Driver, FnObjective};
use catla::optim::{GridSearch, ParamSpace};
use catla::util::bench::{black_box, Bench};
use catla::util::json::Json;

/// Peak resident set (VmHWM) in kB — the "did we materialize the grid"
/// proxy. Linux-only; absent elsewhere.
fn vm_hwm_kb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

fn main() {
    // 64 × 128 × 127 = 1,040,384 grid points; the constraint (with map
    // memory untuned at its 1024 MB default, the bound is 716.8 MB)
    // collapses the io.sort.mb axis above the bound, so the streamed
    // sweep exercises decode + repair + dedup, not just enumeration.
    let spec = TuningSpec::parse(
        "param mapreduce.job.reduces int 1 64 step 1\n\
         param mapreduce.task.io.sort.mb int 16 2048 step 16\n\
         param mapreduce.task.io.sort.factor int 2 128 step 1\n\
         constraint io.sort.mb <= 0.7*map.memory.mb\n",
    )
    .expect("bench spec");
    let space = ParamSpace::new(spec, HadoopConfig::default());
    let total = space.grid_cursor().total_points();
    let dims = space.dims();
    assert!(total > 1_000_000, "bench space shrank: {total} points");

    let quick = std::env::var("CATLA_BENCH_QUICK").is_ok();
    let slice: u64 = if quick { 200_000 } else { total };
    let hwm_before = vm_hwm_kb();
    let mut bench = Bench::new();

    // ---- raw enumeration throughput (iterator: one Vec per point) -----
    let points_per_s = bench
        .run_throughput(
            &format!("stream {slice} of {total} grid points"),
            slice as f64,
            "points",
            || {
                let mut acc = 0.0f64;
                for p in space.grid_cursor().take(slice as usize) {
                    acc += p[dims - 1];
                }
                black_box(acc)
            },
        )
        .throughput
        .map(|(v, _)| v)
        .unwrap_or(0.0);

    // ---- allocation-free enumeration (point_into, one reused buffer) --
    let points_per_s_noalloc = bench
        .run_throughput(
            &format!("stream {slice} points, reused buffer"),
            slice as f64,
            "points",
            || {
                let cursor = space.grid_cursor();
                let mut buf = vec![0.0f64; dims];
                let mut acc = 0.0f64;
                for i in 0..slice {
                    cursor.point_into(i, &mut buf);
                    acc += buf[dims - 1];
                }
                black_box(acc)
            },
        )
        .throughput
        .map(|(v, _)| v)
        .unwrap_or(0.0);

    // ---- the acceptance scenario: a constrained sweep under a fixed ---
    // ---- eval budget, grid never materialized ------------------------
    let budget = 4096usize;
    let sweep_s = {
        let stats = bench.run_throughput(
            &format!("constrained grid sweep, budget {budget} of {total}"),
            budget as f64,
            "evals",
            || {
                let mut obj = FnObjective(|c: &HadoopConfig| c.values.iter().sum::<f64>());
                let out = Driver::new(budget)
                    .run(&mut GridSearch::new(), &space, &mut obj)
                    .expect("sweep");
                assert_eq!(out.evals(), budget);
                out.best_value
            },
        );
        stats.mean_secs()
    };

    // ---- striped shards partition the grid ----------------------------
    let shard_counts: Vec<u64> = (0..4)
        .map(|k| space.grid_cursor().shard(k, 4).remaining())
        .collect();
    assert_eq!(
        shard_counts.iter().sum::<u64>(),
        total,
        "4-way shards do not partition the grid"
    );

    let hwm_after = vm_hwm_kb();

    // cursor state: the per-dimension axes plus three u64s — vs the
    // Vec<Vec<f64>> the materialized cross product used to allocate
    let axis_values: u64 = space
        .spec
        .ranges
        .iter()
        .map(|r| r.grid().len() as u64)
        .sum();
    let cursor_state_bytes = axis_values * 8 + 24 * dims as u64 + 24;
    let materialized_bytes = total * (dims as u64 * 8 + 24);

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("grid_stream".into()));
    doc.set("total_points", Json::Num(total as f64));
    doc.set("dims", Json::Num(dims as f64));
    doc.set("enumerated_points", Json::Num(slice as f64));
    doc.set("points_per_s", Json::Num(points_per_s));
    doc.set("points_per_s_alloc_free", Json::Num(points_per_s_noalloc));
    doc.set("cursor_state_bytes", Json::Num(cursor_state_bytes as f64));
    doc.set(
        "materialized_bytes_estimate",
        Json::Num(materialized_bytes as f64),
    );
    doc.set("sweep_budget", Json::Num(budget as f64));
    doc.set("sweep_s", Json::Num(sweep_s));
    doc.set(
        "shard_counts",
        Json::Arr(shard_counts.iter().map(|&c| Json::Num(c as f64)).collect()),
    );
    doc.set(
        "vm_hwm_kb_before",
        hwm_before.map(Json::Num).unwrap_or(Json::Null),
    );
    doc.set(
        "vm_hwm_kb_after",
        hwm_after.map(Json::Num).unwrap_or(Json::Null),
    );
    std::fs::write("BENCH_grid_stream.json", doc.to_string() + "\n").unwrap();
    println!("wrote BENCH_grid_stream.json");
    println!(
        "cursor state ~{cursor_state_bytes} B vs materialized ~{:.0} MiB ({}x)",
        materialized_bytes as f64 / (1024.0 * 1024.0),
        materialized_bytes / cursor_state_bytes.max(1)
    );

    bench.print_table("PERF — streaming grid enumeration");
}

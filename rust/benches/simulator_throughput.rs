//! PERF1a — cluster-simulator throughput: simulated jobs/second and
//! task-throughput across cluster and input scales. The simulator is the
//! tuning loop's inner cost, so this bounds end-to-end tuning speed.
//! Also measures the `eval_batch` hot path three ways — serial, the
//! legacy per-call pool-spawn pipeline (clone every config + Arc, spawn
//! and join a fresh pool, full `simulate_job`), and the current
//! persistent-pool zero-clone `simulate_runtime` pipeline — asserts the
//! three agree bitwise, and records it to `BENCH_optim_batch.json`.
//!
//! Run: `cargo bench --bench simulator_throughput`

use std::sync::Arc;

use catla::config::params::{HadoopConfig, P_REDUCES, P_SPLIT_MB};
use catla::hadoop::{simulate_job, ClusterSpec, SimCluster, JobSubmission};
use catla::optim::core::BatchObjective;
use catla::optim::ClusterObjective;
use catla::util::bench::Bench;
use catla::util::json::Json;
use catla::util::pool::{default_threads, map_parallel};
use catla::workloads::{terasort, wordcount, WorkloadSpec};

/// The pre-streaming `ClusterObjective::eval_batch`, reproduced as the
/// baseline: per-item `HadoopConfig` clones, `Arc`-wrapped spec/workload
/// clones, a thread pool spawned and joined per call, and the full
/// record-materializing `simulate_job`.
fn spawn_per_call_eval(
    cluster: &mut SimCluster,
    wl: &WorkloadSpec,
    cfgs: &[HadoopConfig],
) -> Vec<f64> {
    let first_seed = cluster.reserve_seeds(cfgs.len() as u64);
    let spec = Arc::new(cluster.spec.clone());
    let wl = Arc::new(wl.clone());
    let items: Vec<(HadoopConfig, u64)> = cfgs
        .iter()
        .enumerate()
        .map(|(i, cfg)| (cfg.clone(), first_seed.wrapping_add(i as u64)))
        .collect();
    map_parallel(
        items,
        default_threads().min(cfgs.len()),
        move |(cfg, seed)| simulate_job(&spec, &wl, &cfg, seed).runtime_s,
    )
}

fn main() {
    let mut bench = Bench::new();

    // scale over input size (task count grows linearly)
    for input_mb in [1024.0, 10_240.0, 102_400.0] {
        let wl = wordcount(input_mb);
        let cl = ClusterSpec::default();
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 16.0);
        let tasks = (input_mb / 128.0).ceil() + 16.0;
        let mut seed = 0u64;
        bench.run_throughput(
            &format!("simulate wordcount {:.0} GiB ({} tasks)", input_mb / 1024.0, tasks as u64),
            tasks,
            "tasks",
            || {
                seed += 1;
                simulate_job(&cl, &wl, &cfg, seed).runtime_s
            },
        );
    }

    // scale over cluster size
    for nodes in [4u32, 16, 64, 256] {
        let wl = terasort(10_240.0);
        let cl = ClusterSpec {
            nodes,
            ..ClusterSpec::default()
        };
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, (nodes * 2) as f64);
        let mut seed = 0u64;
        bench.run_throughput(
            &format!("simulate terasort 10 GiB on {nodes} nodes"),
            1.0,
            "jobs",
            || {
                seed += 1;
                simulate_job(&cl, &wl, &cfg, seed).runtime_s
            },
        );
    }

    // many-task stress: small splits -> 1600 map tasks
    {
        let wl = wordcount(102_400.0);
        let cl = ClusterSpec::default();
        let mut cfg = HadoopConfig::default();
        cfg.set(P_SPLIT_MB, 64.0);
        cfg.set(P_REDUCES, 64.0);
        let mut seed = 0u64;
        bench.run_throughput("simulate 1600-map job", 1664.0, "tasks", || {
            seed += 1;
            simulate_job(&cl, &wl, &cfg, seed).runtime_s
        });
    }

    // the full submit/poll/fetch lifecycle (Task Runner's path)
    {
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let wl = wordcount(2048.0);
        bench.run_throughput("SimCluster run_job lifecycle", 1.0, "jobs", || {
            cluster.run_job(&JobSubmission {
                name: "bench".into(),
                workload: wl.clone(),
                config: HadoopConfig::default(),
            })
            .runtime_s
        });
    }

    // the Driver's eval path, three ways: serial baseline, the legacy
    // per-call pool-spawn pipeline, and the persistent-pool zero-clone
    // pipeline actually used — batch 1 is the sequential-DFO singleton
    // case, where per-ask overhead dominates
    {
        let wl = wordcount(10_240.0);
        let mut results = Vec::new();
        for batch in [1usize, 16, 64, 256] {
            let cfgs: Vec<HadoopConfig> = (0..batch)
                .map(|i| {
                    let mut c = HadoopConfig::default();
                    c.set(P_REDUCES, 2.0 + (i % 31) as f64);
                    c
                })
                .collect();

            // byte-identity first: the optimized pipeline must return the
            // exact bits the legacy pipeline did
            {
                let mut c1 = SimCluster::new(ClusterSpec::default());
                let legacy = spawn_per_call_eval(&mut c1, &wl, &cfgs);
                let mut c2 = SimCluster::new(ClusterSpec::default());
                let current = ClusterObjective::new(&mut c2, &wl, 1)
                    .eval_batch(&cfgs)
                    .unwrap();
                assert_eq!(legacy.len(), current.len());
                for (a, b) in legacy.iter().zip(&current) {
                    assert_eq!(a.to_bits(), b.to_bits(), "optimized eval_batch drifted");
                }
            }

            let serial = bench
                .run_throughput(
                    &format!("objective eval serial, batch {batch}"),
                    batch as f64,
                    "configs",
                    || {
                        let mut cluster = SimCluster::new(ClusterSpec::default());
                        ClusterObjective::new(&mut cluster, &wl, 1)
                            .serial()
                            .eval_batch(&cfgs)
                            .unwrap()
                            .len()
                    },
                )
                .mean_secs();
            let spawn = bench
                .run_throughput(
                    &format!("objective eval spawn-per-call (legacy), batch {batch}"),
                    batch as f64,
                    "configs",
                    || {
                        let mut cluster = SimCluster::new(ClusterSpec::default());
                        spawn_per_call_eval(&mut cluster, &wl, &cfgs).len()
                    },
                )
                .mean_secs();
            let batched = {
                // steady state: ONE objective (and pool) across calls,
                // exactly how a Driver-owned run evaluates its batches
                let mut cluster = SimCluster::new(ClusterSpec::default());
                let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
                bench
                    .run_throughput(
                        &format!("objective eval batched persistent-pool, batch {batch}"),
                        batch as f64,
                        "configs",
                        || obj.eval_batch(&cfgs).unwrap().len(),
                    )
                    .mean_secs()
            };
            let mut row = Json::obj();
            row.set("batch", Json::Num(batch as f64));
            row.set("serial_s", Json::Num(serial));
            row.set("spawn_per_call_s", Json::Num(spawn));
            row.set("batched_s", Json::Num(batched));
            row.set("speedup", Json::Num(serial / batched));
            row.set("speedup_vs_spawn", Json::Num(spawn / batched));
            row.set("bitwise_identical", Json::Bool(true));
            results.push(row);
        }
        let mut doc = Json::obj();
        doc.set("bench", Json::Str("simulator_throughput/optim_batch".into()));
        doc.set("threads", Json::Num(default_threads() as f64));
        doc.set("workload", Json::Str("wordcount-10GiB".into()));
        doc.set("results", Json::Arr(results));
        std::fs::write("BENCH_optim_batch.json", doc.to_string() + "\n").unwrap();
        println!("wrote BENCH_optim_batch.json");
    }

    bench.print_table("PERF1a — simulator throughput");
}

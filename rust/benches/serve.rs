//! PERF — tuning-as-a-service: ~1000 interleaved sessions multiplexed
//! over one dispatcher (shared thread pool + global memo-cache).
//! Measures sessions/s, memo-cache hit rate, and p50/p99 ask-to-tell
//! latency (one dispatcher step = ask → evaluate → tell for every
//! session it admits), asserts bounded memory via VmHWM, and re-asserts
//! the hard correctness bar in-run: every session's outcome fingerprint
//! is byte-identical to the same spec run standalone through
//! `Driver::run`. Records `BENCH_serve.json` for the CI bench smoke.
//!
//! Session population: `GROUPS` distinct (cluster seed, workload input)
//! tuning problems, ~100 sessions each — the realistic serve shape where
//! many users tune the same few workloads, so most evaluations are
//! cache-served and only one session per group per step actually
//! touches the DES.
//!
//! Run: `cargo bench --bench serve` (CATLA_BENCH_QUICK=1 shortens)

use std::time::Instant;

use catla::catla::TuningSettings;
use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::core::DEFAULT_BATCH_CHUNK;
use catla::optim::{ClusterObjective, Driver, Method, ParamSpace, RacingSettings, TuningOutcome};
use catla::serve::{Dispatcher, ServeSession, DEFAULT_CACHE_ENTRIES};
use catla::util::json::Json;
use catla::util::pool::default_threads;
use catla::workloads::{wordcount, WorkloadSpec};

const METHOD: &str = "coordinate";
const BUDGET: usize = 8;
const SEED: u64 = 23;
const GROUPS: usize = 10;

/// Peak resident set (VmHWM) in kB. Linux-only; absent elsewhere.
fn vm_hwm_kb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

/// The g-th distinct tuning problem: its own cluster seed stream and
/// workload size, so groups never share cache entries.
fn group_specs(g: usize) -> (ClusterSpec, WorkloadSpec) {
    let cluster = ClusterSpec {
        seed: 42 + g as u64,
        ..ClusterSpec::default()
    };
    (cluster, wordcount(1024.0 + 256.0 * g as f64))
}

fn settings() -> TuningSettings {
    TuningSettings {
        optimizer: METHOD.to_string(),
        budget: BUDGET,
        repeats: 1,
        seed: SEED,
        prescreen: false,
        early_patience: 0,
        early_tol: 1e-3,
        batch_chunk: DEFAULT_BATCH_CHUNK,
        cache_entries: None,
        retry_max: 2,
        retry_backoff_ms: 0,
        racing: RacingSettings::default(),
    }
}

/// Byte-exact outcome fingerprint (same idiom as rust/tests/serve.rs).
fn fingerprint(out: &TuningOutcome) -> String {
    let mut s = format!("{}|{}|{:x}", out.optimizer, out.evals(), out.best_value.to_bits());
    for r in &out.records {
        s.push_str(&format!(
            ";{}:{:x}:{:x}:{}",
            r.iter,
            r.value.to_bits(),
            r.best_so_far.to_bits(),
            r.unit_x
                .iter()
                .map(|u| format!("{:x}", u.to_bits()))
                .collect::<Vec<_>>()
                .join(","),
        ));
        s.push_str(&format!("{:?}", r.config.values));
    }
    s
}

fn main() {
    let quick = std::env::var("CATLA_BENCH_QUICK").is_ok();
    let n_sessions: usize = if quick { 200 } else { 1000 };

    // standalone references, one per distinct tuning problem
    let refs: Vec<String> = (0..GROUPS)
        .map(|g| {
            let (cl, wl) = group_specs(g);
            let sp = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
            let mut cluster = SimCluster::new(cl);
            let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
            let mut opt = Method::from_name(METHOD, SEED).unwrap().build();
            fingerprint(&Driver::new(BUDGET).run(opt.as_mut(), &sp, &mut obj).unwrap())
        })
        .collect();

    let hwm_before = vm_hwm_kb();

    let mut sessions: Vec<ServeSession> = (0..n_sessions)
        .map(|i| {
            let (cl, wl) = group_specs(i % GROUPS);
            ServeSession::new(
                &format!("s{i}"),
                TuningSpec::fig3(),
                HadoopConfig::default(),
                cl,
                wl,
                &settings(),
            )
            .unwrap()
        })
        .collect();

    let threads = default_threads();
    let mut d = Dispatcher::new(threads, DEFAULT_CACHE_ENTRIES);
    let queue_cap = d.queue_cap();

    let t0 = Instant::now();
    let mut step_ms: Vec<f64> = Vec::new();
    let mut simulated = 0usize;
    loop {
        let s0 = Instant::now();
        let r = d.step(&mut sessions).expect("dispatcher step");
        if r.runs == 0 {
            break;
        }
        step_ms.push(s0.elapsed().as_secs_f64() * 1e3);
        simulated += r.simulated;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let hwm_after = vm_hwm_kb();

    // hard bar: every session byte-identical to its standalone run,
    // regardless of interleaving and cache serving
    for (i, s) in sessions.iter().enumerate() {
        let out = s.outcome().expect("session finished without evaluations");
        assert!(out.evals() > 0, "session {} evaluated nothing", s.id);
        assert_eq!(
            fingerprint(&out),
            refs[i % GROUPS],
            "session {} diverged from standalone Driver::run",
            s.id
        );
    }
    let stats = d.cache_stats();
    assert!(stats.hits > 0, "memo-cache never hit across identical sessions");

    // bounded memory: arenas are sized to the pool and the queue is
    // capped, so a thousand sessions must not blow the heap up
    let growth_mb = match (hwm_before, hwm_after) {
        (Some(b), Some(a)) => {
            let g = (a - b) / 1024.0;
            assert!(g < 512.0, "serve run grew VmHWM by {g:.0} MiB — memory not bounded");
            Some(g)
        }
        _ => None,
    };

    step_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let pct = |q: f64| step_ms[((step_ms.len() as f64 - 1.0) * q) as usize];
    let sessions_per_s = n_sessions as f64 / wall_s;

    println!(
        "{n_sessions} sessions ({GROUPS} distinct problems, budget {BUDGET}, {METHOD}): \
         {wall_s:.2}s wall, {sessions_per_s:.0} sessions/s over {threads} workers"
    );
    println!(
        "cache: {} hits / {} misses / {} evictions / {} deduped (hit rate {:.3}); {} DES runs",
        stats.hits,
        stats.misses,
        stats.evictions,
        d.deduped(),
        stats.hit_rate(),
        simulated
    );
    println!(
        "ask-to-tell step latency: p50 {:.2}ms, p99 {:.2}ms over {} steps",
        pct(0.5),
        pct(0.99),
        step_ms.len()
    );
    if let Some(g) = growth_mb {
        println!("VmHWM growth {g:.1} MiB (bound 512 MiB)");
    }

    let mut doc = Json::obj();
    doc.set("bench", Json::Str("serve".into()));
    doc.set("quick", Json::Bool(quick));
    doc.set("sessions", Json::Num(n_sessions as f64));
    doc.set("groups", Json::Num(GROUPS as f64));
    doc.set("budget", Json::Num(BUDGET as f64));
    doc.set("method", Json::Str(METHOD.into()));
    doc.set("threads", Json::Num(threads as f64));
    doc.set("queue_cap", Json::Num(queue_cap as f64));
    doc.set("steps", Json::Num(step_ms.len() as f64));
    doc.set("wall_s", Json::Num(wall_s));
    doc.set("sessions_per_s", Json::Num(sessions_per_s));
    doc.set("des_runs", Json::Num(simulated as f64));
    doc.set("cache_hits", Json::Num(stats.hits as f64));
    doc.set("cache_misses", Json::Num(stats.misses as f64));
    doc.set("cache_evictions", Json::Num(stats.evictions as f64));
    doc.set("cache_deduped", Json::Num(d.deduped() as f64));
    doc.set("cache_hit_rate", Json::Num(stats.hit_rate()));
    doc.set("p50_ask_to_tell_ms", Json::Num(pct(0.5)));
    doc.set("p99_ask_to_tell_ms", Json::Num(pct(0.99)));
    doc.set(
        "vm_hwm_kb_before",
        hwm_before.map(Json::Num).unwrap_or(Json::Null),
    );
    doc.set(
        "vm_hwm_kb_after",
        hwm_after.map(Json::Num).unwrap_or(Json::Null),
    );
    doc.set("fingerprints_match", Json::Bool(true));
    std::fs::write("BENCH_serve.json", doc.to_string() + "\n").unwrap();
    println!("wrote BENCH_serve.json");
}

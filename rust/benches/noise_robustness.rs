//! ABL3 + ABL4 — robustness ablations (DESIGN.md §7):
//!
//!   ABL3  noise sweep: how does each optimizer family degrade as the
//!         cluster's runtime noise σ grows? (the paper's stated reason
//!         for using black-box DFO)
//!   ABL4  speculative execution: simulator-level ablation — how much do
//!         stragglers hurt, and how much does speculation recover?
//!
//! Run: `cargo bench --bench noise_robustness`

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::noise::NoiseModel;
use catla::hadoop::{simulate_job, ClusterSpec, SimCluster};
use catla::optim::{ClusterObjective, Driver, Method, ParamSpace};
use catla::util::csv::Csv;
use catla::workloads::wordcount;

const BUDGET: usize = 40;
const SEEDS: [u64; 4] = [5, 19, 33, 61];

fn main() {
    let workload = wordcount(10_240.0);
    let spec = TuningSpec::fig2();
    let space = ParamSpace::new(spec, HadoopConfig::default());
    let methods = ["hooke-jeeves", "nelder-mead", "annealing", "bobyqa", "random"];
    let mut csv = Csv::new(&["sigma", "optimizer", "seed", "best_runtime_s"]);

    // ---- ABL3: noise sweep ----------------------------------------------
    println!("# ABL3 — optimizer robustness vs runtime noise (budget {BUDGET}, {} seeds)\n", SEEDS.len());
    println!("| sigma | {} |", methods.join(" | "));
    println!("|{}|", "---|".repeat(methods.len() + 1));
    for sigma in [0.0, 0.06, 0.12, 0.25, 0.40] {
        let mut row = format!("| {sigma:.2} ");
        for m in methods {
            let mut bests = Vec::new();
            for &seed in &SEEDS {
                let cl = ClusterSpec {
                    seed,
                    noise: NoiseModel {
                        sigma,
                        ..NoiseModel::default()
                    },
                    ..ClusterSpec::default()
                };
                let mut cluster = SimCluster::new(cl);
                let out = {
                    let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
                    let mut opt = Method::from_name(m, seed).unwrap().build();
                    Driver::new(BUDGET)
                        .run(opt.as_mut(), &space, &mut obj)
                        .expect("tuning run")
                };
                // re-measure the chosen config on a clean cluster so the
                // comparison is not polluted by lucky noise draws
                let mut verify = SimCluster::new(ClusterSpec {
                    seed: seed + 999,
                    noise: NoiseModel::noiseless(),
                    speculative: false,
                    ..ClusterSpec::default()
                });
                let truth = verify
                    .run_job(&catla::hadoop::JobSubmission {
                        name: "verify".into(),
                        workload: workload.clone(),
                        config: out.best_config.clone(),
                    })
                    .runtime_s;
                csv.push(&[
                    format!("{sigma}"),
                    m.to_string(),
                    seed.to_string(),
                    format!("{truth:.3}"),
                ]);
                bests.push(truth);
            }
            let mean = bests.iter().sum::<f64>() / bests.len() as f64;
            row.push_str(&format!("| {mean:.1} "));
        }
        println!("{row}|");
    }
    println!("\n(cells: true noiseless runtime of the config each optimizer picked, mean over seeds — lower is better)");

    // ---- ABL4: speculative execution --------------------------------------
    println!("\n# ABL4 — speculative execution vs stragglers\n");
    println!("| straggler prob | spec off (s) | spec on (s) | recovered |");
    println!("|---|---|---|---|");
    // map-bound configuration: with the default reduces=1 the job is
    // reduce-bound and map speculation is irrelevant by construction
    let mut cfg = HadoopConfig::default();
    cfg.set_by_name("mapreduce.job.reduces", 32.0).unwrap();
    cfg.set_by_name("mapreduce.task.io.sort.mb", 256.0).unwrap();
    for p in [0.0, 0.02, 0.05, 0.10, 0.20] {
        let mean_rt = |speculative: bool| -> f64 {
            let cl = ClusterSpec {
                speculative,
                noise: NoiseModel {
                    straggler_prob: p,
                    ..NoiseModel::default()
                },
                ..ClusterSpec::default()
            };
            (0..30)
                .map(|s| simulate_job(&cl, &workload, &cfg, s).runtime_s)
                .sum::<f64>()
                / 30.0
        };
        let off = mean_rt(false);
        let on = mean_rt(true);
        println!("| {p:.2} | {off:.1} | {on:.1} | {:.1}% |", (off - on) / off * 100.0);
    }

    std::fs::create_dir_all("history").unwrap();
    csv.save(std::path::Path::new("history/noise_robustness.csv")).unwrap();
    println!("\nwrote history/noise_robustness.csv");
}

//! FIG3 — regenerate the paper's Figure 3: change of WordCount running
//! time over iterations under the BOBYQA optimizer, with the random-search
//! baseline for contrast (the paper shows BOBYQA "can quickly obtain a
//! stable minimum value of running time").
//!
//! Emits `history/fig3_bobyqa.csv` (per-seed series) and terminal charts.
//!
//! Run: `cargo bench --bench fig3_bobyqa`

use catla::catla::visualize::line_chart;
use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::{ClusterObjective, Driver, Method, ParamSpace, TuningOutcome};
use catla::util::bench::Bench;
use catla::util::csv::Csv;
use catla::workloads::wordcount;

const BUDGET: usize = 60;
const SEEDS: [u64; 5] = [3, 7, 13, 29, 51];

fn run_method(method: &Method, seed: u64) -> TuningOutcome {
    let workload = wordcount(10_240.0);
    let spec = TuningSpec::fig3();
    let space = ParamSpace::new(spec, HadoopConfig::default());
    let mut cluster = SimCluster::new(ClusterSpec {
        seed,
        ..ClusterSpec::default()
    });
    let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
    let mut opt = method.build();
    Driver::new(BUDGET)
        .run(opt.as_mut(), &space, &mut obj)
        .expect("tuning run")
}

fn main() {
    println!("# FIG3: BOBYQA convergence, 4 params, budget {BUDGET}, {} seeds", SEEDS.len());

    let mut csv = Csv::new(&["seed", "optimizer", "iter", "runtime_s", "best_so_far"]);
    let mut mean_conv_b = vec![0.0f64; BUDGET];
    let mut mean_conv_r = vec![0.0f64; BUDGET];
    let mut evals_to_stable = Vec::new();

    for &seed in &SEEDS {
        let bob = run_method(&Method::Bobyqa { seed }, seed);
        let rnd = run_method(&Method::Random { seed }, seed);
        for rec in &bob.records {
            csv.push(&[
                seed.to_string(),
                "bobyqa".into(),
                rec.iter.to_string(),
                format!("{:.3}", rec.value),
                format!("{:.3}", rec.best_so_far),
            ]);
            if rec.iter <= BUDGET {
                mean_conv_b[rec.iter - 1] += rec.best_so_far / SEEDS.len() as f64;
            }
        }
        for rec in &rnd.records {
            csv.push(&[
                seed.to_string(),
                "random".into(),
                rec.iter.to_string(),
                format!("{:.3}", rec.value),
                format!("{:.3}", rec.best_so_far),
            ]);
            if rec.iter <= BUDGET {
                mean_conv_r[rec.iter - 1] += rec.best_so_far / SEEDS.len() as f64;
            }
        }
        // iterations until within 3% of this run's final best (stability)
        let target = bob.best_value * 1.03;
        let stable = bob
            .records
            .iter()
            .find(|r| r.best_so_far <= target)
            .map(|r| r.iter)
            .unwrap_or(BUDGET);
        evals_to_stable.push(stable);
    }
    std::fs::create_dir_all("history").unwrap();
    csv.save(std::path::Path::new("history/fig3_bobyqa.csv")).unwrap();

    let series_b: Vec<(usize, f64)> =
        mean_conv_b.iter().enumerate().map(|(i, v)| (i + 1, *v)).collect();
    let series_r: Vec<(usize, f64)> =
        mean_conv_r.iter().enumerate().map(|(i, v)| (i + 1, *v)).collect();
    println!(
        "\n{}",
        line_chart("Fig. 3 — BOBYQA best-so-far, mean over seeds", &series_b, 64, 12)
    );
    println!(
        "{}",
        line_chart("baseline — random search best-so-far, mean over seeds", &series_r, 64, 12)
    );

    // ---- the paper's qualitative observations ---------------------------
    let b_final = series_b.last().unwrap().1;
    let r_final = series_r.last().unwrap().1;
    let b_15 = series_b[14.min(series_b.len() - 1)].1;
    let b_1 = series_b[0].1;
    let mean_stable =
        evals_to_stable.iter().sum::<usize>() as f64 / evals_to_stable.len() as f64;
    println!("## paper-shape checks");
    println!("| check | paper | measured |");
    println!("|---|---|---|");
    println!(
        "| trend of convergence | yes | mean best drops {b_1:.1}s -> {b_15:.1}s by iter 15 -> {b_final:.1}s at {BUDGET} |"
    );
    println!(
        "| quickly obtains stable minimum | yes | within 3% of final after {mean_stable:.1} iters (mean over seeds) |"
    );
    println!(
        "| DFO value vs baseline | implied | bobyqa {b_final:.1}s vs random {r_final:.1}s at equal budget ({}) |",
        if b_final <= r_final { "bobyqa <= random" } else { "random won (noise)" }
    );

    // ---- timing ----------------------------------------------------------
    let mut bench = Bench::new();
    bench.run_throughput("fig3 bobyqa 60-eval run", BUDGET as f64, "evals", || {
        run_method(&Method::Bobyqa { seed: 3 }, 3).best_value
    });
    bench.print_table("FIG3 harness timing");
    println!("wrote history/fig3_bobyqa.csv");
}

//! Property-based tests over coordinator invariants (routing, batching,
//! state) using the in-repo quickcheck driver (proptest is unavailable
//! offline — see DESIGN.md §2).

use catla::config::params::*;
use catla::config::space::{ParamKind, ParamRegistry};
use catla::config::spec::TuningSpec;
use catla::hadoop::hdfs::{locality, place_blocks, Locality, Topology};
use catla::hadoop::mapreduce::TaskKind;
use catla::hadoop::{simulate_job, ClusterSpec};
use catla::optim::{ClusterObjective, Driver, Method, ParamSpace, ALL_METHODS};
use catla::hadoop::SimCluster;
use catla::util::json::{parse, Json};
use catla::util::quickcheck::{forall_cfg, QcConfig};
use catla::util::rng::Rng;
use catla::workloads::wordcount;

fn qc(cases: usize) -> QcConfig {
    QcConfig {
        cases,
        ..QcConfig::default()
    }
}

fn random_config(rng: &mut Rng) -> HadoopConfig {
    let mut c = HadoopConfig::default();
    for (i, d) in ParamRegistry::builtin().defs().iter().enumerate() {
        c.set(i, rng.range_f64(d.lo, d.hi));
    }
    c
}

#[test]
fn prop_simulation_completes_all_tasks_and_orders_times() {
    forall_cfg(
        "sim-task-accounting",
        qc(24),
        |rng| {
            let cfg = random_config(rng);
            let cl = ClusterSpec {
                nodes: 2 + rng.below(16) as u32,
                noise: catla::hadoop::noise::NoiseModel {
                    failure_prob: rng.f64() * 0.05,
                    ..Default::default()
                },
                ..ClusterSpec::default()
            };
            let input = 256.0 + rng.f64() * 8192.0;
            let seed = rng.next_u64();
            (cfg, cl, input, seed)
        },
        |(cfg, cl, input, seed)| {
            let wl = wordcount(*input);
            let r = simulate_job(cl, &wl, cfg, *seed);
            let maps = r.tasks.iter().filter(|t| t.kind == TaskKind::Map).count() as u64;
            let reds = r.tasks.iter().filter(|t| t.kind == TaskKind::Reduce).count() as u64;
            if maps != r.counters.total_maps {
                return Err(format!("maps {maps} != counter {}", r.counters.total_maps));
            }
            if reds != r.counters.total_reduces {
                return Err(format!("reduces {reds} != counter {}", r.counters.total_reduces));
            }
            for t in &r.tasks {
                if !(t.finish > t.start && t.start >= 0.0) {
                    return Err(format!("bad task times {t:?}"));
                }
                if t.finish > r.runtime_s + 1e-6 {
                    return Err(format!("task finishes after job end: {t:?}"));
                }
            }
            if !r.runtime_s.is_finite() || r.runtime_s <= 0.0 {
                return Err(format!("bad runtime {}", r.runtime_s));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulation_deterministic_under_seed() {
    forall_cfg(
        "sim-determinism",
        qc(12),
        |rng| (random_config(rng), rng.next_u64()),
        |(cfg, seed)| {
            let cl = ClusterSpec::default();
            let wl = wordcount(4096.0);
            let a = simulate_job(&cl, &wl, cfg, *seed);
            let b = simulate_job(&cl, &wl, cfg, *seed);
            if a.runtime_s != b.runtime_s {
                return Err(format!("{} != {}", a.runtime_s, b.runtime_s));
            }
            if a.counters != b.counters {
                return Err("counters differ".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hdfs_placement_invariants() {
    forall_cfg(
        "hdfs-placement",
        qc(32),
        |rng| {
            let nodes = 2 + rng.below(40);
            let racks = 1 + rng.below(4);
            let blocks = 1 + rng.below(300) as u64;
            let repl = 1 + rng.below(4);
            let seed = rng.next_u64();
            (nodes, racks, blocks, repl, seed)
        },
        |&(nodes, racks, blocks, repl, seed)| {
            let topo = Topology::new(nodes, racks);
            let mut rng = Rng::new(seed);
            let placed = place_blocks(&topo, blocks, repl, &mut rng);
            if placed.len() != blocks as usize {
                return Err("missing blocks".into());
            }
            for b in &placed {
                let mut uniq = b.replicas.clone();
                uniq.sort_unstable();
                uniq.dedup();
                if uniq.len() != b.replicas.len() {
                    return Err(format!("duplicate replicas {b:?}"));
                }
                if b.replicas.is_empty() || b.replicas.len() > repl.min(nodes) {
                    return Err(format!("bad replica count {b:?}"));
                }
                if b.replicas.iter().any(|&n| n >= nodes) {
                    return Err(format!("replica node out of range {b:?}"));
                }
                // locality must be NodeLocal from any replica holder
                if locality(&topo, b, b.replicas[0]) != Locality::NodeLocal {
                    return Err("replica holder not node-local".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_every_optimizer_stays_in_bounds_and_budget() {
    forall_cfg(
        "optimizer-bounds",
        qc(18),
        |rng| {
            let method = ALL_METHODS[rng.below(ALL_METHODS.len())];
            let budget = 5 + rng.below(40);
            let seed = rng.next_u64();
            (method.to_string(), budget, seed)
        },
        |(method, budget, seed)| {
            let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let wl = wordcount(1024.0);
            let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
            let mut opt = Method::from_name(method, *seed)?.build();
            let out = Driver::new(*budget).run(opt.as_mut(), &space, &mut obj)?;
            if out.evals() > *budget {
                return Err(format!("{method}: {} evals > budget {budget}", out.evals()));
            }
            if out.evals() == 0 {
                return Err(format!("{method}: no evaluations"));
            }
            for r in &out.records {
                if r.unit_x.iter().any(|u| !(0.0..=1.0).contains(u)) {
                    return Err(format!("{method}: out-of-cube proposal {:?}", r.unit_x));
                }
                r.config.validate()?;
            }
            // best-so-far column is monotone
            let mut prev = f64::INFINITY;
            for r in &out.records {
                if r.best_so_far > prev + 1e-12 {
                    return Err(format!("{method}: best_so_far not monotone"));
                }
                prev = r.best_so_far;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_grid_enumerates_exact_cross_product() {
    forall_cfg(
        "grid-cross-product",
        qc(16),
        |rng| {
            // random 2-param spec with random steps
            let s1 = 1 + rng.below(8);
            let s2 = 25 + rng.below(200);
            (s1 as f64, s2 as f64)
        },
        |&(step1, step2)| {
            let text = format!(
                "param mapreduce.job.reduces int 2 32 step {step1}\n\
                 param mapreduce.task.io.sort.mb int 50 800 step {step2}\n"
            );
            let spec = TuningSpec::parse(&text)?;
            let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
            let grid = space.unit_grid();
            if grid.len() != spec.grid_size() {
                return Err(format!("grid {} != expected {}", grid.len(), spec.grid_size()));
            }
            // no duplicate decoded configs
            let mut seen = std::collections::BTreeSet::new();
            for x in &grid {
                let c = space.decode(x);
                let key = format!("{:?}", c.values);
                if !seen.insert(key) {
                    return Err("duplicate grid config".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_arbitrary_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let strings = ["", "plain", "with \"quotes\"", "line\nbreak", "τab\tand λ"];
                Json::Str(strings[rng.below(strings.len())].to_string())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    forall_cfg(
        "json-roundtrip",
        qc(200),
        |rng| random_json(rng, 3),
        |doc| {
            let text = doc.to_string();
            let back = parse(&text).map_err(|e| format!("{e} in {text}"))?;
            if &back != doc {
                return Err(format!("roundtrip mismatch: {doc:?} -> {text} -> {back:?}"));
            }
            Ok(())
        },
    );
}

/// Per-dimension config comparison: exact for discrete kinds, float
/// tolerance for continuous ones.
fn configs_agree(spec: &TuningSpec, a: &HadoopConfig, b: &HadoopConfig) -> Result<(), String> {
    for (i, d) in spec.registry.defs().iter().enumerate() {
        let (x, y) = (a.values[i], b.values[i]);
        if d.kind.is_discrete() {
            if x != y {
                return Err(format!("{}: {x} != {y} (discrete drift)", d.name));
            }
        } else if (x - y).abs() > 1e-9 * x.abs().max(1.0) {
            return Err(format!("{}: {x} vs {y} (float drift)", d.name));
        }
    }
    Ok(())
}

#[test]
fn prop_encode_decode_roundtrip_every_kind_and_transform() {
    // every ParamKind x Transform combination in one space: int/linear,
    // int/log, float/linear, float/log, bool, categorical
    let spec = TuningSpec::parse(
        "param mapreduce.job.reduces int 2 32\n\
         param mapreduce.task.io.sort.mb int 64 1024 log\n\
         param mapreduce.map.sort.spill.percent float 0.5 0.9\n\
         param x.cost.factor float 0.1 10 log\n\
         param mapreduce.map.output.compress bool\n\
         param mapreduce.map.output.compress.codec cat none,snappy,lz4\n",
    )
    .unwrap();
    let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
    let dims = space.dims();
    forall_cfg(
        "encode-decode-roundtrip",
        qc(150),
        |rng| {
            // include points outside the cube: decode must clamp
            (0..dims).map(|_| rng.f64() * 2.0 - 0.5).collect::<Vec<f64>>()
        },
        |x| {
            let c1 = space.decode(x);
            c1.validate()?;
            let c2 = space.decode(&space.encode(&c1));
            configs_agree(&spec, &c1, &c2)?;
            // snapping idempotence: a further encode/decode is stable
            let c3 = space.decode(&space.encode(&c2));
            configs_agree(&spec, &c2, &c3)?;
            // unit coordinates stay in the cube
            if space.encode(&c1).iter().any(|u| !(0.0..=1.0).contains(u)) {
                return Err("encode left the unit cube".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spec_parse_print_roundtrip() {
    // random subsets of a declaration pool (every kind, steps, log,
    // spec-declared extras) plus constraints: parse -> print -> parse is
    // the identity and printing is a fixed point
    let pool = [
        "param mapreduce.job.reduces int 2 32 step 2",
        "param mapreduce.task.io.sort.mb int 64 1024 log",
        "param mapreduce.map.sort.spill.percent float 0.5 0.9 step 0.1",
        "param mapreduce.map.output.compress bool",
        "param mapreduce.map.output.compress.codec cat none,snappy,lz4",
        "param x.shuffle.buffer.kb int 32 4096 step 512 log",
        "param mapreduce.reduce.memory.mb int 1024 8192",
    ];
    let constraints = [
        "constraint io.sort.mb <= 0.7*map.memory.mb",
        "constraint mapreduce.job.reduces <= 48",
        "constraint io.sort.mb <= reduce.memory.mb",
    ];
    forall_cfg(
        "spec-roundtrip",
        qc(60),
        |rng| {
            let mut text = String::new();
            let mut any = false;
            for line in pool {
                if rng.bernoulli(0.6) {
                    text.push_str(line);
                    text.push('\n');
                    any = true;
                }
            }
            if !any {
                text.push_str(pool[0]);
                text.push('\n');
            }
            for line in constraints {
                if rng.bernoulli(0.3) {
                    text.push_str(line);
                    text.push('\n');
                }
            }
            text
        },
        |text| {
            let spec = TuningSpec::parse(text)?;
            let printed = spec.to_string();
            let back = TuningSpec::parse(&printed)
                .map_err(|e| format!("printed spec unparseable: {e}\n{printed}"))?;
            if back != spec {
                return Err(format!("roundtrip mismatch:\n{printed}"));
            }
            if back.to_string() != printed {
                return Err("printing is not a fixed point".into());
            }
            Ok(())
        },
    );
}

#[test]
fn categorical_log_constraint_spec_tunes_end_to_end() {
    // the redesign's acceptance scenario: a spec with a categorical
    // codec, log-scaled memory params and a cross-parameter constraint
    // drives grid AND bobyqa through the shared Driver against the
    // simulated cluster
    let text = "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
                param mapreduce.task.io.sort.mb int 64 1024 step 128 log\n\
                param mapreduce.map.memory.mb int 512 4096 log\n\
                constraint io.sort.mb <= 0.7*map.memory.mb\n";
    let spec = TuningSpec::parse(text).unwrap();
    let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
    let wl = wordcount(1024.0);
    let codec_idx = spec.ranges[0].index;
    assert!(matches!(
        spec.registry.get(codec_idx).kind,
        ParamKind::Categorical(_)
    ));
    for method in ["grid", "bobyqa"] {
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        let mut opt = Method::from_name(method, 7).unwrap().build();
        let out = Driver::new(40)
            .run(opt.as_mut(), &space, &mut obj)
            .unwrap_or_else(|e| panic!("{method}: {e}"));
        assert!(out.evals() > 0 && out.evals() <= 40, "{method}");
        for r in &out.records {
            r.config.validate().unwrap_or_else(|e| panic!("{method}: {e}"));
            assert!(
                space.is_feasible(&r.config),
                "{method} evaluated an infeasible config: {}",
                r.config.summary()
            );
            let codec = r.config.get(codec_idx);
            assert_eq!(codec.fract(), 0.0, "{method}: non-integral codec index");
            assert!((0.0..=2.0).contains(&codec), "{method}: codec out of range");
        }
        out.best_config.validate().unwrap();
    }
}

#[test]
fn prop_paramspace_decode_always_valid() {
    forall_cfg(
        "decode-valid",
        qc(100),
        |rng| {
            let d = TuningSpec::fig3().dims();
            (0..d).map(|_| rng.f64() * 3.0 - 1.0).collect::<Vec<f64>>()
        },
        |x| {
            let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
            space.decode(x).validate()
        },
    );
}

//! Scoped parameter spaces, end to end: the ISSUE-5 acceptance scenario
//! (a two-workload workflow tuned over a merged space, per-job `-D`
//! rendering, byte-identical resume reconstruction) plus the flat-spec
//! bit-identity guarantee across all eight ask/tell methods.

use catla::catla::resume::best_logged_config;
use catla::catla::workflow::{self, WorkflowJob};
use catla::catla::{create_template, History, Project, ProjectKind, TuningSettings};
use catla::config::params::HadoopConfig;
use catla::config::scope::ScopedSpec;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, JobSubmission, SimCluster};
use catla::optim::core::ClusterObjective;
use catla::optim::{Driver, Method, ParamSpace, TuningOutcome, ALL_METHODS};
use catla::workloads::wordcount;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("catla-scoped-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

const ACCEPTANCE_SPEC: &str = "param mapreduce.job.reduces int 2 32\n\
     workload terasort {\n\
       param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
       param mapreduce.reduce.shuffle.parallelcopies int 4 64\n\
     }\n\
     workload wordcount {\n\
       param mapreduce.map.memory.mb int 512 4096\n\
       param mapreduce.job.reduce.slowstart.completedmaps float 0.05 0.95\n\
     }\n";

/// The acceptance criterion: a two-workload workflow tune (terasort:
/// codec + parallelcopies; wordcount: memory + slowstart) runs end to
/// end, each job's rendered `-D` args contain only its scoped + shared
/// params, and replaying the written log reconstructs the identical
/// best configuration.
#[test]
fn two_workload_workflow_tunes_renders_and_replays() {
    let dir = tmp("acceptance");
    create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(dir.join("params.spec"), ACCEPTANCE_SPEC).unwrap();
    std::fs::write(
        dir.join("jobs.list"),
        "sort terasort 1024\nwc wordcount 1024 after=sort\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=20\nrepeats=1\nseed=3\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    let scoped = project.scoped.clone().unwrap();
    assert!(scoped.warnings.is_empty(), "{:?}", scoped.warnings);
    let jobs: Vec<WorkflowJob> = workflow::from_project(&project).unwrap();

    let settings = TuningSettings::from_project(&project).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let (outcome, merged) = workflow::tune_workflow(
        &mut cluster,
        &jobs,
        &scoped,
        project.base_config().unwrap(),
        &Method::from_name(&settings.optimizer, settings.seed).unwrap(),
        &mut settings.driver(),
    )
    .unwrap();
    assert_eq!(merged.dims(), 5, "shared reduces + 2 + 2 scoped dims");
    assert!(outcome.evals() <= 20);
    assert!(outcome.optimizer.contains("workflow x2"), "{}", outcome.optimizer);

    // ---- per-job -D rendering from the projections -------------------
    let best = &outcome.best_config;
    let sort_cfg = merged.job_config(best, "terasort");
    let wc_cfg = merged.job_config(best, "wordcount");
    let cmd = |name: &str, wl: &str, cfg: &HadoopConfig| {
        JobSubmission {
            name: name.into(),
            workload: catla::workloads::by_name(wl, 1024.0).unwrap(),
            config: cfg.clone(),
        }
        .command_line()
    };
    let sort_cmd = cmd("sort", "terasort", &sort_cfg);
    let wc_cmd = cmd("wc", "wordcount", &wc_cfg);
    // terasort renders its scoped codec + parallelcopies...
    assert!(
        sort_cmd.contains("-Dmapreduce.map.output.compress.codec="),
        "{sort_cmd}"
    );
    // ...wordcount's -D args never mention terasort's private knob
    assert!(!wc_cmd.contains("codec"), "scoped param leaked: {wc_cmd}");
    // both carry the SAME shared reduces value, taken from the merged best
    let reduces = best.get_by_name("mapreduce.job.reduces").unwrap();
    let tag = format!("-Dmapreduce.job.reduces={}", reduces as i64);
    assert!(sort_cmd.contains(&tag), "{sort_cmd}");
    assert!(wc_cmd.contains(&tag), "{wc_cmd}");
    // scoped values route to their owner
    assert_eq!(
        sort_cfg.get_by_name("parallelcopies").unwrap(),
        best.get_by_name("mapreduce.reduce.shuffle.parallelcopies@terasort")
            .unwrap()
    );
    assert_eq!(
        wc_cfg.get_by_name("map.memory.mb").unwrap(),
        best.get_by_name("mapreduce.map.memory.mb@wordcount").unwrap()
    );
    // ...and not to the other job: wordcount keeps the Hadoop default
    assert_eq!(wc_cfg.get_by_name("parallelcopies").unwrap(), 5.0);
    sort_cfg.validate().unwrap();
    wc_cfg.validate().unwrap();

    // ---- resume replay reconstructs the identical best config --------
    let history = History::open(&dir).unwrap();
    history.write_tuning_log(&merged.spec, &outcome).unwrap();
    let reloaded = Project::load(&dir).unwrap();
    let rebuilt = best_logged_config(&reloaded)
        .unwrap()
        .expect("merged log written");
    assert_eq!(
        rebuilt, *best,
        "resume replay did not reconstruct the merged best config"
    );
    // the projections of the rebuilt point are the exact per-job configs
    assert_eq!(merged.job_config(&rebuilt, "terasort"), sort_cfg);
    assert_eq!(merged.job_config(&rebuilt, "wordcount"), wc_cfg);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A merged log must win the reconstruction even when the project's own
/// job workload has no block — its flat effective spec covers a strict
/// SUBSET of the merged log's columns, and a subset-based spec match
/// would silently drop every tuned `@workload` dim.
#[test]
fn merged_log_is_not_shadowed_by_a_blockless_project_workload() {
    let dir = tmp("shadow");
    create_template(&dir, ProjectKind::Tuning, "grep", 1024.0).unwrap();
    std::fs::write(dir.join("params.spec"), ACCEPTANCE_SPEC).unwrap();
    std::fs::write(
        dir.join("jobs.list"),
        "sort terasort 1024\nwc wordcount 1024\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=random\nbudget=6\nseed=4\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    // grep has no block: the project's effective spec is the 1-dim
    // shared space, a strict subset of the merged log's columns
    assert_eq!(project.spec.as_ref().unwrap().dims(), 1);
    let scoped = project.scoped.clone().unwrap();
    let jobs = workflow::from_project(&project).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let (outcome, merged) = workflow::tune_workflow(
        &mut cluster,
        &jobs,
        &scoped,
        project.base_config().unwrap(),
        &Method::Random { seed: 4 },
        &mut Driver::new(6),
    )
    .unwrap();
    History::open(&dir)
        .unwrap()
        .write_tuning_log(&merged.spec, &outcome)
        .unwrap();
    let rebuilt = best_logged_config(&Project::load(&dir).unwrap())
        .unwrap()
        .expect("merged log written");
    assert_eq!(
        rebuilt, outcome.best_config,
        "flat project spec shadowed the merged tuning log"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

fn fingerprint(out: &TuningOutcome) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for r in &out.records {
        write!(s, "{:x};", r.value.to_bits()).unwrap();
        for v in &r.config.values {
            write!(s, "{:x},", v.to_bits()).unwrap();
        }
        s.push('|');
    }
    write!(s, "best={:x}", out.best_value.to_bits()).unwrap();
    s
}

/// Legacy guarantee: a flat (blockless) spec driven through the merge
/// layer decodes bit-identically for every one of the eight methods —
/// the merge is a pure superset, not a behavior change.
#[test]
fn flat_specs_drive_all_eight_methods_bit_identically_through_the_merge() {
    let wl = wordcount(512.0);
    let flat = TuningSpec::fig2();
    let scoped = ScopedSpec::flat(flat.clone());
    let merged = scoped.merge(&["wordcount"]).unwrap();
    assert_eq!(merged.spec, flat, "flat merge changed the spec");

    for name in ALL_METHODS {
        let drive = |spec: &TuningSpec| -> TuningOutcome {
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
            let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
            let mut opt = Method::from_name(name, 17).unwrap().build();
            Driver::new(12).run(opt.as_mut(), &space, &mut obj).unwrap()
        };
        let direct = drive(&flat);
        let through_merge = drive(&merged.spec);
        assert_eq!(
            fingerprint(&direct),
            fingerprint(&through_merge),
            "{name}: flat spec diverged through the merge layer"
        );
        // projection is the identity on every evaluated config
        for r in &through_merge.records {
            assert_eq!(merged.job_config(&r.config, "wordcount"), r.config, "{name}");
        }
    }
}

//! Cross-layer validation: the batched runtime backend (the AOT
//! JAX/Pallas artifacts through PJRT when built with `--features pjrt`,
//! the f32 native mirror otherwise) must agree with the f64 analytic
//! cost model. With `pjrt`, run `make artifacts` first.

use catla::config::params::{HadoopConfig, N_AOT_PARAMS};
use catla::config::space::ParamRegistry;
use catla::hadoop::{costmodel, ClusterSpec};
use catla::optim::surrogate::CandidateScorer;
use catla::runtime::{CostModelExec, QuadraticExec, Runtime};
use catla::util::rng::Rng;
use catla::workloads::{terasort, wordcount};

fn runtime() -> Runtime {
    Runtime::open_default().expect("run `make artifacts` before cargo test")
}

fn random_configs(n: usize, seed: u64) -> Vec<HadoopConfig> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut c = HadoopConfig::default();
            for (i, d) in ParamRegistry::builtin().defs().iter().enumerate() {
                c.set(i, rng.range_f64(d.lo, d.hi));
            }
            c
        })
        .collect()
}

#[test]
fn pjrt_costmodel_matches_native_mirror() {
    let rt = runtime();
    let wl = wordcount(10240.0);
    let cl = ClusterSpec::default();
    let mut exec = CostModelExec::load(&rt, &wl, &cl).unwrap();
    let cfgs = random_configs(64, 1);
    let got = exec.predict(&cfgs).unwrap();
    for (cfg, pjrt) in cfgs.iter().zip(&got) {
        let native = costmodel::predict_runtime(cfg, &wl, &cl);
        let rel = ((*pjrt as f64) - native).abs() / native.max(1.0);
        assert!(
            rel < 1e-3,
            "config {:?}: pjrt {} vs native {native} (rel {rel})",
            cfg.summary(),
            pjrt
        );
    }
}

#[test]
fn pjrt_phases_match_native_phases() {
    let rt = runtime();
    let wl = terasort(4096.0);
    let cl = ClusterSpec::default();
    let mut exec = CostModelExec::load(&rt, &wl, &cl).unwrap();
    let cfgs = random_configs(16, 2);
    let (_, phases) = exec.predict_with_phases(&cfgs).unwrap();
    for (cfg, ph) in cfgs.iter().zip(&phases) {
        let native = costmodel::predict_phases(cfg, &wl, &cl);
        for k in 0..costmodel::N_PHASES {
            let diff = (ph[k] as f64 - native[k]).abs();
            let tol = 1e-3 * native[k].abs().max(1.0);
            assert!(
                diff < tol,
                "phase {} mismatch: {} vs {}",
                costmodel::PHASE_NAMES[k],
                ph[k],
                native[k]
            );
        }
    }
}

#[test]
fn batch_padding_and_chunking_are_transparent() {
    let rt = runtime();
    let wl = wordcount(2048.0);
    let cl = ClusterSpec::default();
    let mut exec = CostModelExec::load(&rt, &wl, &cl).unwrap();
    // sizes below, at and above the artifact batch sizes
    for n in [1usize, 7, 128, 129, 1024, 1500, 2100] {
        let cfgs = random_configs(n, n as u64);
        let got = exec.predict(&cfgs).unwrap();
        assert_eq!(got.len(), n, "batch {n}: wrong output length");
        // single-config predictions must equal batched ones
        let solo = exec.predict(&cfgs[..1]).unwrap();
        assert!(
            (solo[0] - got[0]).abs() < 1e-4,
            "batch {n}: solo {} vs batched {}",
            solo[0],
            got[0]
        );
    }
}

#[test]
fn scorer_interface_works_through_pjrt() {
    let rt = runtime();
    let wl = wordcount(10240.0);
    let cl = ClusterSpec::default();
    let mut exec = CostModelExec::load(&rt, &wl, &cl).unwrap();
    let cfgs = random_configs(10, 5);
    let scores = exec.score(&cfgs).unwrap();
    assert_eq!(scores.len(), 10);
    assert!(scores.iter().all(|s| s.is_finite() && *s > 0.0));
    let expect = if cfg!(feature = "pjrt") {
        "pjrt-costmodel"
    } else {
        "native-costmodel"
    };
    assert_eq!(exec.name(), expect);
}

#[test]
fn pjrt_quadratic_matches_direct_evaluation() {
    let rt = runtime();
    let mut quad = QuadraticExec::load(&rt).unwrap();
    let mut rng = Rng::new(3);
    for d in [2usize, 4, 8] {
        let xs: Vec<Vec<f64>> = (0..33)
            .map(|_| (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let g: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut h = vec![vec![0.0; d]; d];
        for i in 0..d {
            for j in 0..=i {
                let v = rng.range_f64(-1.0, 1.0);
                h[i][j] = v;
                h[j][i] = v;
            }
        }
        let c0 = rng.range_f64(-1.0, 1.0);
        let got = quad.eval(&xs, &g, &h, c0).unwrap();
        for (x, q) in xs.iter().zip(&got) {
            let mut expect = c0;
            for i in 0..d {
                expect += g[i] * x[i];
                for j in 0..d {
                    expect += 0.5 * x[i] * h[i][j] * x[j];
                }
            }
            assert!(
                (q - expect).abs() < 1e-4,
                "d={d}: pjrt {q} vs direct {expect}"
            );
        }
    }
}

#[test]
fn prescreen_through_pjrt_finds_good_starts() {
    use catla::config::spec::TuningSpec;
    use catla::optim::surrogate::Prescreen;
    use catla::optim::ParamSpace;

    let rt = runtime();
    let wl = wordcount(10240.0);
    let cl = ClusterSpec::default();
    let exec = CostModelExec::load(&rt, &wl, &cl).unwrap();
    let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
    let mut p = Prescreen::new(exec);
    p.n_candidates = 512;
    let starts = p.top_starts(&space, 3).unwrap();
    assert_eq!(starts.len(), 3);
    // the best PJRT-scored start must beat the default config on the
    // native model too (the two models agree)
    let best_cfg = space.decode(&starts[0]);
    let best = costmodel::predict_runtime(&best_cfg, &wl, &cl);
    let default = costmodel::predict_runtime(&HadoopConfig::default(), &wl, &cl);
    assert!(
        best < default,
        "prescreened start {best} not better than default {default}"
    );
}

#[test]
fn config_row_layout_matches_param_table() {
    // guard against silent reordering between the registry's builtin
    // prefix and to_f32_row
    let mut c = HadoopConfig::default();
    c.set_by_name("mapreduce.task.io.sort.mb", 256.0).unwrap();
    let row = c.to_f32_row();
    assert_eq!(row.len(), N_AOT_PARAMS);
    assert_eq!(row[1], 256.0); // P_IO_SORT_MB == index 1 in spec.py
}

#[test]
fn extended_registry_keeps_the_aot_prefix_stable() {
    // a spec-declared extra param must not disturb the artifact row:
    // to_f32_row exports exactly the builtin prefix, in prefix order
    let spec = catla::config::spec::TuningSpec::parse(
        "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
         param mapreduce.task.io.sort.mb int 64 1024\n",
    )
    .unwrap();
    let mut c = HadoopConfig::for_registry(spec.registry.clone());
    c.set_by_name("mapreduce.task.io.sort.mb", 512.0).unwrap();
    c.set_category("mapreduce.map.output.compress.codec", "lz4")
        .unwrap();
    let row = c.to_f32_row();
    assert_eq!(row.len(), N_AOT_PARAMS);
    assert_eq!(row[1], 512.0);
    let plain = {
        let mut p = HadoopConfig::default();
        p.set_by_name("mapreduce.task.io.sort.mb", 512.0).unwrap();
        p.to_f32_row()
    };
    assert_eq!(row, plain, "extra params leaked into the AOT row");
}

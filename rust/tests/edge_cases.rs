//! Edge-case hardening: degenerate clusters, extreme configurations and
//! workloads must simulate sanely (finite, positive, accounted) rather
//! than panic or hang.

use catla::config::params::*;
use catla::hadoop::noise::NoiseModel;
use catla::hadoop::{simulate_job, Cluster, ClusterSpec, JobSubmission, SimCluster};
use catla::workloads::{terasort, wordcount, WorkloadSpec};

fn assert_sane(r: &catla::hadoop::JobResult, label: &str) {
    assert!(r.runtime_s.is_finite() && r.runtime_s > 0.0, "{label}: runtime {}", r.runtime_s);
    assert_eq!(
        r.tasks.len() as u64,
        r.counters.total_maps + r.counters.total_reduces,
        "{label}: task accounting"
    );
    for t in &r.tasks {
        assert!(t.finish > t.start, "{label}: inverted task times");
    }
}

#[test]
fn single_node_cluster() {
    let cl = ClusterSpec {
        nodes: 1,
        racks: 1,
        ..ClusterSpec::default()
    };
    let r = simulate_job(&cl, &wordcount(1024.0), &HadoopConfig::default(), 1);
    assert_sane(&r, "single node");
    // everything must be node-local on a 1-node cluster
    assert_eq!(r.counters.data_local_maps, r.counters.total_maps);
}

#[test]
fn tiny_input_single_split() {
    let cl = ClusterSpec::default();
    let r = simulate_job(&cl, &wordcount(16.0), &HadoopConfig::default(), 2);
    assert_sane(&r, "tiny input");
    assert_eq!(r.counters.total_maps, 1);
}

#[test]
fn more_racks_than_meaningful() {
    let cl = ClusterSpec {
        nodes: 4,
        racks: 64, // more racks than nodes: topology must clamp
        ..ClusterSpec::default()
    };
    let r = simulate_job(&cl, &wordcount(512.0), &HadoopConfig::default(), 3);
    assert_sane(&r, "many racks");
}

#[test]
fn memory_starved_containers() {
    // container memory barely fits: one container per node at a time
    let cl = ClusterSpec {
        mem_per_node_mb: 1024,
        ..ClusterSpec::default()
    };
    let mut cfg = HadoopConfig::default();
    cfg.set(P_MAP_MEM_MB, 1024.0);
    cfg.set(P_RED_MEM_MB, 1024.0);
    cfg.set(P_REDUCES, 32.0);
    let r = simulate_job(&cl, &wordcount(10240.0), &cfg, 4);
    assert_sane(&r, "memory starved");
    // 80 maps over 16 single-container nodes = 5 waves: must be slower
    // than the roomy default cluster
    let roomy = simulate_job(&ClusterSpec::default(), &wordcount(10240.0), &cfg, 4);
    assert!(r.runtime_s > roomy.runtime_s);
}

#[test]
fn extreme_config_corners_all_simulate() {
    let cl = ClusterSpec::default();
    let wl = wordcount(2048.0);
    for corner in 0..(1 << 4) {
        let mut cfg = HadoopConfig::default();
        for (bit, p) in [P_REDUCES, P_IO_SORT_MB, P_SORT_FACTOR, P_SPLIT_MB]
            .iter()
            .enumerate()
        {
            let (lo, hi) = {
                let d = cfg.def(*p);
                (d.lo, d.hi)
            };
            cfg.set(*p, if corner & (1 << bit) != 0 { hi } else { lo });
        }
        let r = simulate_job(&cl, &wl, &cfg, corner as u64);
        assert_sane(&r, &format!("corner {corner:04b}"));
    }
}

#[test]
fn pathological_workload_profiles() {
    let cl = ClusterSpec::default();
    // selectivity > 1 (join-like blowup), microscopic records, zero skew
    let blowup = WorkloadSpec {
        name: "blowup".into(),
        tuning_spec: None,
        input_mb: 1024.0,
        map_selectivity: 50.0,
        cpu_per_mb_map: 0.001,
        cpu_per_mb_red: 0.001,
        compress_ratio: 0.9,
        output_selectivity: 10.0,
        record_kb: 0.001,
        key_skew: 0.0,
    };
    blowup.validate().unwrap();
    let r = simulate_job(&cl, &blowup, &HadoopConfig::default(), 5);
    assert_sane(&r, "blowup");
    // a 50x shuffle blowup must dwarf the same-sized wordcount
    let wc = simulate_job(&cl, &wordcount(1024.0), &HadoopConfig::default(), 5);
    assert!(r.runtime_s > 3.0 * wc.runtime_s, "blowup {} vs wc {}", r.runtime_s, wc.runtime_s);
}

#[test]
fn heavy_failures_still_terminate() {
    let cl = ClusterSpec {
        noise: NoiseModel {
            failure_prob: 0.30, // 30% of attempts fail mid-flight
            max_attempts: 4,
            ..NoiseModel::default()
        },
        ..ClusterSpec::default()
    };
    let r = simulate_job(&cl, &terasort(2048.0), &HadoopConfig::default(), 6);
    assert_sane(&r, "heavy failures");
    assert!(r.counters.failed_task_attempts > 0);
    // failures cost time vs the clean cluster
    let clean = simulate_job(&ClusterSpec::default(), &terasort(2048.0), &HadoopConfig::default(), 6);
    assert!(r.runtime_s > clean.runtime_s * 0.9);
}

#[test]
fn submission_rejects_invalid_workload() {
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut wl = wordcount(1024.0);
    wl.input_mb = -5.0;
    let err = cluster
        .submit_job(JobSubmission {
            name: "bad".into(),
            workload: wl,
            config: HadoopConfig::default(),
        })
        .unwrap_err();
    assert!(err.contains("input_mb"));
}

#[test]
fn thousand_reducers_one_wave_cap() {
    // reduces beyond slots: waves must grow, runtime must not explode to
    // infinity and containers must all come back
    let cl = ClusterSpec::default();
    let mut cfg = HadoopConfig::default();
    cfg.set(P_REDUCES, 64.0); // == param hi
    cfg.set(P_RED_MEM_MB, 8192.0); // 1 reducer per node -> 4 waves
    let r = simulate_job(&cl, &terasort(4096.0), &cfg, 7);
    assert_sane(&r, "many reducers");
    assert_eq!(r.counters.total_reduces, 64);
}

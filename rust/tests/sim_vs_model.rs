//! Simulator-vs-analytic-model agreement: the discrete-event engine's
//! noiseless behaviour must track `costmodel::predict_runtime` across the
//! configuration space, and its noisy behaviour must center on it.
//! This is what makes surrogate prescreening (ABL2) legitimate.

use catla::config::params::*;
use catla::hadoop::noise::NoiseModel;
use catla::hadoop::{costmodel, simulate_job, ClusterSpec};
use catla::util::rng::Rng;
use catla::workloads::{grep, join, terasort, wordcount, WorkloadSpec};

fn noiseless_cluster() -> ClusterSpec {
    ClusterSpec {
        noise: NoiseModel::noiseless(),
        speculative: false,
        ..ClusterSpec::default()
    }
}

fn random_config(rng: &mut Rng) -> HadoopConfig {
    let mut c = HadoopConfig::default();
    for (i, d) in catla::config::space::ParamRegistry::builtin().defs().iter().enumerate() {
        c.set(i, rng.range_f64(d.lo, d.hi));
    }
    // slowstart near 1 keeps the DES and the closed-form overlap model
    // comparable (the analytic model's overlap term is an approximation)
    c.set(P_SLOWSTART, rng.range_f64(0.8, 1.0));
    c
}

#[test]
fn noiseless_sim_within_band_of_model_across_space() {
    let cl = noiseless_cluster();
    let wl = wordcount(10240.0);
    let mut rng = Rng::new(42);
    let mut worst: f64 = 1.0;
    for i in 0..40 {
        let cfg = random_config(&mut rng);
        let sim = simulate_job(&cl, &wl, &cfg, i).runtime_s;
        let model = costmodel::predict_runtime(&cfg, &wl, &cl);
        let ratio = sim / model;
        worst = worst.max(ratio.max(1.0 / ratio));
        assert!(
            (0.4..2.5).contains(&ratio),
            "cfg {}: sim {sim:.1} vs model {model:.1} (ratio {ratio:.2})",
            cfg.summary()
        );
    }
    assert!(worst < 2.5, "worst-case ratio {worst}");
}

#[test]
fn model_ranks_configs_like_the_simulator() {
    // Spearman-style check: for pairs with clearly different predicted
    // runtimes, the simulator should agree on the ordering
    let cl = noiseless_cluster();
    let wl = terasort(8192.0);
    let mut rng = Rng::new(7);
    let mut agree = 0;
    let mut total = 0;
    let cfgs: Vec<HadoopConfig> = (0..20).map(|_| random_config(&mut rng)).collect();
    for i in 0..cfgs.len() {
        for j in i + 1..cfgs.len() {
            let mi = costmodel::predict_runtime(&cfgs[i], &wl, &cl);
            let mj = costmodel::predict_runtime(&cfgs[j], &wl, &cl);
            if (mi - mj).abs() / mi.min(mj) < 0.30 {
                continue; // too close to call
            }
            let si = simulate_job(&cl, &wl, &cfgs[i], 100 + i as u64).runtime_s;
            let sj = simulate_job(&cl, &wl, &cfgs[j], 200 + j as u64).runtime_s;
            total += 1;
            if (mi < mj) == (si < sj) {
                agree += 1;
            }
        }
    }
    assert!(total >= 20, "not enough decisive pairs ({total})");
    let rate = agree as f64 / total as f64;
    assert!(rate > 0.85, "rank agreement only {rate:.2} ({agree}/{total})");
}

#[test]
fn noisy_sim_centers_on_noiseless_sim() {
    let mut noisy = ClusterSpec::default();
    noisy.noise.straggler_prob = 0.0; // stragglers skew the mean by design
    noisy.noise.failure_prob = 0.0;
    let clean = noiseless_cluster();
    let wl = wordcount(4096.0);
    let cfg = HadoopConfig::default();
    let base = simulate_job(&clean, &wl, &cfg, 0).runtime_s;
    let n = 60;
    let mean: f64 = (0..n)
        .map(|s| simulate_job(&noisy, &wl, &cfg, s).runtime_s)
        .sum::<f64>()
        / n as f64;
    let rel = (mean - base).abs() / base;
    assert!(rel < 0.12, "noisy mean {mean:.1} vs clean {base:.1} (rel {rel:.3})");
}

#[test]
fn fig2_trends_hold_in_the_simulator() {
    // the paper's observed trends must emerge from the DES, not just the
    // closed-form model: larger reduces and larger io.sort.mb help
    let cl = ClusterSpec::default();
    let wl = wordcount(10240.0);
    let avg = |cfg: &HadoopConfig| -> f64 {
        (0..7)
            .map(|s| simulate_job(&cl, &wl, cfg, s).runtime_s)
            .sum::<f64>()
            / 7.0
    };
    let mut corner_bad = HadoopConfig::default();
    corner_bad.set(P_REDUCES, 2.0);
    corner_bad.set(P_IO_SORT_MB, 50.0);
    let mut corner_good = HadoopConfig::default();
    corner_good.set(P_REDUCES, 32.0);
    corner_good.set(P_IO_SORT_MB, 800.0);
    let bad = avg(&corner_bad);
    let good = avg(&corner_good);
    assert!(
        good < bad,
        "Fig2 trend missing: good corner {good:.1}s vs bad corner {bad:.1}s"
    );
}

#[test]
fn every_workload_simulates_and_predicts() {
    let cl = noiseless_cluster();
    let wls: Vec<WorkloadSpec> = vec![
        wordcount(2048.0),
        terasort(2048.0),
        grep(2048.0),
        join(2048.0),
        catla::workloads::pagerank_iteration(2048.0),
    ];
    for wl in wls {
        let cfg = HadoopConfig::default();
        let sim = simulate_job(&cl, &wl, &cfg, 1).runtime_s;
        let model = costmodel::predict_runtime(&cfg, &wl, &cl);
        assert!(sim > 0.0 && model > 0.0, "{}: sim {sim} model {model}", wl.name);
        let ratio = sim / model;
        assert!(
            (0.3..3.0).contains(&ratio),
            "{}: sim {sim:.1} vs model {model:.1}",
            wl.name
        );
    }
}

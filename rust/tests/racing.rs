//! Multi-fidelity racing guarantees, pinned byte-for-byte:
//! * racing-on runs are deterministic: repeat runs, different dispatcher
//!   thread counts, and serve-vs-standalone all land on the identical
//!   outcome — values AND fidelity tiers;
//! * monotone promotion at the run level: with an ask stream that
//!   ignores told values (random), every full-fidelity record of a
//!   racing-on run is bit-identical to the racing-off run's measurement
//!   of the same candidate, and the race simulates strictly less;
//! * a cost-model-blind parameter in the spec refuses tier 0: no record
//!   ever carries `model` fidelity — the cheapest tier is one simulated
//!   seed.
//!
//! (The racing-OFF byte-identity bar for all eight methods lives in
//! `rust/tests/ask_tell.rs`; the pure tier planner's unit invariants in
//! `rust/src/optim/racing.rs`.)

use catla::catla::{create_template, OptimizerRunner, Project, ProjectKind, TuningSettings};
use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::core::DEFAULT_BATCH_CHUNK;
use catla::optim::surrogate::{CandidateScorer, NativeScorer};
use catla::optim::{
    ClusterObjective, Driver, Fidelity, Method, ParamSpace, RacingObjective, RacingSettings,
    TuningOutcome, ALL_METHODS,
};
use catla::serve::{Dispatcher, ServeSession};
use catla::workloads::wordcount;

const BUDGET: usize = 18;
const SEED: u64 = 23;

fn racing_on() -> RacingSettings {
    RacingSettings {
        enabled: true,
        eta: 4,
        min_tier_evals: 2,
    }
}

/// Standalone racing-enabled drive over fig3 — every fig3 dim is
/// cost-model-mapped, so tier 0 is armed with the native scorer exactly
/// like the `OptimizerRunner` arms it.
fn standalone_raced(optimizer: &str, repeats: usize) -> TuningOutcome {
    let wl = wordcount(2048.0);
    let sp = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let cluster_spec = cluster.spec.clone();
    let inner = ClusterObjective::new(&mut cluster, &wl, repeats);
    let scorer: Option<Box<dyn CandidateScorer>> = Some(Box::new(NativeScorer {
        workload: wl.clone(),
        cluster: cluster_spec,
    }));
    let mut obj = RacingObjective::new(inner, racing_on(), scorer);
    let mut opt = Method::from_name(optimizer, SEED).unwrap().build();
    Driver::new(BUDGET).run(opt.as_mut(), &sp, &mut obj).unwrap()
}

fn standalone_plain(optimizer: &str, repeats: usize) -> TuningOutcome {
    let wl = wordcount(2048.0);
    let sp = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, repeats);
    let mut opt = Method::from_name(optimizer, SEED).unwrap().build();
    Driver::new(BUDGET).run(opt.as_mut(), &sp, &mut obj).unwrap()
}

fn settings(optimizer: &str, repeats: usize) -> TuningSettings {
    TuningSettings {
        optimizer: optimizer.to_string(),
        budget: BUDGET,
        repeats,
        seed: SEED,
        prescreen: false,
        early_patience: 0,
        early_tol: 1e-3,
        batch_chunk: DEFAULT_BATCH_CHUNK,
        cache_entries: None,
        retry_max: 2,
        retry_backoff_ms: 0,
        racing: racing_on(),
    }
}

fn session(id: &str, optimizer: &str, repeats: usize) -> ServeSession {
    ServeSession::new(
        id,
        TuningSpec::fig3(),
        HadoopConfig::default(),
        ClusterSpec::default(),
        wordcount(2048.0),
        &settings(optimizer, repeats),
    )
    .unwrap()
}

/// Byte-exact fingerprint including each record's fidelity tier.
fn fingerprint(out: &TuningOutcome) -> String {
    let mut s = format!("{}|{}|{:x}", out.optimizer, out.evals(), out.best_value.to_bits());
    for r in &out.records {
        s.push_str(&format!(
            ";{}@{}:{:x}:{:x}:{}",
            r.iter,
            r.fidelity.label(),
            r.value.to_bits(),
            r.best_so_far.to_bits(),
            r.unit_x
                .iter()
                .map(|u| format!("{:x}", u.to_bits()))
                .collect::<Vec<_>>()
                .join(","),
        ));
        s.push_str(&format!("{:?}", r.config.values));
    }
    s
}

#[test]
fn racing_runs_are_repeatable_for_all_methods() {
    for name in ALL_METHODS {
        assert_eq!(
            fingerprint(&standalone_raced(name, 2)),
            fingerprint(&standalone_raced(name, 2)),
            "{name}: racing run is not repeatable"
        );
    }
}

#[test]
fn serve_racing_matches_standalone_across_thread_counts() {
    // the serve daemon drives the identical Race planner through its
    // memo-cache and thread pool: interleaved sessions, any pool size —
    // the outcome (values and tiers) must not move a byte
    for name in ALL_METHODS {
        let reference = fingerprint(&standalone_raced(name, 2));
        for threads in [1usize, 4] {
            let mut sessions = vec![session("a", name, 2), session("b", name, 2)];
            let mut d = Dispatcher::new(threads, 1 << 14);
            d.run_all(&mut sessions).unwrap();
            for s in &sessions {
                assert_eq!(
                    fingerprint(&s.outcome().unwrap()),
                    reference,
                    "{name} threads={threads}: serve session {} diverged from standalone racing",
                    s.id
                );
            }
        }
    }
}

#[test]
fn full_fidelity_records_match_racing_off_bitwise() {
    // random's ask stream ignores told values, so racing-on and
    // racing-off evaluate the SAME candidates on the SAME reserved
    // seeds: promotion is monotone (a finalist's value is the exact
    // racing-off measurement) and the race simulates strictly less
    let off = standalone_plain("random", 3);
    let on = standalone_raced("random", 3);
    assert_eq!(off.evals(), on.evals());

    let mut promoted = 0usize;
    for (a, b) in off.records.iter().zip(&on.records) {
        assert_eq!(
            format!("{:?}", a.config.values),
            format!("{:?}", b.config.values),
            "iter {}: candidate streams diverged",
            a.iter
        );
        if b.fidelity.is_full() {
            promoted += 1;
            assert_eq!(
                a.value.to_bits(),
                b.value.to_bits(),
                "iter {}: finalist value diverged from the racing-off measurement",
                a.iter
            );
        }
    }
    assert!(
        promoted >= 2 && promoted < on.evals(),
        "degenerate race: {promoted} of {} promoted",
        on.evals()
    );
    // the incumbent is always a full-fidelity measurement
    let best_full = on
        .records
        .iter()
        .filter(|r| r.fidelity.is_full())
        .map(|r| r.value)
        .fold(f64::INFINITY, f64::min);
    assert_eq!(on.best_value.to_bits(), best_full.to_bits());
}

#[test]
fn blind_param_spec_refuses_tier_zero() {
    // `x.shuffle.buffer.kb` is invisible to the cost model, so the
    // OptimizerRunner must arm the race WITHOUT a tier-0 scorer: no
    // record may carry `model` fidelity, and tier-1 pruning still runs
    let dir = std::env::temp_dir().join(format!("catla-racing-blind-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(
        dir.join("params.spec"),
        "param mapreduce.task.io.sort.mb int 50 800 step 50\n\
         param x.shuffle.buffer.kb int 32 4096\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=random\nbudget=12\nrepeats=2\nseed=5\nracing.enabled=true\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
    let recs = &out.outcome.records;
    assert!(
        recs.iter().all(|r| r.fidelity != Fidelity::CostModel),
        "blind-param spec must refuse cost-model fidelity"
    );
    assert!(
        recs.iter().any(|r| matches!(r.fidelity, Fidelity::Seeds(_))),
        "tier-1 pruning should still race a blind-param spec"
    );
    assert!(recs.iter().any(|r| r.fidelity.is_full()), "no finalist reached full fidelity");
    let _ = std::fs::remove_dir_all(&dir);
}

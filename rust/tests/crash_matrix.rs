//! Crash-point matrix: kill the serve daemon at EVERY registered
//! durability point and assert full recovery.
//!
//! Each case spawns the compiled `catla` binary with the hidden
//! `--crash-at <point>` hook, drives one project-backed session over the
//! line protocol, and lets [`std::process::abort`] cut it down at the
//! armed point (the in-process stand-in for `kill -9`). A second,
//! unarmed daemon over the same directory must then finish the session
//! with `history/tuning_log.csv` and `history/summary.csv` byte-identical
//! to an uninterrupted run — the full matrix on bobyqa, and the
//! complete-journal re-drive point pinned for all eight methods.
//!
//! The point list comes from `catla::util::crashpoint::POINTS`, so a
//! newly registered point is exercised here automatically (and an
//! unreachable one fails the "armed daemon did not abort" assert).

use std::io::Write;
use std::path::PathBuf;
use std::process::{Command, Output, Stdio};

use catla::catla::{create_template, ProjectKind};
use catla::optim::ALL_METHODS;
use catla::util::crashpoint::POINTS;

const SMALL: &str = "optimizer=bobyqa\nbudget=12\nrepeats=1\nseed=7\n";

fn catla_bin() -> PathBuf {
    // cargo puts integration-test binaries in target/<profile>/deps;
    // the main binary lives one level up
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("catla")
}

fn tuning_project(name: &str, properties: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("catla-crash-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(dir.join("tuning.properties"), properties).unwrap();
    dir
}

/// Drive one session end to end in a spawned daemon; `crash_at` arms the
/// named point. Stdin write errors are ignored — an armed daemon may
/// abort before draining the script, which is exactly the test.
fn serve(dir: &std::path::Path, crash_at: Option<&str>) -> Output {
    let mut cmd = Command::new(catla_bin());
    cmd.arg("serve");
    if let Some(point) = crash_at {
        cmd.args(["--crash-at", point]);
    }
    cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::piped());
    let mut child = cmd.spawn().expect("failed to spawn catla binary — build it first");
    let script = format!("open s {}\nrun s\nclose s\nshutdown\n", dir.display());
    let _ = child.stdin.take().unwrap().write_all(script.as_bytes());
    child.wait_with_output().unwrap()
}

fn history_file(dir: &std::path::Path, name: &str) -> Vec<u8> {
    std::fs::read(dir.join("history").join(name))
        .unwrap_or_else(|e| panic!("{}: history/{name} unreadable: {e}", dir.display()))
}

/// Run the reference (uninterrupted) session and return the durable
/// state every recovery must reproduce byte for byte.
fn reference(name: &str, properties: &str) -> (Vec<u8>, Vec<u8>) {
    let dir = tuning_project(name, properties);
    let out = serve(&dir, None);
    assert!(
        out.status.success(),
        "reference run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let log = history_file(&dir, "tuning_log.csv");
    let summary = history_file(&dir, "summary.csv");
    let _ = std::fs::remove_dir_all(&dir);
    (log, summary)
}

/// Crash at `point`, recover unarmed, and assert the recovered history
/// is byte-identical to the reference.
fn crash_and_recover(tag: &str, point: &str, properties: &str, ref_log: &[u8], ref_summary: &[u8]) {
    let dir = tuning_project(&format!("{tag}-{}", point.replace('.', "-")), properties);

    let crashed = serve(&dir, Some(point));
    assert!(
        !crashed.status.success(),
        "{tag}/{point}: armed daemon did not abort — the point never fired"
    );
    let stderr = String::from_utf8_lossy(&crashed.stderr);
    assert!(
        stderr.contains(&format!("crash point {point:?} hit")),
        "{tag}/{point}: abort came from somewhere else:\n{stderr}"
    );

    let recovered = serve(&dir, None);
    assert!(
        recovered.status.success(),
        "{tag}/{point}: recovery run failed:\n{}",
        String::from_utf8_lossy(&recovered.stderr)
    );
    assert_eq!(
        history_file(&dir, "tuning_log.csv"),
        ref_log,
        "{tag}/{point}: recovered tuning log is not byte-identical"
    );
    assert_eq!(
        history_file(&dir, "summary.csv"),
        ref_summary,
        "{tag}/{point}: recovered summary is not byte-identical (lost or duplicated row?)"
    );
    assert!(
        !dir.join("history").join("tuning_log.csv.journal").is_file(),
        "{tag}/{point}: checkpoint journal survived a clean finalize"
    );
    for entry in std::fs::read_dir(dir.join("history")).unwrap() {
        let name = entry.unwrap().file_name().to_string_lossy().into_owned();
        assert!(
            !(name.starts_with('.') && name.ends_with(".tmp")),
            "{tag}/{point}: stray staging file {name} after recovery"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_registered_point_recovers_byte_identically() {
    let (ref_log, ref_summary) = reference("matrix-ref", SMALL);
    assert!(!POINTS.is_empty());
    for point in POINTS {
        crash_and_recover("matrix", point, SMALL, &ref_log, &ref_summary);
    }
}

#[test]
fn complete_journal_redrive_is_pinned_for_all_methods() {
    // finalize.before-fin crashes with the journal fully written but the
    // final log / fin / summary absent: the recovery must re-drive every
    // slice through a fresh optimizer and land on the identical outcome —
    // the strongest per-method determinism pin in the matrix
    for name in ALL_METHODS {
        let props = format!("optimizer={name}\nbudget=12\nrepeats=1\nseed=7\n");
        let (ref_log, ref_summary) = reference(&format!("m-{name}-ref"), &props);
        crash_and_recover(
            &format!("m-{name}"),
            "finalize.before-fin",
            &props,
            &ref_log,
            &ref_summary,
        );
    }
}

#[test]
fn unknown_crash_point_is_rejected_before_any_work() {
    let out = serve(std::path::Path::new("/nonexistent"), Some("no.such.point"));
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("unknown crash point"),
        "typo in --crash-at must fail loudly:\n{stderr}"
    );
}

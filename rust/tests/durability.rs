//! Torn-log durability: truncate a reference `tuning_log.csv` at EVERY
//! byte boundary and assert the resume path never panics and never
//! replays a corrupt row — the clean prefix of full lines is all that
//! ever comes back, for both the flat single-job space and a merged
//! (scoped) workflow space whose log carries `<param>@<workload>`
//! columns.
//!
//! The tuning log is atomically replaced, so a torn log cannot come from
//! this writer crashing — but logs also arrive from older versions,
//! network copies and `aggregate` runs over foreign histories, and the
//! tolerant loader is the single front door for all of them.

use catla::catla::resume::{best_logged_config, resume_tuning, PriorRuns};
use catla::catla::workflow::{self, WorkflowJob};
use catla::catla::{create_template, History, OptimizerRunner, Project, ProjectKind, TuningSettings};
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::Method;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("catla-durab-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn flat_project(name: &str) -> PathBuf {
    let dir = tmp(name);
    create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(
        dir.join("params.spec"),
        "param mapreduce.job.reduces int 2 32 step 2\n\
         param mapreduce.task.io.sort.mb int 50 800 step 150\n",
    )
    .unwrap();
    std::fs::write(dir.join("tuning.properties"), "optimizer=bobyqa\nbudget=8\nseed=3\n").unwrap();
    dir
}

/// For every cut of `reference` at byte boundary `0..=len`, the tolerant
/// loader must return exactly the rows of the complete data lines in the
/// prefix — each byte-equal to its reference row — or a hard error when
/// not even the header survives. Returns how many cuts parsed.
fn assert_clean_prefixes(dir: &std::path::Path, reference: &[u8]) -> usize {
    let history = History::open(dir).unwrap();
    let log_path = history.dir.join("tuning_log.csv");
    let ref_rows = {
        let (csv, torn) = history.load_tuning_log_tolerant().unwrap();
        assert!(torn.is_none(), "reference log is torn?");
        csv.rows
    };
    let header_end = reference.iter().position(|&b| b == b'\n').unwrap() + 1;
    let mut parsed = 0;
    for cut in 0..=reference.len() {
        std::fs::write(&log_path, &reference[..cut]).unwrap();
        match history.load_tuning_log_tolerant() {
            Err(e) => assert!(
                cut < header_end,
                "cut {cut}: a log with an intact header must load its clean prefix: {e}"
            ),
            Ok((csv, torn)) => {
                parsed += 1;
                assert!(
                    cut >= header_end,
                    "cut {cut}: a headerless fragment parsed as a log"
                );
                let complete = reference[header_end..cut].iter().filter(|&&b| b == b'\n').count();
                assert_eq!(
                    csv.rows.len(),
                    complete,
                    "cut {cut}: row count is not the clean prefix"
                );
                for (i, row) in csv.rows.iter().enumerate() {
                    assert_eq!(
                        row, &ref_rows[i],
                        "cut {cut}: row {i} differs from the reference — a corrupt or \
                         truncated row leaked into the replay"
                    );
                }
                assert_eq!(
                    torn.is_some(),
                    cut > header_end && reference[cut - 1] != b'\n',
                    "cut {cut}: torn-tail warning disagrees with the cut position"
                );
            }
        }
        // the opportunistic best-config rebuild must never panic either,
        // whatever the cut (it may degrade to Ok(None))
        let project = Project::load(dir).unwrap();
        let _ = best_logged_config(&project);
    }
    std::fs::write(&log_path, reference).unwrap();
    parsed
}

#[test]
fn flat_log_truncated_at_every_byte_replays_only_the_clean_prefix() {
    let dir = flat_project("flat");
    let project = Project::load(&dir).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    OptimizerRunner::new(&mut cluster).run(&project).unwrap();
    let log_path = dir.join("history").join("tuning_log.csv");
    let reference = std::fs::read(&log_path).unwrap();
    assert!(reference.ends_with(b"\n"), "writer must newline-terminate");

    let parsed = assert_clean_prefixes(&dir, &reference);
    assert!(parsed > 0, "no cut parsed — the matrix tested nothing");

    // and the full resume front door over a mid-row tear: the clean
    // prefix replays, the torn row is dropped (not evaluated twice, not
    // mangled), and the run completes to the original budget
    std::fs::write(&log_path, &reference[..reference.len() - 3]).unwrap();
    let resumed = resume_tuning(&mut cluster, &project, 8).unwrap();
    assert_eq!(resumed.evals(), 8, "torn-tail resume lost the budget");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn merged_scoped_log_truncated_at_every_byte_replays_only_the_clean_prefix() {
    let dir = tmp("merged");
    create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(
        dir.join("params.spec"),
        "param mapreduce.job.reduces int 2 32\n\
         workload terasort {\n\
           param mapreduce.reduce.shuffle.parallelcopies int 4 64\n\
         }\n\
         workload wordcount {\n\
           param mapreduce.map.memory.mb int 512 4096\n\
         }\n",
    )
    .unwrap();
    std::fs::write(dir.join("jobs.list"), "sort terasort 1024\nwc wordcount 1024 after=sort\n")
        .unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=10\nrepeats=1\nseed=3\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    let scoped = project.scoped.clone().unwrap();
    let jobs: Vec<WorkflowJob> = workflow::from_project(&project).unwrap();
    let settings = TuningSettings::from_project(&project).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let (outcome, merged) = workflow::tune_workflow(
        &mut cluster,
        &jobs,
        &scoped,
        project.base_config().unwrap(),
        &Method::from_name(&settings.optimizer, settings.seed).unwrap(),
        &mut settings.driver(),
    )
    .unwrap();
    let history = History::open(&dir).unwrap();
    history.write_tuning_log(&merged.spec, &outcome).unwrap();
    let log_path = dir.join("history").join("tuning_log.csv");
    let reference = std::fs::read(&log_path).unwrap();
    let header = String::from_utf8_lossy(&reference);
    assert!(
        header.lines().next().unwrap().contains('@'),
        "merged log lost its scoped columns"
    );

    let parsed = assert_clean_prefixes(&dir, &reference);
    assert!(parsed > 0);

    // the merged-space prior parse accepts exactly the clean prefix too
    std::fs::write(&log_path, &reference[..reference.len() - 5]).unwrap();
    let (csv, torn) = History::open(&dir).unwrap().load_tuning_log_tolerant().unwrap();
    assert!(torn.is_some(), "mid-row cut must surface the torn-tail warning");
    let prior = PriorRuns::from_log(&csv, &merged.spec).unwrap();
    assert_eq!(prior.evals.len(), csv.rows.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

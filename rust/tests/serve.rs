//! Serve-subsystem guarantees, pinned byte-for-byte:
//! * the hard bar: a session's eval sequence and `TuningOutcome` are
//!   byte-identical to the same spec run standalone through
//!   `Driver::run` + `ClusterObjective`, for ALL eight methods, whether
//!   sessions interleave or the memo-cache serves every evaluation;
//! * a second identical session is 100% cache hits (zero new misses)
//!   and still lands on the identical outcome;
//! * project-backed sessions write tuning logs byte-identical to the
//!   standalone `OptimizerRunner`'s, cache-served or not;
//! * spec typo-guard warnings are emitted exactly once per loaded
//!   session (at `open`), never again on step/run/ask paths;
//! * a killed daemon re-drives its per-slice checkpoint journal back to
//!   the exact optimizer state, so the resumed outcome is byte-identical
//!   to an uninterrupted run (and `fsck --repair` can retire a journal
//!   into a plain log for the legacy `[resumed@n]` replay path);
//! * the bounded work-queue starves no session, and the external
//!   `ask`/`tell` protocol path drives a session to completion.

use std::io::Cursor;
use std::path::PathBuf;

use catla::catla::{create_template, OptimizerRunner, Project, ProjectKind, TuningSettings};
use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::core::DEFAULT_BATCH_CHUNK;
use catla::optim::{
    ClusterObjective, Driver, Method, ParamSpace, RacingSettings, TuningOutcome, ALL_METHODS,
};
use catla::serve::{Daemon, Dispatcher, ServeSession};
use catla::workloads::wordcount;

const BUDGET: usize = 18;
const SEED: u64 = 23;

fn settings(optimizer: &str, repeats: usize) -> TuningSettings {
    TuningSettings {
        optimizer: optimizer.to_string(),
        budget: BUDGET,
        repeats,
        seed: SEED,
        prescreen: false,
        early_patience: 0,
        early_tol: 1e-3,
        batch_chunk: DEFAULT_BATCH_CHUNK,
        cache_entries: None,
        retry_max: 2,
        retry_backoff_ms: 0,
        racing: RacingSettings::default(),
    }
}

fn session(id: &str, optimizer: &str, repeats: usize) -> ServeSession {
    ServeSession::new(
        id,
        TuningSpec::fig3(),
        HadoopConfig::default(),
        ClusterSpec::default(),
        wordcount(2048.0),
        &settings(optimizer, repeats),
    )
    .unwrap()
}

/// The reference every session must reproduce: the same spec through the
/// standalone driver against the batched cluster objective.
fn standalone(optimizer: &str, repeats: usize) -> TuningOutcome {
    let wl = wordcount(2048.0);
    let sp = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, repeats);
    let mut opt = Method::from_name(optimizer, SEED).unwrap().build();
    Driver::new(BUDGET).run(opt.as_mut(), &sp, &mut obj).unwrap()
}

/// Byte-exact fingerprint of an outcome (f64s via to_bits, so any drift
/// in values, order or config decoding shows up).
fn fingerprint(out: &TuningOutcome) -> String {
    let mut s = format!("{}|{}|{:x}", out.optimizer, out.evals(), out.best_value.to_bits());
    for r in &out.records {
        s.push_str(&format!(
            ";{}:{:x}:{:x}:{}",
            r.iter,
            r.value.to_bits(),
            r.best_so_far.to_bits(),
            r.unit_x
                .iter()
                .map(|u| format!("{:x}", u.to_bits()))
                .collect::<Vec<_>>()
                .join(","),
        ));
        s.push_str(&format!("{:?}", r.config.values));
    }
    s
}

#[test]
fn interleaved_sessions_match_standalone_driver_for_all_methods() {
    for name in ALL_METHODS {
        let reference = fingerprint(&standalone(name, 1));
        let mut sessions = vec![session("a", name, 1), session("b", name, 1)];
        let mut d = Dispatcher::new(2, 1 << 14);
        d.run_all(&mut sessions).unwrap();
        for s in &sessions {
            assert_eq!(
                fingerprint(&s.outcome().unwrap()),
                reference,
                "{name}: interleaved session {} diverged from standalone Driver::run",
                s.id
            );
        }
    }
}

#[test]
fn cache_served_session_is_all_hits_and_byte_identical() {
    for name in ALL_METHODS {
        let reference = fingerprint(&standalone(name, 1));
        let mut d = Dispatcher::new(2, 1 << 14);
        let mut sessions = vec![session("a", name, 1)];
        d.run_all(&mut sessions).unwrap();
        let after_a = d.cache_stats();

        // session B over the same spec: every evaluation must come out
        // of the memo-cache (zero new misses) and the outcome must not
        // move a byte
        sessions.push(session("b", name, 1));
        d.run_all(&mut sessions).unwrap();
        let after_b = d.cache_stats();
        let evals = sessions[1].evals() as u64;
        assert!(evals > 0, "{name}: session B evaluated nothing");
        assert_eq!(
            after_b.misses, after_a.misses,
            "{name}: session B missed the cache"
        );
        assert_eq!(
            after_b.hits - after_a.hits,
            evals,
            "{name}: session B's evals were not all served from cache"
        );
        for s in &sessions {
            assert_eq!(
                fingerprint(&s.outcome().unwrap()),
                reference,
                "{name}: session {} diverged (cache hits changed the outcome?)",
                s.id
            );
        }
    }
}

#[test]
fn repeats_fold_matches_cluster_objective() {
    // repeats > 1: each config is simulated `repeats` times on distinct
    // reserved seeds and folded into a mean — the serve fold must be the
    // exact ClusterObjective expression
    let reference = fingerprint(&standalone("bobyqa", 2));
    let mut sessions = vec![session("a", "bobyqa", 2), session("b", "bobyqa", 2)];
    let mut d = Dispatcher::new(3, 1 << 14);
    d.run_all(&mut sessions).unwrap();
    for s in &sessions {
        assert_eq!(
            fingerprint(&s.outcome().unwrap()),
            reference,
            "session {}: repeats fold diverged from standalone",
            s.id
        );
    }
}

#[test]
fn queue_cap_bounds_a_step_and_starves_no_session() {
    let mut sessions: Vec<ServeSession> =
        (0..6).map(|i| session(&format!("s{i}"), "random", 1)).collect();
    let mut d = Dispatcher::new(2, 1 << 14).with_queue_cap(1);
    let r = d.step(&mut sessions).unwrap();
    assert_eq!(r.sessions, 1, "cap 1 should admit exactly one session's slice");
    d.run_all(&mut sessions).unwrap();
    for s in &sessions {
        assert_eq!(s.evals(), BUDGET, "session {} starved behind the queue cap", s.id);
    }
}

// ---- project-backed daemon tests -----------------------------------

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("catla-serve-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn tuning_project(name: &str, properties: &str) -> PathBuf {
    let dir = tmp(name);
    create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(dir.join("tuning.properties"), properties).unwrap();
    dir
}

const SMALL: &str = "optimizer=bobyqa\nbudget=12\nrepeats=1\nseed=7\n";

fn serve_script(daemon: &mut Daemon, script: String) -> String {
    let mut out = Vec::new();
    daemon.serve(Cursor::new(script), &mut out).unwrap();
    String::from_utf8(out).unwrap()
}

#[test]
fn project_logs_are_byte_identical_across_serve_and_standalone() {
    let dir_a = tuning_project("log-a", SMALL);
    let dir_b = tuning_project("log-b", SMALL);
    let dir_c = tuning_project("log-c", SMALL);

    // standalone reference: the OptimizerRunner writes dir_c's log
    let project = Project::load(&dir_c).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::from_env(&project.env));
    OptimizerRunner::new(&mut cluster).run(&project).unwrap();

    // daemon: run A fully, then B — identical project, so B must be
    // 100% cache hits — then close both
    let mut daemon = Daemon::new(Dispatcher::new(2, 1 << 12));
    let reply = serve_script(
        &mut daemon,
        format!(
            "open a {a}\nrun a\nstats\nopen b {b}\nrun b\nstats\nclose a\nclose b\nshutdown\n",
            a = dir_a.display(),
            b = dir_b.display()
        ),
    );
    assert_eq!(
        reply.lines().filter(|l| l.starts_with("ok close")).count(),
        2,
        "close failed:\n{reply}"
    );
    let stats: Vec<&str> = reply.lines().filter(|l| l.starts_with("ok stats")).collect();
    assert_eq!(stats.len(), 2, "missing stats replies:\n{reply}");
    let field = |line: &str, key: &str| -> u64 {
        line.split_whitespace()
            .find_map(|t| t.strip_prefix(&format!("{key}=")))
            .unwrap_or_else(|| panic!("no {key}= in {line:?}"))
            .parse()
            .unwrap()
    };
    assert_eq!(
        field(stats[0], "misses"),
        field(stats[1], "misses"),
        "session B missed the cache:\n{reply}"
    );
    assert!(
        field(stats[1], "hits") > field(stats[0], "hits"),
        "session B registered no cache hits:\n{reply}"
    );

    let log = |d: &PathBuf| std::fs::read(d.join("history").join("tuning_log.csv")).unwrap();
    assert_eq!(
        log(&dir_a),
        log(&dir_c),
        "serve session A's tuning log differs from the standalone OptimizerRunner's"
    );
    assert_eq!(
        log(&dir_b),
        log(&dir_c),
        "cache-served session B's tuning log differs from the standalone run's"
    );
    for d in [dir_a, dir_b, dir_c] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn spec_typo_warning_is_emitted_once_per_session() {
    let dir = tuning_project("warn", SMALL);
    let spec_path = dir.join("params.spec");
    let mut spec = std::fs::read_to_string(&spec_path).unwrap();
    spec.push_str("param memory.mbb int 512 4096\n");
    std::fs::write(&spec_path, spec).unwrap();

    let mut daemon = Daemon::new(Dispatcher::new(2, 1 << 12));
    let reply = serve_script(
        &mut daemon,
        format!(
            "open s {d}\nstep s\nstep s\nrun s\nstatus s\nclose s\nshutdown\n",
            d = dir.display()
        ),
    );
    let warnings: Vec<&str> = reply.lines().filter(|l| l.starts_with("warning ")).collect();
    assert_eq!(
        warnings.len(),
        1,
        "typo-guard warning must surface exactly once per loaded session:\n{reply}"
    );
    assert!(
        warnings[0].contains("memory.mbb"),
        "wrong warning surfaced: {}",
        warnings[0]
    );
    assert!(
        reply.lines().any(|l| l.starts_with("ok close s")),
        "session did not close cleanly:\n{reply}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn killed_daemon_resumes_from_journal_byte_identically() {
    // reference: the same project driven to completion uninterrupted
    let dir_ref = tuning_project("resume-ref", SMALL);
    let reference = {
        let mut sessions = vec![ServeSession::open(&dir_ref, "s", "tuning_log.csv").unwrap()];
        let mut d = Dispatcher::new(2, 1 << 12);
        d.run_all(&mut sessions).unwrap();
        fingerprint(&sessions[0].finalize().unwrap())
    };

    let dir = tuning_project("resume", SMALL);
    {
        let mut sessions = vec![ServeSession::open(&dir, "s", "tuning_log.csv").unwrap()];
        let mut d = Dispatcher::new(2, 1 << 12);
        for _ in 0..3 {
            d.step(&mut sessions).unwrap();
        }
        assert!(sessions[0].evals() > 0, "no slices completed before the crash");
        assert!(!sessions[0].is_done(), "budget too small to interrupt mid-run");
        // dropped without finalize: the "crash" loses only in-flight work
    }
    assert!(
        dir.join("history").join("tuning_log.csv.journal").is_file(),
        "per-slice checkpoint journal missing after interrupted steps"
    );
    let mut sessions = vec![ServeSession::open(&dir, "s", "tuning_log.csv").unwrap()];
    let prior = sessions[0].evals();
    assert!(prior > 0, "checkpoint journal was not re-driven");
    // journal recovery rebuilds the EXACT optimizer state, so the
    // session keeps its original label (no [resumed@n] marker) and the
    // finished outcome must not move a byte vs the uninterrupted run
    assert_eq!(
        sessions[0].label(),
        "bobyqa",
        "journal recovery must keep the original label"
    );
    let mut d = Dispatcher::new(2, 1 << 12);
    d.run_all(&mut sessions).unwrap();
    let out = sessions[0].finalize().unwrap();
    assert_eq!(out.evals(), 12, "resume did not complete the original budget");
    assert_eq!(
        fingerprint(&out),
        reference,
        "journal-recovered outcome diverged from the uninterrupted run"
    );
    let log = |d: &PathBuf| std::fs::read(d.join("history").join("tuning_log.csv")).unwrap();
    assert_eq!(log(&dir), log(&dir_ref), "recovered tuning log is not byte-identical");
    assert!(
        !dir.join("history").join("tuning_log.csv.journal").is_file(),
        "journal must be retired after finalize"
    );
    let summary = std::fs::read_to_string(dir.join("history").join("summary.csv")).unwrap();
    assert!(summary.lines().count() >= 2, "summary row missing after finalize");
    let _ = std::fs::remove_dir_all(dir);
    let _ = std::fs::remove_dir_all(dir_ref);
}

#[test]
fn fsck_repair_materializes_checkpoint_and_legacy_resume_still_works() {
    // interrupt a session mid-run, then retire its journal with
    // `fsck --repair`: the checkpoint CSV it materializes feeds the
    // legacy PriorRuns resume path, which replays a flat history into a
    // fresh optimizer under the [resumed@n] label
    let dir = tuning_project("resume-legacy", SMALL);
    {
        let mut sessions = vec![ServeSession::open(&dir, "s", "tuning_log.csv").unwrap()];
        let mut d = Dispatcher::new(2, 1 << 12);
        for _ in 0..3 {
            d.step(&mut sessions).unwrap();
        }
        assert!(sessions[0].evals() > 0, "no slices completed before the crash");
    }
    let report = catla::catla::fsck::fsck_dir(&dir, true).unwrap();
    assert!(report.repaired > 0, "fsck --repair retired no journal:\n{report}");
    assert!(report.problems.is_empty(), "fsck left problems:\n{report}");
    assert!(
        !dir.join("history").join("tuning_log.csv.journal").is_file(),
        "repair must retire the journal"
    );
    let mut sessions = vec![ServeSession::open(&dir, "s", "tuning_log.csv").unwrap()];
    let prior = sessions[0].evals();
    assert!(prior > 0, "materialized checkpoint log was not replayed");
    assert!(
        sessions[0].label().contains("resumed"),
        "legacy CSV resume must carry the [resumed@n] label: {}",
        sessions[0].label()
    );
    let mut d = Dispatcher::new(2, 1 << 12);
    d.run_all(&mut sessions).unwrap();
    let out = sessions[0].finalize().unwrap();
    assert_eq!(out.evals(), 12, "legacy resume did not complete the original budget");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn external_ask_tell_protocol_drives_a_session() {
    // a session measured by an external client: random with budget 4
    // asks its whole design up front, the client tells 4 values
    let dir = tuning_project("external", "optimizer=random\nbudget=4\nrepeats=1\nseed=7\n");
    let mut daemon = Daemon::new(Dispatcher::new(2, 1 << 12));
    let reply = serve_script(
        &mut daemon,
        format!(
            "open s {d}\nask s\ntell s 40 30 20 10\nstatus s\nask s\nstatus s\nclose s\nshutdown\n",
            d = dir.display()
        ),
    );
    let candidates = reply.lines().filter(|l| l.starts_with("candidate s ")).count();
    assert_eq!(candidates, 4, "expected the whole random design:\n{reply}");
    assert!(
        reply.contains("ok tell s evals=4"),
        "tell did not record 4 evals:\n{reply}"
    );
    assert!(
        reply.contains("ok ask s n=0"),
        "second ask should find the stream exhausted:\n{reply}"
    );
    assert!(
        reply.lines().any(|l| l.starts_with("ok status s") && l.contains("done=true")),
        "session never reported done:\n{reply}"
    );
    assert!(
        reply.lines().any(|l| l.starts_with("ok close s") && l.contains("best=10.000")),
        "close did not report the told best:\n{reply}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

// ---- crash tolerance: retries, Failed sessions, sibling isolation ---

#[test]
fn retried_evaluations_are_byte_identical_to_unfaulted_runs() {
    // two injected panics per step against a retry budget of two: every
    // poisoned evaluation eventually succeeds on a retry, and because a
    // retry re-runs the same pure simulation inputs the outcome must
    // not move a byte — for all eight methods
    for name in ALL_METHODS {
        let reference = fingerprint(&standalone(name, 1));
        let mut sessions = vec![session("a", name, 1), session("b", name, 1)];
        let mut d = Dispatcher::new(2, 1 << 14);
        d.inject_eval_faults("a", 2);
        d.run_all(&mut sessions).unwrap();
        for s in &sessions {
            assert!(
                s.failed().is_none(),
                "{name}: session {} failed despite a sufficient retry budget: {:?}",
                s.id,
                s.failed()
            );
            assert_eq!(
                fingerprint(&s.outcome().unwrap()),
                reference,
                "{name}: session {} diverged after evaluation retries",
                s.id
            );
        }
    }
}

#[test]
fn poisoned_session_fails_alone_and_siblings_complete() {
    // "bad" gets more injected faults than any retry budget; "good"
    // tunes a DIFFERENT cluster (distinct seed ⇒ no shared cache keys)
    // and must run to the exact standalone outcome while its sibling
    // moves to the Failed terminal state
    let reference = fingerprint(&standalone("bobyqa", 1));
    let bad = ServeSession::new(
        "bad",
        TuningSpec::fig3(),
        HadoopConfig::default(),
        ClusterSpec {
            seed: 999,
            ..ClusterSpec::default()
        },
        wordcount(2048.0),
        &settings("bobyqa", 1),
    )
    .unwrap();
    let mut sessions = vec![bad, session("good", "bobyqa", 1)];
    let mut d = Dispatcher::new(2, 1 << 14);
    d.inject_eval_faults("bad", u64::MAX);
    let first = d.step(&mut sessions).unwrap();
    assert_eq!(first.failed, 1, "bad session should fail on its first slice");
    d.run_all(&mut sessions).unwrap();

    assert!(sessions[0].is_done(), "failed session must report done");
    let reason = sessions[0]
        .failed()
        .expect("bad session should be Failed")
        .to_string();
    assert!(
        reason.contains("injected evaluation fault"),
        "failure reason lost the panic payload: {reason}"
    );
    assert!(
        sessions[0].finalize().is_err(),
        "finalize of a failed session must error"
    );

    let good = &sessions[1];
    assert!(good.failed().is_none(), "sibling caught the failure");
    assert_eq!(
        fingerprint(&good.outcome().unwrap()),
        reference,
        "sibling session diverged while sharing a dispatcher with a failing one"
    );
}

#[test]
fn protocol_surfaces_failed_sessions() {
    // different input sizes so the two sessions share no cache keys
    let dir_bad = tmp("poison-bad");
    create_template(&dir_bad, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
    std::fs::write(dir_bad.join("tuning.properties"), SMALL).unwrap();
    let dir_good = tmp("poison-good");
    create_template(&dir_good, ProjectKind::Tuning, "wordcount", 512.0).unwrap();
    std::fs::write(dir_good.join("tuning.properties"), SMALL).unwrap();

    let mut daemon = Daemon::new(Dispatcher::new(2, 1 << 12));
    daemon.dispatcher.inject_eval_faults("bad", u64::MAX);
    let reply = serve_script(
        &mut daemon,
        format!(
            "open bad {b}\nopen good {g}\nrun\nstatus bad\nstatus good\nclose good\nclose bad\nshutdown\n",
            b = dir_bad.display(),
            g = dir_good.display()
        ),
    );
    let status_bad = reply
        .lines()
        .find(|l| l.starts_with("ok status bad"))
        .unwrap_or_else(|| panic!("no status for bad:\n{reply}"));
    assert!(
        status_bad.contains("done=true") && status_bad.contains("failed="),
        "failed session's status must carry done=true + the reason: {status_bad}"
    );
    let status_good = reply
        .lines()
        .find(|l| l.starts_with("ok status good"))
        .unwrap_or_else(|| panic!("no status for good:\n{reply}"));
    assert!(
        status_good.contains("done=true") && !status_good.contains("failed="),
        "healthy session's status reply changed: {status_good}"
    );
    assert!(
        reply.lines().any(|l| l.starts_with("ok close good")),
        "healthy session did not close cleanly:\n{reply}"
    );
    assert!(
        reply
            .lines()
            .any(|l| l.starts_with("err ") && l.contains("failed")),
        "close of the failed session must answer err with the reason:\n{reply}"
    );
    assert!(
        dir_good.join("history").join("tuning_log.csv").is_file(),
        "healthy session's tuning log missing"
    );
    for d in [dir_bad, dir_good] {
        let _ = std::fs::remove_dir_all(d);
    }
}

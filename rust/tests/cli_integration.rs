//! CLI integration: drive the compiled `catla` binary the way the paper's
//! §II.B.2 walkthrough drives `Catla.jar`, asserting on process output
//! and the files it leaves behind.

use std::path::PathBuf;
use std::process::Command;

fn catla_bin() -> PathBuf {
    // cargo puts integration-test binaries in target/<profile>/deps;
    // the main binary lives one level up
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("catla")
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("catla-cli-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(catla_bin())
        .args(args)
        .env("CATLA_ARTIFACTS", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .output()
        .expect("failed to spawn catla binary — build it first");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_lists_all_tools() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for tool in ["template", "task", "project", "tuning", "aggregate", "visualize"] {
        assert!(stdout.contains(tool), "help missing {tool}");
    }
}

#[test]
fn unknown_tool_fails_with_usage() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown tool"));
}

#[test]
fn paper_walkthrough_steps_1_to_5() {
    let dir = tmp("walkthrough");
    let dir_s = dir.to_str().unwrap();

    // Step 1+3: prepare the task-based project folder
    let (ok, stdout, stderr) = run(&[
        "template", "--dir", dir_s, "--workload", "wordcount", "--input-mb", "1024",
    ]);
    assert!(ok, "template failed: {stderr}");
    assert!(stdout.contains("created"));
    assert!(dir.join("HadoopEnv.txt").is_file(), "Step 2 file missing");

    // Step 4: run the task tool
    let (ok, stdout, stderr) = run(&["task", "--dir", dir_s]);
    assert!(ok, "task failed: {stderr}");
    assert!(stdout.contains("finished"), "no completion message: {stdout}");

    // Step 5: downloaded_results appears with the analyzing results
    assert!(dir.join("downloaded_results").is_dir());
    let has_history = std::fs::read_dir(dir.join("downloaded_results"))
        .unwrap()
        .any(|e| {
            e.unwrap()
                .file_name()
                .to_string_lossy()
                .ends_with("history.json")
        });
    assert!(has_history, "no history.json downloaded");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tuning_tool_writes_log_and_chart() {
    let dir = tmp("tuning");
    let dir_s = dir.to_str().unwrap();
    run(&["template", "--dir", dir_s, "--kind", "tuning", "--input-mb", "1024"]);
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=15\nrepeats=1\nseed=2\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["tuning", "--dir", dir_s]);
    assert!(ok, "tuning failed: {stderr}");
    assert!(stdout.contains("best configuration"));
    assert!(stdout.contains("convergence"), "CatlaUI chart missing");
    assert!(dir.join("history/tuning_log.csv").is_file());
    assert!(dir.join("history/summary.csv").is_file());

    // visualize re-renders from the log, --gnuplot drops a script
    let (ok, stdout, _) = run(&["visualize", "--dir", dir_s, "--gnuplot"]);
    assert!(ok);
    assert!(stdout.contains("running time per iteration"));
    assert!(dir.join("history/fig3.gnuplot").is_file());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tuning_tool_prints_spec_typo_warning() {
    let dir = tmp("typo");
    let dir_s = dir.to_str().unwrap();
    run(&["template", "--dir", dir_s, "--kind", "tuning", "--input-mb", "512"]);
    // memory.mbb: edit distance 1 from the builtin's memory.mb suffix —
    // the run proceeds (declaring new knobs is the feature) but the CLI
    // must surface the typo guard's warning on stderr
    std::fs::write(
        dir.join("params.spec"),
        "param mapreduce.job.reduces int 2 32\nparam memory.mbb int 512 4096\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=random\nbudget=6\nrepeats=1\nseed=3\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["tuning", "--dir", dir_s]);
    assert!(ok, "tuning failed: {stderr}");
    assert!(stdout.contains("tuning finished"));
    assert!(
        stderr.contains("memory.mbb") && stderr.contains("mapreduce.map.memory.mb"),
        "typo warning missing from stderr: {stderr}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pjrt_prescreen_tuning_via_cli() {
    // exercises the full three-layer stack from the CLI: artifacts must
    // exist (make artifacts) for this to pass
    let dir = tmp("pjrt");
    let dir_s = dir.to_str().unwrap();
    run(&["template", "--dir", dir_s, "--kind", "tuning", "--input-mb", "2048"]);
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=12\nrepeats=1\nseed=4\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["tuning", "--dir", dir_s, "--prescreen", "pjrt"]);
    assert!(ok, "pjrt tuning failed: {stderr}");
    assert!(stdout.contains("tuning finished"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sharded_sweep_partitions_the_grid_across_processes() {
    let dir = tmp("sweep");
    let dir_s = dir.to_str().unwrap();
    run(&["template", "--dir", dir_s, "--kind", "tuning", "--input-mb", "512"]);
    // 4 x 4 = 16 grid points
    std::fs::write(
        dir.join("params.spec"),
        "param mapreduce.job.reduces int 2 8 step 2\n\
         param mapreduce.task.io.sort.mb int 100 400 step 100\n",
    )
    .unwrap();
    let mut rows = 0usize;
    for k in 0..2 {
        let shard = format!("{k}/2");
        let (ok, stdout, stderr) = run(&["sweep", "--dir", dir_s, "--shard", &shard]);
        assert!(ok, "sweep shard {k} failed: {stderr}");
        assert!(stdout.contains("of 16 grid points"), "{stdout}");
        let log = dir.join(format!("history/tuning_log.shard{k}of2.csv"));
        assert!(log.is_file(), "missing {}", log.display());
        let text = std::fs::read_to_string(&log).unwrap();
        rows += text.lines().count() - 1; // minus header
    }
    assert_eq!(rows, 16, "shards did not partition the sweep");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn scoped_workflow_tune_renders_per_job_configs() {
    let dir = tmp("scoped-wf");
    let dir_s = dir.to_str().unwrap();
    let (ok, _, stderr) = run(&[
        "template",
        "--dir",
        dir_s,
        "--workloads",
        "terasort,wordcount",
        "--input-mb",
        "512",
    ]);
    assert!(ok, "scoped template failed: {stderr}");
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=random\nbudget=6\nrepeats=1\nseed=2\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["workflow", "--dir", dir_s, "--tune"]);
    assert!(ok, "scoped workflow --tune failed: {stderr}");
    assert!(stdout.contains("per-job configurations"), "{stdout}");
    assert!(stdout.contains("workflow makespan"), "{stdout}");
    // merged log records scoped dims as <param>@<workload> columns
    let log = std::fs::read_to_string(dir.join("history/tuning_log.csv")).unwrap();
    let header = log.lines().next().unwrap();
    assert!(header.contains("@terasort"), "{header}");
    assert!(header.contains("@wordcount"), "{header}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn aggregate_tool_reports() {
    let dir = tmp("agg");
    let dir_s = dir.to_str().unwrap();
    run(&["template", "--dir", dir_s, "--input-mb", "512"]);
    run(&["task", "--dir", dir_s]);
    let (ok, stdout, _) = run(&["aggregate", "--dir", dir_s]);
    assert!(ok);
    assert!(stdout.contains("1 histories found"), "{stdout}");
    std::fs::remove_dir_all(&dir).unwrap();
}

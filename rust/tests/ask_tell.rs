//! Ask/tell architecture guarantees, across ALL eight methods:
//! * determinism regression: same `Method` + seed + budget ⇒ byte-identical
//!   `TuningOutcome` through the new `Driver`;
//! * serial and batched objective evaluation produce identical outcomes;
//! * budget accounting: over-sized ask-batches are truncated, never
//!   overspent, and `tell` covers every evaluated candidate;
//! * ask-batch shapes: population methods batch, sequential methods ask
//!   singletons (bobyqa: one init batch, then singletons);
//! * streaming: the lazy `GridCursor` reproduces the materialized cross
//!   product exactly, shards partition it, and `batch.chunk` (driver
//!   eval slicing + grid ask streaming) never changes any method's
//!   outcome byte.

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::core::{BatchObjective, Candidate, Driver, FnObjective, Optimizer};
use catla::optim::{
    Bobyqa, ClusterObjective, EarlyStop, EvalRecord, Fidelity, Method, ParamSpace, RacingObjective,
    RacingSettings, TuningOutcome, ALL_METHODS,
};
use catla::workloads::wordcount;

const BUDGET: usize = 30;
const SEED: u64 = 23;

fn space() -> ParamSpace {
    ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
}

fn drive(name: &str, serial: bool) -> TuningOutcome {
    drive_custom(name, serial, None, false)
}

fn drive_chunked(name: &str, serial: bool, chunk: Option<usize>) -> TuningOutcome {
    drive_custom(name, serial, chunk, false)
}

fn drive_custom(
    name: &str,
    serial: bool,
    chunk: Option<usize>,
    fresh_buffers: bool,
) -> TuningOutcome {
    let wl = wordcount(2048.0);
    let sp = space();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
    if serial {
        obj = obj.serial();
    }
    if fresh_buffers {
        obj = obj.without_arena();
    }
    let mut opt = Method::from_name(name, SEED).unwrap().build();
    let mut driver = Driver::new(BUDGET);
    if let Some(c) = chunk {
        driver = driver.chunk(c);
    }
    driver.run(opt.as_mut(), &sp, &mut obj).unwrap()
}

/// Byte-exact fingerprint of an outcome (f64s via to_bits, so any drift
/// in values, order or config decoding shows up).
fn fingerprint(out: &TuningOutcome) -> String {
    let mut s = format!("{}|{}|{:x}", out.optimizer, out.evals(), out.best_value.to_bits());
    for r in &out.records {
        s.push_str(&format!(
            ";{}:{:x}:{:x}:{}:{}",
            r.iter,
            r.value.to_bits(),
            r.best_so_far.to_bits(),
            r.fidelity.label(),
            r.unit_x
                .iter()
                .map(|u| format!("{:x}", u.to_bits()))
                .collect::<Vec<_>>()
                .join(","),
        ));
        s.push_str(&format!("{:?}", r.config.values));
    }
    s
}

#[test]
fn determinism_same_method_seed_budget_is_byte_identical() {
    for name in ALL_METHODS {
        let a = drive(name, false);
        let b = drive(name, false);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name}: outcome not reproducible under a fixed seed"
        );
        assert!(a.evals() > 0 && a.evals() <= BUDGET, "{name}: bad eval count");
    }
}

#[test]
fn disabled_racing_objective_is_byte_identical_for_all_methods() {
    // racing.enabled=false must be a structural no-op: the RacingObjective
    // wrapper delegates straight to the inner ClusterObjective, so every
    // method's outcome (values, best-so-far, configs, fidelities — all
    // Full) stays byte-identical to the unwrapped driver
    let wl = wordcount(2048.0);
    let sp = space();
    for name in ALL_METHODS {
        let plain = drive(name, false);
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let inner = ClusterObjective::new(&mut cluster, &wl, 1);
        let mut obj = RacingObjective::new(inner, RacingSettings::default(), None);
        let mut opt = Method::from_name(name, SEED).unwrap().build();
        let raced = Driver::new(BUDGET).run(opt.as_mut(), &sp, &mut obj).unwrap();
        assert_eq!(
            fingerprint(&plain),
            fingerprint(&raced),
            "{name}: disabled racing changed the outcome"
        );
        assert!(raced.records.iter().all(|r| r.fidelity == Fidelity::Full));
    }
}

#[test]
fn chunked_and_whole_batch_driving_agree_bitwise_for_all_methods() {
    // batch.chunk re-slices the identical candidate stream: grid streams
    // 7-point asks, population batches are evaluated/told in 7-point
    // slices, bobyqa's 9-point init design is told in 7+2 — every
    // outcome must stay byte-identical to the unchunked run
    for name in ALL_METHODS {
        let whole = drive_chunked(name, false, None);
        let chunked = drive_chunked(name, false, Some(7));
        assert_eq!(
            fingerprint(&whole),
            fingerprint(&chunked),
            "{name}: batch.chunk changed the outcome"
        );
        // and a singleton chunk (the most aggressive slicing) too
        let drip = drive_chunked(name, false, Some(1));
        assert_eq!(
            fingerprint(&whole),
            fingerprint(&drip),
            "{name}: batch.chunk=1 changed the outcome"
        );
    }
}

#[test]
fn early_stop_fires_at_the_same_eval_under_any_chunk() {
    // the stop decision is per evaluation, so the stopping point cannot
    // depend on how ask-batches are sliced (or on grid's ask size)
    let sp = space();
    let run = |chunk: Option<usize>, method: &str| -> TuningOutcome {
        let mut obj = FnObjective(|_: &HadoopConfig| 42.0); // flat: must stop
        let mut opt = Method::from_name(method, SEED).unwrap().build();
        let mut driver = Driver::new(200).early_stop(EarlyStop::new(5));
        if let Some(c) = chunk {
            driver = driver.chunk(c);
        }
        driver.run(opt.as_mut(), &sp, &mut obj).unwrap()
    };
    for method in ["random", "grid", "latin"] {
        let whole = run(None, method);
        assert!(whole.evals() < 200, "{method}: early stop never fired");
        for chunk in [1usize, 3, 7] {
            let sliced = run(Some(chunk), method);
            assert_eq!(
                fingerprint(&whole),
                fingerprint(&sliced),
                "{method}: chunk {chunk} moved the early stop"
            );
        }
    }
}

#[test]
fn streamed_grid_equals_materialized_grid_on_small_spaces() {
    for spec in [TuningSpec::fig2(), TuningSpec::fig3()] {
        let sp = ParamSpace::new(spec, HadoopConfig::default());
        let materialized = sp.unit_grid();
        let streamed: Vec<Vec<f64>> = sp.grid_cursor().collect();
        assert_eq!(streamed, materialized);
        assert_eq!(streamed.len() as u64, sp.grid_cursor().total_points());
    }
}

#[test]
fn grid_shards_union_to_the_full_grid_without_overlap() {
    let sp = space();
    let full: Vec<Vec<f64>> = sp.grid_cursor().collect();
    for n in [2u64, 5] {
        let mut by_index: Vec<Option<Vec<f64>>> = vec![None; full.len()];
        for k in 0..n {
            for (j, p) in sp.grid_cursor().shard(k, n).enumerate() {
                let idx = (k + j as u64 * n) as usize; // stripe k, k+n, …
                assert!(by_index[idx].is_none(), "shard overlap at index {idx}");
                by_index[idx] = Some(p);
            }
        }
        let union: Vec<Vec<f64>> = by_index.into_iter().map(|p| p.unwrap()).collect();
        assert_eq!(union, full, "{n}-way shard union is not the grid");
    }
}

#[test]
fn batched_and_serial_evaluation_agree_bitwise() {
    for name in ALL_METHODS {
        let serial = drive(name, true);
        let batched = drive(name, false);
        assert_eq!(
            fingerprint(&serial),
            fingerprint(&batched),
            "{name}: batched objective evaluation changed the outcome"
        );
    }
}

#[test]
fn arena_backed_and_fresh_allocation_objectives_agree_bitwise() {
    // the default ClusterObjective reuses per-worker SimArenas across
    // every eval of the run (reset-not-reallocate); the whole
    // TuningOutcome — every value, unit point and decoded config —
    // must match the fresh-buffers path byte for byte, for ALL eight
    // methods, in both the parallel and the serial (DFO-singleton
    // slot-0 arena) paths
    for name in ALL_METHODS {
        let arena = drive_custom(name, false, None, false);
        let fresh = drive_custom(name, false, None, true);
        assert_eq!(
            fingerprint(&arena),
            fingerprint(&fresh),
            "{name}: arena reuse changed the outcome"
        );
        let arena_serial = drive_custom(name, true, None, false);
        let fresh_serial = drive_custom(name, true, None, true);
        assert_eq!(
            fingerprint(&arena_serial),
            fingerprint(&fresh_serial),
            "{name}: serial arena reuse changed the outcome"
        );
    }
}

#[test]
fn cluster_api_arena_stays_clean_across_mixed_workloads() {
    // SimCluster simulates every submission inside ONE owned arena; a
    // stream of different workload shapes through the Cluster API must
    // produce exactly what isolated fresh clusters (same seeds) produce
    use catla::hadoop::{Cluster, JobStatus, JobSubmission};
    let submit = |c: &mut SimCluster, wl: catla::workloads::WorkloadSpec| -> f64 {
        let id = c
            .submit_job(JobSubmission {
                name: "mix".into(),
                workload: wl,
                config: sp_cfg(),
            })
            .unwrap();
        loop {
            if let JobStatus::Succeeded { runtime_s } = c.poll(&id).unwrap() {
                return runtime_s;
            }
        }
    };
    fn sp_cfg() -> catla::config::params::HadoopConfig {
        catla::config::params::HadoopConfig::default()
    }
    let mut mixed = SimCluster::new(ClusterSpec::default());
    let a = submit(&mut mixed, wordcount(4096.0));
    let b = submit(&mut mixed, catla::workloads::terasort(1024.0));
    let c = submit(&mut mixed, wordcount(4096.0));

    // isolated reference clusters advanced to the same per-job seeds
    let mut r1 = SimCluster::new(ClusterSpec::default());
    let ra = submit(&mut r1, wordcount(4096.0));
    let mut r2 = SimCluster::new(ClusterSpec::default());
    r2.reserve_seeds(1);
    let rb = submit(&mut r2, catla::workloads::terasort(1024.0));
    let mut r3 = SimCluster::new(ClusterSpec::default());
    r3.reserve_seeds(2);
    let rc = submit(&mut r3, wordcount(4096.0));
    assert_eq!(a.to_bits(), ra.to_bits(), "first job diverged");
    assert_eq!(b.to_bits(), rb.to_bits(), "dirty-arena terasort diverged");
    assert_eq!(c.to_bits(), rc.to_bits(), "re-dirtied wordcount diverged");
}

#[test]
fn population_methods_ask_one_big_batch_sequential_ask_singletons() {
    let sp = space();
    for name in ["grid", "random", "latin"] {
        let mut opt = Method::from_name(name, SEED).unwrap().build();
        let batch = opt.ask(&sp, BUDGET);
        assert_eq!(batch.len(), BUDGET, "{name}: population method should batch");
    }
    for name in ["coordinate", "hooke-jeeves", "nelder-mead", "annealing"] {
        let mut opt = Method::from_name(name, SEED).unwrap().build();
        for step in 0..10 {
            let batch = opt.ask(&sp, BUDGET);
            assert_eq!(batch.len(), 1, "{name}: ask {step} not a singleton");
            opt.tell(&[record(&sp, &batch[0], 10.0 - step as f64 * 0.1)]);
        }
    }
    // bobyqa: one init-design batch, then singletons
    let mut bob = Method::from_name("bobyqa", SEED).unwrap().build();
    let init = bob.ask(&sp, BUDGET);
    assert_eq!(init.len(), 2 * sp.dims() + 1, "bobyqa init design batches");
    let records: Vec<EvalRecord> = init
        .iter()
        .enumerate()
        .map(|(i, c)| record(&sp, c, 5.0 + i as f64))
        .collect();
    bob.tell(&records);
    for step in 0..5 {
        let batch = bob.ask(&sp, BUDGET);
        assert_eq!(batch.len(), 1, "bobyqa ask {step} not a singleton");
        bob.tell(&[record(&sp, &batch[0], 4.0)]);
    }
}

fn record(sp: &ParamSpace, c: &Candidate, value: f64) -> EvalRecord {
    EvalRecord {
        iter: 1,
        config: sp.decode(&c.unit_x),
        unit_x: c.unit_x.clone(),
        value,
        best_so_far: value,
        fidelity: Fidelity::Full,
    }
}

/// An optimizer that deliberately over-asks to probe driver accounting.
struct Greedy {
    factor: usize,
    telled: Vec<usize>, // batch sizes seen by tell
}

impl Optimizer for Greedy {
    fn name(&self) -> &str {
        "greedy"
    }
    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        let d = space.dims();
        (0..budget_left * self.factor)
            .map(|i| Candidate::new(vec![(i % 7) as f64 / 7.0; d]))
            .collect()
    }
    fn tell(&mut self, evals: &[EvalRecord]) {
        self.telled.push(evals.len());
    }
    fn best(&self) -> Option<(Vec<f64>, f64)> {
        None
    }
}

#[test]
fn driver_truncates_oversized_batches_and_tells_everything_evaluated() {
    let wl = wordcount(1024.0);
    let sp = space();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
    let mut opt = Greedy {
        factor: 3,
        telled: Vec::new(),
    };
    let out = Driver::new(25).run(&mut opt, &sp, &mut obj).unwrap();
    assert_eq!(out.evals(), 25, "budget overspent");
    assert_eq!(
        opt.telled.iter().sum::<usize>(),
        25,
        "tell did not cover every evaluated candidate"
    );
    // a single ask covered the whole budget: one truncated batch
    assert_eq!(opt.telled, vec![25]);
}

#[test]
fn driver_counts_objective_calls_not_asks() {
    // the batched objective is called once per ask-batch, not per config
    struct Counting<'a> {
        inner: ClusterObjective<'a>,
        calls: usize,
    }
    impl BatchObjective for Counting<'_> {
        fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
            self.calls += 1;
            self.inner.eval_batch(cfgs)
        }
    }
    let wl = wordcount(1024.0);
    let sp = space();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = Counting {
        inner: ClusterObjective::new(&mut cluster, &wl, 1),
        calls: 0,
    };
    let mut opt = Method::from_name("random", SEED).unwrap().build();
    let out = Driver::new(40).run(opt.as_mut(), &sp, &mut obj).unwrap();
    assert_eq!(out.evals(), 40);
    assert_eq!(obj.calls, 1, "population ask-batch split into many calls");
}

#[test]
fn early_stop_chunking_does_not_change_bobyqa_trajectory() {
    // with early stopping armed the driver evaluates and tells in
    // patience-sized slices, splitting bobyqa's init design; the
    // trajectory must match the unchunked run byte for byte
    let sp = space();
    let mk_obj = || {
        let mut v = 1000.0;
        // strictly improving, so the stop itself never fires
        FnObjective(move |_: &HadoopConfig| {
            v -= 10.0;
            v
        })
    };
    let mut o1 = mk_obj();
    let plain = Driver::new(20)
        .run(&mut Bobyqa::default(), &sp, &mut o1)
        .unwrap();
    let mut o2 = mk_obj();
    let chunked = Driver::new(20)
        .early_stop(EarlyStop::new(4))
        .run(&mut Bobyqa::default(), &sp, &mut o2)
        .unwrap();
    assert_eq!(
        fingerprint(&plain),
        fingerprint(&chunked),
        "patience-sized tell chunks changed the bobyqa trajectory"
    );
}

#[test]
fn flaky_cluster_tuning_replays_byte_identically_for_all_methods() {
    // the seeded node failure/recovery schedule is part of the simulation
    // state, so an entire tuning run over a flaky cluster must replay
    // byte for byte for every method — and must not silently equal the
    // fault-free run (the schedule has to have touched at least one
    // evaluation's runtime)
    use catla::hadoop::FaultModel;
    let flaky = ClusterSpec {
        fault: FaultModel {
            mttf_s: 150.0,
            recovery_s: 60.0,
            max_concurrent: 1,
        },
        ..ClusterSpec::default()
    };
    let drive_on = |cl: &ClusterSpec, name: &str| -> TuningOutcome {
        let wl = wordcount(4096.0);
        let sp = space();
        let mut cluster = SimCluster::new(cl.clone());
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        let mut opt = Method::from_name(name, SEED).unwrap().build();
        Driver::new(BUDGET).run(opt.as_mut(), &sp, &mut obj).unwrap()
    };
    for name in ALL_METHODS {
        let a = drive_on(&flaky, name);
        let b = drive_on(&flaky, name);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name}: tuning over a flaky cluster is not replayable"
        );
        let clean = drive_on(&ClusterSpec::default(), name);
        assert_ne!(
            fingerprint(&a),
            fingerprint(&clean),
            "{name}: the fault schedule never touched a single evaluation"
        );
    }
}

#[test]
fn resume_replay_then_continue_covers_total_budget() {
    let wl = wordcount(1024.0);
    let sp = space();

    // phase 1: a 10-eval run
    let first = {
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        let mut opt = Method::from_name("bobyqa", SEED).unwrap().build();
        Driver::new(10).run(opt.as_mut(), &sp, &mut obj).unwrap()
    };

    // phase 2: replay those 10 into a fresh optimizer, continue to 25
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
    let mut opt = Method::from_name("bobyqa", SEED).unwrap().build();
    let resumed = Driver::new(25)
        .run_with_history(opt.as_mut(), &sp, &mut obj, &first.records)
        .unwrap();
    assert_eq!(resumed.evals(), 25);
    // the replayed prefix is identical to the original run
    for (a, b) in first.records.iter().zip(&resumed.records) {
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(a.unit_x, b.unit_x);
    }
    // and the resumed best can only be >= as good
    assert!(resumed.best_value <= first.best_value);
}

//! Ask/tell architecture guarantees, across ALL eight methods:
//! * determinism regression: same `Method` + seed + budget ⇒ byte-identical
//!   `TuningOutcome` through the new `Driver`;
//! * serial and batched objective evaluation produce identical outcomes;
//! * budget accounting: over-sized ask-batches are truncated, never
//!   overspent, and `tell` covers every evaluated candidate;
//! * ask-batch shapes: population methods batch, sequential methods ask
//!   singletons (bobyqa: one init batch, then singletons).

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::core::{BatchObjective, Candidate, Driver, FnObjective, Optimizer};
use catla::optim::{
    Bobyqa, ClusterObjective, EarlyStop, EvalRecord, Method, ParamSpace, TuningOutcome,
    ALL_METHODS,
};
use catla::workloads::wordcount;

const BUDGET: usize = 30;
const SEED: u64 = 23;

fn space() -> ParamSpace {
    ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
}

fn drive(name: &str, serial: bool) -> TuningOutcome {
    let wl = wordcount(2048.0);
    let sp = space();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
    if serial {
        obj = obj.serial();
    }
    let mut opt = Method::from_name(name, SEED).unwrap().build();
    Driver::new(BUDGET)
        .run(opt.as_mut(), &sp, &mut obj)
        .unwrap()
}

/// Byte-exact fingerprint of an outcome (f64s via to_bits, so any drift
/// in values, order or config decoding shows up).
fn fingerprint(out: &TuningOutcome) -> String {
    let mut s = format!("{}|{}|{:x}", out.optimizer, out.evals(), out.best_value.to_bits());
    for r in &out.records {
        s.push_str(&format!(
            ";{}:{:x}:{:x}:{}",
            r.iter,
            r.value.to_bits(),
            r.best_so_far.to_bits(),
            r.unit_x
                .iter()
                .map(|u| format!("{:x}", u.to_bits()))
                .collect::<Vec<_>>()
                .join(","),
        ));
        s.push_str(&format!("{:?}", r.config.values));
    }
    s
}

#[test]
fn determinism_same_method_seed_budget_is_byte_identical() {
    for name in ALL_METHODS {
        let a = drive(name, false);
        let b = drive(name, false);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name}: outcome not reproducible under a fixed seed"
        );
        assert!(a.evals() > 0 && a.evals() <= BUDGET, "{name}: bad eval count");
    }
}

#[test]
fn batched_and_serial_evaluation_agree_bitwise() {
    for name in ALL_METHODS {
        let serial = drive(name, true);
        let batched = drive(name, false);
        assert_eq!(
            fingerprint(&serial),
            fingerprint(&batched),
            "{name}: batched objective evaluation changed the outcome"
        );
    }
}

#[test]
fn population_methods_ask_one_big_batch_sequential_ask_singletons() {
    let sp = space();
    for name in ["grid", "random", "latin"] {
        let mut opt = Method::from_name(name, SEED).unwrap().build();
        let batch = opt.ask(&sp, BUDGET);
        assert_eq!(batch.len(), BUDGET, "{name}: population method should batch");
    }
    for name in ["coordinate", "hooke-jeeves", "nelder-mead", "annealing"] {
        let mut opt = Method::from_name(name, SEED).unwrap().build();
        for step in 0..10 {
            let batch = opt.ask(&sp, BUDGET);
            assert_eq!(batch.len(), 1, "{name}: ask {step} not a singleton");
            opt.tell(&[record(&sp, &batch[0], 10.0 - step as f64 * 0.1)]);
        }
    }
    // bobyqa: one init-design batch, then singletons
    let mut bob = Method::from_name("bobyqa", SEED).unwrap().build();
    let init = bob.ask(&sp, BUDGET);
    assert_eq!(init.len(), 2 * sp.dims() + 1, "bobyqa init design batches");
    let records: Vec<EvalRecord> = init
        .iter()
        .enumerate()
        .map(|(i, c)| record(&sp, c, 5.0 + i as f64))
        .collect();
    bob.tell(&records);
    for step in 0..5 {
        let batch = bob.ask(&sp, BUDGET);
        assert_eq!(batch.len(), 1, "bobyqa ask {step} not a singleton");
        bob.tell(&[record(&sp, &batch[0], 4.0)]);
    }
}

fn record(sp: &ParamSpace, c: &Candidate, value: f64) -> EvalRecord {
    EvalRecord {
        iter: 1,
        config: sp.decode(&c.unit_x),
        unit_x: c.unit_x.clone(),
        value,
        best_so_far: value,
    }
}

/// An optimizer that deliberately over-asks to probe driver accounting.
struct Greedy {
    factor: usize,
    telled: Vec<usize>, // batch sizes seen by tell
}

impl Optimizer for Greedy {
    fn name(&self) -> &str {
        "greedy"
    }
    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        let d = space.dims();
        (0..budget_left * self.factor)
            .map(|i| Candidate::new(vec![(i % 7) as f64 / 7.0; d]))
            .collect()
    }
    fn tell(&mut self, evals: &[EvalRecord]) {
        self.telled.push(evals.len());
    }
    fn best(&self) -> Option<(Vec<f64>, f64)> {
        None
    }
}

#[test]
fn driver_truncates_oversized_batches_and_tells_everything_evaluated() {
    let wl = wordcount(1024.0);
    let sp = space();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
    let mut opt = Greedy {
        factor: 3,
        telled: Vec::new(),
    };
    let out = Driver::new(25).run(&mut opt, &sp, &mut obj).unwrap();
    assert_eq!(out.evals(), 25, "budget overspent");
    assert_eq!(
        opt.telled.iter().sum::<usize>(),
        25,
        "tell did not cover every evaluated candidate"
    );
    // a single ask covered the whole budget: one truncated batch
    assert_eq!(opt.telled, vec![25]);
}

#[test]
fn driver_counts_objective_calls_not_asks() {
    // the batched objective is called once per ask-batch, not per config
    struct Counting<'a> {
        inner: ClusterObjective<'a>,
        calls: usize,
    }
    impl BatchObjective for Counting<'_> {
        fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
            self.calls += 1;
            self.inner.eval_batch(cfgs)
        }
    }
    let wl = wordcount(1024.0);
    let sp = space();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = Counting {
        inner: ClusterObjective::new(&mut cluster, &wl, 1),
        calls: 0,
    };
    let mut opt = Method::from_name("random", SEED).unwrap().build();
    let out = Driver::new(40).run(opt.as_mut(), &sp, &mut obj).unwrap();
    assert_eq!(out.evals(), 40);
    assert_eq!(obj.calls, 1, "population ask-batch split into many calls");
}

#[test]
fn early_stop_chunking_does_not_change_bobyqa_trajectory() {
    // with early stopping armed the driver tells ask-batches back in
    // patience-sized chunks, splitting bobyqa's init design; the
    // trajectory must match the unchunked run byte for byte
    let sp = space();
    let mk_obj = || {
        let mut v = 1000.0;
        // strictly improving, so the stop itself never fires
        FnObjective(move |_: &HadoopConfig| {
            v -= 10.0;
            v
        })
    };
    let mut o1 = mk_obj();
    let plain = Driver::new(20)
        .run(&mut Bobyqa::default(), &sp, &mut o1)
        .unwrap();
    let mut o2 = mk_obj();
    let chunked = Driver::new(20)
        .early_stop(EarlyStop::new(4))
        .run(&mut Bobyqa::default(), &sp, &mut o2)
        .unwrap();
    assert_eq!(
        fingerprint(&plain),
        fingerprint(&chunked),
        "patience-sized tell chunks changed the bobyqa trajectory"
    );
}

#[test]
fn resume_replay_then_continue_covers_total_budget() {
    let wl = wordcount(1024.0);
    let sp = space();

    // phase 1: a 10-eval run
    let first = {
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        let mut opt = Method::from_name("bobyqa", SEED).unwrap().build();
        Driver::new(10).run(opt.as_mut(), &sp, &mut obj).unwrap()
    };

    // phase 2: replay those 10 into a fresh optimizer, continue to 25
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
    let mut opt = Method::from_name("bobyqa", SEED).unwrap().build();
    let resumed = Driver::new(25)
        .run_with_history(opt.as_mut(), &sp, &mut obj, &first.records)
        .unwrap();
    assert_eq!(resumed.evals(), 25);
    // the replayed prefix is identical to the original run
    for (a, b) in first.records.iter().zip(&resumed.records) {
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(a.unit_x, b.unit_x);
    }
    // and the resumed best can only be >= as good
    assert!(resumed.best_value <= first.best_value);
}

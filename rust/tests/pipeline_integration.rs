//! Whole-pipeline integration: template → task → project → tuning →
//! interrupt → aggregate → visualize, on real temp directories, exactly
//! as a user would drive the CLI.

use std::path::PathBuf;

use catla::catla::{
    aggregate, create_template, visualize, History, OptimizerRunner, Project, ProjectKind,
    ProjectRunner, TaskRunner,
};
use catla::config::params::HadoopConfig;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::surrogate::NativeScorer;
use catla::workloads::wordcount;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("catla-e2e-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn full_task_pipeline_produces_paper_layout() {
    let dir = tmp("task");
    create_template(&dir, ProjectKind::Task, "wordcount", 2048.0).unwrap();
    // paper Step 2: user edits HadoopEnv.txt for their cluster
    let env_path = dir.join("HadoopEnv.txt");
    let mut env_text = std::fs::read_to_string(&env_path).unwrap();
    env_text = env_text.replace("sim.nodes=16", "sim.nodes=8");
    std::fs::write(&env_path, env_text).unwrap();

    let project = Project::load(&dir).unwrap();
    let spec = ClusterSpec::from_env(&project.env);
    assert_eq!(spec.nodes, 8, "HadoopEnv edit not honored");

    let mut cluster = SimCluster::new(spec);
    let out = TaskRunner::new(&mut cluster).run(&project).unwrap();

    // paper Step 5 layout
    assert!(dir.join("downloaded_results").is_dir());
    assert!(dir.join("downloaded_results/logs").is_dir());
    assert!(dir.join("history/jobs.csv").is_file());
    assert!(out.metrics.runtime_s > 0.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tuning_interrupt_aggregate_resume_cycle() {
    let dir = tmp("resume");
    create_template(&dir, ProjectKind::Tuning, "wordcount", 2048.0).unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=20\nrepeats=1\nseed=3\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    OptimizerRunner::new(&mut cluster).run(&project).unwrap();

    // simulate an interruption corrupting the best_so_far column
    let history = History::open(&dir).unwrap();
    let log_path = history.dir.join("tuning_log.csv");
    let text = std::fs::read_to_string(&log_path).unwrap();
    let mut lines: Vec<String> = text.lines().map(|l| l.to_string()).collect();
    let n = lines.len();
    lines.truncate(n - 3); // lose the tail
    // corrupt a best_so_far cell
    if let Some(line) = lines.get_mut(2) {
        let mut parts: Vec<&str> = line.split(',').collect();
        parts[3] = "99999.000";
        *line = parts.join(",");
    }
    std::fs::write(&log_path, lines.join("\n") + "\n").unwrap();

    // aggregate repairs it
    let report = aggregate::aggregate(&dir).unwrap();
    assert!(report.tuning_rows_repaired >= 1);
    let csv = history.load_tuning_log().unwrap();
    let conv = History::convergence_from_log(&csv).unwrap();
    for w in conv.windows(2) {
        assert!(w[1].1 <= w[0].1 + 1e-9, "best_so_far not repaired");
    }

    // visualization renders from the repaired log
    let chart = visualize::chart_from_tuning_log(&csv).unwrap();
    assert!(chart.contains("convergence"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn project_group_then_aggregate_collects_all_jobs() {
    let dir = tmp("group");
    create_template(&dir, ProjectKind::Project, "terasort", 2048.0).unwrap();
    let project = Project::load(&dir).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let out = ProjectRunner::new(&mut cluster).run(&project).unwrap();
    assert_eq!(out.jobs.len(), 2);

    // wipe jobs.csv, re-aggregate from downloaded artifacts alone
    std::fs::remove_file(dir.join("history/jobs.csv")).unwrap();
    let report = aggregate::aggregate(&dir).unwrap();
    assert_eq!(report.histories_found, 2);
    assert_eq!(report.jobs_csv_rows, 2);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tuned_config_beats_hadoop_defaults() {
    // the system's reason to exist: tuning must beat the default config
    let dir = tmp("beats-default");
    create_template(&dir, ProjectKind::Tuning, "wordcount", 10240.0).unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=50\nrepeats=1\nseed=9\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();

    // measure default vs tuned on fresh seeds, averaged
    let wl = wordcount(10240.0);
    let avg = |cluster: &mut SimCluster, cfg: &HadoopConfig| -> f64 {
        (0..10)
            .map(|_| {
                cluster.run_job(&catla::hadoop::JobSubmission {
                    name: "verify".into(),
                    workload: wl.clone(),
                    config: cfg.clone(),
                })
                .runtime_s
            })
            .sum::<f64>()
            / 10.0
    };
    let default_rt = avg(&mut cluster, &HadoopConfig::default());
    let tuned_rt = avg(&mut cluster, &out.outcome.best_config);
    assert!(
        tuned_rt < default_rt,
        "tuned {tuned_rt:.1}s not better than default {default_rt:.1}s"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prescreened_tuning_runs_and_logs() {
    let dir = tmp("prescreen");
    create_template(&dir, ProjectKind::Tuning, "wordcount", 4096.0).unwrap();
    std::fs::write(
        dir.join("tuning.properties"),
        "optimizer=bobyqa\nbudget=20\nrepeats=1\nseed=5\nprescreen=auto\n",
    )
    .unwrap();
    let project = Project::load(&dir).unwrap();
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let mut scorer = NativeScorer {
        workload: wordcount(4096.0),
        cluster: ClusterSpec::default(),
    };
    let out = OptimizerRunner::with_scorer(&mut cluster, &mut scorer)
        .run(&project)
        .unwrap();
    assert!(out.outcome.optimizer.contains("prescreen"));
    let history = History::open(&dir).unwrap();
    assert!(history.load_tuning_log().unwrap().rows.len() <= 20);
    std::fs::remove_dir_all(&dir).unwrap();
}

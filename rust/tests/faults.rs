//! Fault-injection guarantees at the public-API level:
//! * zero-cost-zero-drift: with `fault.*` knobs at their defaults
//!   (mttf 0 = off), every builtin workload's timeline is bit-identical
//!   to a spec that never heard of fault injection — whatever the other
//!   fault knobs say;
//! * replay: a seeded node failure/recovery schedule produces the
//!   identical `JobResult` (runtime bits, counters, every task record)
//!   when simulated twice, and demonstrably re-executes completed maps
//!   (lost shuffle output) and kills in-flight attempts;
//! * tunable dimensions: a `params.spec`-declared `fault.node.mttf.s`
//!   hands the optimizer the failure scenario through the ordinary
//!   typed config space — decode(0) is bit-identical to no injection;
//! * a job that exhausts task attempts surfaces Hadoop's FAILED
//!   terminal state through the Cluster API and the history artifact.

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::mapreduce::TaskKind;
use catla::hadoop::{
    simulate_job, Cluster, ClusterSpec, FaultModel, JobResult, JobStatus, JobSubmission,
    SimCluster,
};
use catla::optim::ParamSpace;
use catla::workloads::{by_name, wordcount, BUILTIN_NAMES};

fn flaky(mttf_s: f64) -> ClusterSpec {
    ClusterSpec {
        fault: FaultModel {
            mttf_s,
            recovery_s: 45.0,
            max_concurrent: 2,
        },
        ..ClusterSpec::default()
    }
}

/// Byte-exact fingerprint of a whole `JobResult`: runtime bits, failure
/// state, counters, and every task record (kind/id/node/times/attempts).
fn job_fingerprint(r: &JobResult) -> String {
    let mut s = format!(
        "{:x}|{:?}|{}",
        r.runtime_s.to_bits(),
        r.failed,
        r.counters.to_json()
    );
    for t in &r.tasks {
        s.push_str(&format!(
            ";{}:{}:{}:{:x}:{:x}:{}:{}:{:?}",
            if t.kind == TaskKind::Map { "m" } else { "r" },
            t.id,
            t.node,
            t.start.to_bits(),
            t.finish.to_bits(),
            t.attempts,
            t.speculative,
            t.locality,
        ));
    }
    s
}

#[test]
fn disabled_fault_knobs_are_zero_drift_for_every_builtin_workload() {
    // recovery/concurrency knobs moved while mttf stays 0: the fault
    // chain must draw nothing and no timeline byte may move, for every
    // builtin workload shape
    let cfg = HadoopConfig::default();
    let off = ClusterSpec {
        fault: FaultModel {
            mttf_s: 0.0,
            recovery_s: 7.0,
            max_concurrent: 5,
        },
        ..ClusterSpec::default()
    };
    for name in BUILTIN_NAMES {
        let wl = by_name(name, 1536.0).unwrap();
        for seed in 1..=3u64 {
            let a = simulate_job(&ClusterSpec::default(), &wl, &cfg, seed);
            let b = simulate_job(&off, &wl, &cfg, seed);
            assert_eq!(
                job_fingerprint(&a),
                job_fingerprint(&b),
                "{name} seed {seed}: disabled fault model drifted the timeline"
            );
        }
    }
}

#[test]
fn seeded_fault_schedule_replays_bit_identically_and_reexecutes_maps() {
    let wl = wordcount(8192.0);
    let cfg = HadoopConfig::default();
    let (mut reexecuted, mut killed) = (0u64, 0u64);
    for seed in 1..=5u64 {
        let a = simulate_job(&flaky(250.0), &wl, &cfg, seed);
        let b = simulate_job(&flaky(250.0), &wl, &cfg, seed);
        assert_eq!(
            job_fingerprint(&a),
            job_fingerprint(&b),
            "seed {seed}: fault schedule not replayable"
        );
        assert!(
            a.counters.node_failures > 0,
            "seed {seed}: the schedule never fired"
        );
        reexecuted += a.counters.reexecuted_maps;
        killed += a.counters.killed_attempts;
    }
    assert!(
        reexecuted > 0,
        "no completed map was re-executed across any seed — the lost-shuffle path never ran"
    );
    assert!(
        killed > 0,
        "no in-flight attempt was killed across any seed"
    );
}

#[test]
fn spec_declared_fault_knob_is_a_tunable_dimension() {
    // fault.node.mttf.s declared like any other parameter: the decoded
    // value overrides the cluster model, so the optimizer owns the
    // scenario — and decode(0.0) is bit-identical to no injection
    let spec = TuningSpec::parse("param fault.node.mttf.s float 0 600\n").unwrap();
    let space = ParamSpace::new(spec, HadoopConfig::default());
    let off_cfg = space.decode(&[0.0]);
    let on_cfg = space.decode(&[1.0]);
    let wl = wordcount(4096.0);
    let mut fired = 0u64;
    for seed in 1..=4u64 {
        let base = simulate_job(&ClusterSpec::default(), &wl, &HadoopConfig::default(), seed);
        let off = simulate_job(&ClusterSpec::default(), &wl, &off_cfg, seed);
        assert_eq!(
            base.runtime_s.to_bits(),
            off.runtime_s.to_bits(),
            "seed {seed}: mttf=0 through the spec drifted from the plain config"
        );
        let on = simulate_job(&ClusterSpec::default(), &wl, &on_cfg, seed);
        fired += on.counters.node_failures;
    }
    assert!(
        fired > 0,
        "spec-declared mttf=600 never injected a failure across any seed"
    );
}

#[test]
fn attempt_exhaustion_surfaces_failed_state_end_to_end() {
    let mut spec = ClusterSpec::default();
    spec.noise.failure_prob = 0.9;
    spec.noise.max_attempts = 2;
    spec.speculative = false;
    let mut cluster = SimCluster::new(spec);
    let id = cluster
        .submit_job(JobSubmission {
            name: "doomed".into(),
            workload: wordcount(1024.0),
            config: HadoopConfig::default(),
        })
        .unwrap();
    let reason = loop {
        match cluster.poll(&id).unwrap() {
            JobStatus::Failed { reason } => break reason,
            JobStatus::Succeeded { runtime_s } => {
                panic!("job should have failed, succeeded in {runtime_s}s")
            }
            JobStatus::Running { .. } => {}
        }
    };
    assert!(reason.contains("attempts"), "reason: {reason}");
    // artifacts of a failed job are still downloadable, carry the FAILED
    // state + reason, and stay parseable (no JSON infinity leak)
    let art = cluster.fetch_artifacts(&id).unwrap();
    assert!(art.history_json.contains("\"state\":\"FAILED\""));
    assert!(art.history_json.contains("failReason"));
    let parsed = catla::hadoop::joblogs::parse_history(&art.history_json).unwrap();
    assert_eq!(parsed.runtime_s, -1.0, "failed history must use the -1 sentinel");
}

//! The daemon's evaluation engine: a bounded global work-queue drained
//! through ONE persistent [`ThreadPool`] and the global [`MemoCache`].
//!
//! One [`Dispatcher::step`] is the daemon's heartbeat: collect the next
//! job slice from every ready session round-robin (up to the queue
//! bound), resolve each job against the memo-cache, simulate only the
//! unique misses in parallel (per-worker [`SimArena`] scratch through
//! [`ThreadPool::scoped_run_slots`] — the arena pool is sized ONCE at
//! construction, which is what bounds the daemon's memory for its whole
//! lifetime), then deliver every session's runtimes in job order.
//!
//! Delivery order per session is always the session's own ask order, and
//! cached values are bit-identical to freshly simulated ones (the DES is
//! a pure function of the fingerprinted inputs) — so interleaving and
//! cache hits are invisible to any single session's outcome.
//!
//! The step is also the daemon's fault boundary. Evaluations run through
//! [`ThreadPool::try_scoped_run_slots`], so a panicking evaluation is
//! caught per-slot instead of poisoning the pool; panicked evaluations
//! are retried with bounded deterministic backoff (the owning session's
//! `serve.retry.max` / `serve.retry.backoff_ms`), and a session whose
//! evaluation still fails after its retry budget moves to the `Failed`
//! terminal state ([`ServeSession::fail`]) — sibling sessions in the
//! same step deliver normally. A retried evaluation re-runs the same
//! pure simulation inputs, so a retry that succeeds is bit-identical to
//! one that never failed.

use std::collections::BTreeMap;
use std::thread;
use std::time::Duration;

use crate::config::params::HadoopConfig;
use crate::hadoop::{simulate_runtime_in, ClusterSpec, SimArena};
use crate::serve::cache::{CacheStats, MemoCache};
use crate::serve::session::{EvalJob, ServeSession};
use crate::util::pool::ThreadPool;
use crate::workloads::WorkloadSpec;

/// Default bound on runs collected per step. A soft bound: a session's
/// slice is taken whole, so one step may overshoot by at most one
/// slice.
pub const DEFAULT_QUEUE_CAP: usize = 256;

/// What one [`Dispatcher::step`] did.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    /// Simulation runs delivered this step (cache hits included).
    pub runs: usize,
    /// Runs actually simulated (unique cache misses).
    pub simulated: usize,
    /// Sessions whose slice completed this step.
    pub sessions: usize,
    /// Sessions moved to the `Failed` terminal state this step
    /// (evaluation retries exhausted, or a delivery error).
    pub failed: usize,
}

pub struct Dispatcher {
    pool: ThreadPool,
    /// Per-worker simulation arenas, sized once to the pool — the
    /// daemon's simulation memory never grows with session count.
    arenas: Vec<SimArena>,
    pub cache: MemoCache,
    queue_cap: usize,
    /// Round-robin start position, so a full queue never starves the
    /// sessions at the back of the registry.
    cursor: usize,
    /// Intra-step duplicate jobs served off a miss computed in the same
    /// step (counted separately from cache hits).
    deduped: u64,
    /// Deterministic evaluation-fault injection (tests and the serve
    /// smoke's poison case): session id → remaining evaluation attempts
    /// to fail with an injected panic. Attempts owned by the session
    /// panic until the budget drains; later attempts run the pure
    /// simulation, so a drained budget converges on the exact no-fault
    /// result.
    faults: BTreeMap<String, u64>,
}

impl Dispatcher {
    pub fn new(threads: usize, cache_entries: usize) -> Dispatcher {
        let pool = ThreadPool::new(threads);
        let arenas = (0..pool.size()).map(|_| SimArena::new()).collect();
        Dispatcher {
            pool,
            arenas,
            cache: MemoCache::new(cache_entries),
            queue_cap: DEFAULT_QUEUE_CAP,
            cursor: 0,
            deduped: 0,
            faults: BTreeMap::new(),
        }
    }

    /// Arrange for the next `n` evaluation attempts owned by session
    /// `id` to panic — the deterministic fault hook behind the retry
    /// and `Failed`-session tests and `scripts/serve_smoke.sh`'s poison
    /// case. `n = 0` clears the injection.
    #[doc(hidden)]
    pub fn inject_eval_faults(&mut self, id: &str, n: u64) {
        if n == 0 {
            self.faults.remove(id);
        } else {
            self.faults.insert(id.to_string(), n);
        }
    }

    pub fn with_queue_cap(mut self, cap: usize) -> Dispatcher {
        self.queue_cap = cap.max(1);
        self
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    pub fn deduped(&self) -> u64 {
        self.deduped
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// One round: ask ready sessions for jobs (bounded, round-robin),
    /// serve cache hits, simulate unique misses in parallel, deliver
    /// results in each session's ask order.
    pub fn step(&mut self, sessions: &mut [ServeSession]) -> Result<StepReport, String> {
        let n = sessions.len();
        if n == 0 {
            return Ok(StepReport::default());
        }

        // collect: whole slices, soft-bounded by queue_cap
        let mut queue: Vec<(usize, Vec<EvalJob>)> = Vec::new();
        let mut queued = 0usize;
        let mut examined = 0usize;
        for k in 0..n {
            if queued >= self.queue_cap {
                break;
            }
            let s = (self.cursor + k) % n;
            examined = k + 1;
            let jobs = sessions[s].next_jobs();
            if jobs.is_empty() {
                continue;
            }
            queued += jobs.len();
            queue.push((s, jobs));
        }
        self.cursor = (self.cursor + examined) % n;
        if queue.is_empty() {
            return Ok(StepReport::default());
        }

        // resolve: cache hit, intra-step duplicate, or unique miss
        enum Resolved {
            Val(f64),
            Miss(usize),
        }
        // Ordered map (detlint `hash-collections`): keyed lookups only,
        // but miss indices feed the parallel simulation order — keep any
        // future iteration deterministic by construction.
        let mut miss_of: BTreeMap<u64, usize> = BTreeMap::new();
        let mut misses: Vec<(usize, usize)> = Vec::new(); // (queue idx, job idx)
        let mut resolved: Vec<Vec<Resolved>> = Vec::with_capacity(queue.len());
        for (qi, (_, jobs)) in queue.iter().enumerate() {
            let mut row = Vec::with_capacity(jobs.len());
            for (j, job) in jobs.iter().enumerate() {
                row.push(if let Some(v) = self.cache.get(job.key) {
                    Resolved::Val(v)
                } else if let Some(&u) = miss_of.get(&job.key) {
                    self.deduped += 1;
                    Resolved::Miss(u)
                } else {
                    let u = misses.len();
                    miss_of.insert(job.key, u);
                    misses.push((qi, j));
                    Resolved::Miss(u)
                });
            }
            resolved.push(row);
        }

        // simulate the unique misses over the once-sized arena pool.
        // Sessions hold a `Box<dyn Optimizer>` and so aren't `Sync`;
        // the parallel closure only needs the pure simulation inputs,
        // so collect those (all plain shared-read data) up front.
        let simulated = misses.len();
        let inputs: Vec<(&ClusterSpec, &WorkloadSpec, &HadoopConfig, u64)> = misses
            .iter()
            .map(|&(qi, j)| {
                let (s, jobs) = &queue[qi];
                let sess = &sessions[*s];
                let job = &jobs[j];
                (&sess.cluster, &sess.workload, &job.cfg, job.seed)
            })
            .collect();
        // each miss's retry policy is its OWNING session's — the first
        // to queue it this step; an intra-step duplicate of a miss that
        // exhausts its owner's retries fails its session too
        let owner_of: Vec<usize> = misses.iter().map(|&(qi, _)| queue[qi].0).collect();

        // panic-isolated evaluation with bounded deterministic retries:
        // round k re-runs only the evaluations that panicked, after
        // sleeping `retry_backoff_ms × k` (retries re-run the same pure
        // inputs, so a retry that succeeds is bit-identical to a first
        // try that never failed)
        let mut results: Vec<Result<f64, String>> =
            (0..simulated).map(|_| Err(String::new())).collect();
        let mut pending: Vec<usize> = (0..simulated).collect();
        let mut round = 0usize;
        while !pending.is_empty() {
            // injected faults are decided up front in deterministic
            // miss order — the parallel workers never touch shared state
            let poison: Vec<bool> = pending
                .iter()
                .map(|&u| {
                    let id = &sessions[owner_of[u]].id;
                    match self.faults.get_mut(id) {
                        Some(rem) if *rem > 0 => {
                            *rem -= 1;
                            true
                        }
                        _ => false,
                    }
                })
                .collect();
            let outs = {
                let (inputs, pending, poison) = (&inputs, &pending, &poison);
                self.pool
                    .try_scoped_run_slots(pending.len(), &mut self.arenas, move |arena, k| {
                        assert!(!poison[k], "injected evaluation fault");
                        let (cl, wl, cfg, seed) = inputs[pending[k]];
                        simulate_runtime_in(arena, cl, wl, cfg, seed)
                    })
            };
            let mut next = Vec::new();
            for (k, out) in outs.into_iter().enumerate() {
                let u = pending[k];
                match out {
                    Ok(v) => results[u] = Ok(v),
                    Err(_) if round < sessions[owner_of[u]].retry_max => next.push(u),
                    Err(p) => {
                        results[u] = Err(format!(
                            "evaluation panicked {} time(s): {}",
                            round + 1,
                            panic_text(p.as_ref()),
                        ));
                    }
                }
            }
            pending = next;
            if pending.is_empty() {
                break;
            }
            round += 1;
            let backoff = pending
                .iter()
                .map(|&u| sessions[owner_of[u]].retry_backoff_ms)
                .max()
                .unwrap_or(0);
            if backoff > 0 {
                thread::sleep(Duration::from_millis(backoff.saturating_mul(round as u64)));
            }
        }
        drop(inputs);
        for (u, r) in results.iter().enumerate() {
            if let Ok(v) = r {
                let (qi, j) = misses[u];
                self.cache.insert(queue[qi].1[j].key, *v);
            }
        }

        // deliver, per session in its ask order; a session whose slice
        // holds an exhausted-retries evaluation (or whose delivery
        // errors) fails alone — its siblings in this step still deliver
        let mut failed = 0usize;
        for (qi, (s, jobs)) in queue.iter().enumerate() {
            let mut runtimes = Vec::with_capacity(jobs.len());
            let mut err: Option<String> = None;
            for j in 0..jobs.len() {
                match &resolved[qi][j] {
                    Resolved::Val(v) => runtimes.push(*v),
                    Resolved::Miss(u) => match &results[*u] {
                        Ok(v) => runtimes.push(*v),
                        Err(e) => {
                            err = Some(e.clone());
                            break;
                        }
                    },
                }
            }
            let delivered = match err {
                Some(e) => Err(e),
                None => sessions[*s].complete(&runtimes),
            };
            if let Err(e) = delivered {
                sessions[*s].fail(e);
                failed += 1;
            }
        }
        Ok(StepReport {
            runs: queued,
            simulated,
            sessions: queue.len(),
            failed,
        })
    }

    /// Step until every session's candidate stream is exhausted.
    /// Sessions driven by external `ask`/`tell` clients are skipped (a
    /// slice they hold stays outstanding). Returns the number of steps.
    pub fn run_all(&mut self, sessions: &mut [ServeSession]) -> Result<usize, String> {
        let mut steps = 0usize;
        loop {
            let r = self.step(sessions)?;
            if r.runs == 0 {
                return Ok(steps);
            }
            steps += 1;
        }
    }

    /// The daemon's periodic stderr stats line — every counter is
    /// measured, not inferred.
    pub fn stats_line(&self, sessions: &[ServeSession]) -> String {
        let live = sessions.iter().filter(|s| !s.is_done()).count();
        let failed = sessions.iter().filter(|s| s.failed().is_some()).count();
        let s = self.cache.stats();
        format!(
            "serve: sessions={} live={} failed={} cache[entries={} cap={} hits={} misses={} evictions={} deduped={} hit_rate={:.3}]",
            sessions.len(),
            live,
            failed,
            self.cache.len(),
            self.cache.cap(),
            s.hits,
            s.misses,
            s.evictions,
            self.deduped,
            s.hit_rate(),
        )
    }
}

/// Best-effort text of a caught panic payload (`&str` and `String`
/// payloads cover `panic!`/`assert!`; anything else gets a placeholder).
fn panic_text(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

//! The daemon's evaluation engine: a bounded global work-queue drained
//! through ONE persistent [`ThreadPool`] and the global [`MemoCache`].
//!
//! One [`Dispatcher::step`] is the daemon's heartbeat: collect the next
//! job slice from every ready session round-robin (up to the queue
//! bound), resolve each job against the memo-cache, simulate only the
//! unique misses in parallel (per-worker [`SimArena`] scratch through
//! [`ThreadPool::scoped_run_slots`] — the arena pool is sized ONCE at
//! construction, which is what bounds the daemon's memory for its whole
//! lifetime), then deliver every session's runtimes in job order.
//!
//! Delivery order per session is always the session's own ask order, and
//! cached values are bit-identical to freshly simulated ones (the DES is
//! a pure function of the fingerprinted inputs) — so interleaving and
//! cache hits are invisible to any single session's outcome.

use std::collections::BTreeMap;

use crate::config::params::HadoopConfig;
use crate::hadoop::{simulate_runtime_in, ClusterSpec, SimArena};
use crate::serve::cache::{CacheStats, MemoCache};
use crate::serve::session::{EvalJob, ServeSession};
use crate::util::pool::ThreadPool;
use crate::workloads::WorkloadSpec;

/// Default bound on runs collected per step. A soft bound: a session's
/// slice is taken whole, so one step may overshoot by at most one
/// slice.
pub const DEFAULT_QUEUE_CAP: usize = 256;

/// What one [`Dispatcher::step`] did.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    /// Simulation runs delivered this step (cache hits included).
    pub runs: usize,
    /// Runs actually simulated (unique cache misses).
    pub simulated: usize,
    /// Sessions whose slice completed this step.
    pub sessions: usize,
}

pub struct Dispatcher {
    pool: ThreadPool,
    /// Per-worker simulation arenas, sized once to the pool — the
    /// daemon's simulation memory never grows with session count.
    arenas: Vec<SimArena>,
    pub cache: MemoCache,
    queue_cap: usize,
    /// Round-robin start position, so a full queue never starves the
    /// sessions at the back of the registry.
    cursor: usize,
    /// Intra-step duplicate jobs served off a miss computed in the same
    /// step (counted separately from cache hits).
    deduped: u64,
}

impl Dispatcher {
    pub fn new(threads: usize, cache_entries: usize) -> Dispatcher {
        let pool = ThreadPool::new(threads);
        let arenas = (0..pool.size()).map(|_| SimArena::new()).collect();
        Dispatcher {
            pool,
            arenas,
            cache: MemoCache::new(cache_entries),
            queue_cap: DEFAULT_QUEUE_CAP,
            cursor: 0,
            deduped: 0,
        }
    }

    pub fn with_queue_cap(mut self, cap: usize) -> Dispatcher {
        self.queue_cap = cap.max(1);
        self
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    pub fn deduped(&self) -> u64 {
        self.deduped
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// One round: ask ready sessions for jobs (bounded, round-robin),
    /// serve cache hits, simulate unique misses in parallel, deliver
    /// results in each session's ask order.
    pub fn step(&mut self, sessions: &mut [ServeSession]) -> Result<StepReport, String> {
        let n = sessions.len();
        if n == 0 {
            return Ok(StepReport::default());
        }

        // collect: whole slices, soft-bounded by queue_cap
        let mut queue: Vec<(usize, Vec<EvalJob>)> = Vec::new();
        let mut queued = 0usize;
        let mut examined = 0usize;
        for k in 0..n {
            if queued >= self.queue_cap {
                break;
            }
            let s = (self.cursor + k) % n;
            examined = k + 1;
            let jobs = sessions[s].next_jobs();
            if jobs.is_empty() {
                continue;
            }
            queued += jobs.len();
            queue.push((s, jobs));
        }
        self.cursor = (self.cursor + examined) % n;
        if queue.is_empty() {
            return Ok(StepReport::default());
        }

        // resolve: cache hit, intra-step duplicate, or unique miss
        enum Resolved {
            Val(f64),
            Miss(usize),
        }
        // Ordered map (detlint `hash-collections`): keyed lookups only,
        // but miss indices feed the parallel simulation order — keep any
        // future iteration deterministic by construction.
        let mut miss_of: BTreeMap<u64, usize> = BTreeMap::new();
        let mut misses: Vec<(usize, usize)> = Vec::new(); // (queue idx, job idx)
        let mut resolved: Vec<Vec<Resolved>> = Vec::with_capacity(queue.len());
        for (qi, (_, jobs)) in queue.iter().enumerate() {
            let mut row = Vec::with_capacity(jobs.len());
            for (j, job) in jobs.iter().enumerate() {
                row.push(if let Some(v) = self.cache.get(job.key) {
                    Resolved::Val(v)
                } else if let Some(&u) = miss_of.get(&job.key) {
                    self.deduped += 1;
                    Resolved::Miss(u)
                } else {
                    let u = misses.len();
                    miss_of.insert(job.key, u);
                    misses.push((qi, j));
                    Resolved::Miss(u)
                });
            }
            resolved.push(row);
        }

        // simulate the unique misses over the once-sized arena pool.
        // Sessions hold a `Box<dyn Optimizer>` and so aren't `Sync`;
        // the parallel closure only needs the pure simulation inputs,
        // so collect those (all plain shared-read data) up front.
        let simulated = misses.len();
        let inputs: Vec<(&ClusterSpec, &WorkloadSpec, &HadoopConfig, u64)> = misses
            .iter()
            .map(|&(qi, j)| {
                let (s, jobs) = &queue[qi];
                let sess = &sessions[*s];
                let job = &jobs[j];
                (&sess.cluster, &sess.workload, &job.cfg, job.seed)
            })
            .collect();
        let results: Vec<f64> = {
            let inputs = &inputs;
            self.pool.scoped_run_slots(simulated, &mut self.arenas, |arena, u| {
                let (cl, wl, cfg, seed) = inputs[u];
                simulate_runtime_in(arena, cl, wl, cfg, seed)
            })
        };
        drop(inputs);
        for (u, &v) in results.iter().enumerate() {
            let (qi, j) = misses[u];
            self.cache.insert(queue[qi].1[j].key, v);
        }

        // deliver, per session in its ask order
        for (qi, (s, jobs)) in queue.iter().enumerate() {
            let runtimes: Vec<f64> = (0..jobs.len())
                .map(|j| match resolved[qi][j] {
                    Resolved::Val(v) => v,
                    Resolved::Miss(u) => results[u],
                })
                .collect();
            sessions[*s].complete(&runtimes)?;
        }
        Ok(StepReport {
            runs: queued,
            simulated,
            sessions: queue.len(),
        })
    }

    /// Step until every session's candidate stream is exhausted.
    /// Sessions driven by external `ask`/`tell` clients are skipped (a
    /// slice they hold stays outstanding). Returns the number of steps.
    pub fn run_all(&mut self, sessions: &mut [ServeSession]) -> Result<usize, String> {
        let mut steps = 0usize;
        loop {
            let r = self.step(sessions)?;
            if r.runs == 0 {
                return Ok(steps);
            }
            steps += 1;
        }
    }

    /// The daemon's periodic stderr stats line — every counter is
    /// measured, not inferred.
    pub fn stats_line(&self, sessions: &[ServeSession]) -> String {
        let live = sessions.iter().filter(|s| !s.is_done()).count();
        let s = self.cache.stats();
        format!(
            "serve: sessions={} live={} cache[entries={} cap={} hits={} misses={} evictions={} deduped={} hit_rate={:.3}]",
            sessions.len(),
            live,
            self.cache.len(),
            self.cache.cap(),
            s.hits,
            s.misses,
            s.evictions,
            self.deduped,
            s.hit_rate(),
        )
    }
}

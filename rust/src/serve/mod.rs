//! Tuning-as-a-service: a long-running daemon multiplexing many
//! concurrent tuning sessions over one persistent [`ThreadPool`].
//!
//! Where `catla tune` is one process per tuning run, `catla serve` keeps
//! the simulator hot and lets any number of users (or one user's batch
//! of projects) tune concurrently:
//!
//! * [`session`] — one project's optimizer + `DriverSession` in ask/tell
//!   form, with its own deterministic seed stream and checkpoint log;
//! * [`dispatcher`] — the bounded global work-queue: collects job slices
//!   round-robin, resolves them against the memo-cache, simulates unique
//!   misses on the shared pool (per-worker arenas sized once), delivers
//!   results in ask order;
//! * [`cache`] — the global simulation memo-cache, keyed by the
//!   bit-exact (cluster, workload, config-values, seed) fingerprint and
//!   LRU-bounded;
//! * [`protocol`] — the `open`/`ask`/`tell`/`step`/`run`/`close` line
//!   protocol behind `catla serve`.
//!
//! The whole subsystem is pinned to one invariant (`rust/tests/serve.rs`):
//! a session's evaluation sequence and `TuningOutcome` are byte-identical
//! to the same spec run standalone through `Driver::run`, no matter how
//! sessions interleave or how many evaluations the cache serves.
//!
//! [`ThreadPool`]: crate::util::pool::ThreadPool

pub mod cache;
pub mod dispatcher;
pub mod protocol;
pub mod session;

pub use cache::{CacheStats, MemoCache, DEFAULT_CACHE_ENTRIES};
pub use dispatcher::{Dispatcher, StepReport, DEFAULT_QUEUE_CAP};
pub use protocol::Daemon;
pub use session::{EvalJob, ServeSession};

//! One tuning session inside the serve daemon: a project's optimizer +
//! [`DriverSession`] in ask/tell form, plus the session's own simulation
//! seed stream.
//!
//! The hard correctness bar (pinned in `rust/tests/serve.rs`): a
//! session's evaluation sequence and final `TuningOutcome` are
//! byte-identical to the same spec run standalone through
//! `Driver::run` + `ClusterObjective`, regardless of how its steps
//! interleave with other sessions or how many evaluations the global
//! memo-cache serves. Three things make that hold:
//!
//! 1. the slice stream comes from the same [`DriverSession`] machine the
//!    standalone driver runs on;
//! 2. [`ServeSession::next_jobs`] reserves simulation seeds with the
//!    exact `SimCluster::reserve_seeds` arithmetic (counter starts at
//!    the cluster spec's seed, first = counter+1, advance by
//!    `cfgs × repeats`), so job *i* of a slice gets the seed serial
//!    submission would have given it;
//! 3. [`ServeSession::complete`] folds repeats into per-config means
//!    with the exact `ClusterObjective` expression.
//!
//! Sessions checkpoint their records to a per-session tuning log after
//! every completed slice and resume through the existing replay
//! machinery (`PriorRuns` → `DriverSession::replay`), so a killed daemon
//! loses at most the in-flight slice.

use std::path::{Path, PathBuf};

use crate::catla::history::History;
use crate::catla::optimizer_runner::TuningSettings;
use crate::catla::project::Project;
use crate::catla::resume::PriorRuns;
use crate::config::params::HadoopConfig;
use crate::config::spec::TuningSpec;
use crate::hadoop::ClusterSpec;
use crate::optim::core::{DriverSession, EarlyStop};
use crate::optim::{EvalRecord, Method, Optimizer, ParamSpace, TuningOutcome};
use crate::util::csv::Csv;
use crate::util::fingerprint::eval_fingerprint;
use crate::workloads::WorkloadSpec;

/// One simulation run a session wants evaluated: the memo-cache key, the
/// decoded config and the reserved seed. The owning session's cluster
/// and workload specs complete the simulation inputs.
pub struct EvalJob {
    pub key: u64,
    pub cfg: HadoopConfig,
    pub seed: u64,
}

/// What kind of evaluation the outstanding slice is waiting on.
enum Flight {
    /// Simulator jobs dispatched through the daemon (`runs` runtimes
    /// expected: one per config × repeat).
    Sim { runs: usize },
    /// Externally measured values (`ask`/`tell` protocol lines): one
    /// value per config, no simulator seeds consumed.
    External,
}

pub struct ServeSession {
    pub id: String,
    dir: Option<PathBuf>,
    log_name: String,
    spec: TuningSpec,
    space: ParamSpace,
    opt: Box<dyn Optimizer>,
    driver: DriverSession,
    pub cluster: ClusterSpec,
    pub workload: WorkloadSpec,
    repeats: usize,
    seed_counter: u64,
    /// Optimizer label recorded into logs and the outcome — the bare
    /// method name for fresh sessions (matching standalone `Driver::run`
    /// byte-for-byte), the `[resumed@n]` form for resumed ones.
    label: String,
    /// Spec typo-guard diagnostics, captured ONCE at session creation.
    /// Emission is the daemon's job (also once, at `open`) — replay,
    /// ask and step paths never re-surface them.
    warnings: Vec<String>,
    /// The project's `serve.cache_entries` request, if any.
    pub cache_entries: Option<usize>,
    /// Dispatcher retry budget for this session's evaluations
    /// (`serve.retry.max`): how many times a panicked evaluation is
    /// re-run before the session fails.
    pub retry_max: usize,
    /// Base retry backoff in ms (`serve.retry.backoff_ms`), scaled
    /// linearly by retry number by the dispatcher.
    pub retry_backoff_ms: u64,
    in_flight: Option<Flight>,
    finalized: bool,
    /// Terminal failure (evaluation retries exhausted, or a delivery
    /// error): the session stops asking, and the reason is surfaced
    /// over the line protocol. Sibling sessions are unaffected.
    failed: Option<String>,
}

impl ServeSession {
    /// Build a session from parts, without touching the filesystem (no
    /// checkpointing) — the serve bench drives a thousand of these.
    pub fn new(
        id: &str,
        spec: TuningSpec,
        base: HadoopConfig,
        cluster: ClusterSpec,
        workload: WorkloadSpec,
        settings: &TuningSettings,
    ) -> Result<ServeSession, String> {
        Self::with_prior(id, spec, base, cluster, workload, settings, &[])
    }

    /// [`ServeSession::new`] resuming from replayed prior evaluations.
    /// The budget covers prior + new evaluations and is clamped up to
    /// the prior count, exactly like `resume_tuning` — logged
    /// evaluations are never dropped.
    pub fn with_prior(
        id: &str,
        spec: TuningSpec,
        base: HadoopConfig,
        cluster: ClusterSpec,
        workload: WorkloadSpec,
        settings: &TuningSettings,
        prior: &[EvalRecord],
    ) -> Result<ServeSession, String> {
        if settings.prescreen {
            return Err("serve sessions do not support prescreen=auto (no surrogate scorer)".into());
        }
        if spec.dims() == 0 {
            return Err(format!(
                "params.spec declares no parameters for workload {:?}",
                workload.name
            ));
        }
        // dedupe at the session-creation boundary: however many parse
        // paths contributed diagnostics, each distinct warning is held
        // (and later emitted) once per loaded session
        let mut warnings: Vec<String> = Vec::new();
        for w in &spec.warnings {
            if !warnings.contains(w) {
                warnings.push(w.clone());
            }
        }
        let mut opt = Method::from_name(&settings.optimizer, settings.seed)?.build();
        let base_label = opt.name().to_string();
        let label = if prior.is_empty() {
            base_label
        } else if prior.len() >= settings.budget {
            format!("{base_label}[resumed,exhausted]")
        } else {
            format!("{base_label}[resumed@{}]", prior.len())
        };
        let early = if settings.early_patience > 0 {
            Some(EarlyStop {
                patience: settings.early_patience,
                min_rel: settings.early_tol,
            })
        } else {
            None
        };
        let budget = settings.budget.max(prior.len());
        let mut driver = DriverSession::new(budget, early, settings.batch_chunk);
        driver.replay(opt.as_mut(), prior);
        let seed_counter = cluster.seed;
        Ok(ServeSession {
            id: id.to_string(),
            dir: None,
            log_name: crate::catla::history::TUNING_CSV.to_string(),
            space: ParamSpace::new(spec.clone(), base),
            spec,
            opt,
            driver,
            cluster,
            workload,
            repeats: settings.repeats.max(1),
            seed_counter,
            label,
            warnings,
            cache_entries: settings.cache_entries,
            retry_max: settings.retry_max,
            retry_backoff_ms: settings.retry_backoff_ms,
            in_flight: None,
            finalized: false,
            failed: None,
        })
    }

    /// Open a session over a tuning project directory, checkpointing to
    /// `history/<log_name>` and resuming from it when it already exists.
    pub fn open(dir: &Path, id: &str, log_name: &str) -> Result<ServeSession, String> {
        let project = Project::load(dir)?;
        let settings = TuningSettings::from_project(&project)?;
        let spec = project
            .spec
            .clone()
            .ok_or("not a tuning project (missing params.spec)")?;
        let base = project.base_config()?;
        let cluster = ClusterSpec::from_env(&project.env);
        let workload = project.workload()?;
        // the scoped aggregate carries the per-block diagnostics the
        // flat spec may not; prefer it when present (same source the
        // CLI's print_spec_warnings uses)
        let scoped_warnings = project
            .scoped
            .as_ref()
            .map(|s| s.warnings.clone())
            .unwrap_or_default();
        let log_path = dir.join("history").join(log_name);
        let prior = if log_path.is_file() {
            let csv = Csv::load(&log_path)?;
            let space = ParamSpace::new(spec.clone(), base.clone());
            PriorRuns::from_log(&csv, &spec)?.to_records(&space)?
        } else {
            Vec::new()
        };
        let mut sess =
            Self::with_prior(id, spec, base, cluster, workload, &settings, &prior)?;
        if !scoped_warnings.is_empty() {
            let mut warnings: Vec<String> = Vec::new();
            for w in scoped_warnings {
                if !warnings.contains(&w) {
                    warnings.push(w);
                }
            }
            sess.warnings = warnings;
        }
        sess.dir = Some(dir.to_path_buf());
        sess.log_name = log_name.to_string();
        Ok(sess)
    }

    /// Spec diagnostics to surface once per loaded session.
    pub fn warnings(&self) -> &[String] {
        &self.warnings
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn log_name(&self) -> &str {
        &self.log_name
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    pub fn evals(&self) -> usize {
        self.driver.evals()
    }

    pub fn best_value(&self) -> Option<f64> {
        self.driver.best_value()
    }

    /// The run is over and nothing is in flight. Note this only flips
    /// after a `next_jobs`/`ask_configs` call observed the end of the
    /// candidate stream — or the session failed terminally.
    pub fn is_done(&self) -> bool {
        self.failed.is_some()
            || self.finalized
            || (self.driver.is_done() && self.in_flight.is_none())
    }

    /// Why the session is in its `Failed` terminal state, if it is.
    pub fn failed(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    /// Move the session to its `Failed` terminal state (first reason
    /// wins): the outstanding slice is dropped, no further candidates
    /// are asked, and `is_done` reports true. The checkpoint log keeps
    /// every slice completed before the failure, so a re-opened session
    /// resumes from there.
    pub fn fail(&mut self, reason: String) {
        self.in_flight = None;
        if self.failed.is_none() {
            self.failed = Some(reason);
        }
    }

    /// The next slice of simulation jobs this session wants evaluated,
    /// with seeds reserved exactly like serial submission. Empty while a
    /// slice is outstanding, or once the run is over.
    pub fn next_jobs(&mut self) -> Vec<EvalJob> {
        if self.in_flight.is_some() || self.finalized || self.failed.is_some() {
            return Vec::new();
        }
        let cfgs: Vec<HadoopConfig> = match self.driver.next_slice(self.opt.as_mut(), &self.space)
        {
            Some(s) => s.to_vec(),
            None => return Vec::new(),
        };
        let runs = cfgs.len() * self.repeats;
        // SimCluster::reserve_seeds, verbatim: first = counter+1, then
        // advance by the run count
        let first = self.seed_counter.wrapping_add(1);
        self.seed_counter = self.seed_counter.wrapping_add(runs as u64);
        let jobs = (0..runs)
            .map(|i| {
                let cfg = &cfgs[i / self.repeats];
                let seed = first.wrapping_add(i as u64);
                EvalJob {
                    key: eval_fingerprint(&self.cluster, &self.workload, cfg, seed),
                    cfg: cfg.clone(),
                    seed,
                }
            })
            .collect();
        self.in_flight = Some(Flight::Sim { runs });
        jobs
    }

    /// Deliver the runtimes for the outstanding [`ServeSession::next_jobs`]
    /// slice (in job order), fold repeats into per-config means exactly
    /// like `ClusterObjective`, tell the optimizer, and checkpoint.
    pub fn complete(&mut self, runtimes: &[f64]) -> Result<(), String> {
        match self.in_flight.take() {
            Some(Flight::Sim { runs }) => {
                if runtimes.len() != runs {
                    return Err(format!(
                        "session {}: {} runtimes delivered for {} dispatched runs",
                        self.id,
                        runtimes.len(),
                        runs
                    ));
                }
                let vals: Vec<f64> = runtimes
                    .chunks(self.repeats)
                    .map(|c| c.iter().sum::<f64>() / self.repeats as f64)
                    .collect();
                self.driver.tell_values(self.opt.as_mut(), &vals, &mut [])?;
                self.checkpoint()
            }
            other => {
                self.in_flight = other;
                Err(format!("session {}: complete without dispatched jobs", self.id))
            }
        }
    }

    /// Manual ask (protocol `ask` line): the next slice of decoded
    /// configs for an external client to measure. No simulator seeds are
    /// consumed — a session driven this way is measured outside the DES,
    /// so the standalone-simulation byte-identity bar does not apply.
    pub fn ask_configs(&mut self) -> Vec<HadoopConfig> {
        if self.in_flight.is_some() || self.finalized || self.failed.is_some() {
            return Vec::new();
        }
        let cfgs = match self.driver.next_slice(self.opt.as_mut(), &self.space) {
            Some(s) => s.to_vec(),
            None => return Vec::new(),
        };
        self.in_flight = Some(Flight::External);
        cfgs
    }

    /// Manual tell (protocol `tell` line): one externally measured value
    /// per config of the outstanding `ask` slice.
    pub fn tell_external(&mut self, vals: &[f64]) -> Result<(), String> {
        match self.in_flight.take() {
            Some(Flight::External) => {
                self.driver.tell_values(self.opt.as_mut(), vals, &mut [])?;
                self.checkpoint()
            }
            other => {
                self.in_flight = other;
                Err(format!("session {}: tell without an outstanding ask", self.id))
            }
        }
    }

    /// Write the running records to the session's tuning log (no-op for
    /// filesystem-less sessions).
    fn checkpoint(&self) -> Result<(), String> {
        let Some(dir) = &self.dir else {
            return Ok(());
        };
        let history = History::open(dir).map_err(|e| e.to_string())?;
        history.write_tuning_records_to(&self.log_name, &self.spec, &self.label, self.driver.records())?;
        Ok(())
    }

    /// Snapshot the outcome so far (errors if nothing was evaluated).
    pub fn outcome(&self) -> Result<TuningOutcome, String> {
        self.driver.outcome(&self.label)
    }

    /// Finalize: write the tuning log and summary row (project-backed
    /// sessions), mark the session closed, and return the outcome.
    pub fn finalize(&mut self) -> Result<TuningOutcome, String> {
        if let Some(reason) = &self.failed {
            return Err(format!("session {} failed: {reason}", self.id));
        }
        let outcome = self.driver.outcome(&self.label)?;
        if let Some(dir) = &self.dir {
            let history = History::open(dir).map_err(|e| e.to_string())?;
            history.write_tuning_log_to(&self.log_name, &self.spec, &outcome)?;
            history.append_summary(&self.spec, &outcome)?;
        }
        self.finalized = true;
        Ok(outcome)
    }
}

//! One tuning session inside the serve daemon: a project's optimizer +
//! [`DriverSession`] in ask/tell form, plus the session's own simulation
//! seed stream.
//!
//! The hard correctness bar (pinned in `rust/tests/serve.rs`): a
//! session's evaluation sequence and final `TuningOutcome` are
//! byte-identical to the same spec run standalone through
//! `Driver::run` + `ClusterObjective`, regardless of how its steps
//! interleave with other sessions or how many evaluations the global
//! memo-cache serves. Three things make that hold:
//!
//! 1. the slice stream comes from the same [`DriverSession`] machine the
//!    standalone driver runs on;
//! 2. [`ServeSession::next_jobs`] reserves simulation seeds with the
//!    exact `SimCluster::reserve_seeds` arithmetic (counter starts at
//!    the cluster spec's seed, first = counter+1, advance by
//!    `cfgs × repeats`), so job *i* of a slice gets the seed serial
//!    submission would have given it;
//! 3. [`ServeSession::complete`] folds repeats into per-config means
//!    with the exact `ClusterObjective` expression.
//!
//! Sessions checkpoint by appending one CRC-trailered record per
//! completed slice to `history/<log>.journal` (see [`crate::catla::journal`])
//! — O(1) bytes per slice instead of the old rewrite-the-whole-CSV
//! checkpoint. A killed daemon loses at most the in-flight slice:
//! [`ServeSession::open`] re-drives the journal through a fresh
//! optimizer (verifying every re-asked config bit-for-bit), so the
//! recovered session is in the *identical* optimizer state and its
//! final outcome is byte-identical to an uninterrupted run — pinned by
//! the crash matrix in `rust/tests/crash_matrix.rs`.

use std::path::{Path, PathBuf};

use crate::catla::history::History;
use crate::catla::journal::{self, Journal};
use crate::catla::optimizer_runner::{cost_model_blind_params, TuningSettings};
use crate::catla::project::Project;
use crate::catla::resume::PriorRuns;
use crate::config::params::HadoopConfig;
use crate::config::spec::TuningSpec;
use crate::hadoop::{costmodel, ClusterSpec};
use crate::optim::core::{DriverSession, EarlyStop};
use crate::optim::racing::{Race, RacingSettings};
use crate::optim::result::Fidelity;
use crate::optim::{EvalRecord, Method, Optimizer, ParamSpace, TuningOutcome};
use crate::util::csv::Csv;
use crate::util::fingerprint::eval_fingerprint;
use crate::util::{crashpoint, durable};
use crate::workloads::WorkloadSpec;

/// One simulation run a session wants evaluated: the memo-cache key, the
/// decoded config and the reserved seed. The owning session's cluster
/// and workload specs complete the simulation inputs.
pub struct EvalJob {
    pub key: u64,
    pub cfg: HadoopConfig,
    pub seed: u64,
}

/// What kind of evaluation the outstanding slice is waiting on. Both
/// variants keep the slice's decoded configs so the checkpoint journal
/// can record them (for bitwise verification on recovery).
enum Flight {
    /// Simulator jobs dispatched through the daemon (`runs` runtimes
    /// expected: one per config × repeat).
    Sim { runs: usize, cfgs: Vec<HadoopConfig> },
    /// A multi-fidelity race over the slice (`racing.enabled=true`): the
    /// [`Race`] planner decides which of the slice's reserved seeds are
    /// simulated, one dispatched wave per tier. `dispatched` is `None`
    /// between tiers — the next [`ServeSession::next_jobs`] call hands
    /// out the current tier's pending runs.
    Race {
        race: Race,
        cfgs: Vec<HadoopConfig>,
        /// First seed of the slice's reserved `cfgs × repeats` block.
        first: u64,
        dispatched: Option<usize>,
    },
    /// Externally measured values (`ask`/`tell` protocol lines): one
    /// value per config, no simulator seeds consumed.
    External { cfgs: Vec<HadoopConfig> },
}

pub struct ServeSession {
    pub id: String,
    dir: Option<PathBuf>,
    log_name: String,
    spec: TuningSpec,
    space: ParamSpace,
    opt: Box<dyn Optimizer>,
    driver: DriverSession,
    pub cluster: ClusterSpec,
    pub workload: WorkloadSpec,
    repeats: usize,
    seed_counter: u64,
    /// Optimizer label recorded into logs and the outcome — the bare
    /// method name for fresh sessions (matching standalone `Driver::run`
    /// byte-for-byte), the `[resumed@n]` form for resumed ones.
    label: String,
    /// Spec typo-guard diagnostics, captured ONCE at session creation.
    /// Emission is the daemon's job (also once, at `open`) — replay,
    /// ask and step paths never re-surface them.
    warnings: Vec<String>,
    /// The project's `serve.cache_entries` request, if any.
    pub cache_entries: Option<usize>,
    /// Dispatcher retry budget for this session's evaluations
    /// (`serve.retry.max`): how many times a panicked evaluation is
    /// re-run before the session fails.
    pub retry_max: usize,
    /// Base retry backoff in ms (`serve.retry.backoff_ms`), scaled
    /// linearly by retry number by the dispatcher.
    pub retry_backoff_ms: u64,
    /// Multi-fidelity racing knobs (`racing.*` in tuning.properties).
    racing: RacingSettings,
    /// Tier 0 is usable: every tuned parameter is cost-model-mapped.
    /// With a blind param in the spec the race starts at tier 1.
    tier0_ok: bool,
    /// Pre-rendered journal header record (see [`journal::header_payload`]),
    /// appended lazily before the first checkpointed slice.
    header_payload: String,
    /// The journal file exists on disk with its header written (either
    /// this session appended it, or recovery found it).
    journal_started: bool,
    in_flight: Option<Flight>,
    finalized: bool,
    /// Terminal failure (evaluation retries exhausted, or a delivery
    /// error): the session stops asking, and the reason is surfaced
    /// over the line protocol. Sibling sessions are unaffected.
    failed: Option<String>,
}

impl ServeSession {
    /// Build a session from parts, without touching the filesystem (no
    /// checkpointing) — the serve bench drives a thousand of these.
    pub fn new(
        id: &str,
        spec: TuningSpec,
        base: HadoopConfig,
        cluster: ClusterSpec,
        workload: WorkloadSpec,
        settings: &TuningSettings,
    ) -> Result<ServeSession, String> {
        Self::with_prior(id, spec, base, cluster, workload, settings, &[])
    }

    /// [`ServeSession::new`] resuming from replayed prior evaluations.
    /// The budget covers prior + new evaluations and is clamped up to
    /// the prior count, exactly like `resume_tuning` — logged
    /// evaluations are never dropped.
    pub fn with_prior(
        id: &str,
        spec: TuningSpec,
        base: HadoopConfig,
        cluster: ClusterSpec,
        workload: WorkloadSpec,
        settings: &TuningSettings,
        prior: &[EvalRecord],
    ) -> Result<ServeSession, String> {
        if settings.prescreen {
            return Err("serve sessions do not support prescreen=auto (no surrogate scorer)".into());
        }
        if spec.dims() == 0 {
            return Err(format!(
                "params.spec declares no parameters for workload {:?}",
                workload.name
            ));
        }
        // dedupe at the session-creation boundary: however many parse
        // paths contributed diagnostics, each distinct warning is held
        // (and later emitted) once per loaded session
        let mut warnings: Vec<String> = Vec::new();
        for w in &spec.warnings {
            if !warnings.contains(w) {
                warnings.push(w.clone());
            }
        }
        let mut opt = Method::from_name(&settings.optimizer, settings.seed)?.build();
        let base_label = opt.name().to_string();
        let label = if prior.is_empty() {
            base_label
        } else if prior.len() >= settings.budget {
            format!("{base_label}[resumed,exhausted]")
        } else {
            format!("{base_label}[resumed@{}]", prior.len())
        };
        let early = if settings.early_patience > 0 {
            Some(EarlyStop {
                patience: settings.early_patience,
                min_rel: settings.early_tol,
            })
        } else {
            None
        };
        let budget = settings.budget.max(prior.len());
        let mut driver = DriverSession::new(budget, early, settings.batch_chunk);
        driver.replay(opt.as_mut(), prior);
        let seed_counter = cluster.seed;
        let header_payload = journal::header_payload(settings, &label, &spec, prior.len());
        let tier0_ok = cost_model_blind_params(&spec).is_empty();
        Ok(ServeSession {
            id: id.to_string(),
            dir: None,
            log_name: crate::catla::history::TUNING_CSV.to_string(),
            space: ParamSpace::new(spec.clone(), base),
            spec,
            opt,
            driver,
            cluster,
            workload,
            repeats: settings.repeats.max(1),
            seed_counter,
            label,
            warnings,
            cache_entries: settings.cache_entries,
            retry_max: settings.retry_max,
            retry_backoff_ms: settings.retry_backoff_ms,
            racing: settings.racing,
            tier0_ok,
            header_payload,
            journal_started: false,
            in_flight: None,
            finalized: false,
            failed: None,
        })
    }

    /// Open a session over a tuning project directory, checkpointing to
    /// `history/<log_name>.journal` and recovering from whatever a
    /// previous (possibly killed) daemon left behind:
    ///
    /// * journal present → re-drive it (see the module docs): replay the
    ///   CSV prior it declares, then re-ask the optimizer slice by
    ///   slice, verifying configs bitwise and telling the journaled
    ///   values. The recovered session keeps its original label, so its
    ///   outcome is byte-identical to an uninterrupted run. A torn final
    ///   record (the crash hit mid-append) is truncated with a one-line
    ///   warning; mid-file corruption or changed settings are hard
    ///   errors. A `fin`-marked journal means the final log is already
    ///   durable: the summary row is appended only if missing and the
    ///   journal retired.
    /// * no journal, tuning log present → legacy resume through
    ///   `PriorRuns` replay with the `[resumed@n]` label (the log alone
    ///   cannot reconstruct optimizer state); a torn final CSV line is
    ///   dropped with a warning.
    pub fn open(dir: &Path, id: &str, log_name: &str) -> Result<ServeSession, String> {
        let project = Project::load(dir)?;
        let settings = TuningSettings::from_project(&project)?;
        let spec = project
            .spec
            .clone()
            .ok_or("not a tuning project (missing params.spec)")?;
        let base = project.base_config()?;
        let cluster = ClusterSpec::from_env(&project.env);
        let workload = project.workload()?;
        // the scoped aggregate carries the per-block diagnostics the
        // flat spec may not; prefer it when present (same source the
        // CLI's print_spec_warnings uses)
        let scoped_warnings = project
            .scoped
            .as_ref()
            .map(|s| s.warnings.clone())
            .unwrap_or_default();
        let hist_dir = dir.join("history");
        let log_path = hist_dir.join(log_name);
        let jpath = journal::journal_path(&hist_dir, log_name);
        let mut recovery: Vec<String> = Vec::new();

        let jrnl = if jpath.is_file() {
            match Journal::load(&jpath)? {
                Some(j) => {
                    j.check_header(&settings, &spec)
                        .map_err(|e| format!("{}: {e}", jpath.display()))?;
                    if j.torn_bytes > 0 {
                        durable::truncate_to(&jpath, j.clean_len).map_err(|e| e.to_string())?;
                        recovery.push(format!(
                            "{}: dropped torn final journal record ({} bytes) — crash mid-append",
                            jpath.display(),
                            j.torn_bytes
                        ));
                    }
                    Some(j)
                }
                None => {
                    // the crash tore the very first (header) append;
                    // nothing was checkpointed, start fresh
                    std::fs::remove_file(&jpath).map_err(|e| e.to_string())?;
                    recovery.push(format!(
                        "{}: discarded unreadable journal (no complete record survived)",
                        jpath.display()
                    ));
                    None
                }
            }
        } else {
            None
        };

        let space = ParamSpace::new(spec.clone(), base.clone());
        let mut load_prior = |expect: Option<usize>| -> Result<Vec<EvalRecord>, String> {
            let (mut csv, warn) = Csv::load_tolerant(&log_path)?;
            if let Some(w) = warn {
                recovery.push(w);
            }
            if let Some(n) = expect {
                if csv.rows.len() < n {
                    return Err(format!(
                        "{}: journal expects {} prior rows but the log has only {} — \
                         history was modified; run `catla fsck {}`",
                        log_path.display(),
                        n,
                        csv.rows.len(),
                        dir.display()
                    ));
                }
                csv.rows.truncate(n);
            }
            PriorRuns::from_log(&csv, &spec)?.to_records(&space)
        };
        let prior = match &jrnl {
            // only the CSV prefix the crashed session itself replayed
            // counts as prior — everything after it re-drives from the
            // journal (the CSV may also hold a full finalize rewrite)
            Some(j) if j.header.prior > 0 => load_prior(Some(j.header.prior))?,
            Some(_) => Vec::new(),
            None if log_path.is_file() => load_prior(None)?,
            None => Vec::new(),
        };

        let mut sess = Self::with_prior(id, spec, base, cluster, workload, &settings, &prior)?;
        if !scoped_warnings.is_empty() {
            let mut warnings: Vec<String> = Vec::new();
            for w in scoped_warnings {
                if !warnings.contains(&w) {
                    warnings.push(w);
                }
            }
            sess.warnings = warnings;
        }
        sess.dir = Some(dir.to_path_buf());
        sess.log_name = log_name.to_string();

        if let Some(j) = jrnl {
            // the original label (not `[resumed@n]`): the re-driven
            // optimizer is in the exact crashed state, so the session
            // IS the original one, continued
            sess.label = j.header.label.clone();
            sess.header_payload =
                journal::header_payload(&settings, &sess.label, &sess.spec, j.header.prior);
            sess.journal_started = true;
            for (i, slice) in j.slices.iter().enumerate() {
                sess.redrive_slice(slice)
                    .map_err(|e| format!("{}: slice {}: {e}", jpath.display(), i + 1))?;
            }
            if j.finalized {
                // fin is appended only after the final log write
                // completed, so only the summary row is in doubt
                let history = History::open(dir).map_err(|e| e.to_string())?;
                let outcome = sess.driver.outcome(&sess.label)?;
                history.append_summary_if_missing(&sess.spec, &outcome)?;
                std::fs::remove_file(&jpath).map_err(|e| e.to_string())?;
                durable::fsync_dir(&hist_dir);
                sess.journal_started = false;
                sess.finalized = true;
            }
        }
        for w in recovery {
            if !sess.warnings.contains(&w) {
                sess.warnings.push(w);
            }
        }
        Ok(sess)
    }

    /// Recovery step: re-ask the optimizer for the next slice, verify it
    /// bit-for-bit against the journal record, advance the seed stream
    /// exactly as the original dispatch did, and tell the journaled
    /// values back. Any divergence is a hard error — it means the
    /// journal was written under different code or inputs, and silently
    /// continuing would break the byte-identity contract.
    fn redrive_slice(&mut self, slice: &journal::JournalSlice) -> Result<(), String> {
        let cfgs: Vec<HadoopConfig> = self
            .driver
            .next_slice(self.opt.as_mut(), &self.space)
            .ok_or("journal holds more slices than the optimizer re-asks — settings or code drift")?
            .to_vec();
        if cfgs.len() != slice.evals.len() {
            return Err(format!(
                "re-asked slice has {} configs, journal recorded {}",
                cfgs.len(),
                slice.evals.len()
            ));
        }
        for (k, (cfg, (_, _, logged))) in cfgs.iter().zip(&slice.evals).enumerate() {
            for (r, logged_v) in self.spec.ranges.iter().zip(logged) {
                if cfg.get(r.index).to_bits() != logged_v.to_bits() {
                    return Err(format!(
                        "config {} param {} diverged on re-ask ({} vs journaled {})",
                        k + 1,
                        r.name(),
                        cfg.get(r.index),
                        logged_v
                    ));
                }
            }
        }
        if !slice.external {
            // SimCluster::reserve_seeds arithmetic, replayed without
            // dispatching: the next real slice gets the same seeds it
            // would have in the uninterrupted run
            let runs = cfgs.len() * self.repeats;
            self.seed_counter = self.seed_counter.wrapping_add(runs as u64);
        }
        let vals: Vec<f64> = slice.evals.iter().map(|e| e.0).collect();
        let fids: Vec<Fidelity> = slice.evals.iter().map(|e| e.1).collect();
        self.driver
            .tell_values_tiered(self.opt.as_mut(), &vals, &fids, &mut [])
    }

    /// Spec diagnostics to surface once per loaded session.
    pub fn warnings(&self) -> &[String] {
        &self.warnings
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn log_name(&self) -> &str {
        &self.log_name
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    pub fn evals(&self) -> usize {
        self.driver.evals()
    }

    pub fn best_value(&self) -> Option<f64> {
        self.driver.best_value()
    }

    /// The run is over and nothing is in flight. Note this only flips
    /// after a `next_jobs`/`ask_configs` call observed the end of the
    /// candidate stream — or the session failed terminally.
    pub fn is_done(&self) -> bool {
        self.failed.is_some()
            || self.finalized
            || (self.driver.is_done() && self.in_flight.is_none())
    }

    /// Why the session is in its `Failed` terminal state, if it is.
    pub fn failed(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    /// Move the session to its `Failed` terminal state (first reason
    /// wins): the outstanding slice is dropped, no further candidates
    /// are asked, and `is_done` reports true. The checkpoint log keeps
    /// every slice completed before the failure, so a re-opened session
    /// resumes from there.
    pub fn fail(&mut self, reason: String) {
        self.in_flight = None;
        if self.failed.is_none() {
            self.failed = Some(reason);
        }
    }

    /// The next slice of simulation jobs this session wants evaluated,
    /// with seeds reserved exactly like serial submission. Empty while a
    /// slice is outstanding, or once the run is over.
    pub fn next_jobs(&mut self) -> Vec<EvalJob> {
        if self.finalized || self.failed.is_some() {
            return Vec::new();
        }
        // a multi-tier race re-arms between tiers: hand out the current
        // tier's pending runs before asking the optimizer for anything
        if let Some(Flight::Race {
            race,
            cfgs,
            first,
            dispatched,
        }) = &mut self.in_flight
        {
            if dispatched.is_none() {
                let jobs = Self::race_jobs(
                    race,
                    cfgs,
                    *first,
                    self.repeats,
                    &self.cluster,
                    &self.workload,
                );
                *dispatched = Some(jobs.len());
                return jobs;
            }
            return Vec::new();
        }
        if self.in_flight.is_some() {
            return Vec::new();
        }
        let cfgs: Vec<HadoopConfig> = match self.driver.next_slice(self.opt.as_mut(), &self.space)
        {
            Some(s) => s.to_vec(),
            None => return Vec::new(),
        };
        let runs = cfgs.len() * self.repeats;
        // SimCluster::reserve_seeds, verbatim: first = counter+1, then
        // advance by the run count. Racing reserves the IDENTICAL full
        // block — it only chooses which reserved seeds get simulated, so
        // the seed stream advance matches the racing-off session exactly.
        let first = self.seed_counter.wrapping_add(1);
        self.seed_counter = self.seed_counter.wrapping_add(runs as u64);
        if self.racing.enabled {
            let model_scores = if self.tier0_ok {
                Some(
                    cfgs.iter()
                        .map(|c| costmodel::predict_runtime(c, &self.workload, &self.cluster))
                        .collect(),
                )
            } else {
                None
            };
            let race = Race::new(cfgs.len(), self.repeats, &self.racing, model_scores);
            let jobs = Self::race_jobs(
                &race,
                &cfgs,
                first,
                self.repeats,
                &self.cluster,
                &self.workload,
            );
            self.in_flight = Some(Flight::Race {
                dispatched: Some(jobs.len()),
                race,
                cfgs,
                first,
            });
            return jobs;
        }
        let jobs = (0..runs)
            .map(|i| {
                let cfg = &cfgs[i / self.repeats];
                let seed = first.wrapping_add(i as u64);
                EvalJob {
                    key: eval_fingerprint(&self.cluster, &self.workload, cfg, seed),
                    cfg: cfg.clone(),
                    seed,
                }
            })
            .collect();
        self.in_flight = Some(Flight::Sim { runs, cfgs });
        jobs
    }

    /// Jobs for the current tier of a race: each pending (cfg, rep)
    /// maps to seed offset `cfg × repeats + rep` of the slice's reserved
    /// block — the same seed that run gets in the standalone
    /// `RacingObjective`, and in a racing-off session's full sweep.
    fn race_jobs(
        race: &Race,
        cfgs: &[HadoopConfig],
        first: u64,
        repeats: usize,
        cluster: &ClusterSpec,
        workload: &WorkloadSpec,
    ) -> Vec<EvalJob> {
        race.pending()
            .iter()
            .map(|r| {
                let cfg = &cfgs[r.cfg];
                let seed = first.wrapping_add((r.cfg * repeats + r.rep) as u64);
                EvalJob {
                    key: eval_fingerprint(cluster, workload, cfg, seed),
                    cfg: cfg.clone(),
                    seed,
                }
            })
            .collect()
    }

    /// Deliver the runtimes for the outstanding [`ServeSession::next_jobs`]
    /// slice (in job order), fold repeats into per-config means exactly
    /// like `ClusterObjective`, tell the optimizer, and checkpoint.
    pub fn complete(&mut self, runtimes: &[f64]) -> Result<(), String> {
        match self.in_flight.take() {
            Some(Flight::Sim { runs, cfgs }) => {
                if runtimes.len() != runs {
                    self.in_flight = Some(Flight::Sim { runs, cfgs });
                    return Err(format!(
                        "session {}: {} runtimes delivered for {} dispatched runs",
                        self.id,
                        runtimes.len(),
                        runs
                    ));
                }
                let vals: Vec<f64> = runtimes
                    .chunks(self.repeats)
                    .map(|c| c.iter().sum::<f64>() / self.repeats as f64)
                    .collect();
                self.driver.tell_values(self.opt.as_mut(), &vals, &mut [])?;
                let fids = vec![Fidelity::Full; vals.len()];
                self.checkpoint(false, &cfgs, &vals, &fids)
            }
            Some(Flight::Race {
                mut race,
                cfgs,
                first,
                dispatched,
            }) => {
                if dispatched.is_none() || runtimes.len() != race.pending().len() {
                    let msg = if dispatched.is_none() {
                        format!("session {}: complete without dispatched jobs", self.id)
                    } else {
                        format!(
                            "session {}: {} runtimes delivered for {} dispatched runs",
                            self.id,
                            runtimes.len(),
                            race.pending().len()
                        )
                    };
                    self.in_flight = Some(Flight::Race {
                        race,
                        cfgs,
                        first,
                        dispatched,
                    });
                    return Err(msg);
                }
                race.absorb(runtimes)?;
                if race.is_finished() {
                    let (vals, fids) = race.values();
                    self.driver
                        .tell_values_tiered(self.opt.as_mut(), &vals, &fids, &mut [])?;
                    self.checkpoint(false, &cfgs, &vals, &fids)
                } else {
                    // re-arm: the next tier's runs go out on the next
                    // next_jobs call
                    self.in_flight = Some(Flight::Race {
                        race,
                        cfgs,
                        first,
                        dispatched: None,
                    });
                    Ok(())
                }
            }
            other => {
                self.in_flight = other;
                Err(format!("session {}: complete without dispatched jobs", self.id))
            }
        }
    }

    /// Manual ask (protocol `ask` line): the next slice of decoded
    /// configs for an external client to measure. No simulator seeds are
    /// consumed — a session driven this way is measured outside the DES,
    /// so the standalone-simulation byte-identity bar does not apply.
    pub fn ask_configs(&mut self) -> Vec<HadoopConfig> {
        if self.in_flight.is_some() || self.finalized || self.failed.is_some() {
            return Vec::new();
        }
        let cfgs = match self.driver.next_slice(self.opt.as_mut(), &self.space) {
            Some(s) => s.to_vec(),
            None => return Vec::new(),
        };
        self.in_flight = Some(Flight::External { cfgs: cfgs.clone() });
        cfgs
    }

    /// Manual tell (protocol `tell` line): one externally measured value
    /// per config of the outstanding `ask` slice.
    pub fn tell_external(&mut self, vals: &[f64]) -> Result<(), String> {
        match self.in_flight.take() {
            Some(Flight::External { cfgs }) => {
                self.driver.tell_values(self.opt.as_mut(), vals, &mut [])?;
                let fids = vec![Fidelity::Full; vals.len()];
                self.checkpoint(true, &cfgs, vals, &fids)
            }
            other => {
                self.in_flight = other;
                Err(format!("session {}: tell without an outstanding ask", self.id))
            }
        }
    }

    /// Journal the just-told slice (no-op for filesystem-less sessions):
    /// one durable O_APPEND record, preceded once by the header record.
    /// Replaces the old full-log rewrite — O(1) bytes per checkpoint
    /// instead of O(evals), and a torn write can only ever damage the
    /// final record, which recovery truncates.
    fn checkpoint(
        &mut self,
        external: bool,
        cfgs: &[HadoopConfig],
        vals: &[f64],
        fids: &[Fidelity],
    ) -> Result<(), String> {
        let Some(dir) = &self.dir else {
            return Ok(());
        };
        let history = History::open(dir).map_err(|e| e.to_string())?;
        let jpath = journal::journal_path(&history.dir, &self.log_name);
        crashpoint::crash_if("journal.before-append");
        if !self.journal_started {
            durable::append_framed(&jpath, &self.header_payload, "journal.mid-append")
                .map_err(|e| format!("{}: {e}", jpath.display()))?;
            self.journal_started = true;
        }
        let payload = journal::slice_payload(external, &self.spec, cfgs, vals, fids);
        durable::append_framed(&jpath, &payload, "journal.mid-append")
            .map_err(|e| format!("{}: {e}", jpath.display()))?;
        crashpoint::crash_if("journal.after-append");
        Ok(())
    }

    /// Snapshot the outcome so far (errors if nothing was evaluated).
    pub fn outcome(&self) -> Result<TuningOutcome, String> {
        self.driver.outcome(&self.label)
    }

    /// Finalize: write the tuning log and summary row (project-backed
    /// sessions), retire the checkpoint journal, mark the session closed,
    /// and return the outcome. Idempotent — a session already finalized
    /// (including by `fin`-recovery in [`ServeSession::open`]) just
    /// returns its outcome.
    ///
    /// The durable ordering is what makes a crash anywhere in here
    /// recoverable with exactly-once summary semantics:
    /// final log (atomic replace) → `fin` journal record → summary row →
    /// journal removal. Before `fin`, recovery re-drives and finalizes
    /// again from scratch; after `fin`, recovery knows the log is done
    /// and appends the summary row only if it is missing.
    pub fn finalize(&mut self) -> Result<TuningOutcome, String> {
        if let Some(reason) = &self.failed {
            return Err(format!("session {} failed: {reason}", self.id));
        }
        let outcome = self.driver.outcome(&self.label)?;
        if self.finalized {
            return Ok(outcome);
        }
        if let Some(dir) = &self.dir {
            let history = History::open(dir).map_err(|e| e.to_string())?;
            crashpoint::crash_if("finalize.before-log");
            history.write_tuning_log_to(&self.log_name, &self.spec, &outcome)?;
            if self.journal_started {
                let jpath = journal::journal_path(&history.dir, &self.log_name);
                crashpoint::crash_if("finalize.before-fin");
                durable::append_framed(&jpath, journal::FIN, "fin.mid-append")
                    .map_err(|e| format!("{}: {e}", jpath.display()))?;
                crashpoint::crash_if("finalize.before-summary");
                history.append_summary(&self.spec, &outcome)?;
                crashpoint::crash_if("finalize.before-cleanup");
                std::fs::remove_file(&jpath).map_err(|e| e.to_string())?;
                durable::fsync_dir(&history.dir);
                self.journal_started = false;
            } else {
                // no slice was ever journaled (e.g. a resumed-exhausted
                // session that only replayed history)
                history.append_summary(&self.spec, &outcome)?;
            }
        }
        self.finalized = true;
        Ok(outcome)
    }
}

//! Global simulation memo-cache: fingerprint → runtime, LRU-bounded.
//!
//! `simulate_runtime` is a pure function of the bit-exact
//! (cluster, workload, config-values, seed) tuple, and
//! [`crate::util::fingerprint::eval_fingerprint`] hashes exactly that
//! tuple — so a hit returns the identical `f64` the DES would have
//! produced, and serving it changes nothing about a session's outcome
//! (the serve determinism tests pin this byte-for-byte). The cache is
//! shared across every session of the daemon: two users tuning the same
//! workload on the same cluster spec re-evaluate nothing.
//!
//! Bounded by an entry cap (`serve.cache_entries` in tuning.properties,
//! default [`DEFAULT_CACHE_ENTRIES`]) with least-recently-used eviction,
//! and instrumented with hit/miss/eviction counters so the daemon's
//! stats line and `BENCH_serve.json`'s hit-rate column are measured, not
//! inferred.

use std::collections::BTreeMap;

/// Default LRU cap — generous: an entry is 40 bytes of links + key +
/// value plus map overhead, so the default tops out around a few MiB.
pub const DEFAULT_CACHE_ENTRIES: usize = 65_536;

const NIL: usize = usize::MAX;

struct Entry {
    key: u64,
    value: f64,
    prev: usize,
    next: usize,
}

/// Monotone cache counters (never reset by evictions or cap changes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Hits over lookups, 0.0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Index-linked LRU map over 64-bit fingerprints: `get` promotes to the
/// front, `insert` evicts the tail at capacity. No per-entry boxing —
/// entries live in one `Vec` and the recency list is a pair of indices.
pub struct MemoCache {
    /// Fingerprint → entry index. Ordered map (detlint
    /// `hash-collections`): only keyed lookups today, and the recency
    /// list — not map order — defines eviction, but the ordered map
    /// keeps any future iteration deterministic by construction.
    map: BTreeMap<u64, usize>,
    entries: Vec<Entry>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
    cap: usize,
    stats: CacheStats,
}

impl MemoCache {
    pub fn new(cap: usize) -> MemoCache {
        MemoCache {
            map: BTreeMap::new(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            cap: cap.max(1),
            stats: CacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Re-bound the cache (a session's `serve.cache_entries`, applied at
    /// open — last opened wins). Shrinking evicts LRU entries down to
    /// the new cap immediately.
    pub fn set_cap(&mut self, cap: usize) {
        self.cap = cap.max(1);
        while self.map.len() > self.cap {
            self.evict_tail();
        }
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.entries[i].prev, self.entries[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.entries[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.entries[next].prev = prev;
        }
    }

    fn link_front(&mut self, i: usize) {
        self.entries[i].prev = NIL;
        self.entries[i].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn evict_tail(&mut self) {
        let t = self.tail;
        debug_assert_ne!(t, NIL, "evict on empty cache");
        self.unlink(t);
        self.map.remove(&self.entries[t].key);
        self.free.push(t);
        self.stats.evictions += 1;
    }

    /// Look up a fingerprint; a hit promotes the entry to
    /// most-recently-used and counts toward `stats().hits`.
    pub fn get(&mut self, key: u64) -> Option<f64> {
        match self.map.get(&key).copied() {
            Some(i) => {
                self.stats.hits += 1;
                if self.head != i {
                    self.unlink(i);
                    self.link_front(i);
                }
                Some(self.entries[i].value)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a fingerprint → runtime entry, evicting the
    /// least-recently-used entry when at capacity.
    pub fn insert(&mut self, key: u64, value: f64) {
        if let Some(&i) = self.map.get(&key) {
            self.entries[i].value = value;
            if self.head != i {
                self.unlink(i);
                self.link_front(i);
            }
            return;
        }
        if self.map.len() >= self.cap {
            self.evict_tail();
        }
        let i = match self.free.pop() {
            Some(i) => {
                self.entries[i] = Entry {
                    key,
                    value,
                    prev: NIL,
                    next: NIL,
                };
                i
            }
            None => {
                self.entries.push(Entry {
                    key,
                    value,
                    prev: NIL,
                    next: NIL,
                });
                self.entries.len() - 1
            }
        };
        self.link_front(i);
        self.map.insert(key, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_counters_are_measured() {
        let mut c = MemoCache::new(8);
        assert_eq!(c.get(1), None);
        c.insert(1, 10.0);
        assert_eq!(c.get(1), Some(10.0));
        assert_eq!(c.get(2), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn evicts_least_recently_used_at_cap() {
        let mut c = MemoCache::new(3);
        c.insert(1, 1.0);
        c.insert(2, 2.0);
        c.insert(3, 3.0);
        // touch 1 so 2 becomes the LRU
        assert_eq!(c.get(1), Some(1.0));
        c.insert(4, 4.0);
        assert_eq!(c.len(), 3);
        assert_eq!(c.get(2), None, "LRU entry 2 should have been evicted");
        assert_eq!(c.get(1), Some(1.0));
        assert_eq!(c.get(3), Some(3.0));
        assert_eq!(c.get(4), Some(4.0));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn shrinking_the_cap_evicts_down() {
        let mut c = MemoCache::new(16);
        for k in 0..10u64 {
            c.insert(k, k as f64);
        }
        c.set_cap(4);
        assert_eq!(c.len(), 4);
        // the four most recently inserted survive
        for k in 6..10u64 {
            assert_eq!(c.get(k), Some(k as f64), "key {k} missing after shrink");
        }
        assert_eq!(c.stats().evictions, 6);
        // slots are recycled: lots of churn never grows the arena past cap
        for k in 100..200u64 {
            c.insert(k, 0.0);
        }
        assert!(c.entries.len() <= 16, "entry arena grew past the original cap");
    }

    #[test]
    fn zero_cap_is_clamped_to_one() {
        let mut c = MemoCache::new(0);
        c.insert(1, 1.0);
        c.insert(2, 2.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(2), Some(2.0));
    }

    #[test]
    fn bit_exact_values_roundtrip() {
        let mut c = MemoCache::new(4);
        let v = f64::from_bits(0x3ff0_0000_0000_0001); // 1.0 + 1 ulp
        c.insert(9, v);
        assert_eq!(c.get(9).unwrap().to_bits(), v.to_bits());
    }
}

//! Line-protocol frontend for the serve daemon: one command per line on
//! the reader, one `ok`/`err` reply (plus any `warning`/`candidate`
//! payload lines) per command on the writer.
//!
//! Commands (tokens are whitespace-separated; `#` starts a comment):
//!
//! ```text
//! open <id> <project-dir>     load/resume a tuning project as session <id>
//! step [<id>]                 one dispatcher round (all sessions, or one)
//! run [<id>]                  step until the candidate stream drains
//! ask <id>                    next configs for an EXTERNAL client to measure
//! tell <id> <v1> <v2> ...     externally measured values for the last ask
//! status <id>                 evals / best / done for one session
//! close <id>                  finalize: write log + summary, report best
//! stats                       global cache + session counters
//! shutdown                    reply ok and stop serving (EOF does the same)
//! ```
//!
//! Replies are single lines: `ok <cmd> key=value ...`, `err <message>`,
//! `warning <id> <text>` (spec typo-guard diagnostics, emitted exactly
//! once per loaded session, at `open`), and `candidate <id> <i> <values>`
//! (the `ask` payload). A recoverable command error answers `err` and
//! keeps serving; only I/O failure on the stream aborts the daemon.
//!
//! A session that exhausts its evaluation retry budget moves to the
//! `Failed` terminal state without disturbing siblings: `status` then
//! reports `done=true failed="<reason>"`, `close` answers `err` (there
//! is no outcome to finalize), and `step`/`run` keep serving every
//! other session.
//!
//! When several sessions open the SAME project directory, the first gets
//! the default `tuning_log.csv` and later ones get `tuning_log.<id>.csv`
//! — concurrent users of one project never clobber each other's
//! checkpoint, and a re-opened id resumes from its own log.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::catla::history::TUNING_CSV;
use crate::serve::dispatcher::Dispatcher;
use crate::serve::session::ServeSession;

pub struct Daemon {
    sessions: Vec<ServeSession>,
    pub dispatcher: Dispatcher,
    /// Commands handled since the last stderr stats line.
    since_stats: usize,
}

/// Print the stats line to stderr every this many commands (and always
/// at shutdown).
const STATS_EVERY: usize = 32;

impl Daemon {
    pub fn new(dispatcher: Dispatcher) -> Daemon {
        Daemon {
            sessions: Vec::new(),
            dispatcher,
            since_stats: 0,
        }
    }

    pub fn sessions(&self) -> &[ServeSession] {
        &self.sessions
    }

    fn find(&self, id: &str) -> Result<usize, String> {
        self.sessions
            .iter()
            .position(|s| s.id == id)
            .ok_or_else(|| format!("no session {id:?} (open it first)"))
    }

    /// Register a project-backed session. Routes the checkpoint log so
    /// sessions sharing a directory never collide, applies the project's
    /// `serve.cache_entries` request (last opened wins), and returns the
    /// registry index.
    pub fn open_session(&mut self, id: &str, dir: &Path) -> Result<usize, String> {
        if self.sessions.iter().any(|s| s.id == id) {
            return Err(format!("session {id:?} already open"));
        }
        let shared_dir = self.sessions.iter().any(|s| s.dir() == Some(dir));
        let log_name = if shared_dir {
            format!("tuning_log.{id}.csv")
        } else {
            TUNING_CSV.to_string()
        };
        let sess = ServeSession::open(dir, id, &log_name)?;
        if let Some(cap) = sess.cache_entries {
            self.dispatcher.cache.set_cap(cap);
        }
        self.sessions.push(sess);
        Ok(self.sessions.len() - 1)
    }

    /// Serve the line protocol until `shutdown` or EOF. Only stream I/O
    /// failure is fatal; command errors answer `err ...` and continue.
    pub fn serve(&mut self, reader: impl BufRead, mut writer: impl Write) -> Result<(), String> {
        for line in reader.lines() {
            let line = line.map_err(|e| format!("serve: read failed: {e}"))?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts[0] == "shutdown" {
                writeln!(writer, "ok shutdown").map_err(|e| e.to_string())?;
                break;
            }
            match self.command(&parts, &mut writer) {
                Ok(ok_line) => {
                    writeln!(writer, "ok {ok_line}").map_err(|e| e.to_string())?
                }
                Err(CommandError::Recoverable(msg)) => {
                    writeln!(writer, "err {msg}").map_err(|e| e.to_string())?
                }
                Err(CommandError::Io(e)) => return Err(e),
            }
            writer.flush().map_err(|e| e.to_string())?;
            self.since_stats += 1;
            if self.since_stats >= STATS_EVERY {
                self.eprint_stats();
            }
        }
        writer.flush().map_err(|e| e.to_string())?;
        self.eprint_stats();
        Ok(())
    }

    fn eprint_stats(&mut self) {
        eprintln!("{}", self.dispatcher.stats_line(&self.sessions));
        self.since_stats = 0;
    }

    /// Handle one command; returns the tail of the `ok` reply line.
    /// Payload lines (`warning`, `candidate`) are written here, before
    /// the `ok`.
    fn command(&mut self, parts: &[&str], writer: &mut impl Write) -> Result<String, CommandError> {
        let arg = |i: usize, what: &str| -> Result<&str, CommandError> {
            parts
                .get(i)
                .copied()
                .ok_or_else(|| CommandError::Recoverable(format!("{} needs {what}", parts[0])))
        };
        match parts[0] {
            "open" => {
                let id = arg(1, "an id")?.to_string();
                let dir = arg(2, "a project dir")?;
                let idx = self.open_session(&id, Path::new(dir))?;
                let sess = &self.sessions[idx];
                for w in sess.warnings() {
                    writeln!(writer, "warning {id} {w}").map_err(CommandError::io)?;
                }
                Ok(format!(
                    "open {id} label={} evals={} log={}",
                    sess.label(),
                    sess.evals(),
                    sess.log_name()
                ))
            }
            "step" => {
                let r = match parts.get(1) {
                    Some(id) => {
                        let i = self.find(id)?;
                        self.dispatcher.step(&mut self.sessions[i..i + 1])?
                    }
                    None => self.dispatcher.step(&mut self.sessions)?,
                };
                Ok(format!(
                    "step runs={} simulated={} sessions={} failed={}",
                    r.runs, r.simulated, r.sessions, r.failed
                ))
            }
            "run" => {
                let steps = match parts.get(1) {
                    Some(id) => {
                        let i = self.find(id)?;
                        self.dispatcher.run_all(&mut self.sessions[i..i + 1])?
                    }
                    None => self.dispatcher.run_all(&mut self.sessions)?,
                };
                Ok(format!("run steps={steps}"))
            }
            "ask" => {
                let id = arg(1, "an id")?.to_string();
                let i = self.find(&id)?;
                let cfgs = self.sessions[i].ask_configs();
                for (k, cfg) in cfgs.iter().enumerate() {
                    let vals: Vec<String> = cfg.values.iter().map(|v| v.to_string()).collect();
                    writeln!(writer, "candidate {id} {k} {}", vals.join(" "))
                        .map_err(CommandError::io)?;
                }
                Ok(format!("ask {id} n={}", cfgs.len()))
            }
            "tell" => {
                let id = arg(1, "an id")?.to_string();
                let i = self.find(&id)?;
                let vals = parts[2..]
                    .iter()
                    .map(|t| {
                        t.parse::<f64>()
                            .map_err(|_| format!("tell {id}: bad value {t:?}"))
                    })
                    .collect::<Result<Vec<f64>, String>>()?;
                self.sessions[i].tell_external(&vals)?;
                Ok(format!("tell {id} evals={}", self.sessions[i].evals()))
            }
            "status" => {
                let id = arg(1, "an id")?;
                let i = self.find(id)?;
                let sess = &self.sessions[i];
                let best = sess
                    .best_value()
                    .map(|b| format!("{b:.3}"))
                    .unwrap_or_else(|| "none".to_string());
                // failed sessions carry their reason on the status line
                // (quoted, so the reply stays a single line); healthy
                // sessions' replies are unchanged
                let failed = match sess.failed() {
                    Some(reason) => format!(" failed={reason:?}"),
                    None => String::new(),
                };
                Ok(format!(
                    "status {id} evals={} best={best} done={}{failed}",
                    sess.evals(),
                    sess.is_done()
                ))
            }
            "close" => {
                let id = arg(1, "an id")?;
                let i = self.find(id)?;
                let outcome = self.sessions[i].finalize()?;
                Ok(format!(
                    "close {id} optimizer={} evals={} best={:.3}",
                    outcome.optimizer,
                    outcome.evals(),
                    outcome.best_value
                ))
            }
            "stats" => {
                let live = self.sessions.iter().filter(|s| !s.is_done()).count();
                let s = self.dispatcher.cache_stats();
                Ok(format!(
                    "stats sessions={} live={} entries={} cap={} hits={} misses={} evictions={} deduped={}",
                    self.sessions.len(),
                    live,
                    self.dispatcher.cache.len(),
                    self.dispatcher.cache.cap(),
                    s.hits,
                    s.misses,
                    s.evictions,
                    self.dispatcher.deduped()
                ))
            }
            other => Err(CommandError::Recoverable(format!(
                "unknown command {other:?} (open/step/run/ask/tell/status/close/stats/shutdown)"
            ))),
        }
    }
}

/// Command errors split by what they mean for the serve loop: bad input
/// answers `err ...` and keeps serving, stream I/O failure aborts.
enum CommandError {
    Recoverable(String),
    Io(String),
}

impl CommandError {
    fn io(e: std::io::Error) -> CommandError {
        CommandError::Io(format!("serve: write failed: {e}"))
    }
}

impl From<String> for CommandError {
    fn from(msg: String) -> CommandError {
        CommandError::Recoverable(msg)
    }
}

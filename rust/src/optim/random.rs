//! Uniform random search — the standard no-structure baseline every
//! optimizer comparison needs (ABL1).

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandomSearch {
    pub seed: u64,
}

impl RandomSearch {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let mut rng = Rng::new(self.seed);
        let d = space.dims();
        let mut rec = Recorder::new();
        for _ in 0..max_evals {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let cfg = space.decode(&x);
            let v = obj(&cfg);
            rec.record(x, cfg, v);
        }
        rec.finish("random")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;

    #[test]
    fn improves_with_budget_on_smooth_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let bowl = |space: &ParamSpace, c: &HadoopConfig| -> f64 {
            space.encode(c).iter().map(|u| (u - 0.7).powi(2)).sum()
        };
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| bowl(&sp, c);
        let small = RandomSearch::new(1).run(&space, &mut obj, 5).best_value;
        let large = RandomSearch::new(1).run(&space, &mut obj, 200).best_value;
        assert!(large <= small);
        assert!(large < 0.05, "200 random points should land near optimum: {large}");
    }

    #[test]
    fn deterministic_per_seed() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut obj = |c: &HadoopConfig| c.values.iter().sum::<f64>();
        let a = RandomSearch::new(9).run(&space, &mut obj, 20);
        let b = RandomSearch::new(9).run(&space, &mut obj, 20);
        assert_eq!(a.best_value, b.best_value);
        assert_eq!(a.records.len(), b.records.len());
    }
}

//! Uniform random search — the standard no-structure baseline every
//! optimizer comparison needs (ABL1).
//!
//! Ask/tell port: the whole remaining budget is proposed as one batch.
//! The points come off one sequential RNG stream, so the proposal
//! sequence (and therefore the outcome) is byte-identical to the old
//! one-eval-per-iteration loop.

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::EvalRecord;
use crate::optim::space::ParamSpace;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandomSearch {
    pub seed: u64,
    rng: Option<Rng>,
    best: BestSeen,
}

impl RandomSearch {
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch {
            seed,
            rng: None,
            best: BestSeen::default(),
        }
    }
}

impl Optimizer for RandomSearch {
    fn name(&self) -> &str {
        "random"
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        let seed = self.seed;
        let rng = self.rng.get_or_insert_with(|| Rng::new(seed));
        let d = space.dims();
        (0..budget_left)
            .map(|_| Candidate::new((0..d).map(|_| rng.f64()).collect()))
            .collect()
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    #[test]
    fn improves_with_budget_on_smooth_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| {
            sp.encode(c).iter().map(|u| (u - 0.7).powi(2)).sum()
        });
        let small = Driver::new(5)
            .run(&mut RandomSearch::new(1), &space, &mut obj)
            .unwrap()
            .best_value;
        let large = Driver::new(200)
            .run(&mut RandomSearch::new(1), &space, &mut obj)
            .unwrap()
            .best_value;
        assert!(large <= small);
        assert!(large < 0.05, "200 random points should land near optimum: {large}");
    }

    #[test]
    fn deterministic_per_seed() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut obj = FnObjective(|c: &HadoopConfig| c.values.iter().sum::<f64>());
        let a = Driver::new(20)
            .run(&mut RandomSearch::new(9), &space, &mut obj)
            .unwrap();
        let b = Driver::new(20)
            .run(&mut RandomSearch::new(9), &space, &mut obj)
            .unwrap();
        assert_eq!(a.best_value, b.best_value);
        assert_eq!(a.records.len(), b.records.len());
    }

    #[test]
    fn asks_in_one_full_budget_batch() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut r = RandomSearch::new(4);
        assert_eq!(r.ask(&space, 37).len(), 37);
    }
}

//! Uniform random search — the standard no-structure baseline every
//! optimizer comparison needs (ABL1).
//!
//! Ask/tell port: the whole remaining budget is proposed as one batch.
//! The points come off one sequential RNG stream, so the proposal
//! sequence (and therefore the outcome) is byte-identical to the old
//! one-eval-per-iteration loop.
//!
//! Constraint-aware sampling: on a constrained space each point is drawn
//! by rejection against the spec's `Constraint` predicates — an
//! infeasible draw is redrawn up to [`INIT_REJECTION_TRIES`] times, then
//! the original draw is kept and decode's snap-down repair takes over.
//! Uniform-on-the-feasible-region instead of "uniform then project",
//! which piled probability mass onto the constraint boundary.
//! Constraint-free specs consume the RNG stream exactly as before
//! (byte-identical proposals).

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::EvalRecord;
use crate::optim::space::{ParamSpace, INIT_REJECTION_TRIES};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandomSearch {
    pub seed: u64,
    rng: Option<Rng>,
    best: BestSeen,
}

impl RandomSearch {
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch {
            seed,
            rng: None,
            best: BestSeen::default(),
        }
    }
}

impl Optimizer for RandomSearch {
    fn name(&self) -> &str {
        "random"
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        let seed = self.seed;
        let rng = self.rng.get_or_insert_with(|| Rng::new(seed));
        let d = space.dims();
        if space.spec.constraints.is_empty() {
            return (0..budget_left)
                .map(|_| Candidate::new((0..d).map(|_| rng.f64()).collect()))
                .collect();
        }
        // rejection against the feasible region; the first draw is the
        // fallback so pathologically thin regions still sample
        let mut scratch = space.base.clone();
        (0..budget_left)
            .map(|_| {
                let first: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                if space.unit_feasible(&first, &mut scratch) {
                    return Candidate::new(first);
                }
                for _ in 0..INIT_REJECTION_TRIES {
                    let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    if space.unit_feasible(&x, &mut scratch) {
                        return Candidate::new(x);
                    }
                }
                Candidate::new(first)
            })
            .collect()
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    #[test]
    fn improves_with_budget_on_smooth_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| {
            sp.encode(c).iter().map(|u| (u - 0.7).powi(2)).sum()
        });
        let small = Driver::new(5)
            .run(&mut RandomSearch::new(1), &space, &mut obj)
            .unwrap()
            .best_value;
        let large = Driver::new(200)
            .run(&mut RandomSearch::new(1), &space, &mut obj)
            .unwrap()
            .best_value;
        assert!(large <= small);
        assert!(large < 0.05, "200 random points should land near optimum: {large}");
    }

    #[test]
    fn deterministic_per_seed() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut obj = FnObjective(|c: &HadoopConfig| c.values.iter().sum::<f64>());
        let a = Driver::new(20)
            .run(&mut RandomSearch::new(9), &space, &mut obj)
            .unwrap();
        let b = Driver::new(20)
            .run(&mut RandomSearch::new(9), &space, &mut obj)
            .unwrap();
        assert_eq!(a.best_value, b.best_value);
        assert_eq!(a.records.len(), b.records.len());
    }

    #[test]
    fn asks_in_one_full_budget_batch() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut r = RandomSearch::new(4);
        assert_eq!(r.ask(&space, 37).len(), 37);
    }

    #[test]
    fn unconstrained_sampling_is_the_plain_rng_stream() {
        // no constraints -> the ask must consume the RNG exactly as the
        // pre-rejection code did (one f64 per dimension per point)
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let batch = RandomSearch::new(11).ask(&space, 9);
        let mut rng = Rng::new(11);
        for c in &batch {
            for &v in &c.unit_x {
                assert_eq!(v.to_bits(), rng.f64().to_bits());
            }
        }
    }

    fn constrained_space() -> ParamSpace {
        // the bound 0.25*memory cuts deep into sort.mb's range, so a
        // large fraction of the unit cube is infeasible pre-repair
        let spec = TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 16 2048\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             constraint io.sort.mb <= 0.25*map.memory.mb\n",
        )
        .unwrap();
        ParamSpace::new(spec, HadoopConfig::default())
    }

    #[test]
    fn constrained_sampling_is_deterministic_and_mostly_feasible() {
        let space = constrained_space();
        let a = RandomSearch::new(7).ask(&space, 64);
        let b = RandomSearch::new(7).ask(&space, 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.unit_x, y.unit_x, "rejection sampling not deterministic");
        }
        let mut scratch = space.base.clone();
        let feasible = a
            .iter()
            .filter(|c| space.unit_feasible(&c.unit_x, &mut scratch))
            .count();
        assert!(feasible >= 60, "only {feasible}/64 draws feasible pre-repair");
    }

    #[test]
    fn rejection_takes_mass_off_the_constraint_boundary() {
        let space = constrained_space();
        let n = 200;
        // legacy behavior: decode the raw stream and count configs that
        // repair snapped exactly onto the bound
        let on_boundary = |xs: &[Vec<f64>]| -> usize {
            xs.iter()
                .filter(|x| {
                    let cfg = space.decode(x);
                    let bound = space.spec.constraints[0].bound_value(&cfg.values);
                    cfg.values[space.spec.ranges[0].index] == bound.floor()
                })
                .count()
        };
        let mut rng = Rng::new(3);
        let legacy: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..space.dims()).map(|_| rng.f64()).collect())
            .collect();
        let rejection: Vec<Vec<f64>> = RandomSearch::new(3)
            .ask(&space, n)
            .into_iter()
            .map(|c| c.unit_x)
            .collect();
        let (legacy_hits, rejection_hits) = (on_boundary(&legacy), on_boundary(&rejection));
        assert!(
            rejection_hits * 4 <= legacy_hits,
            "boundary mass not reduced: legacy {legacy_hits}/{n}, rejection {rejection_hits}/{n}"
        );
    }
}

//! Quadratic interpolation models with minimum-Frobenius-norm Hessians —
//! the model machinery of Powell's BOBYQA family.
//!
//! Given m interpolation points and values, find q(x) = c + gᵀs + ½sᵀHs
//! (s = x − center) that interpolates all points with the Hessian of
//! minimum Frobenius norm. The KKT system of that variational problem is
//!
//!   [ A  P ] [λ]   [f]        A_ij = ½ (sᵢ·sⱼ)²
//!   [ Pᵀ 0 ] [c,g] [0]        P row i = [1, sᵢᵀ]
//!
//! and H = Σ λᵢ sᵢ sᵢᵀ. We re-solve the dense system each iteration
//! (m ≤ 2n+1, n ≤ 10 here ⇒ ≤ 32×32 — microseconds), trading Powell's
//! incremental inverse updates for clarity; DESIGN.md records the
//! divergence.

use crate::util::linalg::{dot, Mat};

#[derive(Clone, Debug)]
pub struct QuadModel {
    pub center: Vec<f64>,
    pub c: f64,
    pub g: Vec<f64>,
    pub h: Mat,
}

impl QuadModel {
    /// Evaluate the model at absolute coordinates `x`.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let s: Vec<f64> = x.iter().zip(&self.center).map(|(a, b)| a - b).collect();
        self.eval_step(&s)
    }

    /// Evaluate at step `s` relative to the center.
    pub fn eval_step(&self, s: &[f64]) -> f64 {
        let hs = self.h.matvec(s);
        self.c + dot(&self.g, s) + 0.5 * dot(s, &hs)
    }

    /// Model gradient at step `s`: g + H s.
    pub fn grad_step(&self, s: &[f64]) -> Vec<f64> {
        let mut hs = self.h.matvec(s);
        for (hi, gi) in hs.iter_mut().zip(&self.g) {
            *hi += gi;
        }
        hs
    }
}

/// Fit the minimum-Frobenius-norm quadratic through `(points, values)`
/// centered at `center`. Returns None when the interpolation system is
/// singular (degenerate geometry) — callers must take a geometry step.
#[allow(clippy::float_cmp)] // exact-zero Lagrange multipliers skip a rank-1 update, no tolerance wanted
pub fn fit_min_frobenius(
    points: &[Vec<f64>],
    values: &[f64],
    center: &[f64],
) -> Option<QuadModel> {
    let m = points.len();
    let n = center.len();
    assert_eq!(values.len(), m);
    if m < n + 2 {
        return None; // not enough points for a linear model + curvature
    }
    let steps: Vec<Vec<f64>> = points
        .iter()
        .map(|p| p.iter().zip(center).map(|(a, b)| a - b).collect())
        .collect();

    let dim = m + n + 1;
    let mut w = Mat::zeros(dim, dim);
    for i in 0..m {
        for j in 0..m {
            let d = dot(&steps[i], &steps[j]);
            w[(i, j)] = 0.5 * d * d;
        }
        w[(i, m)] = 1.0;
        w[(m, i)] = 1.0;
        for k in 0..n {
            w[(i, m + 1 + k)] = steps[i][k];
            w[(m + 1 + k, i)] = steps[i][k];
        }
    }
    let mut rhs = vec![0.0; dim];
    rhs[..m].copy_from_slice(values);

    let sol = w.solve(&rhs)?;
    let lambda = &sol[..m];
    let c = sol[m];
    let g = sol[m + 1..].to_vec();
    let mut h = Mat::zeros(n, n);
    for (l, s) in lambda.iter().zip(&steps) {
        if *l == 0.0 {
            continue;
        }
        for a in 0..n {
            for b in 0..n {
                h[(a, b)] += l * s[a] * s[b];
            }
        }
    }
    Some(QuadModel {
        center: center.to_vec(),
        c,
        g,
        h,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Build the standard 2n+1 design around `x0` with radius `delta`.
    fn design(x0: &[f64], delta: f64) -> Vec<Vec<f64>> {
        let n = x0.len();
        let mut pts = vec![x0.to_vec()];
        for i in 0..n {
            let mut p = x0.to_vec();
            p[i] += delta;
            pts.push(p);
            let mut q = x0.to_vec();
            q[i] -= delta;
            pts.push(q);
        }
        pts
    }

    #[test]
    fn interpolates_exactly_at_points() {
        let x0 = vec![0.4, 0.6, 0.5];
        let pts = design(&x0, 0.1);
        let f = |x: &[f64]| x[0] * x[0] + 2.0 * x[1] * x[2] + x[2];
        let vals: Vec<f64> = pts.iter().map(|p| f(p)).collect();
        let m = fit_min_frobenius(&pts, &vals, &x0).unwrap();
        for (p, v) in pts.iter().zip(&vals) {
            assert!((m.eval(p) - v).abs() < 1e-8, "{} vs {v}", m.eval(p));
        }
    }

    #[test]
    fn recovers_separable_quadratic_gradient() {
        // f = Σ (x_i - 0.3)^2: at center x0 the model gradient should
        // approximate 2(x0 - 0.3)
        let x0 = vec![0.5, 0.7];
        let pts = design(&x0, 0.05);
        let f = |x: &[f64]| x.iter().map(|v| (v - 0.3) * (v - 0.3)).sum::<f64>();
        let vals: Vec<f64> = pts.iter().map(|p| f(p)).collect();
        let m = fit_min_frobenius(&pts, &vals, &x0).unwrap();
        let g = m.grad_step(&vec![0.0; 2]);
        assert!((g[0] - 0.4).abs() < 1e-6, "g0 {}", g[0]);
        assert!((g[1] - 0.8).abs() < 1e-6, "g1 {}", g[1]);
    }

    #[test]
    fn degenerate_geometry_returns_none() {
        // all points on a line -> singular system
        let pts: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64 * 0.1, 0.0]).collect();
        let vals = vec![0.0; 6];
        assert!(fit_min_frobenius(&pts, &vals, &[0.0, 0.0]).is_none());
    }

    #[test]
    fn too_few_points_returns_none() {
        let pts = vec![vec![0.0, 0.0], vec![0.1, 0.0]];
        assert!(fit_min_frobenius(&pts, &[1.0, 2.0], &[0.0, 0.0]).is_none());
    }

    #[test]
    fn model_prediction_decent_off_points() {
        let mut rng = Rng::new(5);
        let x0 = vec![0.5; 4];
        let pts = design(&x0, 0.15);
        let f = |x: &[f64]| {
            x.iter().enumerate().map(|(i, v)| (1.0 + i as f64) * (v - 0.4) * (v - 0.4)).sum::<f64>()
        };
        let vals: Vec<f64> = pts.iter().map(|p| f(p)).collect();
        let m = fit_min_frobenius(&pts, &vals, &x0).unwrap();
        for _ in 0..20 {
            let x: Vec<f64> = x0.iter().map(|v| v + rng.range_f64(-0.1, 0.1)).collect();
            let err = (m.eval(&x) - f(&x)).abs();
            assert!(err < 0.05, "model err {err} at {x:?}");
        }
    }
}

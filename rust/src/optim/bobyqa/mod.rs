//! BOBYQA-style bound-constrained DFO (Powell 2009), the optimizer behind
//! the paper's Fig. 3.
//!
//! Outer loop: maintain a 2n+1-point interpolation set, fit the
//! minimum-Frobenius-norm quadratic ([`model`]), take a box-constrained
//! trust-region step ([`trust_region`]), update the radius from the
//! actual/predicted reduction ratio, and repair geometry when the set
//! degenerates. Differences from Powell's Fortran (re-solved dense KKT
//! instead of incremental inverse updates; projected-gradient TRSBOX) are
//! catalogued in DESIGN.md — behaviourally it retains the property the
//! paper relies on: rapid convergence on noisy black-box objectives in
//! few evaluations.

pub mod model;
pub mod trust_region;

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;
use crate::util::linalg::norm2;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Bobyqa {
    /// Initial trust-region radius (unit-cube units).
    pub rho_begin: f64,
    /// Final radius: below this the run restarts around the incumbent
    /// (the objective is noisy; extra samples near the optimum are useful).
    pub rho_end: f64,
    pub start: Option<Vec<f64>>,
    pub seed: u64,
}

impl Default for Bobyqa {
    fn default() -> Self {
        Self {
            rho_begin: 0.2,
            rho_end: 5e-3,
            start: None,
            seed: 7,
        }
    }
}

impl Bobyqa {
    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let n = space.dims();
        let m = 2 * n + 1;
        let mut rng = Rng::new(self.seed);
        let mut rec = Recorder::new();
        let mut eval = |rec: &mut Recorder, x: &[f64]| -> f64 {
            let x: Vec<f64> = x.iter().map(|u| u.clamp(0.0, 1.0)).collect();
            let cfg = space.decode(&x);
            let v = obj(&cfg);
            rec.record(x, cfg, v);
            v
        };

        let x0 = self.start.clone().unwrap_or_else(|| vec![0.5; n]);
        let mut delta = self.rho_begin;

        // ---- initial design: x0 ± delta e_i, clipped to the cube -------
        let mut pts: Vec<Vec<f64>> = Vec::with_capacity(m);
        let mut vals: Vec<f64> = Vec::with_capacity(m);
        let mut push = |rec: &mut Recorder, pts: &mut Vec<Vec<f64>>, vals: &mut Vec<f64>, x: Vec<f64>| {
            let v = eval(rec, &x);
            pts.push(x);
            vals.push(v);
        };
        push(&mut rec, &mut pts, &mut vals, x0.clone());
        for i in 0..n {
            if rec.evals() + 2 > max_evals {
                break;
            }
            let mut p = x0.clone();
            p[i] = (p[i] + delta).min(1.0);
            push(&mut rec, &mut pts, &mut vals, p);
            let mut q = x0.clone();
            q[i] = (q[i] - delta).max(0.0);
            push(&mut rec, &mut pts, &mut vals, q);
        }

        let best_idx = |vals: &[f64]| -> usize {
            vals.iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap()
        };

        while rec.evals() < max_evals {
            let bi = best_idx(&vals);
            let xb = pts[bi].clone();
            let fb = vals[bi];

            // fit model centered on the incumbent
            let model = model::fit_min_frobenius(&pts, &vals, &xb);
            let step = model.as_ref().map(|md| {
                let lo: Vec<f64> = xb.iter().map(|v| -v).collect();
                let hi: Vec<f64> = xb.iter().map(|v| 1.0 - v).collect();
                trust_region::solve(md, delta, &lo, &hi)
            });

            let (s, pred) = match step {
                Some((s, pred)) if pred > 1e-12 && norm2(&s) > 1e-9 => (s, pred),
                _ => {
                    // geometry step: replace the farthest point with a
                    // random point in the current trust region
                    let gi = farthest(&pts, &xb);
                    let mut p: Vec<f64> = xb
                        .iter()
                        .map(|v| (v + rng.range_f64(-delta, delta)).clamp(0.0, 1.0))
                        .collect();
                    if p == xb {
                        p[0] = (p[0] + delta * 0.5).min(1.0);
                    }
                    let v = eval(&mut rec, &p);
                    pts[gi] = p;
                    vals[gi] = v;
                    delta = (delta * 0.7).max(self.rho_end * 0.5);
                    if delta <= self.rho_end {
                        delta = self.rho_begin * 0.5; // noisy-objective restart
                    }
                    continue;
                }
            };

            let xn: Vec<f64> = xb.iter().zip(&s).map(|(a, b)| (a + b).clamp(0.0, 1.0)).collect();
            let fn_ = eval(&mut rec, &xn);
            let rho = (fb - fn_) / pred;

            // replace the farthest point (never the incumbent unless the
            // new point beats it)
            let ri = {
                let cand = farthest(&pts, &xb);
                if cand == bi && fn_ > fb {
                    second_farthest(&pts, &xb, bi)
                } else {
                    cand
                }
            };
            pts[ri] = xn;
            vals[ri] = fn_;

            delta = if rho >= 0.7 {
                (delta * 2.0).min(0.5)
            } else if rho >= 0.1 {
                delta
            } else {
                delta * 0.5
            };
            if delta <= self.rho_end {
                delta = self.rho_begin * 0.5; // restart radius near incumbent
            }
        }
        rec.finish("bobyqa")
    }
}

fn farthest(pts: &[Vec<f64>], from: &[f64]) -> usize {
    pts.iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| dist2(a, from).total_cmp(&dist2(b, from)))
        .map(|(i, _)| i)
        .unwrap()
}

fn second_farthest(pts: &[Vec<f64>], from: &[f64], skip: usize) -> usize {
    pts.iter()
        .enumerate()
        .filter(|(i, _)| *i != skip)
        .max_by(|(_, a), (_, b)| dist2(a, from).total_cmp(&dist2(b, from)))
        .map(|(i, _)| i)
        .unwrap()
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::util::rng::Rng;

    fn space4() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    #[test]
    fn converges_on_smooth_bowl() {
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.62).powi(2)).sum()
        };
        let out = Bobyqa::default().run(&space, &mut obj, 80);
        assert!(out.best_value < 0.01, "bobyqa stuck at {}", out.best_value);
    }

    #[test]
    fn converges_under_noise() {
        // the paper's core claim: DFO tolerates noisy runtimes
        let space = space4();
        let sp = space.clone();
        let mut noise = Rng::new(3);
        let mut obj = move |c: &HadoopConfig| -> f64 {
            let clean: f64 = sp.encode(c).iter().map(|u| (u - 0.4).powi(2)).sum();
            (1.0 + clean) * noise.lognormal(0.0, 0.03)
        };
        let out = Bobyqa::default().run(&space, &mut obj, 120);
        // best observed should be close to the noise floor around 1.0
        assert!(out.best_value < 1.06, "noisy bobyqa best {}", out.best_value);
    }

    #[test]
    fn handles_optimum_on_boundary() {
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (1.0 - u).powi(2)).sum()
        };
        let out = Bobyqa::default().run(&space, &mut obj, 100);
        assert!(out.best_value < 0.02, "boundary optimum missed: {}", out.best_value);
        for r in &out.records {
            assert!(r.unit_x.iter().all(|&u| (0.0..=1.0).contains(&u)));
        }
    }

    #[test]
    fn beats_random_on_same_budget() {
        let space = space4();
        let sp = space.clone();
        let mk_obj = move || {
            let sp = sp.clone();
            move |c: &HadoopConfig| -> f64 {
                let u = sp.encode(c);
                let mut s = 0.0;
                for i in 0..u.len() {
                    s += (u[i] - 0.35).powi(2) * (1.0 + i as f64);
                }
                s
            }
        };
        let budget = 60;
        let mut o1 = mk_obj();
        let bq = Bobyqa::default().run(&space, &mut o1, budget).best_value;
        let mut o2 = mk_obj();
        let rnd = crate::optim::random::RandomSearch::new(1)
            .run(&space, &mut o2, budget)
            .best_value;
        assert!(bq <= rnd, "bobyqa {bq} worse than random {rnd}");
    }

    #[test]
    fn budget_respected_exactly() {
        let space = space4();
        let mut obj = |_: &HadoopConfig| 1.0;
        let out = Bobyqa::default().run(&space, &mut obj, 25);
        assert!(out.evals() <= 25);
        assert!(out.evals() >= 20, "should use most of the budget");
    }
}

//! BOBYQA-style bound-constrained DFO (Powell 2009), the optimizer behind
//! the paper's Fig. 3.
//!
//! Outer loop: maintain a 2n+1-point interpolation set, fit the
//! minimum-Frobenius-norm quadratic ([`model`]), take a box-constrained
//! trust-region step ([`trust_region`]), update the radius from the
//! actual/predicted reduction ratio, and repair geometry when the set
//! degenerates. Differences from Powell's Fortran (re-solved dense KKT
//! instead of incremental inverse updates; projected-gradient TRSBOX) are
//! catalogued in DESIGN.md — behaviourally it retains the property the
//! paper relies on: rapid convergence on noisy black-box objectives in
//! few evaluations.
//!
//! Ask/tell port: the 2n+1 initial design is ONE ask-batch (its values do
//! not influence its own construction, so batched evaluation is exact);
//! every later ask is a singleton — trust-region or geometry-repair point
//! — reproducing the old monolithic loop decision for decision.

pub mod model;
pub mod trust_region;

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::{EvalRecord, Fidelity};
use crate::optim::space::ParamSpace;
use crate::util::linalg::norm2;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Bobyqa {
    /// Initial trust-region radius (unit-cube units).
    pub rho_begin: f64,
    /// Final radius: below this the run restarts around the incumbent
    /// (the objective is noisy; extra samples near the optimum are useful).
    pub rho_end: f64,
    pub start: Option<Vec<f64>>,
    pub seed: u64,
    label: Option<String>,
    st: Option<State>,
    best: BestSeen,
}

impl Default for Bobyqa {
    fn default() -> Self {
        Self {
            rho_begin: 0.2,
            rho_end: 5e-3,
            start: None,
            seed: 7,
            label: None,
            st: None,
            best: BestSeen::default(),
        }
    }
}

impl Bobyqa {
    pub fn new(seed: u64) -> Bobyqa {
        Bobyqa {
            seed,
            ..Bobyqa::default()
        }
    }

    pub fn with_start(mut self, start: Vec<f64>) -> Bobyqa {
        self.start = Some(start);
        self
    }

    /// Override the outcome label (e.g. `"bobyqa+prescreen(native)"`).
    pub fn with_label(mut self, label: String) -> Bobyqa {
        self.label = Some(label);
        self
    }
}

#[derive(Clone, Debug)]
struct State {
    rng: Rng,
    delta: f64,
    pts: Vec<Vec<f64>>,
    vals: Vec<f64>,
    pending: Pending,
}

#[derive(Clone, Debug)]
enum Pending {
    None,
    /// The initial design: stays pending until the next `ask`, because
    /// the driver tells one ask-batch back in several `batch.chunk`-sized
    /// slices.
    Init,
    /// Trust-region step from incumbent `xb` (= pts[bi], value fb).
    Trust {
        bi: usize,
        xb: Vec<f64>,
        fb: f64,
        pred: f64,
    },
    /// Geometry-repair point replacing pts[gi].
    Geom { gi: usize },
}

fn best_idx(vals: &[f64]) -> usize {
    vals.iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

impl Optimizer for Bobyqa {
    fn name(&self) -> &str {
        self.label.as_deref().unwrap_or("bobyqa")
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        let n = space.dims();
        let st = match &mut self.st {
            None => {
                // ---- initial design: x0 ± delta e_i, clipped to the cube,
                // truncated in (+,−) pairs exactly like the old budget check
                let x0 = self.start.clone().unwrap_or_else(|| vec![0.5; n]);
                let delta = self.rho_begin;
                let mut batch: Vec<Vec<f64>> = vec![x0.clone()];
                for i in 0..n {
                    if batch.len() + 2 > budget_left {
                        break;
                    }
                    let mut p = x0.clone();
                    p[i] = (p[i] + delta).min(1.0);
                    batch.push(p);
                    let mut q = x0.clone();
                    q[i] = (q[i] - delta).max(0.0);
                    batch.push(q);
                }
                batch.truncate(budget_left.max(1));
                self.st = Some(State {
                    rng: Rng::new(self.seed),
                    delta,
                    pts: Vec::new(),
                    vals: Vec::new(),
                    pending: Pending::Init,
                });
                return batch.into_iter().map(Candidate::new).collect();
            }
            Some(st) => st,
        };
        match st.pending {
            Pending::None => {}
            // every told-back chunk of the init batch has arrived by the
            // driver contract (tell covers the whole batch before the
            // next ask), so the design is complete now
            Pending::Init => st.pending = Pending::None,
            _ => return Vec::new(), // tell pending
        }
        if st.pts.is_empty() {
            return Vec::new(); // init batch was fully truncated away
        }

        let bi = best_idx(&st.vals);
        let xb = st.pts[bi].clone();
        let fb = st.vals[bi];

        // fit the model centered on the incumbent, try a trust step
        let model = model::fit_min_frobenius(&st.pts, &st.vals, &xb);
        let step = model.as_ref().map(|md| {
            let lo: Vec<f64> = xb.iter().map(|v| -v).collect();
            let hi: Vec<f64> = xb.iter().map(|v| 1.0 - v).collect();
            trust_region::solve(md, st.delta, &lo, &hi)
        });

        match step {
            Some((s, pred)) if pred > 1e-12 && norm2(&s) > 1e-9 => {
                let xn: Vec<f64> = xb
                    .iter()
                    .zip(&s)
                    .map(|(a, b)| (a + b).clamp(0.0, 1.0))
                    .collect();
                st.pending = Pending::Trust { bi, xb, fb, pred };
                vec![Candidate::new(xn)]
            }
            _ => {
                // geometry step: replace the farthest point with a random
                // point in the current trust region
                let gi = farthest(&st.pts, &xb);
                let delta = st.delta;
                let mut p: Vec<f64> = xb
                    .iter()
                    .map(|v| (v + st.rng.range_f64(-delta, delta)).clamp(0.0, 1.0))
                    .collect();
                if p == xb {
                    p[0] = (p[0] + delta * 0.5).min(1.0);
                }
                st.pending = Pending::Geom { gi };
                vec![Candidate::new(p)]
            }
        }
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
        let st = match &mut self.st {
            // told before the first ask (resume replay): seed the start
            None => {
                if let Some((x, _)) = self.best.get() {
                    self.start = Some(x);
                }
                return;
            }
            Some(st) => st,
        };
        match std::mem::replace(&mut st.pending, Pending::None) {
            Pending::None => {}
            Pending::Init => {
                for r in evals {
                    st.pts.push(r.unit_x.clone());
                    st.vals.push(r.value);
                }
                // keep absorbing: a chunking driver may tell the rest of
                // the init batch in later calls
                st.pending = Pending::Init;
            }
            Pending::Trust { bi, xb, fb, pred } => {
                let r = &evals[0];
                let fn_ = r.value;
                let rho = (fb - fn_) / pred;

                // replace the farthest point (never the incumbent unless
                // the new point beats it)
                let ri = {
                    let cand = farthest(&st.pts, &xb);
                    if cand == bi && fn_ > fb {
                        second_farthest(&st.pts, &xb, bi)
                    } else {
                        cand
                    }
                };
                st.pts[ri] = r.unit_x.clone();
                st.vals[ri] = fn_;

                st.delta = if rho >= 0.7 {
                    (st.delta * 2.0).min(0.5)
                } else if rho >= 0.1 {
                    st.delta
                } else {
                    st.delta * 0.5
                };
                if st.delta <= self.rho_end {
                    st.delta = self.rho_begin * 0.5; // restart near incumbent
                }
            }
            Pending::Geom { gi } => {
                let r = &evals[0];
                st.pts[gi] = r.unit_x.clone();
                st.vals[gi] = r.value;
                st.delta = (st.delta * 0.7).max(self.rho_end * 0.5);
                if st.delta <= self.rho_end {
                    st.delta = self.rho_begin * 0.5; // noisy-objective restart
                }
            }
        }
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

fn farthest(pts: &[Vec<f64>], from: &[f64]) -> usize {
    pts.iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| dist2(a, from).total_cmp(&dist2(b, from)))
        .map(|(i, _)| i)
        .unwrap()
}

fn second_farthest(pts: &[Vec<f64>], from: &[f64], skip: usize) -> usize {
    pts.iter()
        .enumerate()
        .filter(|(i, _)| *i != skip)
        .max_by(|(_, a), (_, b)| dist2(a, from).total_cmp(&dist2(b, from)))
        .map(|(i, _)| i)
        .unwrap()
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};
    use crate::optim::random::RandomSearch;
    use crate::util::rng::Rng;

    fn space4() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    #[test]
    fn converges_on_smooth_bowl() {
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.62).powi(2)).sum()
        });
        let out = Driver::new(80)
            .run(&mut Bobyqa::default(), &space, &mut obj)
            .unwrap();
        assert!(out.best_value < 0.01, "bobyqa stuck at {}", out.best_value);
    }

    #[test]
    fn converges_under_noise() {
        // the paper's core claim: DFO tolerates noisy runtimes
        let space = space4();
        let sp = space.clone();
        let mut noise = Rng::new(3);
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            let clean: f64 = sp.encode(c).iter().map(|u| (u - 0.4).powi(2)).sum();
            (1.0 + clean) * noise.lognormal(0.0, 0.03)
        });
        let out = Driver::new(120)
            .run(&mut Bobyqa::default(), &space, &mut obj)
            .unwrap();
        // best observed should be close to the noise floor around 1.0
        assert!(out.best_value < 1.06, "noisy bobyqa best {}", out.best_value);
    }

    #[test]
    fn handles_optimum_on_boundary() {
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (1.0 - u).powi(2)).sum()
        });
        let out = Driver::new(100)
            .run(&mut Bobyqa::default(), &space, &mut obj)
            .unwrap();
        assert!(
            out.best_value < 0.02,
            "boundary optimum missed: {}",
            out.best_value
        );
        for r in &out.records {
            assert!(r.unit_x.iter().all(|&u| (0.0..=1.0).contains(&u)));
        }
    }

    #[test]
    fn beats_random_on_same_budget() {
        let space = space4();
        let sp = space.clone();
        let mk_obj = move || {
            let sp = sp.clone();
            FnObjective(move |c: &HadoopConfig| -> f64 {
                let u = sp.encode(c);
                let mut s = 0.0;
                for i in 0..u.len() {
                    s += (u[i] - 0.35).powi(2) * (1.0 + i as f64);
                }
                s
            })
        };
        let budget = 60;
        let mut o1 = mk_obj();
        let bq = Driver::new(budget)
            .run(&mut Bobyqa::default(), &space, &mut o1)
            .unwrap()
            .best_value;
        let mut o2 = mk_obj();
        let rnd = Driver::new(budget)
            .run(&mut RandomSearch::new(1), &space, &mut o2)
            .unwrap()
            .best_value;
        assert!(bq <= rnd, "bobyqa {bq} worse than random {rnd}");
    }

    #[test]
    fn budget_respected_exactly() {
        let space = space4();
        let mut obj = FnObjective(|_: &HadoopConfig| 1.0);
        let out = Driver::new(25)
            .run(&mut Bobyqa::default(), &space, &mut obj)
            .unwrap();
        assert!(out.evals() <= 25);
        assert!(out.evals() >= 20, "should use most of the budget");
    }

    #[test]
    fn init_design_is_one_batch_then_singletons() {
        let space = space4();
        let n = space.dims();
        let mut bob = Bobyqa::default();
        let init = bob.ask(&space, 100);
        assert_eq!(init.len(), 2 * n + 1, "init design should be one batch");
        let records: Vec<EvalRecord> = init
            .iter()
            .enumerate()
            .map(|(i, c)| EvalRecord {
                iter: i + 1,
                config: space.decode(&c.unit_x),
                unit_x: c.unit_x.clone(),
                value: 1.0 + i as f64,
                best_so_far: 1.0,
                fidelity: Fidelity::Full,
            })
            .collect();
        bob.tell(&records);
        for _ in 0..5 {
            let b = bob.ask(&space, 100);
            assert_eq!(b.len(), 1, "post-init asks must be singletons");
            bob.tell(&[EvalRecord {
                iter: 1,
                config: space.decode(&b[0].unit_x),
                unit_x: b[0].unit_x.clone(),
                value: 2.0,
                best_so_far: 1.0,
                fidelity: Fidelity::Full,
            }]);
        }
    }

    #[test]
    fn init_design_survives_chunked_tells() {
        // an early-stopping driver tells one ask-batch back in
        // patience-sized chunks; every chunk must enter the design
        let space = space4();
        let mk_records = |init: &[Candidate]| -> Vec<EvalRecord> {
            init.iter()
                .enumerate()
                .map(|(i, c)| EvalRecord {
                    iter: i + 1,
                    config: space.decode(&c.unit_x),
                    unit_x: c.unit_x.clone(),
                    value: 9.0 - i as f64 * 0.5,
                    best_so_far: 9.0,
                    fidelity: Fidelity::Full,
                })
                .collect()
        };
        let mut whole = Bobyqa::default();
        let records = mk_records(&whole.ask(&space, 100));
        whole.tell(&records);

        let mut chunked = Bobyqa::default();
        let records2 = mk_records(&chunked.ask(&space, 100));
        for chunk in records2.chunks(2) {
            chunked.tell(chunk);
        }

        // same design absorbed -> same deterministic next proposal
        let a = whole.ask(&space, 100);
        let b = chunked.ask(&space, 100);
        assert_eq!(a.len(), 1);
        assert_eq!(
            a[0].unit_x, b[0].unit_x,
            "chunked init tells diverged from one-batch tell"
        );
    }
}

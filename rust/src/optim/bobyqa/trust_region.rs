//! Box-constrained trust-region subproblem:
//!
//!   minimize q(s)  subject to  ‖s‖₂ ≤ Δ  and  lo ≤ s ≤ hi
//!
//! solved by projected gradient descent with backtracking from the Cauchy
//! point — not Powell's TRSBOX, but the same contract: a feasible step
//! with guaranteed model decrease. Dimensions here are ≤ 10, so a few
//! dozen projected-gradient iterations reach the subproblem's practical
//! optimum far faster than the cluster evaluation it precedes.

use super::model::QuadModel;
use crate::util::linalg::norm2;

/// Project `s` onto { ‖s‖ ≤ delta } ∩ [lo, hi] (box first, then ball —
/// iterating the pair twice is enough at these scales).
fn project(s: &mut [f64], delta: f64, lo: &[f64], hi: &[f64]) {
    for _ in 0..2 {
        for i in 0..s.len() {
            s[i] = s[i].clamp(lo[i], hi[i]);
        }
        let n = norm2(s);
        if n > delta && n > 0.0 {
            let k = delta / n;
            for v in s.iter_mut() {
                *v *= k;
            }
        }
    }
}

/// Solve the subproblem; returns (step, predicted_reduction ≥ 0).
pub fn solve(model: &QuadModel, delta: f64, lo: &[f64], hi: &[f64]) -> (Vec<f64>, f64) {
    let n = model.g.len();
    let q0 = model.eval_step(&vec![0.0; n]);
    let mut s = vec![0.0; n];
    let mut qs = q0;

    // initial step size from gradient scale
    let g0 = model.grad_step(&s);
    let gnorm = norm2(&g0).max(1e-12);
    let mut t = (delta / gnorm).min(1.0);

    for _ in 0..60 {
        let g = model.grad_step(&s);
        if norm2(&g) < 1e-10 {
            break;
        }
        // backtracking line search on the projected path
        let mut improved = false;
        let mut tt = t;
        for _ in 0..20 {
            let mut cand: Vec<f64> = s.iter().zip(&g).map(|(si, gi)| si - tt * gi).collect();
            project(&mut cand, delta, lo, hi);
            let qc = model.eval_step(&cand);
            if qc < qs - 1e-15 {
                s = cand;
                qs = qc;
                improved = true;
                t = tt * 1.5; // be a bit more aggressive next iteration
                break;
            }
            tt *= 0.5;
        }
        if !improved {
            break;
        }
    }
    (s, (q0 - qs).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::bobyqa::model::fit_min_frobenius;
    use crate::util::linalg::norm2;

    fn bowl_model(center: &[f64], target: &[f64], delta: f64) -> QuadModel {
        let n = center.len();
        let mut pts = vec![center.to_vec()];
        for i in 0..n {
            for d in [delta, -delta] {
                let mut p = center.to_vec();
                p[i] += d;
                pts.push(p);
            }
        }
        let f = |x: &[f64]| -> f64 {
            x.iter().zip(target).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        let vals: Vec<f64> = pts.iter().map(|p| f(p)).collect();
        fit_min_frobenius(&pts, &vals, center).unwrap()
    }

    #[test]
    fn unconstrained_minimum_inside_region() {
        let m = bowl_model(&[0.5, 0.5], &[0.55, 0.45], 0.1);
        let (s, red) = solve(&m, 0.5, &[-0.5, -0.5], &[0.5, 0.5]);
        assert!(red > 0.0);
        assert!((s[0] - 0.05).abs() < 1e-3, "s {s:?}");
        assert!((s[1] + 0.05).abs() < 1e-3, "s {s:?}");
    }

    #[test]
    fn respects_trust_radius() {
        let m = bowl_model(&[0.5, 0.5], &[5.0, 5.0], 0.1); // far-away target
        let (s, red) = solve(&m, 0.2, &[-0.5, -0.5], &[0.5, 0.5]);
        assert!(red > 0.0);
        assert!(norm2(&s) <= 0.2 + 1e-9, "|s| = {}", norm2(&s));
    }

    #[test]
    fn respects_box() {
        let m = bowl_model(&[0.9, 0.9], &[2.0, 2.0], 0.05);
        let lo = vec![-0.9, -0.9];
        let hi = vec![0.1, 0.1]; // box: x <= 1.0
        let (s, _) = solve(&m, 1.0, &lo, &hi);
        assert!(s[0] <= 0.1 + 1e-9 && s[1] <= 0.1 + 1e-9, "s {s:?}");
    }

    #[test]
    fn zero_gradient_returns_zero_step() {
        let m = bowl_model(&[0.5, 0.5], &[0.5, 0.5], 0.1); // already optimal
        let (s, red) = solve(&m, 0.3, &[-0.5, -0.5], &[0.5, 0.5]);
        assert!(norm2(&s) < 1e-6, "s {s:?}");
        assert!(red.abs() < 1e-9);
    }
}

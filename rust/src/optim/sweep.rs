//! The exploratory coordinate-sweep sub-machine shared by the
//! direct-search methods: probe ±step along one dimension at a time,
//! adopting strict improvements immediately (which ends that dimension's
//! probes), flipping direction then advancing on failure. CoordinateSearch
//! runs it sweep after sweep with step halving; Hooke–Jeeves runs one
//! sweep per exploratory move.

#[derive(Clone, Debug)]
pub(crate) struct Sweep {
    /// Current point (updated as improvements are adopted).
    pub(crate) x: Vec<f64>,
    /// Value at `x`.
    pub(crate) fx: f64,
    i: usize,
    dir: usize, // 0 → +step, 1 → −step
    pending: Option<Vec<f64>>,
}

impl Sweep {
    pub(crate) fn new(x: Vec<f64>, fx: f64) -> Sweep {
        Sweep {
            x,
            fx,
            i: 0,
            dir: 0,
            pending: None,
        }
    }

    /// Begin a fresh sweep from the current point.
    pub(crate) fn restart(&mut self) {
        self.i = 0;
        self.dir = 0;
        self.pending = None;
    }

    /// Next probe point, or None when the sweep is exhausted. Probes that
    /// clamp back onto the current point are skipped.
    pub(crate) fn next_probe(&mut self, step: f64) -> Option<Vec<f64>> {
        let d = self.x.len();
        while self.i < d {
            while self.dir < 2 {
                let sign = if self.dir == 0 { 1.0 } else { -1.0 };
                let cand = (self.x[self.i] + sign * step).clamp(0.0, 1.0);
                if (cand - self.x[self.i]).abs() < 1e-12 {
                    self.dir += 1;
                    continue;
                }
                let mut xc = self.x.clone();
                xc[self.i] = cand;
                self.pending = Some(xc.clone());
                return Some(xc);
            }
            self.i += 1;
            self.dir = 0;
        }
        None
    }

    /// Absorb the value of the last probe returned by [`Sweep::next_probe`].
    pub(crate) fn absorb(&mut self, value: f64) {
        let xc = self.pending.take().expect("absorb without probe");
        if value < self.fx {
            self.x = xc;
            self.fx = value;
            self.i += 1; // improvement ends this dimension's probes
            self.dir = 0;
        } else {
            self.dir += 1;
            if self.dir > 1 {
                self.dir = 0;
                self.i += 1;
            }
        }
    }

    /// Is a probe outstanding (asked but not yet absorbed)?
    pub(crate) fn awaiting(&self) -> bool {
        self.pending.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvement_moves_to_next_dimension_with_updated_point() {
        let mut s = Sweep::new(vec![0.5, 0.5], 10.0);
        let p1 = s.next_probe(0.25).unwrap();
        assert_eq!(p1, vec![0.75, 0.5]);
        s.absorb(9.0); // improvement: adopt, move to dim 1
        let p2 = s.next_probe(0.25).unwrap();
        assert_eq!(p2, vec![0.75, 0.75]);
        s.absorb(9.5); // worse: flip direction on dim 1
        let p3 = s.next_probe(0.25).unwrap();
        assert_eq!(p3, vec![0.75, 0.25]);
        s.absorb(9.5); // worse again: sweep exhausted
        assert!(s.next_probe(0.25).is_none());
        assert_eq!(s.x, vec![0.75, 0.5]);
        assert_eq!(s.fx, 9.0);
    }

    #[test]
    fn clamped_probes_are_skipped() {
        let mut s = Sweep::new(vec![1.0], 5.0);
        // +step clamps onto x → skipped; −step is the only probe
        let p = s.next_probe(0.25).unwrap();
        assert_eq!(p, vec![0.75]);
        s.absorb(6.0);
        assert!(s.next_probe(0.25).is_none());
    }
}

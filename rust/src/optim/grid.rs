//! Exhaustive (direct) search over the spec's parameter grid — the
//! paper's "direct search" family: "the system tries all combinations of
//! parameter values" (§II.C.2). Also the generator of Fig. 2 surfaces.
//!
//! Ask/tell port: the whole remaining grid is proposed as ONE batch (the
//! driver truncates it to the budget), so a batched objective can score
//! the sweep in a single call. Points told before the first ask (resume
//! replay) are skipped — that is how an interrupted sweep continues.

use std::collections::BTreeSet;

use crate::config::params::HadoopConfig;
use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::EvalRecord;
use crate::optim::space::ParamSpace;

#[derive(Clone, Debug, Default)]
pub struct GridSearch {
    points: Option<Vec<Vec<f64>>>,
    cursor: usize,
    /// Decoded-config keys already evaluated (tell / resume replay).
    done: BTreeSet<String>,
    best: BestSeen,
}

fn config_key(cfg: &HadoopConfig) -> String {
    format!("{:?}", cfg.values)
}

impl GridSearch {
    pub fn new() -> GridSearch {
        GridSearch::default()
    }
}

impl Optimizer for GridSearch {
    fn name(&self) -> &str {
        "grid"
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        let points = self
            .points
            .get_or_insert_with(|| space.unit_grid());
        // Decoded-config keys are only needed when distinct grid points
        // can collapse to one config (constraint repair) or a resume
        // replay marked points done — fresh unconstrained sweeps skip
        // the per-point decode + key allocation entirely.
        let need_keys = !self.done.is_empty() || !space.spec.constraints.is_empty();
        let mut batch = Vec::new();
        let mut batch_keys = BTreeSet::new();
        while self.cursor < points.len() && batch.len() < budget_left {
            let x = points[self.cursor].clone();
            self.cursor += 1;
            if need_keys {
                let key = config_key(&space.decode(&x));
                if self.done.contains(&key) || !batch_keys.insert(key) {
                    // evaluated before the interruption, or a duplicate
                    // of a config already in this batch
                    continue;
                }
            }
            batch.push(Candidate::new(x));
        }
        batch
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        for r in evals {
            self.done.insert(config_key(&r.config));
        }
        self.best.update(evals);
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{P_IO_SORT_MB, P_REDUCES};
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    fn space() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default())
    }

    #[test]
    fn visits_every_grid_point_once() {
        let space = space();
        let mut seen = std::collections::BTreeSet::new();
        let mut obj = FnObjective(|c: &HadoopConfig| {
            seen.insert((c.get(P_REDUCES) as i64, c.get(P_IO_SORT_MB) as i64));
            1.0
        });
        let out = Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .unwrap();
        drop(obj);
        assert_eq!(out.evals(), 256);
        assert_eq!(seen.len(), 256, "grid points not distinct");
    }

    #[test]
    fn finds_grid_optimum() {
        let space = space();
        // minimum at reduces=32, sort.mb=800 (paper's Fig.2 trend corner)
        let mut obj = FnObjective(|c: &HadoopConfig| {
            (32.0 - c.get(P_REDUCES)) + (800.0 - c.get(P_IO_SORT_MB)) / 100.0
        });
        let out = Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .unwrap();
        assert_eq!(out.best_config.get(P_REDUCES), 32.0);
        assert_eq!(out.best_config.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn respects_budget() {
        let space = space();
        let mut obj = FnObjective(|_: &HadoopConfig| 1.0);
        let out = Driver::new(10)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .unwrap();
        assert_eq!(out.evals(), 10);
    }

    #[test]
    fn asks_the_whole_remaining_grid_in_one_batch() {
        let space = space();
        let mut g = GridSearch::new();
        let batch = g.ask(&space, usize::MAX);
        assert_eq!(batch.len(), 256);
        assert!(g.ask(&space, usize::MAX).is_empty(), "grid re-proposed points");
    }

    #[test]
    fn constraint_collapsed_grid_points_are_deduped_within_a_batch() {
        // distinct grid points that repair to the same config must not
        // each spend an evaluation
        let spec = crate::config::spec::TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024 log\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             constraint io.sort.mb <= 0.7*map.memory.mb\n",
        )
        .unwrap();
        let space = ParamSpace::new(spec, HadoopConfig::default());
        let mut g = GridSearch::new();
        let batch = g.ask(&space, usize::MAX);
        let mut keys: Vec<String> = batch
            .iter()
            .map(|c| config_key(&space.decode(&c.unit_x)))
            .collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate decoded configs in one ask-batch");
        assert!(n < space.unit_grid().len(), "constraint collapsed nothing?");
    }

    #[test]
    fn told_points_are_skipped_on_resume() {
        let space = space();
        let grid = space.unit_grid();
        // replay the first 10 points as prior history
        let prior: Vec<EvalRecord> = grid[..10]
            .iter()
            .enumerate()
            .map(|(i, x)| EvalRecord {
                iter: i + 1,
                config: space.decode(x),
                unit_x: x.clone(),
                value: 1.0,
                best_so_far: 1.0,
            })
            .collect();
        let mut g = GridSearch::new();
        g.tell(&prior);
        let batch = g.ask(&space, usize::MAX);
        assert_eq!(batch.len(), 246, "prior points not skipped");
    }
}

//! Exhaustive (direct) search over the spec's parameter grid — the
//! paper's "direct search" family: "the system tries all combinations of
//! parameter values" (§II.C.2). Also the generator of Fig. 2 surfaces.
//!
//! Streaming ask/tell: grid points come off a lazy [`GridCursor`]
//! odometer, at most one `batch.chunk` (default
//! [`DEFAULT_BATCH_CHUNK`]) per ask — a >10^6-point space sweeps in
//! O(dims) enumeration memory instead of materializing the cross
//! product. Points told before the first ask (resume replay) are skipped
//! by bit-exact config key — that is how an interrupted sweep continues.

use std::collections::BTreeSet;

use crate::config::params::HadoopConfig;
use crate::optim::core::{BestSeen, Candidate, Optimizer, DEFAULT_BATCH_CHUNK};
use crate::optim::result::{EvalRecord, Fidelity};
use crate::optim::space::{GridCursor, ParamSpace};
use crate::util::fingerprint::config_value_key;

#[derive(Clone, Debug)]
pub struct GridSearch {
    cursor: Option<GridCursor>,
    /// Max points proposed per ask (the driver's `batch.chunk`).
    chunk: usize,
    /// Sweep only stripe `k` of `n` ([`GridCursor::shard`]): shard
    /// unions partition the full grid exactly, so independent processes
    /// (`catla sweep --shard k/n`) can split an exhaustive sweep.
    shard: Option<(u64, u64)>,
    /// Does this sweep dedup by decoded config? Latched at the first
    /// ask: constraints can collapse distinct grid points onto one
    /// config, and a tell arriving before the first ask (resume replay)
    /// marks points done. Without either, the cursor is injective and
    /// ALL key bookkeeping — per-point decode in ask, hashing, `done`
    /// growth — is skipped for the whole sweep.
    need_keys: Option<bool>,
    /// Bit-exact keys of decoded configs already evaluated (tell /
    /// resume replay). Stays empty when `need_keys` latches false.
    /// Ordered set (detlint `hash-collections`): membership-only here,
    /// but hash-iteration order must never be one accident away from an
    /// eval sequence.
    done: BTreeSet<u64>,
    best: BestSeen,
}

impl Default for GridSearch {
    fn default() -> GridSearch {
        GridSearch::new()
    }
}

/// Bit-exact dedup key: FNV-1a over the raw value bits of the decoded
/// config ([`config_value_key`], shared with the serve daemon's
/// simulation memo-cache). Replaces the old `format!("{:?}", values)`
/// string keys — no formatting, no per-key heap string, and exact (two
/// configs share a key iff every value is bit-identical, up to the
/// ~2^-64 hash-collision odds a 64-bit key carries).
fn config_key(cfg: &HadoopConfig) -> u64 {
    config_value_key(&cfg.values)
}

impl GridSearch {
    pub fn new() -> GridSearch {
        GridSearch {
            cursor: None,
            chunk: DEFAULT_BATCH_CHUNK,
            shard: None,
            need_keys: None,
            done: BTreeSet::new(),
            best: BestSeen::default(),
        }
    }

    /// Restrict this sweep to stripe `k` of `n` of the grid (points
    /// `k, k+n, k+2n, …` in cursor order). Shards partition the grid
    /// exactly — run one process per shard to split an exhaustive sweep.
    pub fn sharded(mut self, k: u64, n: u64) -> GridSearch {
        assert!(n > 0 && k < n, "sharded({k}, {n}): need 0 <= k < n");
        self.shard = Some((k, n));
        self
    }

    /// Bound the number of points proposed per ask when driving the
    /// optimizer BY HAND (direct `ask` calls in tests/tools). A
    /// [`Driver`](crate::optim::core::Driver) overrides this before its
    /// first ask — it pushes its own `batch.chunk` through
    /// [`Optimizer::set_chunk`] — so driver-run sweeps configure the
    /// chunk on the driver (`Driver::chunk` / `batch.chunk` in
    /// tuning.properties), not here.
    pub fn with_chunk(mut self, chunk: usize) -> GridSearch {
        self.chunk = chunk.max(1);
        self
    }
}

impl Optimizer for GridSearch {
    fn name(&self) -> &str {
        "grid"
    }

    fn set_chunk(&mut self, chunk: usize) {
        self.chunk = chunk.max(1);
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        // Latch the dedup mode on the first ask (see the field docs):
        // fresh unconstrained sweeps skip the per-point decode entirely
        // (the driver decodes instead). When a point IS decoded here,
        // the candidate carries the config so nothing decodes twice.
        let need_keys = *self
            .need_keys
            .get_or_insert(!self.done.is_empty() || !space.spec.constraints.is_empty());
        let shard = self.shard;
        let cursor = self.cursor.get_or_insert_with(|| match shard {
            Some((k, n)) => space.grid_cursor().shard(k, n),
            None => space.grid_cursor(),
        });
        let want = budget_left.min(self.chunk);
        let mut batch = Vec::with_capacity(want.min(DEFAULT_BATCH_CHUNK));
        let mut batch_keys = BTreeSet::new();
        while batch.len() < want {
            let x = match cursor.next() {
                Some(x) => x,
                None => break, // sweep complete
            };
            if need_keys {
                let cfg = space.decode(&x);
                let key = config_key(&cfg);
                if self.done.contains(&key) || !batch_keys.insert(key) {
                    // evaluated before the interruption, or a duplicate
                    // of a config already in this batch
                    continue;
                }
                batch.push(Candidate::new(x).with_config(cfg));
            } else {
                batch.push(Candidate::new(x));
            }
        }
        batch
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        // keys are recorded before the first ask (this could be a resume
        // replay) and for deduping sweeps; a latched-injective sweep
        // skips the per-eval hash + set growth
        if self.need_keys.unwrap_or(true) {
            for r in evals {
                self.done.insert(config_key(&r.config));
            }
        }
        self.best.update(evals);
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{P_IO_SORT_MB, P_REDUCES};
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    fn space() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default())
    }

    #[test]
    fn visits_every_grid_point_once() {
        let space = space();
        let mut seen = std::collections::BTreeSet::new();
        let mut obj = FnObjective(|c: &HadoopConfig| {
            seen.insert((c.get(P_REDUCES) as i64, c.get(P_IO_SORT_MB) as i64));
            1.0
        });
        let out = Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .unwrap();
        drop(obj);
        assert_eq!(out.evals(), 256);
        assert_eq!(seen.len(), 256, "grid points not distinct");
    }

    #[test]
    fn finds_grid_optimum() {
        let space = space();
        // minimum at reduces=32, sort.mb=800 (paper's Fig.2 trend corner)
        let mut obj = FnObjective(|c: &HadoopConfig| {
            (32.0 - c.get(P_REDUCES)) + (800.0 - c.get(P_IO_SORT_MB)) / 100.0
        });
        let out = Driver::new(usize::MAX)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .unwrap();
        assert_eq!(out.best_config.get(P_REDUCES), 32.0);
        assert_eq!(out.best_config.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn respects_budget() {
        let space = space();
        let mut obj = FnObjective(|_: &HadoopConfig| 1.0);
        let out = Driver::new(10)
            .run(&mut GridSearch::new(), &space, &mut obj)
            .unwrap();
        assert_eq!(out.evals(), 10);
    }

    #[test]
    fn asks_stream_in_cursor_order_up_to_the_chunk() {
        let space = space();
        // default chunk (1024) covers the whole 256-point grid in one ask
        let mut g = GridSearch::new();
        let batch = g.ask(&space, usize::MAX);
        assert_eq!(batch.len(), 256);
        assert!(g.ask(&space, usize::MAX).is_empty(), "grid re-proposed points");

        // a smaller chunk streams the same points over several asks
        let mut s = GridSearch::new().with_chunk(100);
        let mut streamed: Vec<Vec<f64>> = Vec::new();
        let mut sizes = Vec::new();
        loop {
            let b = s.ask(&space, usize::MAX);
            if b.is_empty() {
                break;
            }
            sizes.push(b.len());
            streamed.extend(b.into_iter().map(|c| c.unit_x));
        }
        assert_eq!(sizes, vec![100, 100, 56]);
        let whole: Vec<Vec<f64>> = batch.into_iter().map(|c| c.unit_x).collect();
        assert_eq!(streamed, whole, "chunked stream diverged from one-shot ask");
    }

    #[test]
    fn enumeration_memory_stays_bounded_on_huge_spaces() {
        // ~5.2M-point space: the old materialized grid would allocate
        // >300 MB here; the streaming ask must touch only one chunk
        let spec = TuningSpec::parse(
            "param mapreduce.job.reduces int 1 64 step 1\n\
             param mapreduce.task.io.sort.mb int 16 2048 step 4\n\
             param mapreduce.task.io.sort.factor int 2 128 step 1\n",
        )
        .unwrap();
        let space = ParamSpace::new(spec, HadoopConfig::default());
        assert!(space.grid_cursor().total_points() > 4_000_000);
        let mut g = GridSearch::new();
        let batch = g.ask(&space, usize::MAX);
        assert_eq!(batch.len(), DEFAULT_BATCH_CHUNK);
        // telling results back on an injective (unconstrained, fresh)
        // sweep must not start key bookkeeping: later chunks stay
        // decode-free and `done` stays empty for the whole sweep
        let recs: Vec<EvalRecord> = batch
            .iter()
            .take(3)
            .map(|c| EvalRecord {
                iter: 1,
                config: space.decode(&c.unit_x),
                unit_x: c.unit_x.clone(),
                value: 1.0,
                best_so_far: 1.0,
                fidelity: Fidelity::Full,
            })
            .collect();
        g.tell(&recs);
        assert!(g.done.is_empty(), "injective sweep accumulated dedup keys");
        // and the sweep continues exactly where it stopped
        let again = g.ask(&space, usize::MAX);
        assert_eq!(again.len(), DEFAULT_BATCH_CHUNK);
        assert_ne!(batch[0].unit_x, again[0].unit_x);
    }

    #[test]
    fn constraint_collapsed_grid_points_are_deduped_within_a_batch() {
        // distinct grid points that repair to the same config must not
        // each spend an evaluation
        let spec = crate::config::spec::TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024 log\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             constraint io.sort.mb <= 0.7*map.memory.mb\n",
        )
        .unwrap();
        let space = ParamSpace::new(spec, HadoopConfig::default());
        let mut g = GridSearch::new();
        let batch = g.ask(&space, usize::MAX);
        let mut keys: Vec<u64> = batch
            .iter()
            .map(|c| config_key(c.config.as_ref().expect("dedup decoded the config")))
            .collect();
        let n = keys.len();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate decoded configs in one ask-batch");
        assert!(
            (n as u64) < space.grid_cursor().total_points(),
            "constraint collapsed nothing?"
        );
    }

    #[test]
    fn sharded_searches_partition_the_grid() {
        let space = space();
        let n = 3u64;
        let mut seen: Vec<(i64, i64)> = Vec::new();
        for k in 0..n {
            let mut obj = FnObjective(|c: &HadoopConfig| {
                seen.push((c.get(P_REDUCES) as i64, c.get(P_IO_SORT_MB) as i64));
                1.0
            });
            let out = Driver::new(usize::MAX)
                .run(&mut GridSearch::new().sharded(k, n), &space, &mut obj)
                .unwrap();
            assert!(out.evals() > 0);
        }
        assert_eq!(seen.len(), 256, "shards did not cover the grid");
        let distinct: std::collections::BTreeSet<_> = seen.iter().collect();
        assert_eq!(distinct.len(), 256, "shards overlapped");
    }

    #[test]
    fn told_points_are_skipped_on_resume() {
        let space = space();
        let grid: Vec<Vec<f64>> = space.grid_cursor().take(10).collect();
        // replay the first 10 points as prior history
        let prior: Vec<EvalRecord> = grid
            .iter()
            .enumerate()
            .map(|(i, x)| EvalRecord {
                iter: i + 1,
                config: space.decode(x),
                unit_x: x.clone(),
                value: 1.0,
                best_so_far: 1.0,
                fidelity: Fidelity::Full,
            })
            .collect();
        let mut g = GridSearch::new();
        g.tell(&prior);
        let batch = g.ask(&space, usize::MAX);
        assert_eq!(batch.len(), 246, "prior points not skipped");
    }
}

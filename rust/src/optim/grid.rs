//! Exhaustive (direct) search over the spec's parameter grid — the
//! paper's "direct search" family: "the system tries all combinations of
//! parameter values" (§II.C.2). Also the generator of Fig. 2 surfaces.

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;

#[derive(Clone, Debug, Default)]
pub struct GridSearch;

impl GridSearch {
    /// Evaluate every grid point (the budget caps runaway grids).
    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let mut rec = Recorder::new();
        for x in space.unit_grid() {
            if rec.evals() >= max_evals {
                break;
            }
            let cfg = space.decode(&x);
            let v = obj(&cfg);
            rec.record(x, cfg, v);
        }
        rec.finish("grid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{HadoopConfig, P_IO_SORT_MB, P_REDUCES};
    use crate::config::spec::TuningSpec;

    #[test]
    fn visits_every_grid_point_once() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut seen = std::collections::BTreeSet::new();
        let mut obj = |c: &HadoopConfig| {
            seen.insert((c.get(P_REDUCES) as i64, c.get(P_IO_SORT_MB) as i64));
            1.0
        };
        let out = GridSearch.run(&space, &mut obj, usize::MAX);
        assert_eq!(out.evals(), 256);
        assert_eq!(seen.len(), 256, "grid points not distinct");
    }

    #[test]
    fn finds_grid_optimum() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        // minimum at reduces=32, sort.mb=800 (paper's Fig.2 trend corner)
        let mut obj = |c: &HadoopConfig| {
            (32.0 - c.get(P_REDUCES)) + (800.0 - c.get(P_IO_SORT_MB)) / 100.0
        };
        let out = GridSearch.run(&space, &mut obj, usize::MAX);
        assert_eq!(out.best_config.get(P_REDUCES), 32.0);
        assert_eq!(out.best_config.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn respects_budget() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut obj = |_: &HadoopConfig| 1.0;
        let out = GridSearch.run(&space, &mut obj, 10);
        assert_eq!(out.evals(), 10);
    }
}

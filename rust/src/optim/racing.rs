//! Multi-fidelity racing: successive halving over cost-model →
//! low-seed → full-fidelity tiers.
//!
//! The DES is the expensive truth; `hadoop/costmodel` is a cheap
//! analytic oracle. Racing spends the cheap tiers first, so a wide
//! ask-batch reaches full fidelity only for the candidates that earn it
//! (BestConfig's wide-then-narrow sampling, arxiv 1710.03439; the
//! low-cost-predictor screening of Bao et al., arxiv 1808.06008):
//!
//! * **tier 0** — `costmodel::predict_runtime` scores the whole batch
//!   with zero simulations and only the top `keep` fraction advances.
//!   Refused (every candidate advances) when any tuned parameter is
//!   blind to the model — the wrapper is built without a scorer then.
//! * **tier 1** — each survivor simulates its *first* reserved seed.
//!   The top `keep` fraction of those one-seed scores advances.
//! * **tier 2** — survivors simulate their remaining `repeats - 1`
//!   seeds and report the full-fidelity mean. With `repeats == 1`,
//!   tier 1 already is full fidelity and there is no tier 2.
//!
//! Per tier, `keep = max(ceil(n / racing.eta), racing.min_tier_evals)`,
//! clamped to the field — eta-style halving with a floor so tiny fields
//! are never over-pruned. A singleton slice (every sequential DFO
//! method) degenerates to full fidelity, so racing cannot perturb
//! those methods at all.
//!
//! # Seed discipline (see docs/DETERMINISM.md)
//!
//! A raced slice reserves the **full** `n_cfgs * repeats` seed block up
//! front, exactly like a racing-off evaluation; racing only decides
//! which reserved seeds are actually simulated. Config `c`, repeat `r`
//! always owns seed `first + c * repeats + r`, so:
//!
//! * a promoted config's tier-1 seed is seed 0 of its block and tier 2
//!   adds seeds `1..repeats` — no seed is ever re-simulated, and the
//!   tier-k seed set is a prefix of the tier-k+1 set;
//! * finalists' full-fidelity values are byte-identical to what a
//!   racing-off run would have measured for them;
//! * the cluster's seed stream advances identically with racing on or
//!   off, so all later slices are unperturbed.
//!
//! The tier planner is the pure [`Race`] state machine; this wrapper
//! drives it against [`ClusterObjective`]'s pool, and the serve
//! daemon's `ServeSession` drives the identical machine through the
//! dispatcher's memo-cache — shared planner, so serve-vs-standalone
//! byte-identity holds by construction (`rust/tests/racing.rs`).

use crate::config::params::HadoopConfig;
use crate::optim::core::{BatchObjective, ClusterObjective};
use crate::optim::result::Fidelity;
use crate::optim::surrogate::CandidateScorer;

/// The `racing.*` knobs from `tuning.properties`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RacingSettings {
    /// `racing.enabled` — off by default: the driver is then
    /// byte-identical to a build without the racing layer.
    pub enabled: bool,
    /// `racing.eta` — halving rate: each tier keeps `ceil(n / eta)`.
    pub eta: usize,
    /// `racing.min_tier_evals` — promotion floor: no tier prunes the
    /// field below this many candidates.
    pub min_tier_evals: usize,
}

impl Default for RacingSettings {
    fn default() -> RacingSettings {
        RacingSettings {
            enabled: false,
            eta: 4,
            min_tier_evals: 2,
        }
    }
}

impl RacingSettings {
    pub fn validate(&self) -> Result<(), String> {
        if self.eta < 2 {
            return Err(format!("racing.eta must be >= 2, got {}", self.eta));
        }
        if self.min_tier_evals < 1 {
            return Err("racing.min_tier_evals must be >= 1".to_string());
        }
        Ok(())
    }
}

/// Number of candidates a tier promotes out of a field of `n`.
pub fn keep_count(n: usize, eta: usize, min_keep: usize) -> usize {
    n.div_ceil(eta.max(2)).max(min_keep.max(1)).min(n)
}

/// Rank `live` by `score` (ascending, ties by candidate index) and keep
/// the top of the field, returned in ascending candidate-index order so
/// downstream work is scheduled in ask order.
fn top_keep(
    live: &[usize],
    score: impl Fn(usize) -> f64,
    eta: usize,
    min_keep: usize,
) -> Vec<usize> {
    let k = keep_count(live.len(), eta, min_keep);
    if k == live.len() {
        return live.to_vec();
    }
    let mut ranked = live.to_vec();
    ranked.sort_by(|&a, &b| score(a).total_cmp(&score(b)).then(a.cmp(&b)));
    ranked.truncate(k);
    ranked.sort_unstable();
    ranked
}

/// One simulation a race wants run: candidate `cfg`'s repeat `rep`
/// (seed offset `cfg * repeats + rep` into the slice's seed block).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunRequest {
    pub cfg: usize,
    pub rep: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Stage {
    /// Tier 1 outstanding: every live candidate's first seed.
    Seed,
    /// Tier 2 outstanding: survivors' remaining `1..repeats` seeds.
    Confirm,
    Done,
}

/// The pure successive-halving planner for one ask-slice: which
/// simulations to run next, and the per-candidate (value, fidelity)
/// verdicts once finished. Both executors drive this same machine —
/// [`RacingObjective`] against the in-process pool, the serve daemon's
/// session against the dispatcher's memo-cache — so they cannot drift.
#[derive(Clone, Debug)]
pub struct Race {
    n: usize,
    repeats: usize,
    eta: usize,
    min_keep: usize,
    /// Tier-0 scores for the whole slice; `None` = tier 0 refused.
    model_scores: Option<Vec<f64>>,
    /// Simulated runtimes per candidate, in seed (repeat) order. A
    /// candidate's list is always a prefix of its reserved seed block.
    seed_vals: Vec<Vec<f64>>,
    live: Vec<usize>,
    pending: Vec<RunRequest>,
    stage: Stage,
}

impl Race {
    /// Plan a race over `n` candidates. With `model_scores`, tier 0
    /// prunes the field before any simulation; without (a blind
    /// parameter in the spec), every candidate enters tier 1 — the
    /// cheapest fidelity is then one seed.
    pub fn new(
        n: usize,
        repeats: usize,
        settings: &RacingSettings,
        model_scores: Option<Vec<f64>>,
    ) -> Race {
        assert!(n > 0, "cannot race an empty slice");
        if let Some(scores) = &model_scores {
            assert_eq!(scores.len(), n, "model score count != slice size");
        }
        let repeats = repeats.max(1);
        let eta = settings.eta.max(2);
        let min_keep = settings.min_tier_evals.max(1);
        let all: Vec<usize> = (0..n).collect();
        let live = match &model_scores {
            Some(scores) => top_keep(&all, |c| scores[c], eta, min_keep),
            None => all,
        };
        let pending = live.iter().map(|&c| RunRequest { cfg: c, rep: 0 }).collect();
        Race {
            n,
            repeats,
            eta,
            min_keep,
            model_scores,
            seed_vals: vec![Vec::new(); n],
            live,
            pending,
            stage: Stage::Seed,
        }
    }

    /// Simulations the current tier still needs, in candidate order.
    pub fn pending(&self) -> &[RunRequest] {
        &self.pending
    }

    pub fn is_finished(&self) -> bool {
        self.stage == Stage::Done
    }

    /// Candidates still in the running (ascending index order).
    pub fn live(&self) -> &[usize] {
        &self.live
    }

    /// Simulated runtimes candidate `c` has accumulated, in seed order.
    pub fn seed_values(&self, c: usize) -> &[f64] {
        &self.seed_vals[c]
    }

    /// Total simulations the race has run so far.
    pub fn sims(&self) -> usize {
        self.seed_vals.iter().map(Vec::len).sum()
    }

    /// Candidates that reached full fidelity.
    pub fn full_evals(&self) -> usize {
        self.seed_vals.iter().filter(|v| v.len() == self.repeats).count()
    }

    /// Feed back the runtimes for the outstanding [`Race::pending`]
    /// requests (same order), advancing the race one tier.
    pub fn absorb(&mut self, results: &[f64]) -> Result<(), String> {
        if self.stage == Stage::Done {
            return Err("race already finished".to_string());
        }
        if results.len() != self.pending.len() {
            return Err(format!(
                "race absorbed {} results for {} pending runs",
                results.len(),
                self.pending.len()
            ));
        }
        for (req, v) in self.pending.iter().zip(results) {
            self.seed_vals[req.cfg].push(*v);
        }
        self.pending.clear();
        match self.stage {
            Stage::Seed if self.repeats > 1 => {
                let sv = &self.seed_vals;
                let survivors = top_keep(&self.live, |c| sv[c][0], self.eta, self.min_keep);
                self.pending = survivors
                    .iter()
                    .flat_map(|&c| (1..self.repeats).map(move |rep| RunRequest { cfg: c, rep }))
                    .collect();
                self.live = survivors;
                self.stage = Stage::Confirm;
            }
            // repeats == 1: one seed IS full fidelity — no tier 2
            Stage::Seed | Stage::Confirm => self.stage = Stage::Done,
            Stage::Done => unreachable!(),
        }
        Ok(())
    }

    /// The per-candidate verdicts of a finished race: each candidate's
    /// highest-fidelity score and the tier it came from. Full-fidelity
    /// means use the exact `ClusterObjective` fold (sum over the seed
    /// block in seed order / repeats), so a finalist's value is
    /// byte-identical to a racing-off evaluation.
    pub fn values(&self) -> (Vec<f64>, Vec<Fidelity>) {
        debug_assert!(self.is_finished(), "values() on an unfinished race");
        let mut vals = Vec::with_capacity(self.n);
        let mut fids = Vec::with_capacity(self.n);
        for (c, sv) in self.seed_vals.iter().enumerate() {
            if sv.len() == self.repeats {
                vals.push(sv.iter().sum::<f64>() / self.repeats as f64);
                fids.push(Fidelity::Full);
            } else if !sv.is_empty() {
                vals.push(sv.iter().sum::<f64>() / sv.len() as f64);
                fids.push(Fidelity::Seeds(sv.len() as u32));
            } else {
                let m = self
                    .model_scores
                    .as_ref()
                    .expect("tier-0-pruned candidate without model scores");
                vals.push(m[c]);
                fids.push(Fidelity::CostModel);
            }
        }
        (vals, fids)
    }
}

/// Cumulative counters across a run's raced slices (reported by the
/// optimizer runner and the racing bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct RacingStats {
    pub slices: usize,
    pub candidates: usize,
    /// DES runs actually simulated.
    pub sims: usize,
    /// Candidates that reached full fidelity.
    pub full_evals: usize,
}

/// [`BatchObjective`] adapter that races each ask-slice through the
/// fidelity tiers against a [`ClusterObjective`]. With
/// `racing.enabled=false` (or no tiering-aware caller) it is a plain
/// pass-through — byte-identical to the wrapped objective.
pub struct RacingObjective<'a> {
    inner: ClusterObjective<'a>,
    /// Tier-0 oracle; `None` = tier 0 refused (some tuned parameter is
    /// blind to the cost model) and tier 1 is the cheapest fidelity.
    scorer: Option<Box<dyn CandidateScorer>>,
    settings: RacingSettings,
    stats: RacingStats,
}

impl<'a> RacingObjective<'a> {
    pub fn new(
        inner: ClusterObjective<'a>,
        settings: RacingSettings,
        scorer: Option<Box<dyn CandidateScorer>>,
    ) -> RacingObjective<'a> {
        RacingObjective {
            inner,
            scorer,
            settings,
            stats: RacingStats::default(),
        }
    }

    pub fn stats(&self) -> RacingStats {
        self.stats
    }

    /// Whether tier 0 is available (a scorer was supplied).
    pub fn has_tier0(&self) -> bool {
        self.scorer.is_some()
    }
}

impl BatchObjective for RacingObjective<'_> {
    /// Full-fidelity pass-through (used by non-tiering callers).
    fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        self.inner.eval_batch(cfgs)
    }

    fn eval_batch_tiered(
        &mut self,
        cfgs: &[HadoopConfig],
    ) -> Result<(Vec<f64>, Vec<Fidelity>), String> {
        if !self.settings.enabled || cfgs.is_empty() {
            // structurally the racing-off path: same eval_batch, same
            // all-Full labels as a plain ClusterObjective
            return self.inner.eval_batch_tiered(cfgs);
        }
        let repeats = self.inner.repeats();
        let model_scores = match self.scorer.as_mut() {
            Some(s) => {
                let scores = s.score(cfgs)?;
                if scores.len() != cfgs.len() {
                    return Err(format!(
                        "scorer {} returned {} scores for {} configs",
                        s.name(),
                        scores.len(),
                        cfgs.len()
                    ));
                }
                Some(scores)
            }
            None => None,
        };
        let mut race = Race::new(cfgs.len(), repeats, &self.settings, model_scores);
        // reserve the FULL seed block, exactly like eval_batch: racing
        // only chooses which reserved seeds get simulated
        let first = self.inner.reserve_block(cfgs.len());
        while !race.is_finished() {
            let jobs: Vec<(usize, u64)> = race
                .pending()
                .iter()
                .map(|r| (r.cfg, first.wrapping_add((r.cfg * repeats + r.rep) as u64)))
                .collect();
            let results = self.inner.run_jobs(cfgs, &jobs);
            race.absorb(&results)?;
        }
        self.stats.slices += 1;
        self.stats.candidates += cfgs.len();
        self.stats.sims += race.sims();
        self.stats.full_evals += race.full_evals();
        Ok(race.values())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::TuningSpec;
    use crate::hadoop::{ClusterSpec, SimCluster};
    use crate::optim::space::ParamSpace;
    use crate::workloads::wordcount;

    fn on(eta: usize, min_keep: usize) -> RacingSettings {
        RacingSettings {
            enabled: true,
            eta,
            min_tier_evals: min_keep,
        }
    }

    #[test]
    fn keep_count_halving_with_floor() {
        assert_eq!(keep_count(1024, 4, 2), 256);
        assert_eq!(keep_count(9, 4, 2), 3);
        assert_eq!(keep_count(4, 4, 2), 2); // floor wins over ceil(4/4)=1
        assert_eq!(keep_count(3, 4, 2), 2);
        assert_eq!(keep_count(2, 4, 2), 2);
        assert_eq!(keep_count(1, 4, 2), 1); // never exceeds the field
    }

    #[test]
    fn settings_validation() {
        assert!(on(2, 1).validate().is_ok());
        assert!(on(1, 2).validate().is_err());
        assert!(on(4, 0).validate().is_err());
        assert!(!RacingSettings::default().enabled);
    }

    #[test]
    fn race_prunes_by_model_then_seed_then_confirms() {
        // 8 candidates, repeats 3, eta 2: tier 0 keeps 4, tier 1 keeps 2
        let model: Vec<f64> = vec![8.0, 1.0, 7.0, 2.0, 6.0, 3.0, 5.0, 4.0];
        let mut race = Race::new(8, 3, &on(2, 2), Some(model));
        // best model scores: candidates 1, 3, 5, 7 — promoted in index order
        let t1: Vec<usize> = race.pending().iter().map(|r| r.cfg).collect();
        assert_eq!(t1, vec![1, 3, 5, 7]);
        assert!(race.pending().iter().all(|r| r.rep == 0));
        // tier-1 results invert the model's ranking for 5 and 7
        race.absorb(&[4.0, 3.0, 1.0, 2.0]).unwrap();
        assert_eq!(race.live(), &[5, 7]);
        let t2: Vec<(usize, usize)> = race.pending().iter().map(|r| (r.cfg, r.rep)).collect();
        assert_eq!(t2, vec![(5, 1), (5, 2), (7, 1), (7, 2)]);
        race.absorb(&[1.5, 2.5, 2.0, 3.0]).unwrap();
        assert!(race.is_finished());

        let (vals, fids) = race.values();
        // tier-0 losers carry the model score
        assert_eq!(fids[0], Fidelity::CostModel);
        assert_eq!(vals[0], 8.0);
        // tier-1 losers carry their one-seed score
        assert_eq!(fids[1], Fidelity::Seeds(1));
        assert_eq!(vals[1], 4.0);
        // finalists carry the full mean over all three seeds
        assert_eq!(fids[5], Fidelity::Full);
        assert_eq!(vals[5], (1.0 + 1.5 + 2.5) / 3.0);
        assert_eq!(fids[7], Fidelity::Full);
        assert_eq!(vals[7], (2.0 + 2.0 + 3.0) / 3.0);
        assert_eq!(race.sims(), 4 + 2 * 2);
        assert_eq!(race.full_evals(), 2);
    }

    #[test]
    fn tier_seed_sets_are_prefixes() {
        // the monotone-promotion invariant: a candidate's tier-k seed
        // list is a prefix of its tier-k+1 list (seed 0, then 1..repeats)
        let mut race = Race::new(4, 3, &on(2, 1), None);
        race.absorb(&[4.0, 1.0, 3.0, 2.0]).unwrap();
        let after_t1: Vec<Vec<f64>> = (0..4).map(|c| race.seed_values(c).to_vec()).collect();
        race.absorb(&[1.1, 1.2, 2.1, 2.2]).unwrap();
        for c in 0..4 {
            let now = race.seed_values(c);
            assert!(
                now.starts_with(&after_t1[c]),
                "candidate {c}: {after_t1:?} not a prefix of {now:?}"
            );
        }
        assert_eq!(race.seed_values(1), &[1.0, 1.1, 1.2]);
    }

    #[test]
    fn no_model_scores_sends_everyone_to_tier_one() {
        let race = Race::new(6, 2, &on(2, 2), None);
        assert_eq!(race.pending().len(), 6, "tier 0 refused: nobody pruned before a sim");
    }

    #[test]
    fn singleton_slice_degenerates_to_full_fidelity() {
        let mut race = Race::new(1, 3, &on(4, 2), Some(vec![5.0]));
        assert_eq!(race.pending().len(), 1);
        race.absorb(&[2.0]).unwrap();
        assert_eq!(race.live(), &[0]);
        race.absorb(&[3.0, 4.0]).unwrap();
        let (vals, fids) = race.values();
        assert_eq!(fids, vec![Fidelity::Full]);
        assert_eq!(vals[0], (2.0 + 3.0 + 4.0) / 3.0);
    }

    #[test]
    fn repeats_one_has_no_confirm_tier() {
        let mut race = Race::new(4, 1, &on(2, 1), None);
        race.absorb(&[4.0, 3.0, 2.0, 1.0]).unwrap();
        assert!(race.is_finished());
        let (_, fids) = race.values();
        assert_eq!(fids, vec![Fidelity::Full; 4]);
    }

    #[test]
    fn absorb_length_mismatch_is_an_error() {
        let mut race = Race::new(2, 1, &on(2, 1), None);
        assert!(race.absorb(&[1.0]).is_err());
    }

    /// Finalists' full-fidelity values must be byte-identical to a
    /// racing-off evaluation of the same slice on an identical cluster.
    #[test]
    fn finalists_match_racing_off_values_bitwise() {
        let wl = wordcount(2048.0);
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let cfgs: Vec<HadoopConfig> = (0..6)
            .map(|i| space.decode(&vec![i as f64 / 5.0; space.dims()]))
            .collect();

        let mut off_cluster = SimCluster::new(ClusterSpec::default());
        let mut off = ClusterObjective::new(&mut off_cluster, &wl, 3);
        let off_vals = off.eval_batch(&cfgs).unwrap();

        let mut on_cluster = SimCluster::new(ClusterSpec::default());
        let inner = ClusterObjective::new(&mut on_cluster, &wl, 3);
        let mut raced = RacingObjective::new(inner, on(2, 2), None);
        let (vals, fids) = raced.eval_batch_tiered(&cfgs).unwrap();

        let full: Vec<usize> = (0..6).filter(|&i| fids[i] == Fidelity::Full).collect();
        assert!(!full.is_empty(), "race promoted nobody");
        assert!(full.len() < 6, "race pruned nobody");
        for &i in &full {
            assert_eq!(
                vals[i].to_bits(),
                off_vals[i].to_bits(),
                "finalist {i} diverged from racing-off value"
            );
        }
        let st = raced.stats();
        assert_eq!(st.slices, 1);
        assert!(st.sims < 6 * 3, "racing simulated the whole block");
    }

    /// Racing advances the seed stream exactly like a full evaluation,
    /// so everything AFTER a raced slice is also unperturbed.
    #[test]
    fn seed_stream_advance_matches_racing_off() {
        let wl = wordcount(2048.0);
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let cfgs: Vec<HadoopConfig> = (0..4)
            .map(|i| space.decode(&vec![i as f64 / 3.0; space.dims()]))
            .collect();
        let probe = space.decode(&vec![0.5; space.dims()]);

        let mut off_cluster = SimCluster::new(ClusterSpec::default());
        let mut off = ClusterObjective::new(&mut off_cluster, &wl, 2);
        off.eval_batch(&cfgs).unwrap();
        let off_probe = off.eval_batch(std::slice::from_ref(&probe)).unwrap();

        let mut on_cluster = SimCluster::new(ClusterSpec::default());
        let inner = ClusterObjective::new(&mut on_cluster, &wl, 2);
        let mut raced = RacingObjective::new(inner, on(2, 1), None);
        raced.eval_batch_tiered(&cfgs).unwrap();
        let (on_probe, _) = raced.eval_batch_tiered(std::slice::from_ref(&probe)).unwrap();

        assert_eq!(off_probe[0].to_bits(), on_probe[0].to_bits());
    }

    #[test]
    fn disabled_racing_is_a_passthrough() {
        let wl = wordcount(2048.0);
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let cfgs: Vec<HadoopConfig> = (0..5)
            .map(|i| space.decode(&vec![i as f64 / 4.0; space.dims()]))
            .collect();

        let mut a_cluster = SimCluster::new(ClusterSpec::default());
        let mut plain = ClusterObjective::new(&mut a_cluster, &wl, 2);
        let want = plain.eval_batch(&cfgs).unwrap();

        let mut b_cluster = SimCluster::new(ClusterSpec::default());
        let inner = ClusterObjective::new(&mut b_cluster, &wl, 2);
        let mut off = RacingObjective::new(inner, RacingSettings::default(), None);
        let (got, fids) = off.eval_batch_tiered(&cfgs).unwrap();

        assert_eq!(fids, vec![Fidelity::Full; 5]);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_bits(), g.to_bits());
        }
        assert_eq!(off.stats().slices, 0, "disabled racing must not count slices");
    }
}

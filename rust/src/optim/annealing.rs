//! Simulated annealing — a stochastic global-search baseline that, unlike
//! the pattern searches, can escape the local basins the wave-boundary
//! fluctuations of the cost surface create.

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SimulatedAnnealing {
    pub seed: u64,
    /// Initial temperature as a fraction of the first sample's value.
    pub t0_fraction: f64,
    /// Geometric cooling rate per evaluation.
    pub cooling: f64,
    /// Initial proposal step (unit-cube units), shrinks with temperature.
    pub step0: f64,
}

impl Default for SimulatedAnnealing {
    fn default() -> Self {
        Self {
            seed: 17,
            t0_fraction: 0.10,
            cooling: 0.95,
            step0: 0.25,
        }
    }
}

impl SimulatedAnnealing {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }

    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let d = space.dims();
        let mut rng = Rng::new(self.seed);
        let mut rec = Recorder::new();
        let mut eval = |rec: &mut Recorder, x: &[f64]| -> f64 {
            let cfg = space.decode(x);
            let v = obj(&cfg);
            rec.record(x.to_vec(), cfg, v);
            v
        };

        let mut x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let mut fx = eval(&mut rec, &x);
        let t0 = (fx * self.t0_fraction).max(1e-9);
        let mut temp = t0;
        let mut step = self.step0;
        let mut since_improvement = 0usize;

        while rec.evals() < max_evals {
            // Gaussian proposal, clamped to the cube
            let cand: Vec<f64> = x
                .iter()
                .map(|v| (v + rng.normal() * step).clamp(0.0, 1.0))
                .collect();
            let fc = eval(&mut rec, &cand);
            let accept = fc < fx || {
                let p = ((fx - fc) / temp).exp();
                rng.bernoulli(p.min(1.0))
            };
            if accept {
                if fc < fx {
                    since_improvement = 0;
                } else {
                    since_improvement += 1;
                }
                x = cand;
                fx = fc;
            } else {
                since_improvement += 1;
            }
            temp *= self.cooling;
            step = (step * 0.995).max(0.01);
            // reheating: stuck in a basin -> restart from a random point
            if since_improvement >= 40 {
                x = (0..d).map(|_| rng.f64()).collect();
                fx = eval(&mut rec, &x);
                temp = t0;
                step = self.step0;
                since_improvement = 0;
            }
        }
        rec.finish("annealing")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;

    fn space4() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    #[test]
    fn converges_on_bowl() {
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.5).powi(2)).sum::<f64>() + 1.0
        };
        let out = SimulatedAnnealing::new(3).run(&space, &mut obj, 200);
        assert!(out.best_value < 1.03, "SA stuck at {}", out.best_value);
    }

    #[test]
    fn escapes_local_minimum() {
        // two-basin function: local basin at 0.2 (value 1.0),
        // global at 0.8 (value 0.5); start anywhere
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            let u = sp.encode(c);
            let d_local: f64 = u.iter().map(|v| (v - 0.2) * (v - 0.2)).sum();
            let d_global: f64 = u.iter().map(|v| (v - 0.8) * (v - 0.8)).sum();
            (1.0 + 4.0 * d_local).min(0.5 + 4.0 * d_global)
        };
        let out = SimulatedAnnealing::new(11).run(&space, &mut obj, 300);
        assert!(
            out.best_value < 0.8,
            "did not find the global basin: {}",
            out.best_value
        );
    }

    #[test]
    fn budget_exact_and_deterministic() {
        let space = space4();
        let mut obj = |c: &HadoopConfig| c.values.iter().sum::<f64>();
        let a = SimulatedAnnealing::new(5).run(&space, &mut obj, 50);
        let b = SimulatedAnnealing::new(5).run(&space, &mut obj, 50);
        assert_eq!(a.evals(), 50);
        assert_eq!(a.best_value, b.best_value);
    }
}

//! Simulated annealing — a stochastic global-search baseline that, unlike
//! the pattern searches, can escape the local basins the wave-boundary
//! fluctuations of the cost surface create.
//!
//! Ask/tell port: singleton asks; acceptance, cooling and reheating all
//! happen in `tell`, consuming the RNG stream in exactly the order the
//! old monolithic loop did — same seed, same trajectory.

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::EvalRecord;
use crate::optim::space::ParamSpace;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SimulatedAnnealing {
    pub seed: u64,
    /// Initial temperature as a fraction of the first sample's value.
    pub t0_fraction: f64,
    /// Geometric cooling rate per evaluation.
    pub cooling: f64,
    /// Initial proposal step (unit-cube units), shrinks with temperature.
    pub step0: f64,
    /// Starting point (defaults to a seed-derived random draw; set by
    /// checkpoint replay to the best prior point).
    pub start: Option<Vec<f64>>,
    st: Option<State>,
    best: BestSeen,
}

#[derive(Clone, Debug)]
struct State {
    rng: Rng,
    x: Vec<f64>,
    fx: f64,
    t0: f64,
    temp: f64,
    step: f64,
    since_improvement: usize,
    pending: Pending,
    /// A reheat drew a fresh random `x` that still needs evaluating.
    need_restart: bool,
}

#[derive(Clone, Debug)]
enum Pending {
    None,
    /// First sample (also re-used after a reheat restart).
    Restart,
    Proposal(Vec<f64>),
}

impl Default for SimulatedAnnealing {
    fn default() -> Self {
        Self {
            seed: 17,
            t0_fraction: 0.10,
            cooling: 0.95,
            step0: 0.25,
            start: None,
            st: None,
            best: BestSeen::default(),
        }
    }
}

impl SimulatedAnnealing {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }
}

impl Optimizer for SimulatedAnnealing {
    fn name(&self) -> &str {
        "annealing"
    }

    fn ask(&mut self, space: &ParamSpace, _budget_left: usize) -> Vec<Candidate> {
        let d = space.dims();
        let st = match &mut self.st {
            None => {
                let mut rng = Rng::new(self.seed);
                let x: Vec<f64> = self
                    .start
                    .clone()
                    .unwrap_or_else(|| (0..d).map(|_| rng.f64()).collect());
                self.st = Some(State {
                    rng,
                    x: x.clone(),
                    fx: f64::INFINITY,
                    t0: 0.0,
                    temp: 0.0,
                    step: self.step0,
                    since_improvement: 0,
                    pending: Pending::Restart,
                    need_restart: false,
                });
                return vec![Candidate::new(x)];
            }
            Some(st) => st,
        };
        if !matches!(st.pending, Pending::None) {
            return Vec::new(); // tell pending
        }
        if st.need_restart {
            // evaluate the reheat point before proposing again
            st.need_restart = false;
            st.pending = Pending::Restart;
            return vec![Candidate::new(st.x.clone())];
        }
        // Gaussian proposal, clamped to the cube
        let cand: Vec<f64> = st
            .x
            .iter()
            .map(|v| (v + st.rng.normal() * st.step).clamp(0.0, 1.0))
            .collect();
        st.pending = Pending::Proposal(cand.clone());
        vec![Candidate::new(cand)]
    }

    #[allow(clippy::float_cmp)] // t0 == 0.0 is the exact not-yet-set sentinel, never computed
    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
        let st = match &mut self.st {
            // told before the first ask (resume replay): seed the start
            None => {
                if let Some((x, _)) = self.best.get() {
                    self.start = Some(x);
                }
                return;
            }
            Some(st) => st,
        };
        for r in evals {
            let v = r.value;
            match std::mem::replace(&mut st.pending, Pending::None) {
                Pending::None => {}
                Pending::Restart => {
                    st.fx = v;
                    if st.t0 == 0.0 {
                        // very first sample sets the temperature scale
                        st.t0 = (v * self.t0_fraction).max(1e-9);
                        st.temp = st.t0;
                    }
                }
                Pending::Proposal(cand) => {
                    let accept = v < st.fx || {
                        let p = ((st.fx - v) / st.temp).exp();
                        st.rng.bernoulli(p.min(1.0))
                    };
                    if accept {
                        if v < st.fx {
                            st.since_improvement = 0;
                        } else {
                            st.since_improvement += 1;
                        }
                        st.x = cand;
                        st.fx = v;
                    } else {
                        st.since_improvement += 1;
                    }
                    st.temp *= self.cooling;
                    st.step = (st.step * 0.995).max(0.01);
                    // reheating: stuck in a basin -> restart from random
                    if st.since_improvement >= 40 {
                        let d = st.x.len();
                        let x: Vec<f64> = (0..d).map(|_| st.rng.f64()).collect();
                        st.x = x;
                        st.temp = st.t0;
                        st.step = self.step0;
                        st.since_improvement = 0;
                        st.need_restart = true;
                    }
                }
            }
        }
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    fn space4() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    #[test]
    fn converges_on_bowl() {
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.5).powi(2)).sum::<f64>() + 1.0
        });
        let out = Driver::new(200)
            .run(&mut SimulatedAnnealing::new(3), &space, &mut obj)
            .unwrap();
        assert!(out.best_value < 1.03, "SA stuck at {}", out.best_value);
    }

    #[test]
    fn escapes_local_minimum() {
        // two-basin function: local basin at 0.2 (value 1.0),
        // global at 0.8 (value 0.5); start anywhere
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            let u = sp.encode(c);
            let d_local: f64 = u.iter().map(|v| (v - 0.2) * (v - 0.2)).sum();
            let d_global: f64 = u.iter().map(|v| (v - 0.8) * (v - 0.8)).sum();
            (1.0 + 4.0 * d_local).min(0.5 + 4.0 * d_global)
        });
        let out = Driver::new(300)
            .run(&mut SimulatedAnnealing::new(11), &space, &mut obj)
            .unwrap();
        assert!(
            out.best_value < 0.8,
            "did not find the global basin: {}",
            out.best_value
        );
    }

    #[test]
    fn budget_exact_and_deterministic() {
        let space = space4();
        let mut obj = FnObjective(|c: &HadoopConfig| c.values.iter().sum::<f64>());
        let a = Driver::new(50)
            .run(&mut SimulatedAnnealing::new(5), &space, &mut obj)
            .unwrap();
        let b = Driver::new(50)
            .run(&mut SimulatedAnnealing::new(5), &space, &mut obj)
            .unwrap();
        assert_eq!(a.evals(), 50);
        assert_eq!(a.best_value, b.best_value);
    }
}

//! Surrogate prescreening: score thousands of candidate configurations
//! with the *analytic* cost model before spending any cluster
//! evaluations, then start the real optimizer from the best prediction.
//!
//! The scorer is a trait so the same code runs against the AOT-compiled
//! JAX/Pallas artifact through PJRT (`runtime::CostModelExec`, the hot
//! path) or against the native rust mirror (`NativeScorer`, always
//! available). ABL2 in EXPERIMENTS.md measures what prescreening saves.

use crate::config::params::HadoopConfig;
use crate::hadoop::{costmodel, ClusterSpec};
use crate::optim::result::TuningOutcome;
use crate::optim::space::ParamSpace;
use crate::optim::{Bobyqa, ObjectiveFn};
use crate::util::rng::Rng;
use crate::workloads::WorkloadSpec;

/// Anything that can batch-score configurations (lower = better).
pub trait CandidateScorer {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String>;
    fn name(&self) -> &str;
}

/// Mutable references to scorers are scorers (lets callers lend a scorer
/// to a `Prescreen` without giving up ownership).
impl<T: CandidateScorer + ?Sized> CandidateScorer for &mut T {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        (**self).score(cfgs)
    }
    fn name(&self) -> &str {
        (**self).name()
    }
}

/// Pure-rust scorer using the analytic cost model directly.
pub struct NativeScorer {
    pub workload: WorkloadSpec,
    pub cluster: ClusterSpec,
}

impl CandidateScorer for NativeScorer {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        Ok(cfgs
            .iter()
            .map(|c| costmodel::predict_runtime(c, &self.workload, &self.cluster))
            .collect())
    }

    fn name(&self) -> &str {
        "native"
    }
}

/// Prescreening driver.
pub struct Prescreen<S: CandidateScorer> {
    pub scorer: S,
    /// Number of model-scored candidates (cheap — no cluster time).
    pub n_candidates: usize,
    pub seed: u64,
}

impl<S: CandidateScorer> Prescreen<S> {
    pub fn new(scorer: S) -> Self {
        Self {
            scorer,
            n_candidates: 2048,
            seed: 11,
        }
    }

    /// Sample the unit cube, score through the surrogate, return the
    /// best candidates' unit coordinates (best first).
    pub fn top_starts(&mut self, space: &ParamSpace, k: usize) -> Result<Vec<Vec<f64>>, String> {
        let mut rng = Rng::new(self.seed);
        let d = space.dims();
        let xs: Vec<Vec<f64>> = (0..self.n_candidates)
            .map(|_| (0..d).map(|_| rng.f64()).collect())
            .collect();
        let cfgs: Vec<HadoopConfig> = xs.iter().map(|x| space.decode(x)).collect();
        let scores = self.scorer.score(&cfgs)?;
        if scores.len() != cfgs.len() {
            return Err(format!(
                "scorer returned {} scores for {} configs",
                scores.len(),
                cfgs.len()
            ));
        }
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        Ok(idx.into_iter().take(k).map(|i| xs[i].clone()).collect())
    }

    /// Run BOBYQA seeded from the best surrogate prediction.
    pub fn run_bobyqa(
        &mut self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> Result<TuningOutcome, String> {
        let starts = self.top_starts(space, 1)?;
        let bob = Bobyqa {
            start: Some(starts[0].clone()),
            ..Bobyqa::default()
        };
        let mut out = bob.run(space, obj, max_evals);
        out.optimizer = format!("bobyqa+prescreen({})", self.scorer.name());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::TuningSpec;
    use crate::workloads::wordcount;

    fn prescreen() -> Prescreen<NativeScorer> {
        Prescreen::new(NativeScorer {
            workload: wordcount(10240.0),
            cluster: ClusterSpec::default(),
        })
    }

    #[test]
    fn top_starts_sorted_by_model_score() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = prescreen();
        let starts = p.top_starts(&space, 5).unwrap();
        assert_eq!(starts.len(), 5);
        let mut scorer = NativeScorer {
            workload: wordcount(10240.0),
            cluster: ClusterSpec::default(),
        };
        let cfgs: Vec<HadoopConfig> = starts.iter().map(|x| space.decode(x)).collect();
        let scores = scorer.score(&cfgs).unwrap();
        for w in scores.windows(2) {
            assert!(w[0] <= w[1] + 1e-9, "starts not sorted: {scores:?}");
        }
    }

    #[test]
    fn prescreen_start_beats_center_on_model() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = prescreen();
        let start = p.top_starts(&space, 1).unwrap().remove(0);
        let mut scorer = NativeScorer {
            workload: wordcount(10240.0),
            cluster: ClusterSpec::default(),
        };
        let s = scorer
            .score(&[space.decode(&start), space.decode(&vec![0.5, 0.5])])
            .unwrap();
        assert!(s[0] <= s[1], "prescreened start {} vs center {}", s[0], s[1]);
    }

    #[test]
    fn run_bobyqa_labels_optimizer() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = prescreen();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.8).powi(2)).sum()
        };
        let out = p.run_bobyqa(&space, &mut obj, 30).unwrap();
        assert!(out.optimizer.contains("prescreen"));
        assert!(out.evals() <= 30);
    }

    #[test]
    fn scorer_length_mismatch_detected() {
        struct Bad;
        impl CandidateScorer for Bad {
            fn score(&mut self, _c: &[HadoopConfig]) -> Result<Vec<f64>, String> {
                Ok(vec![1.0])
            }
            fn name(&self) -> &str {
                "bad"
            }
        }
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = Prescreen::new(Bad);
        p.n_candidates = 8;
        assert!(p.top_starts(&space, 1).is_err());
    }
}

//! Surrogate prescreening: score thousands of candidate configurations
//! with the *analytic* cost model before spending any cluster
//! evaluations, then start the real optimizer from the best prediction.
//!
//! The scorer is a trait so the same code runs against the AOT-compiled
//! JAX/Pallas artifact through PJRT (`runtime::CostModelExec`, the hot
//! path when built with the `pjrt` feature) or against the native rust
//! mirror (`NativeScorer`, always available). [`Prescreen`] implements
//! [`Optimizer`]: its first ask primes a BOBYQA at the best surrogate
//! prediction, so it plugs into the shared `Driver` like every other
//! method. ABL2 in EXPERIMENTS.md measures what prescreening saves.

use crate::config::params::HadoopConfig;
use crate::hadoop::{costmodel, ClusterSpec};
use crate::optim::core::{BatchObjective, Candidate, Driver, Optimizer};
use crate::optim::result::{EvalRecord, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::Bobyqa;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSpec;

/// Anything that can batch-score configurations (lower = better).
pub trait CandidateScorer {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String>;
    fn name(&self) -> &str;
}

/// Mutable references to scorers are scorers (lets callers lend a scorer
/// to a `Prescreen` without giving up ownership).
impl<T: CandidateScorer + ?Sized> CandidateScorer for &mut T {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        (**self).score(cfgs)
    }
    fn name(&self) -> &str {
        (**self).name()
    }
}

/// Pure-rust scorer using the analytic cost model directly.
pub struct NativeScorer {
    pub workload: WorkloadSpec,
    pub cluster: ClusterSpec,
}

impl CandidateScorer for NativeScorer {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        Ok(cfgs
            .iter()
            .map(|c| costmodel::predict_runtime(c, &self.workload, &self.cluster))
            .collect())
    }

    fn name(&self) -> &str {
        "native"
    }
}

/// Prescreening wrapper: surrogate-seeded BOBYQA behind the [`Optimizer`]
/// trait. Scoring the candidate pool costs NO cluster evaluations — it
/// happens inside the first `ask`.
pub struct Prescreen<S: CandidateScorer> {
    pub scorer: S,
    /// Number of model-scored candidates (cheap — no cluster time).
    pub n_candidates: usize,
    pub seed: u64,
    inner: Bobyqa,
    primed: bool,
    label: String,
}

impl<S: CandidateScorer> Prescreen<S> {
    pub fn new(scorer: S) -> Self {
        let label = format!("bobyqa+prescreen({})", scorer.name());
        Self {
            scorer,
            n_candidates: 2048,
            seed: 11,
            inner: Bobyqa::default(),
            primed: false,
            label,
        }
    }

    /// Sample the unit cube, score through the surrogate, return the
    /// best candidates' unit coordinates (best first).
    pub fn top_starts(&mut self, space: &ParamSpace, k: usize) -> Result<Vec<Vec<f64>>, String> {
        let mut rng = Rng::new(self.seed);
        let d = space.dims();
        let xs: Vec<Vec<f64>> = (0..self.n_candidates)
            .map(|_| (0..d).map(|_| rng.f64()).collect())
            .collect();
        let cfgs: Vec<HadoopConfig> = xs.iter().map(|x| space.decode(x)).collect();
        let scores = self.scorer.score(&cfgs)?;
        if scores.len() != cfgs.len() {
            return Err(format!(
                "scorer returned {} scores for {} configs",
                scores.len(),
                cfgs.len()
            ));
        }
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        Ok(idx.into_iter().take(k).map(|i| xs[i].clone()).collect())
    }

    /// Seed the inner BOBYQA at the best surrogate prediction. Idempotent;
    /// called implicitly by the first `ask`.
    pub fn prime(&mut self, space: &ParamSpace) -> Result<(), String> {
        if self.primed {
            return Ok(());
        }
        let start = self
            .top_starts(space, 1)?
            .into_iter()
            .next()
            .ok_or("prescreen produced no candidates (n_candidates = 0?)")?;
        self.inner = Bobyqa::default()
            .with_start(start)
            .with_label(self.label.clone());
        self.primed = true;
        Ok(())
    }

    /// Run surrogate-seeded BOBYQA through the shared `Driver`.
    pub fn run_bobyqa<B: BatchObjective + ?Sized>(
        &mut self,
        space: &ParamSpace,
        obj: &mut B,
        max_evals: usize,
    ) -> Result<TuningOutcome, String> {
        self.prime(space)?;
        Driver::new(max_evals).run(self, space, obj)
    }
}

impl<S: CandidateScorer> Optimizer for Prescreen<S> {
    fn name(&self) -> &str {
        &self.label
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        if !self.primed {
            if let Err(e) = self.prime(space) {
                // ask cannot return an error; carry the cause in the
                // label so the driver's "produced no evaluations"
                // message names it instead of hiding it
                if !self.label.contains("prime failed") {
                    self.label = format!("{} [prime failed: {e}]", self.label);
                }
                return Vec::new();
            }
        }
        self.inner.ask(space, budget_left)
    }

    fn set_chunk(&mut self, chunk: usize) {
        self.inner.set_chunk(chunk)
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.inner.tell(evals)
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.inner.best()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::FnObjective;
    use crate::workloads::wordcount;

    fn prescreen() -> Prescreen<NativeScorer> {
        Prescreen::new(NativeScorer {
            workload: wordcount(10240.0),
            cluster: ClusterSpec::default(),
        })
    }

    #[test]
    fn top_starts_sorted_by_model_score() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = prescreen();
        let starts = p.top_starts(&space, 5).unwrap();
        assert_eq!(starts.len(), 5);
        let mut scorer = NativeScorer {
            workload: wordcount(10240.0),
            cluster: ClusterSpec::default(),
        };
        let cfgs: Vec<HadoopConfig> = starts.iter().map(|x| space.decode(x)).collect();
        let scores = scorer.score(&cfgs).unwrap();
        for w in scores.windows(2) {
            assert!(w[0] <= w[1] + 1e-9, "starts not sorted: {scores:?}");
        }
    }

    #[test]
    fn prescreen_start_beats_center_on_model() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = prescreen();
        let start = p.top_starts(&space, 1).unwrap().remove(0);
        let mut scorer = NativeScorer {
            workload: wordcount(10240.0),
            cluster: ClusterSpec::default(),
        };
        let s = scorer
            .score(&[space.decode(&start), space.decode(&vec![0.5, 0.5])])
            .unwrap();
        assert!(s[0] <= s[1], "prescreened start {} vs center {}", s[0], s[1]);
    }

    #[test]
    fn run_bobyqa_labels_optimizer() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = prescreen();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.8).powi(2)).sum()
        });
        let out = p.run_bobyqa(&space, &mut obj, 30).unwrap();
        assert!(out.optimizer.contains("prescreen"));
        assert!(out.evals() <= 30);
    }

    #[test]
    fn scorer_length_mismatch_detected() {
        struct Bad;
        impl CandidateScorer for Bad {
            fn score(&mut self, _c: &[HadoopConfig]) -> Result<Vec<f64>, String> {
                Ok(vec![1.0])
            }
            fn name(&self) -> &str {
                "bad"
            }
        }
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut p = Prescreen::new(Bad);
        p.n_candidates = 8;
        assert!(p.top_starts(&space, 1).is_err());
        // and through the Optimizer trait: ask proposes nothing, and the
        // label carries the cause into the driver's error message
        assert!(p.ask(&space, 10).is_empty());
        assert!(p.name().contains("prime failed"), "{}", p.name());
    }
}

//! Hooke–Jeeves pattern search: exploratory coordinate probes followed by
//! an aggressive pattern (momentum) move through the improving direction.
//!
//! Ask/tell port: a singleton-ask state machine with three phases —
//! exploratory sweep around the base, pattern-point evaluation,
//! exploratory sweep around the pattern point — matching the old
//! monolithic loop move for move.

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::{EvalRecord, Fidelity};
use crate::optim::space::ParamSpace;
use crate::optim::sweep::Sweep;

#[derive(Clone, Debug)]
pub struct HookeJeeves {
    pub init_step: f64,
    pub start: Option<Vec<f64>>,
    st: Option<State>,
    best: BestSeen,
}

impl Default for HookeJeeves {
    fn default() -> Self {
        Self {
            init_step: 0.25,
            start: None,
            st: None,
            best: BestSeen::default(),
        }
    }
}

impl HookeJeeves {
    pub fn with_start(mut self, start: Vec<f64>) -> Self {
        self.start = Some(start);
        self
    }
}

#[derive(Clone, Debug)]
enum Phase {
    AwaitInit,
    ExploreBase(Sweep),
    AwaitPattern(Vec<f64>),
    ExplorePattern(Sweep),
    Done,
}

#[derive(Clone, Debug)]
struct State {
    base: Vec<f64>,
    f_base: f64,
    step: f64,
    stop_step: f64,
    phase: Phase,
}

impl Optimizer for HookeJeeves {
    fn name(&self) -> &str {
        "hooke-jeeves"
    }

    fn ask(&mut self, space: &ParamSpace, _budget_left: usize) -> Vec<Candidate> {
        let d = space.dims();
        let st = match &mut self.st {
            None => {
                let base = self.start.clone().unwrap_or_else(|| vec![0.5; d]);
                let stop_step =
                    space.min_steps().iter().cloned().fold(f64::MAX, f64::min) * 0.5;
                self.st = Some(State {
                    base: base.clone(),
                    f_base: f64::INFINITY,
                    step: self.init_step,
                    stop_step,
                    phase: Phase::AwaitInit,
                });
                return vec![Candidate::new(base)];
            }
            Some(st) => st,
        };
        loop {
            match &mut st.phase {
                Phase::AwaitInit | Phase::AwaitPattern(_) => return Vec::new(), // tell pending
                Phase::Done => return Vec::new(),
                Phase::ExploreBase(ex) => {
                    if let Some(p) = ex.next_probe(st.step) {
                        return vec![Candidate::new(p)];
                    }
                    // sweep exhausted: pattern move or step halving
                    let (xe, fe) = (ex.x.clone(), ex.fx);
                    if fe < st.f_base {
                        let pattern: Vec<f64> = xe
                            .iter()
                            .zip(&st.base)
                            .map(|(a, b)| (2.0 * a - b).clamp(0.0, 1.0))
                            .collect();
                        st.base = xe;
                        st.f_base = fe;
                        st.phase = Phase::AwaitPattern(pattern.clone());
                        return vec![Candidate::new(pattern)];
                    }
                    st.step *= 0.5;
                    if st.step <= st.stop_step {
                        st.phase = Phase::Done;
                        return Vec::new();
                    }
                    st.phase =
                        Phase::ExploreBase(Sweep::new(st.base.clone(), st.f_base));
                }
                Phase::ExplorePattern(ex) => {
                    if let Some(p) = ex.next_probe(st.step) {
                        return vec![Candidate::new(p)];
                    }
                    if ex.fx < st.f_base {
                        st.base = ex.x.clone();
                        st.f_base = ex.fx;
                    }
                    st.phase =
                        Phase::ExploreBase(Sweep::new(st.base.clone(), st.f_base));
                }
            }
        }
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
        let st = match &mut self.st {
            // told before the first ask (resume replay): seed the start
            None => {
                if let Some((x, _)) = self.best.get() {
                    self.start = Some(x);
                }
                return;
            }
            Some(st) => st,
        };
        for r in evals {
            match &mut st.phase {
                Phase::AwaitInit => {
                    st.f_base = r.value;
                    st.phase =
                        Phase::ExploreBase(Sweep::new(st.base.clone(), st.f_base));
                }
                Phase::AwaitPattern(p) => {
                    let p = p.clone();
                    st.phase = Phase::ExplorePattern(Sweep::new(p, r.value));
                }
                Phase::ExploreBase(ex) | Phase::ExplorePattern(ex) => ex.absorb(r.value),
                Phase::Done => {}
            }
        }
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    #[test]
    fn converges_on_shifted_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c)
                .iter()
                .enumerate()
                .map(|(i, u)| (u - 0.2 - 0.15 * i as f64).powi(2))
                .sum()
        });
        let out = Driver::new(300)
            .run(&mut HookeJeeves::default(), &space, &mut obj)
            .unwrap();
        assert!(out.best_value < 0.01, "HJ stuck at {}", out.best_value);
    }

    #[test]
    fn beats_or_matches_its_start() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| {
            sp.encode(c).iter().map(|u| (u - 0.9).powi(2)).sum()
        });
        let out = Driver::new(150)
            .run(&mut HookeJeeves::default(), &space, &mut obj)
            .unwrap();
        let first = out.records.first().unwrap().value;
        assert!(out.best_value <= first);
    }

    #[test]
    fn budget_respected() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut obj = FnObjective(|_: &HadoopConfig| 1.0); // flat: worst case
        let out = Driver::new(23)
            .run(&mut HookeJeeves::default(), &space, &mut obj)
            .unwrap();
        assert!(out.evals() <= 23);
    }

    #[test]
    fn asks_singletons_until_convergence() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut hj = HookeJeeves::default();
        let mut n = 0usize;
        loop {
            let batch = hj.ask(&space, 1000);
            if batch.is_empty() {
                break;
            }
            assert_eq!(batch.len(), 1);
            hj.tell(&[EvalRecord {
                iter: n + 1,
                config: space.decode(&batch[0].unit_x),
                unit_x: batch[0].unit_x.clone(),
                value: 1.0, // flat objective: HJ must converge by halving
                best_so_far: 1.0,
                fidelity: Fidelity::Full,
            }]);
            n += 1;
            assert!(n < 10_000, "HJ never converged on a flat objective");
        }
        assert!(n > 0);
    }
}

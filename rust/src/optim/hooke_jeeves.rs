//! Hooke–Jeeves pattern search: exploratory coordinate probes followed by
//! an aggressive pattern (momentum) move through the improving direction.

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;

#[derive(Clone, Debug)]
pub struct HookeJeeves {
    pub init_step: f64,
    pub start: Option<Vec<f64>>,
}

impl Default for HookeJeeves {
    fn default() -> Self {
        Self {
            init_step: 0.25,
            start: None,
        }
    }
}

impl HookeJeeves {
    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let d = space.dims();
        let mut rec = Recorder::new();
        let mut eval = |rec: &mut Recorder, x: &[f64]| -> f64 {
            let cfg = space.decode(x);
            let v = obj(&cfg);
            rec.record(x.to_vec(), cfg, v);
            v
        };

        let mut base = self.start.clone().unwrap_or_else(|| vec![0.5; d]);
        let mut f_base = eval(&mut rec, &base);
        let mut step = self.init_step;
        let stop_step = space.min_steps().iter().cloned().fold(f64::MAX, f64::min) * 0.5;

        // exploratory move around `from`, returns improved point + value
        let explore = |rec: &mut Recorder,
                       eval: &mut dyn FnMut(&mut Recorder, &[f64]) -> f64,
                       from: &[f64],
                       f_from: f64,
                       step: f64,
                       max_evals: usize|
         -> (Vec<f64>, f64) {
            let mut x = from.to_vec();
            let mut fx = f_from;
            for i in 0..x.len() {
                if rec.evals() >= max_evals {
                    break;
                }
                for dir in [1.0, -1.0] {
                    let cand = (x[i] + dir * step).clamp(0.0, 1.0);
                    if (cand - x[i]).abs() < 1e-12 {
                        continue;
                    }
                    let mut xc = x.clone();
                    xc[i] = cand;
                    let v = eval(rec, &xc);
                    if v < fx {
                        x = xc;
                        fx = v;
                        break;
                    }
                    if rec.evals() >= max_evals {
                        break;
                    }
                }
            }
            (x, fx)
        };

        while rec.evals() < max_evals && step > stop_step {
            let (xe, fe) = explore(&mut rec, &mut eval, &base, f_base, step, max_evals);
            if fe < f_base {
                // pattern move: jump to 2*xe - base, then explore there
                let pattern: Vec<f64> = xe
                    .iter()
                    .zip(&base)
                    .map(|(a, b)| (2.0 * a - b).clamp(0.0, 1.0))
                    .collect();
                base = xe;
                f_base = fe;
                if rec.evals() >= max_evals {
                    break;
                }
                let fp = eval(&mut rec, &pattern);
                let (xp, fpe) =
                    explore(&mut rec, &mut eval, &pattern, fp, step, max_evals);
                if fpe < f_base {
                    base = xp;
                    f_base = fpe;
                }
            } else {
                step *= 0.5;
            }
        }
        rec.finish("hooke-jeeves")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;

    #[test]
    fn converges_on_shifted_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c)
                .iter()
                .enumerate()
                .map(|(i, u)| (u - 0.2 - 0.15 * i as f64).powi(2))
                .sum()
        };
        let out = HookeJeeves::default().run(&space, &mut obj, 300);
        assert!(out.best_value < 0.01, "HJ stuck at {}", out.best_value);
    }

    #[test]
    fn beats_or_matches_its_start() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| sp.encode(c).iter().map(|u| (u - 0.9).powi(2)).sum();
        let out = HookeJeeves::default().run(&space, &mut obj, 150);
        let first = out.records.first().unwrap().value;
        assert!(out.best_value <= first);
    }

    #[test]
    fn budget_respected() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut obj = |_: &HadoopConfig| 1.0; // flat: worst case exploration
        let out = HookeJeeves::default().run(&space, &mut obj, 23);
        assert!(out.evals() <= 23);
    }
}

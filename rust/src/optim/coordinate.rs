//! Cyclic coordinate (compass) search: probe ± along one axis at a time,
//! halving the step when a full sweep makes no progress. The simplest
//! member of the direct-search family beyond exhaustive enumeration.

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;

#[derive(Clone, Debug)]
pub struct CoordinateSearch {
    pub init_step: f64,
    /// Starting point in the unit cube (defaults to the center).
    pub start: Option<Vec<f64>>,
}

impl Default for CoordinateSearch {
    fn default() -> Self {
        Self {
            init_step: 0.25,
            start: None,
        }
    }
}

impl CoordinateSearch {
    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let d = space.dims();
        let min_steps = space.min_steps();
        let mut rec = Recorder::new();
        let mut x = self.start.clone().unwrap_or_else(|| vec![0.5; d]);
        let mut eval = |rec: &mut Recorder, x: &[f64]| -> f64 {
            let cfg = space.decode(x);
            let v = obj(&cfg);
            rec.record(x.to_vec(), cfg, v);
            v
        };
        let mut fx = eval(&mut rec, &x);
        let mut step = self.init_step;
        let stop_step = min_steps.iter().cloned().fold(f64::MAX, f64::min) * 0.5;

        while rec.evals() < max_evals && step > stop_step {
            let mut improved = false;
            for i in 0..d {
                if rec.evals() >= max_evals {
                    break;
                }
                for dir in [1.0, -1.0] {
                    let cand = (x[i] + dir * step).clamp(0.0, 1.0);
                    if (cand - x[i]).abs() < 1e-12 {
                        continue;
                    }
                    let mut xc = x.clone();
                    xc[i] = cand;
                    let v = eval(&mut rec, &xc);
                    if v < fx {
                        x = xc;
                        fx = v;
                        improved = true;
                        break; // greedy: keep moving this direction next sweep
                    }
                    if rec.evals() >= max_evals {
                        break;
                    }
                }
            }
            if !improved {
                step *= 0.5;
            }
        }
        rec.finish("coordinate")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;

    fn bowl_obj(space: ParamSpace, target: f64) -> impl FnMut(&HadoopConfig) -> f64 {
        move |c: &HadoopConfig| space.encode(c).iter().map(|u| (u - target).powi(2)).sum()
    }

    #[test]
    fn converges_on_separable_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut obj = bowl_obj(space.clone(), 0.7);
        let out = CoordinateSearch::default().run(&space, &mut obj, 300);
        assert!(
            out.best_value < 0.01,
            "coordinate search stuck at {}",
            out.best_value
        );
    }

    #[test]
    fn stays_in_unit_cube() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut obj = bowl_obj(space.clone(), 1.0); // optimum at the corner
        let out = CoordinateSearch::default().run(&space, &mut obj, 200);
        for r in &out.records {
            assert!(r.unit_x.iter().all(|&u| (0.0..=1.0).contains(&u)));
        }
        // should reach the corner region
        assert!(out.best_value < 0.05, "best {}", out.best_value);
    }

    #[test]
    fn budget_respected() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut obj = bowl_obj(space.clone(), 0.3);
        let out = CoordinateSearch::default().run(&space, &mut obj, 17);
        assert!(out.evals() <= 17);
    }
}

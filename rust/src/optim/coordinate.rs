//! Cyclic coordinate (compass) search: probe ± along one axis at a time,
//! halving the step when a full sweep makes no progress. The simplest
//! member of the direct-search family beyond exhaustive enumeration.
//!
//! Ask/tell port: a singleton-ask state machine over the shared
//! [`Sweep`] probe sub-machine — one probe per ask, sweep bookkeeping
//! and step halving advance between tells. Behaviour is identical to the
//! old monolithic loop.

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::{EvalRecord, Fidelity};
use crate::optim::space::ParamSpace;
use crate::optim::sweep::Sweep;

#[derive(Clone, Debug)]
pub struct CoordinateSearch {
    pub init_step: f64,
    /// Starting point in the unit cube (defaults to the center).
    pub start: Option<Vec<f64>>,
    st: Option<State>,
    best: BestSeen,
}

#[derive(Clone, Debug)]
struct State {
    sweep: Sweep,
    /// Value at the start of the current sweep (progress detection).
    f_sweep_start: f64,
    step: f64,
    stop_step: f64,
    await_init: bool,
    done: bool,
}

impl Default for CoordinateSearch {
    fn default() -> Self {
        Self {
            init_step: 0.25,
            start: None,
            st: None,
            best: BestSeen::default(),
        }
    }
}

impl CoordinateSearch {
    pub fn with_start(mut self, start: Vec<f64>) -> Self {
        self.start = Some(start);
        self
    }
}

impl Optimizer for CoordinateSearch {
    fn name(&self) -> &str {
        "coordinate"
    }

    fn ask(&mut self, space: &ParamSpace, _budget_left: usize) -> Vec<Candidate> {
        let d = space.dims();
        let st = match &mut self.st {
            None => {
                let x = self.start.clone().unwrap_or_else(|| vec![0.5; d]);
                let stop_step =
                    space.min_steps().iter().cloned().fold(f64::MAX, f64::min) * 0.5;
                self.st = Some(State {
                    sweep: Sweep::new(x.clone(), f64::INFINITY),
                    f_sweep_start: f64::INFINITY,
                    step: self.init_step,
                    stop_step,
                    await_init: true,
                    done: false,
                });
                return vec![Candidate::new(x)];
            }
            Some(st) => st,
        };
        if st.done || st.await_init || st.sweep.awaiting() {
            return Vec::new();
        }
        loop {
            // the old `while` gate: refine only while the step is coarser
            // than the spec's resolution
            if st.step <= st.stop_step {
                st.done = true;
                return Vec::new();
            }
            if let Some(p) = st.sweep.next_probe(st.step) {
                return vec![Candidate::new(p)];
            }
            // sweep complete: halve on failure, start the next sweep
            if st.sweep.fx >= st.f_sweep_start {
                st.step *= 0.5;
            }
            st.f_sweep_start = st.sweep.fx;
            st.sweep.restart();
        }
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
        let st = match &mut self.st {
            // told before the first ask (resume replay): seed the start
            None => {
                if let Some((x, _)) = self.best.get() {
                    self.start = Some(x);
                }
                return;
            }
            Some(st) => st,
        };
        for r in evals {
            if st.await_init {
                st.await_init = false;
                st.sweep.fx = r.value;
                st.f_sweep_start = r.value;
            } else if st.sweep.awaiting() {
                st.sweep.absorb(r.value);
            }
        }
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    fn bowl_obj(
        space: ParamSpace,
        target: f64,
    ) -> FnObjective<impl FnMut(&HadoopConfig) -> f64> {
        FnObjective(move |c: &HadoopConfig| {
            space.encode(c).iter().map(|u| (u - target).powi(2)).sum()
        })
    }

    #[test]
    fn converges_on_separable_bowl() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut obj = bowl_obj(space.clone(), 0.7);
        let out = Driver::new(300)
            .run(&mut CoordinateSearch::default(), &space, &mut obj)
            .unwrap();
        assert!(
            out.best_value < 0.01,
            "coordinate search stuck at {}",
            out.best_value
        );
    }

    #[test]
    fn stays_in_unit_cube() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let mut obj = bowl_obj(space.clone(), 1.0); // optimum at the corner
        let out = Driver::new(200)
            .run(&mut CoordinateSearch::default(), &space, &mut obj)
            .unwrap();
        for r in &out.records {
            assert!(r.unit_x.iter().all(|&u| (0.0..=1.0).contains(&u)));
        }
        // should reach the corner region
        assert!(out.best_value < 0.05, "best {}", out.best_value);
    }

    #[test]
    fn budget_respected() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut obj = bowl_obj(space.clone(), 0.3);
        let out = Driver::new(17)
            .run(&mut CoordinateSearch::default(), &space, &mut obj)
            .unwrap();
        assert!(out.evals() <= 17);
    }

    #[test]
    fn asks_singletons_and_converges_on_flat() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let mut cs = CoordinateSearch::default();
        let mut n = 0usize;
        loop {
            let batch = cs.ask(&space, 1000);
            if batch.is_empty() {
                break;
            }
            assert_eq!(batch.len(), 1, "sequential method must ask singletons");
            cs.tell(&[EvalRecord {
                iter: n + 1,
                config: space.decode(&batch[0].unit_x),
                unit_x: batch[0].unit_x.clone(),
                value: 1.0, // flat: every sweep fails, step halves to stop
                best_so_far: 1.0,
                fidelity: Fidelity::Full,
            }]);
            n += 1;
            assert!(n < 10_000, "coordinate search never converged on flat");
        }
        assert!(n > 0);
    }
}

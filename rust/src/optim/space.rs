//! Parameter-space geometry: the bridge between optimizer coordinates
//! (the unit cube `[0,1]^d`) and concrete `HadoopConfig`s.
//!
//! Optimizers are generic over dimension and know nothing about Hadoop;
//! `ParamSpace` owns scaling, integer rounding and clamping. Rounding
//! happens at decode so DFO methods see a smooth box while the cluster
//! only ever receives valid configurations.

use crate::config::params::HadoopConfig;
use crate::config::spec::TuningSpec;

#[derive(Clone, Debug)]
pub struct ParamSpace {
    pub spec: TuningSpec,
    /// Values for parameters NOT being tuned.
    pub base: HadoopConfig,
}

impl ParamSpace {
    pub fn new(spec: TuningSpec, base: HadoopConfig) -> Self {
        Self { spec, base }
    }

    pub fn dims(&self) -> usize {
        self.spec.dims()
    }

    /// Map a unit-cube point to a valid Hadoop configuration.
    pub fn decode(&self, x: &[f64]) -> HadoopConfig {
        assert_eq!(x.len(), self.dims(), "dimension mismatch");
        let mut cfg = self.base.clone();
        for (r, &u) in self.spec.ranges.iter().zip(x) {
            let u = u.clamp(0.0, 1.0);
            let v = r.lo + u * (r.hi - r.lo);
            cfg.set(r.meta.index, v); // set() rounds integers + clamps
        }
        cfg
    }

    /// Map a configuration back to unit coordinates (for seeding).
    pub fn encode(&self, cfg: &HadoopConfig) -> Vec<f64> {
        self.spec
            .ranges
            .iter()
            .map(|r| {
                let v = cfg.get(r.meta.index);
                ((v - r.lo) / (r.hi - r.lo)).clamp(0.0, 1.0)
            })
            .collect()
    }

    /// The unit-cube grid of an exhaustive search (cross product of the
    /// per-parameter grids), in row-major order.
    pub fn unit_grid(&self) -> Vec<Vec<f64>> {
        let axes: Vec<Vec<f64>> = self
            .spec
            .ranges
            .iter()
            .map(|r| {
                r.grid()
                    .into_iter()
                    .map(|v| ((v - r.lo) / (r.hi - r.lo)).clamp(0.0, 1.0))
                    .collect()
            })
            .collect();
        let mut out: Vec<Vec<f64>> = vec![vec![]];
        for axis in &axes {
            let mut next = Vec::with_capacity(out.len() * axis.len());
            for prefix in &out {
                for &v in axis {
                    let mut p = prefix.clone();
                    p.push(v);
                    next.push(p);
                }
            }
            out = next;
        }
        out
    }

    /// Smallest meaningful unit-cube step per dimension (one integer tick
    /// for integer params) — DFO stops refining below this resolution.
    pub fn min_steps(&self) -> Vec<f64> {
        self.spec
            .ranges
            .iter()
            .map(|r| {
                if r.meta.integer {
                    (1.0 / (r.hi - r.lo)).min(0.5)
                } else {
                    1e-3
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{P_IO_SORT_MB, P_REDUCES};

    fn space() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default())
    }

    #[test]
    fn decode_bounds() {
        let s = space();
        let lo = s.decode(&[0.0, 0.0]);
        let hi = s.decode(&[1.0, 1.0]);
        assert_eq!(lo.get(P_REDUCES), 2.0);
        assert_eq!(lo.get(P_IO_SORT_MB), 50.0);
        assert_eq!(hi.get(P_REDUCES), 32.0);
        assert_eq!(hi.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn decode_rounds_integers() {
        let s = space();
        let c = s.decode(&[0.5, 0.5]);
        assert_eq!(c.get(P_REDUCES).fract(), 0.0);
        assert_eq!(c.get(P_IO_SORT_MB).fract(), 0.0);
    }

    #[test]
    fn decode_clamps_out_of_cube() {
        let s = space();
        let c = s.decode(&[-3.0, 7.0]);
        assert_eq!(c.get(P_REDUCES), 2.0);
        assert_eq!(c.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = space();
        for u in [[0.0, 1.0], [0.25, 0.75], [1.0, 0.0]] {
            let cfg = s.decode(&u);
            let back = s.decode(&s.encode(&cfg));
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn untuned_params_keep_base_values() {
        let mut base = HadoopConfig::default();
        base.set_by_name("mapreduce.map.memory.mb", 2048.0).unwrap();
        let s = ParamSpace::new(TuningSpec::fig2(), base);
        let c = s.decode(&[0.5, 0.5]);
        assert_eq!(c.get(crate::config::params::P_MAP_MEM_MB), 2048.0);
    }

    #[test]
    fn unit_grid_is_cross_product() {
        let s = space();
        let g = s.unit_grid();
        assert_eq!(g.len(), s.spec.grid_size());
        assert_eq!(g.len(), 256);
        // all points in the cube, first point is the origin corner
        assert!(g.iter().all(|p| p.iter().all(|&v| (0.0..=1.0).contains(&v))));
        assert_eq!(g[0], vec![0.0, 0.0]);
    }

    #[test]
    fn min_steps_integer_resolution() {
        let s = space();
        let steps = s.min_steps();
        assert!((steps[0] - 1.0 / 30.0).abs() < 1e-12); // reduces 2..32
    }
}

//! Parameter-space geometry: the bridge between optimizer coordinates
//! (the unit cube `[0,1]^d`) and concrete `HadoopConfig`s.
//!
//! Optimizers are generic over dimension and know nothing about Hadoop;
//! [`ParamSpace::decode`] / [`ParamSpace::encode`] are the **only** path
//! between the two worlds. Decode applies each range's transform
//! (linear or log), snaps discrete kinds (int / bool / categorical) and
//! repairs constraint violations, so DFO methods see a smooth box while
//! the cluster only ever receives valid configurations. Encode inverts
//! the transforms (for seeding and checkpoint replay).

use crate::config::params::HadoopConfig;
use crate::config::space::Transform;
use crate::config::spec::TuningSpec;

/// Redraws a constraint-aware init sampler spends per point before
/// falling back to its original draw (whose violation the decode-time
/// snap-down repair then fixes) — bounds worst-case work on specs whose
/// feasible region is a sliver of the unit cube.
pub const INIT_REJECTION_TRIES: usize = 32;

#[derive(Clone, Debug)]
pub struct ParamSpace {
    pub spec: TuningSpec,
    /// Values for parameters NOT being tuned (laid out on the spec's
    /// registry — `new` rebases whatever base it is given).
    pub base: HadoopConfig,
}

impl ParamSpace {
    pub fn new(spec: TuningSpec, base: HadoopConfig) -> Self {
        let base = base.rebased(&spec.registry);
        Self { spec, base }
    }

    pub fn dims(&self) -> usize {
        self.spec.dims()
    }

    /// Map a unit-cube point to a valid Hadoop configuration: transform
    /// per range, snap discrete kinds, then repair constraints (pulling
    /// violating values down to their bound). Idempotent under
    /// re-encoding: `decode(encode(decode(x))) == decode(x)` for
    /// discrete kinds and within float tolerance for floats.
    pub fn decode(&self, x: &[f64]) -> HadoopConfig {
        assert_eq!(x.len(), self.dims(), "dimension mismatch");
        let mut cfg = self.base.clone();
        for (r, &u) in self.spec.ranges.iter().zip(x) {
            let u = u.clamp(0.0, 1.0);
            let v = r.transform.from_unit(u, r.lo, r.hi);
            cfg.set(r.index, v); // set() snaps discrete kinds + clamps
        }
        self.spec.repair(&mut cfg.values);
        cfg
    }

    /// Decode `x` into `scratch` WITHOUT constraint repair: apply each
    /// range's transform and snap discrete kinds only. This is the probe
    /// behind constraint-aware init sampling — rejection wants to know
    /// whether the *unrepaired* point lands in the feasible region
    /// (repaired decode trivially always does). `scratch` must be a
    /// clone of this space's `base`.
    pub fn decode_raw_into(&self, x: &[f64], scratch: &mut HadoopConfig) {
        debug_assert_eq!(x.len(), self.dims(), "dimension mismatch");
        scratch.values.copy_from_slice(&self.base.values);
        for (r, &u) in self.spec.ranges.iter().zip(x) {
            let u = u.clamp(0.0, 1.0);
            scratch.set(r.index, r.transform.from_unit(u, r.lo, r.hi));
        }
    }

    /// Does the unrepaired decode of `x` satisfy every constraint?
    /// (Always true for constraint-free specs.) Used by the rejection
    /// samplers in `optim::random` / `optim::latin`.
    pub fn unit_feasible(&self, x: &[f64], scratch: &mut HadoopConfig) -> bool {
        self.decode_raw_into(x, scratch);
        self.spec
            .constraints
            .iter()
            .all(|c| c.satisfied(&scratch.values))
    }

    /// Does `cfg` satisfy every constraint of the spec? Configs laid out
    /// against a different registry are rebased first (constraints index
    /// the spec's registry).
    pub fn is_feasible(&self, cfg: &HadoopConfig) -> bool {
        let registry = &self.spec.registry;
        if !std::sync::Arc::ptr_eq(cfg.registry(), registry) && cfg.registry() != registry {
            let rebased = cfg.rebased(registry);
            return self
                .spec
                .constraints
                .iter()
                .all(|c| c.satisfied(&rebased.values));
        }
        self.spec.constraints.iter().all(|c| c.satisfied(&cfg.values))
    }

    /// Map a configuration back to unit coordinates (for seeding).
    pub fn encode(&self, cfg: &HadoopConfig) -> Vec<f64> {
        self.spec
            .ranges
            .iter()
            .map(|r| r.transform.to_unit(cfg.get(r.index), r.lo, r.hi))
            .collect()
    }

    /// Streaming enumeration of the exhaustive-search grid (cross product
    /// of the per-parameter grids), in row-major order — the last
    /// dimension varies fastest. Cursor state is O(Σ axis lengths), never
    /// the cross product, so >10^6-point spaces enumerate in constant
    /// memory.
    pub fn grid_cursor(&self) -> GridCursor {
        GridCursor::new(
            self.spec
                .ranges
                .iter()
                .map(|r| {
                    r.grid()
                        .into_iter()
                        .map(|v| r.transform.to_unit(v, r.lo, r.hi))
                        .collect()
                })
                .collect(),
        )
    }

    /// Materialized convenience wrapper over [`ParamSpace::grid_cursor`]
    /// for tests and plotting of SMALL spaces. Allocates the whole cross
    /// product — hot paths (grid search, benches) must stream the cursor
    /// instead.
    pub fn unit_grid(&self) -> Vec<Vec<f64>> {
        self.grid_cursor().collect()
    }

    /// Smallest meaningful unit-cube step per dimension (one integer /
    /// category tick for discrete params) — DFO stops refining below
    /// this resolution. Under a log transform the tightest integer tick
    /// sits at the high end of the range.
    pub fn min_steps(&self) -> Vec<f64> {
        self.spec
            .ranges
            .iter()
            .map(|r| {
                if r.def.kind.is_discrete() {
                    let tick = match r.transform {
                        Transform::Linear => 1.0 / (r.hi - r.lo),
                        Transform::Log => {
                            (r.hi.ln() - (r.hi - 1.0).max(r.lo).ln())
                                / (r.hi.ln() - r.lo.ln())
                        }
                    };
                    tick.min(0.5)
                } else {
                    1e-3
                }
            })
            .collect()
    }
}

/// Lazy odometer over the exhaustive-search grid: a mixed-radix counter
/// whose digit `i` indexes dimension `i`'s grid axis (row-major order,
/// last digit fastest — exactly the order the old materialized
/// `unit_grid` produced). State is the per-dimension axes plus three
/// integers, so a 10^8-point cross product costs the same memory as a
/// 10-point one.
///
/// Supports resumable sweeps ([`GridCursor::position`] /
/// [`GridCursor::seek`], plus an O(1) [`Iterator::nth`]) and striped
/// worker sharding ([`GridCursor::shard`]): shard `k` of `n` yields
/// points `k, k+n, k+2n, …`, so the shard union is the full grid with no
/// overlap and balanced sizes.
#[derive(Clone, Debug)]
pub struct GridCursor {
    /// Per-dimension unit-cube axis values (the mixed-radix digit sets).
    axes: Vec<Vec<f64>>,
    /// Linear index of the next point to yield.
    next: u64,
    /// Exclusive end of the enumeration range.
    end: u64,
    /// Linear-index increment between yielded points (the shard count).
    stride: u64,
}

impl GridCursor {
    fn new(axes: Vec<Vec<f64>>) -> GridCursor {
        let total = axes
            .iter()
            .fold(1u64, |t, a| t.saturating_mul(a.len() as u64));
        GridCursor {
            axes,
            next: 0,
            end: total,
            stride: 1,
        }
    }

    pub fn dims(&self) -> usize {
        self.axes.len()
    }

    /// Size of the full cross product (independent of cursor position or
    /// sharding). Saturates at `u64::MAX` for absurd specs.
    pub fn total_points(&self) -> u64 {
        self.axes
            .iter()
            .fold(1u64, |t, a| t.saturating_mul(a.len() as u64))
    }

    /// Points this cursor will still yield.
    pub fn remaining(&self) -> u64 {
        if self.next >= self.end {
            0
        } else {
            (self.end - self.next - 1) / self.stride + 1
        }
    }

    /// Linear index of the next point — checkpoint this to resume a sweep.
    pub fn position(&self) -> u64 {
        self.next
    }

    /// Resume from a checkpointed [`GridCursor::position`]. For a sharded
    /// cursor the position must lie on this shard's stripe (positions
    /// returned by the same shard's `position` do).
    pub fn seek(&mut self, position: u64) -> &mut GridCursor {
        self.next = position.min(self.end);
        self
    }

    /// Stripe this cursor's remaining range across `n` workers and return
    /// shard `k`: it yields points `k, k+n, k+2n, …` of what `self` would
    /// have yielded. Striping (not block splitting) keeps shards balanced
    /// even when a budget truncates the sweep.
    pub fn shard(&self, k: u64, n: u64) -> GridCursor {
        assert!(n > 0 && k < n, "shard({k}, {n}): need 0 <= k < n");
        GridCursor {
            axes: self.axes.clone(),
            next: self.next.saturating_add(k.saturating_mul(self.stride)),
            end: self.end,
            stride: self.stride.saturating_mul(n),
        }
    }

    /// The grid point at linear index `i` (row-major decomposition).
    pub fn point_at(&self, i: u64) -> Vec<f64> {
        let mut p = vec![0.0; self.axes.len()];
        self.point_into(i, &mut p);
        p
    }

    /// Write the grid point at linear index `i` into `out` — the
    /// allocation-free decode used by the streaming benches.
    pub fn point_into(&self, mut i: u64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.axes.len(), "point_into dims mismatch");
        for (slot, axis) in out.iter_mut().zip(&self.axes).rev() {
            let len = axis.len() as u64;
            *slot = axis[(i % len) as usize];
            i /= len;
        }
    }
}

impl Iterator for GridCursor {
    type Item = Vec<f64>;

    fn next(&mut self) -> Option<Vec<f64>> {
        if self.next >= self.end {
            return None;
        }
        let p = self.point_at(self.next);
        self.next = self.next.saturating_add(self.stride);
        Some(p)
    }

    /// O(1) skip (the default would decode the skipped points).
    fn nth(&mut self, n: usize) -> Option<Vec<f64>> {
        self.next = self
            .next
            .saturating_add(self.stride.saturating_mul(n as u64));
        self.next()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let r = usize::try_from(self.remaining()).unwrap_or(usize::MAX);
        (r, Some(r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{P_IO_SORT_MB, P_MAP_MEM_MB, P_REDUCES};

    fn space() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default())
    }

    /// Categorical + log + constraint in one spec (the redesign's target
    /// scenario).
    fn rich_space() -> ParamSpace {
        let spec = TuningSpec::parse(
            "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
             param mapreduce.task.io.sort.mb int 64 1024 log\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             constraint io.sort.mb <= 0.7*map.memory.mb\n",
        )
        .unwrap();
        ParamSpace::new(spec, HadoopConfig::default())
    }

    #[test]
    fn decode_bounds() {
        let s = space();
        let lo = s.decode(&[0.0, 0.0]);
        let hi = s.decode(&[1.0, 1.0]);
        assert_eq!(lo.get(P_REDUCES), 2.0);
        assert_eq!(lo.get(P_IO_SORT_MB), 50.0);
        assert_eq!(hi.get(P_REDUCES), 32.0);
        assert_eq!(hi.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn decode_rounds_integers() {
        let s = space();
        let c = s.decode(&[0.5, 0.5]);
        assert_eq!(c.get(P_REDUCES).fract(), 0.0);
        assert_eq!(c.get(P_IO_SORT_MB).fract(), 0.0);
    }

    #[test]
    fn decode_clamps_out_of_cube() {
        let s = space();
        let c = s.decode(&[-3.0, 7.0]);
        assert_eq!(c.get(P_REDUCES), 2.0);
        assert_eq!(c.get(P_IO_SORT_MB), 800.0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = space();
        for u in [[0.0, 1.0], [0.25, 0.75], [1.0, 0.0]] {
            let cfg = s.decode(&u);
            let back = s.decode(&s.encode(&cfg));
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn untuned_params_keep_base_values() {
        let mut base = HadoopConfig::default();
        base.set_by_name("mapreduce.map.memory.mb", 2048.0).unwrap();
        let s = ParamSpace::new(TuningSpec::fig2(), base);
        let c = s.decode(&[0.5, 0.5]);
        assert_eq!(c.get(P_MAP_MEM_MB), 2048.0);
    }

    #[test]
    fn unit_grid_is_cross_product() {
        let s = space();
        let g = s.unit_grid();
        assert_eq!(g.len(), s.spec.grid_size());
        assert_eq!(g.len(), 256);
        // all points in the cube, first point is the origin corner
        assert!(g.iter().all(|p| p.iter().all(|&v| (0.0..=1.0).contains(&v))));
        assert_eq!(g[0], vec![0.0, 0.0]);
    }

    #[test]
    fn min_steps_integer_resolution() {
        let s = space();
        let steps = s.min_steps();
        assert!((steps[0] - 1.0 / 30.0).abs() < 1e-12); // reduces 2..32
    }

    #[test]
    fn min_steps_respects_log_transform() {
        // one integer tick near hi=1024 under log is much finer in unit
        // space than the linear 1/(hi-lo)
        let s = rich_space();
        let steps = s.min_steps();
        let expect = (1024f64.ln() - 1023f64.ln()) / (1024f64.ln() - 64f64.ln());
        assert!((steps[1] - expect).abs() < 1e-12, "got {}", steps[1]);
        assert!(steps[1] < 1.0 / (1024.0 - 64.0), "log tick not finer than linear");
    }

    #[test]
    fn log_transform_spends_unit_distance_geometrically() {
        let s = rich_space();
        // dim 1 is io.sort.mb over [64, 1024] log: the unit midpoint is
        // the geometric mean 256, not the arithmetic 544
        let c = s.decode(&[0.0, 0.5, 1.0]);
        assert_eq!(c.get(P_IO_SORT_MB), 256.0);
    }

    #[test]
    fn categorical_dims_snap_to_category_indices() {
        let s = rich_space();
        let codec_idx = s.spec.ranges[0].index;
        for (u, want) in [(0.0, "none"), (0.49, "snappy"), (0.5, "snappy"), (1.0, "lz4")] {
            let c = s.decode(&[u, 0.5, 0.5]);
            assert_eq!(c.get_category(codec_idx), Some(want), "u={u}");
            assert_eq!(c.get(codec_idx).fract(), 0.0);
        }
    }

    #[test]
    fn decode_repairs_constraint_violations() {
        let s = rich_space();
        // sort.mb at its top (1024) with map memory at its bottom (512):
        // 1024 > 0.7*512, so decode must pull sort.mb down to floor(358.4)
        let c = s.decode(&[0.0, 1.0, 0.0]);
        assert!(s.is_feasible(&c), "decode left an infeasible config");
        assert_eq!(c.get(P_IO_SORT_MB), (0.7f64 * 512.0).floor());
        assert_eq!(c.get(P_MAP_MEM_MB), 512.0);
        // decode is idempotent through encode even across a repair
        let again = s.decode(&s.encode(&c));
        assert_eq!(again, c);
    }

    #[test]
    fn chained_constraints_repair_to_a_fixpoint() {
        // a <= b and b <= const: repairing b can re-violate the first
        // constraint, so decode must sweep until clean
        let spec = TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 16 2048\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             constraint io.sort.mb <= map.memory.mb\n\
             constraint map.memory.mb <= 1024\n",
        )
        .unwrap();
        let s = ParamSpace::new(spec, HadoopConfig::default());
        // sort.mb -> 2048, memory -> 4096: pass 1 leaves sort.mb (ok vs
        // 4096), lowers memory to 1024; a second sweep must pull sort.mb
        // down too
        let c = s.decode(&[1.0, 1.0]);
        assert!(s.is_feasible(&c), "chained repair incomplete: {}", c.summary());
        assert_eq!(c.get(P_MAP_MEM_MB), 1024.0);
        assert!(c.get(P_IO_SORT_MB) <= 1024.0);
    }

    #[test]
    fn is_feasible_rebases_foreign_registry_configs() {
        // spec constrains a spec-declared extra param; a builtin-registry
        // config must not panic on the out-of-range index
        let spec = TuningSpec::parse(
            "param x.shuffle.buffer.kb int 32 4096\n\
             constraint x.shuffle.buffer.kb <= 1024\n",
        )
        .unwrap();
        let s = ParamSpace::new(spec, HadoopConfig::default());
        assert!(s.is_feasible(&HadoopConfig::default()));

        // equal-length but DIFFERENT registry: slot 10 holds another
        // spec's param (value 2000+); rebasing by name must prevent the
        // constraint from reading the wrong slot
        let other = TuningSpec::parse("param y.other.knob int 2000 6000\n").unwrap();
        let foreign = HadoopConfig::for_registry(other.registry.clone());
        assert_eq!(foreign.len(), s.spec.registry.len());
        assert!(
            s.is_feasible(&foreign),
            "constraint read a foreign registry's slot positionally"
        );
    }

    #[test]
    fn unit_feasible_probes_the_unrepaired_decode() {
        let s = rich_space();
        let mut scratch = s.base.clone();
        // sort.mb at its top with map memory at its bottom violates the
        // constraint BEFORE repair — decode() would silently fix it
        assert!(!s.unit_feasible(&[0.0, 1.0, 0.0], &mut scratch));
        assert!(s.unit_feasible(&[0.0, 0.0, 1.0], &mut scratch));
        // constraint-free specs are always feasible
        let flat = space();
        let mut scratch = flat.base.clone();
        assert!(flat.unit_feasible(&[1.0, 1.0], &mut scratch));
        // the probe agrees with is_feasible on the raw decode
        let mut raw = s.base.clone();
        s.decode_raw_into(&[0.0, 1.0, 0.0], &mut raw);
        assert!(!s.is_feasible(&raw));
    }

    #[test]
    fn every_grid_point_of_a_constrained_space_is_feasible() {
        let s = rich_space();
        for x in s.unit_grid() {
            let c = s.decode(&x);
            assert!(s.is_feasible(&c), "infeasible grid point {x:?}");
            c.validate().unwrap();
        }
    }

    /// Naive materialized cross product (the pre-streaming algorithm) —
    /// the reference the cursor must reproduce point for point.
    fn naive_cross_product(s: &ParamSpace) -> Vec<Vec<f64>> {
        let axes: Vec<Vec<f64>> = s
            .spec
            .ranges
            .iter()
            .map(|r| {
                r.grid()
                    .into_iter()
                    .map(|v| r.transform.to_unit(v, r.lo, r.hi))
                    .collect()
            })
            .collect();
        let mut out: Vec<Vec<f64>> = vec![vec![]];
        for axis in &axes {
            let mut next = Vec::with_capacity(out.len() * axis.len());
            for prefix in &out {
                for &v in axis {
                    let mut p = prefix.clone();
                    p.push(v);
                    next.push(p);
                }
            }
            out = next;
        }
        out
    }

    #[test]
    fn cursor_streams_the_exact_materialized_order() {
        for s in [space(), rich_space()] {
            let reference = naive_cross_product(&s);
            let streamed: Vec<Vec<f64>> = s.grid_cursor().collect();
            assert_eq!(streamed, reference, "cursor order diverged");
            assert_eq!(s.grid_cursor().total_points(), reference.len() as u64);
            // the convenience wrapper is the cursor, collected
            assert_eq!(s.unit_grid(), streamed);
        }
    }

    #[test]
    fn cursor_nth_and_seek_resume_mid_sweep() {
        let s = rich_space();
        let full: Vec<Vec<f64>> = s.grid_cursor().collect();

        // nth is an O(1) skip landing on the same point
        let mut c = s.grid_cursor();
        assert_eq!(c.nth(17).unwrap(), full[17]);
        assert_eq!(c.next().unwrap(), full[18]);

        // position/seek checkpointing: a fresh cursor seeked to a saved
        // position continues exactly where the interrupted one stopped
        let mut first = s.grid_cursor();
        for _ in 0..10 {
            first.next();
        }
        let checkpoint = first.position();
        let mut resumed = s.grid_cursor();
        resumed.seek(checkpoint);
        let rest: Vec<Vec<f64>> = resumed.collect();
        assert_eq!(rest, full[10..].to_vec());

        // remaining() counts what is actually yielded
        let mut c = s.grid_cursor();
        assert_eq!(c.remaining(), full.len() as u64);
        c.next();
        assert_eq!(c.remaining(), full.len() as u64 - 1);
    }

    #[test]
    fn shards_cover_the_grid_with_no_overlap() {
        let s = space();
        let full: Vec<Vec<f64>> = s.grid_cursor().collect();
        let key = |p: &[f64]| -> Vec<u64> { p.iter().map(|v| v.to_bits()).collect() };
        for n in [1u64, 3, 4, 7] {
            let mut seen = std::collections::BTreeSet::new();
            let mut count = 0u64;
            for k in 0..n {
                let shard = s.grid_cursor().shard(k, n);
                let expect = (full.len() as u64 - k - 1) / n + 1;
                assert_eq!(shard.remaining(), expect, "shard({k},{n}) size");
                for p in shard {
                    assert!(seen.insert(key(&p)), "shard overlap at {p:?} (n={n})");
                    count += 1;
                }
            }
            assert_eq!(count, full.len() as u64, "{n} shards did not cover the grid");
            assert_eq!(seen.len() as u64, count);
        }
    }
}

//! Search methods for the Optimizer Runner, unified behind the batched
//! **ask/tell** protocol in [`core`].
//!
//! Two families, exactly as the paper structures them (§II.C):
//! * **direct search** — [`grid::GridSearch`] (exhaustive),
//!   [`coordinate::CoordinateSearch`], [`hooke_jeeves::HookeJeeves`];
//! * **DFO** — [`bobyqa::Bobyqa`] (trust-region quadratic interpolation),
//!   [`nelder_mead::NelderMead`]; plus [`random::RandomSearch`] and
//!   [`latin::LatinHypercube`] as no-structure baselines,
//!   [`annealing::SimulatedAnnealing`] for basin escape, and
//!   [`surrogate::Prescreen`] for model-assisted seeding through the AOT
//!   artifacts.
//!
//! Every method implements [`core::Optimizer`]: `ask` proposes a batch of
//! unit-cube candidates ([`space::ParamSpace`] owns the decoding to valid
//! `HadoopConfig`s), `tell` feeds measured runtimes back. Population
//! methods (random, latin) ask in large batches that a
//! [`core::BatchObjective`] — the parallel [`core::ClusterObjective`] or
//! the AOT/Pallas batch scorer — evaluates in one call; grid *streams*
//! its exhaustive sweep in `batch.chunk`-sized asks off a lazy
//! [`space::GridCursor`] (constant enumeration memory, >10^6-point
//! spaces included); sequential methods (bobyqa, hooke-jeeves,
//! nelder-mead, coordinate, annealing) ask singletons and behave exactly
//! like their pre-port loops.
//!
//! Nobody calls a method's loop directly any more: the shared
//! [`core::Driver`] owns the evaluation budget, early stopping, observer
//! hooks and checkpoint replay. [`Method`] is the thin name→`Box<dyn
//! Optimizer>` registry the CLI and the Catla runners dispatch through:
//!
//! ```
//! use catla::config::params::HadoopConfig;
//! use catla::config::spec::TuningSpec;
//! use catla::optim::{Driver, FnObjective, Method, ParamSpace};
//!
//! let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
//! let mut opt = Method::from_name("bobyqa", 7).unwrap().build();
//! let mut obj = FnObjective(|cfg: &HadoopConfig| cfg.values.iter().sum::<f64>());
//! let outcome = Driver::new(40).run(opt.as_mut(), &space, &mut obj).unwrap();
//! assert!(outcome.evals() <= 40);
//! ```

pub mod annealing;
pub mod bobyqa;
pub mod coordinate;
pub mod core;
pub mod grid;
pub mod hooke_jeeves;
pub mod latin;
pub mod nelder_mead;
pub mod racing;
pub mod random;
pub mod result;
pub mod space;
pub mod surrogate;
mod sweep;

pub use annealing::SimulatedAnnealing;
pub use bobyqa::Bobyqa;
pub use coordinate::CoordinateSearch;
pub use self::core::{
    BatchObjective, Candidate, ClusterObjective, Driver, DriverSession, EarlyStop, FnObjective,
    Observer, Optimizer, ScorerObjective, DEFAULT_BATCH_CHUNK,
};
pub use grid::GridSearch;
pub use hooke_jeeves::HookeJeeves;
pub use latin::LatinHypercube;
pub use nelder_mead::NelderMead;
pub use racing::{Race, RacingObjective, RacingSettings, RacingStats};
pub use random::RandomSearch;
pub use result::{EvalRecord, Fidelity, TuningOutcome};
pub use space::{GridCursor, ParamSpace};

/// Every optimizer, behind one dispatchable handle (CLI / Optimizer
/// Runner entry point). A thin factory: [`Method::build`] returns the
/// ask/tell implementation to hand to a [`Driver`].
#[derive(Clone, Debug)]
pub enum Method {
    Grid,
    Random { seed: u64 },
    Latin { seed: u64 },
    Coordinate,
    HookeJeeves,
    NelderMead,
    Annealing { seed: u64 },
    Bobyqa { seed: u64 },
}

impl Method {
    /// Parse a CLI name: grid | random | latin | coordinate | hooke-jeeves |
    /// nelder-mead | annealing | bobyqa.
    pub fn from_name(name: &str, seed: u64) -> Result<Method, String> {
        Ok(match name {
            "grid" | "exhaustive" => Method::Grid,
            "random" => Method::Random { seed },
            "latin" | "lhs" => Method::Latin { seed },
            "coordinate" | "compass" => Method::Coordinate,
            "hooke-jeeves" | "hj" => Method::HookeJeeves,
            "nelder-mead" | "nm" => Method::NelderMead,
            "annealing" | "sa" => Method::Annealing { seed },
            "bobyqa" => Method::Bobyqa { seed },
            other => {
                return Err(format!(
                    "unknown optimizer {other:?} (expected grid|random|latin|coordinate|hooke-jeeves|nelder-mead|annealing|bobyqa)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Grid => "grid",
            Method::Random { .. } => "random",
            Method::Latin { .. } => "latin",
            Method::Coordinate => "coordinate",
            Method::HookeJeeves => "hooke-jeeves",
            Method::NelderMead => "nelder-mead",
            Method::Annealing { .. } => "annealing",
            Method::Bobyqa { .. } => "bobyqa",
        }
    }

    /// Is this a direct-search method (vs DFO)?
    pub fn is_direct_search(&self) -> bool {
        matches!(self, Method::Grid | Method::Coordinate | Method::HookeJeeves)
    }

    /// Instantiate a fresh ask/tell optimizer for one tuning run.
    pub fn build(&self) -> Box<dyn Optimizer> {
        match self {
            Method::Grid => Box::new(GridSearch::new()),
            Method::Random { seed } => Box::new(RandomSearch::new(*seed)),
            Method::Latin { seed } => Box::new(LatinHypercube::new(*seed)),
            Method::Coordinate => Box::new(CoordinateSearch::default()),
            Method::HookeJeeves => Box::new(HookeJeeves::default()),
            Method::NelderMead => Box::new(NelderMead::default()),
            Method::Annealing { seed } => Box::new(SimulatedAnnealing::new(*seed)),
            Method::Bobyqa { seed } => Box::new(Bobyqa::new(*seed)),
        }
    }
}

/// All method names, for sweeps and `--help`.
pub const ALL_METHODS: [&str; 8] = [
    "grid",
    "random",
    "latin",
    "coordinate",
    "hooke-jeeves",
    "nelder-mead",
    "annealing",
    "bobyqa",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::hadoop::{ClusterSpec, SimCluster};
    use crate::workloads::wordcount;

    #[test]
    fn method_names_roundtrip() {
        for name in ALL_METHODS {
            let m = Method::from_name(name, 1).unwrap();
            assert_eq!(m.name(), name);
        }
        assert!(Method::from_name("gradient-descent", 1).is_err());
    }

    #[test]
    fn family_classification() {
        assert!(Method::Grid.is_direct_search());
        assert!(Method::HookeJeeves.is_direct_search());
        assert!(!Method::Bobyqa { seed: 1 }.is_direct_search());
        assert!(!Method::NelderMead.is_direct_search());
    }

    #[test]
    fn every_method_runs_against_the_cluster() {
        let wl = wordcount(2048.0);
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        for name in ALL_METHODS {
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
            let mut opt = Method::from_name(name, 3).unwrap().build();
            let out = Driver::new(12).run(opt.as_mut(), &space, &mut obj).unwrap();
            assert!(out.evals() <= 12, "{name} overspent");
            assert!(out.best_value > 0.0, "{name} nonpositive runtime");
            out.best_config.validate().unwrap();
        }
    }

    #[test]
    fn repeats_reduce_objective_variance() {
        let wl = wordcount(2048.0);
        let cfg = HadoopConfig::default();
        let sample_var = |repeats: usize| -> f64 {
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let mut obj = ClusterObjective::new(&mut cluster, &wl, repeats);
            let xs: Vec<f64> = (0..30)
                .map(|_| obj.eval_batch(std::slice::from_ref(&cfg)).unwrap()[0])
                .collect();
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
        };
        assert!(sample_var(4) < sample_var(1));
    }

    #[test]
    fn optimizer_best_tracks_driver_best() {
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| {
            sp.encode(c).iter().map(|u| (u - 0.3).powi(2)).sum()
        });
        let mut opt = Method::HookeJeeves.build();
        let out = Driver::new(60).run(opt.as_mut(), &space, &mut obj).unwrap();
        let (x, v) = opt.best().expect("optimizer tracked no best");
        assert_eq!(v, out.best_value);
        assert_eq!(x, out.records.iter().min_by(|a, b| a.value.total_cmp(&b.value)).unwrap().unit_x);
    }
}

//! Search methods for the Optimizer Runner.
//!
//! Two families, exactly as the paper structures them (§II.C):
//! * **direct search** — [`grid::GridSearch`] (exhaustive),
//!   [`coordinate::CoordinateSearch`], [`hooke_jeeves::HookeJeeves`];
//! * **DFO** — [`bobyqa::Bobyqa`] (trust-region quadratic interpolation),
//!   [`nelder_mead::NelderMead`]; plus [`random::RandomSearch`] as the
//!   no-structure baseline and [`surrogate::Prescreen`] for model-assisted
//!   seeding through the AOT artifacts.
//!
//! All optimizers work on the unit cube via [`space::ParamSpace`] and an
//! opaque objective `FnMut(&HadoopConfig) -> f64` (seconds of job running
//! time — possibly noisy).

pub mod annealing;
pub mod bobyqa;
pub mod coordinate;
pub mod grid;
pub mod hooke_jeeves;
pub mod latin;
pub mod nelder_mead;
pub mod random;
pub mod result;
pub mod space;
pub mod surrogate;

pub use annealing::SimulatedAnnealing;
pub use bobyqa::Bobyqa;
pub use coordinate::CoordinateSearch;
pub use grid::GridSearch;
pub use hooke_jeeves::HookeJeeves;
pub use latin::LatinHypercube;
pub use nelder_mead::NelderMead;
pub use random::RandomSearch;
pub use result::{EvalRecord, TuningOutcome};
pub use space::ParamSpace;

use crate::config::params::HadoopConfig;
use crate::hadoop::{JobSubmission, SimCluster};
use crate::workloads::WorkloadSpec;

/// The black-box objective: a Hadoop configuration's measured job
/// running time in seconds.
pub type ObjectiveFn<'a> = dyn FnMut(&HadoopConfig) -> f64 + 'a;

/// Every optimizer, behind one dispatchable handle (CLI / Optimizer
/// Runner entry point).
#[derive(Clone, Debug)]
pub enum Method {
    Grid,
    Random { seed: u64 },
    Latin { seed: u64 },
    Coordinate,
    HookeJeeves,
    NelderMead,
    Annealing { seed: u64 },
    Bobyqa { seed: u64 },
}

impl Method {
    /// Parse a CLI name: grid | random | coordinate | hooke-jeeves |
    /// nelder-mead | bobyqa.
    pub fn from_name(name: &str, seed: u64) -> Result<Method, String> {
        Ok(match name {
            "grid" | "exhaustive" => Method::Grid,
            "random" => Method::Random { seed },
            "latin" | "lhs" => Method::Latin { seed },
            "coordinate" | "compass" => Method::Coordinate,
            "hooke-jeeves" | "hj" => Method::HookeJeeves,
            "nelder-mead" | "nm" => Method::NelderMead,
            "annealing" | "sa" => Method::Annealing { seed },
            "bobyqa" => Method::Bobyqa { seed },
            other => {
                return Err(format!(
                    "unknown optimizer {other:?} (expected grid|random|latin|coordinate|hooke-jeeves|nelder-mead|annealing|bobyqa)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Grid => "grid",
            Method::Random { .. } => "random",
            Method::Latin { .. } => "latin",
            Method::Coordinate => "coordinate",
            Method::HookeJeeves => "hooke-jeeves",
            Method::NelderMead => "nelder-mead",
            Method::Annealing { .. } => "annealing",
            Method::Bobyqa { .. } => "bobyqa",
        }
    }

    /// Is this a direct-search method (vs DFO)?
    pub fn is_direct_search(&self) -> bool {
        matches!(self, Method::Grid | Method::Coordinate | Method::HookeJeeves)
    }

    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        match self {
            Method::Grid => GridSearch.run(space, obj, max_evals),
            Method::Random { seed } => RandomSearch::new(*seed).run(space, obj, max_evals),
            Method::Latin { seed } => LatinHypercube::new(*seed).run(space, obj, max_evals),
            Method::Coordinate => CoordinateSearch::default().run(space, obj, max_evals),
            Method::HookeJeeves => HookeJeeves::default().run(space, obj, max_evals),
            Method::NelderMead => NelderMead::default().run(space, obj, max_evals),
            Method::Annealing { seed } => {
                SimulatedAnnealing::new(*seed).run(space, obj, max_evals)
            }
            Method::Bobyqa { seed } => Bobyqa {
                seed: *seed,
                ..Bobyqa::default()
            }
            .run(space, obj, max_evals),
        }
    }
}

/// All method names, for sweeps and `--help`.
pub const ALL_METHODS: [&str; 8] = [
    "grid",
    "random",
    "latin",
    "coordinate",
    "hooke-jeeves",
    "nelder-mead",
    "annealing",
    "bobyqa",
];

/// Objective closure that submits to a simulated cluster and averages
/// `repeats` runs (repeats > 1 trades cluster time for noise reduction).
pub fn cluster_objective<'a>(
    cluster: &'a mut SimCluster,
    workload: &'a WorkloadSpec,
    repeats: usize,
) -> impl FnMut(&HadoopConfig) -> f64 + 'a {
    let repeats = repeats.max(1);
    move |cfg: &HadoopConfig| {
        let mut total = 0.0;
        for _ in 0..repeats {
            let job = JobSubmission {
                name: format!("tune-{}", workload.name),
                workload: workload.clone(),
                config: cfg.clone(),
            };
            total += cluster.run_job(&job).runtime_s;
        }
        total / repeats as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::TuningSpec;
    use crate::hadoop::ClusterSpec;
    use crate::workloads::wordcount;

    #[test]
    fn method_names_roundtrip() {
        for name in ALL_METHODS {
            let m = Method::from_name(name, 1).unwrap();
            assert_eq!(m.name(), name);
        }
        assert!(Method::from_name("gradient-descent", 1).is_err());
    }

    #[test]
    fn family_classification() {
        assert!(Method::Grid.is_direct_search());
        assert!(Method::HookeJeeves.is_direct_search());
        assert!(!Method::Bobyqa { seed: 1 }.is_direct_search());
        assert!(!Method::NelderMead.is_direct_search());
    }

    #[test]
    fn every_method_runs_against_the_cluster() {
        let wl = wordcount(2048.0);
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        for name in ALL_METHODS {
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let mut obj = cluster_objective(&mut cluster, &wl, 1);
            let m = Method::from_name(name, 3).unwrap();
            let out = m.run(&space, &mut obj, 12);
            assert!(out.evals() <= 12, "{name} overspent");
            assert!(out.best_value > 0.0, "{name} nonpositive runtime");
            out.best_config.validate().unwrap();
        }
    }

    #[test]
    fn repeats_reduce_objective_variance() {
        let wl = wordcount(2048.0);
        let cfg = HadoopConfig::default();
        let sample_var = |repeats: usize| -> f64 {
            let mut cluster = SimCluster::new(ClusterSpec::default());
            let mut obj = cluster_objective(&mut cluster, &wl, repeats);
            let xs: Vec<f64> = (0..30).map(|_| obj(&cfg)).collect();
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
        };
        assert!(sample_var(4) < sample_var(1));
    }
}

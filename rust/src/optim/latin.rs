//! Latin hypercube sampling (LHS) — space-filling one-shot design, the
//! standard initialization for surrogate-based tuners and a stronger
//! budget-for-budget baseline than uniform random search.
//!
//! Ask/tell port: a one-shot design *is* one ask-batch — the first ask
//! stratifies the remaining budget, later asks return nothing.
//!
//! Constraint-aware sampling: on a constrained space, design points
//! whose unrepaired decode violates a `Constraint` are replaced by
//! uniform rejection draws (up to [`INIT_REJECTION_TRIES`] each, the
//! original stratified point kept as the snap-down-repair fallback).
//! Feasible design points keep their strata, so the design stays
//! space-filling where the feasible region allows it, and probability
//! mass stops piling onto the constraint boundary. Constraint-free
//! specs consume the RNG exactly as before (byte-identical designs).

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::EvalRecord;
use crate::optim::space::{ParamSpace, INIT_REJECTION_TRIES};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LatinHypercube {
    pub seed: u64,
    /// Ask round: 0 is the canonical design; later rounds (only reached
    /// if a driver asks again after an incomplete evaluation) re-stratify
    /// the remaining budget under a derived seed.
    round: u64,
    best: BestSeen,
}

impl LatinHypercube {
    pub fn new(seed: u64) -> LatinHypercube {
        LatinHypercube {
            seed,
            round: 0,
            best: BestSeen::default(),
        }
    }

    /// Generate `n` LHS points in the unit cube of dimension `d`: each
    /// dimension is split into n strata, each stratum hit exactly once.
    pub fn points(&self, n: usize, d: usize) -> Vec<Vec<f64>> {
        points_seeded(self.seed, n, d)
    }
}

fn points_seeded(seed: u64, n: usize, d: usize) -> Vec<Vec<f64>> {
    points_with(&mut Rng::new(seed), n, d)
}

fn points_with(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
    // per-dimension stratum permutations
    let mut perms: Vec<Vec<usize>> = Vec::with_capacity(d);
    for _ in 0..d {
        let mut p: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut p);
        perms.push(p);
    }
    (0..n)
        .map(|i| {
            (0..d)
                .map(|j| (perms[j][i] as f64 + rng.f64()) / n as f64)
                .collect()
        })
        .collect()
}

impl Optimizer for LatinHypercube {
    fn name(&self) -> &str {
        "latin-hypercube"
    }

    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
        if budget_left == 0 {
            return Vec::new();
        }
        let seed = self
            .seed
            .wrapping_add(self.round.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.round += 1;
        let d = space.dims();
        let mut rng = Rng::new(seed);
        let mut pts = points_with(&mut rng, budget_left, d);
        if !space.spec.constraints.is_empty() {
            // replace infeasible design points by feasible uniform draws
            // (the stratified original stays as the repair fallback)
            let mut scratch = space.base.clone();
            for p in pts.iter_mut() {
                if space.unit_feasible(p, &mut scratch) {
                    continue;
                }
                for _ in 0..INIT_REJECTION_TRIES {
                    let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    if space.unit_feasible(&x, &mut scratch) {
                        *p = x;
                        break;
                    }
                }
            }
        }
        pts.into_iter().map(Candidate::new).collect()
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    #[test]
    fn stratification_holds_per_dimension() {
        let lhs = LatinHypercube::new(4);
        let n = 16;
        let pts = lhs.points(n, 3);
        assert_eq!(pts.len(), n);
        for j in 0..3 {
            let mut strata: Vec<usize> =
                pts.iter().map(|p| (p[j] * n as f64) as usize).collect();
            strata.sort_unstable();
            assert_eq!(strata, (0..n).collect::<Vec<_>>(), "dim {j} not stratified");
        }
    }

    #[test]
    fn better_coverage_than_random_on_average() {
        // min pairwise distance of LHS should beat uniform random
        let d = 4;
        let n = 20;
        let min_dist = |pts: &[Vec<f64>]| -> f64 {
            let mut m = f64::MAX;
            for i in 0..pts.len() {
                for j in i + 1..pts.len() {
                    let d2: f64 = pts[i]
                        .iter()
                        .zip(&pts[j])
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    m = m.min(d2.sqrt());
                }
            }
            m
        };
        let mut lhs_wins = 0;
        for seed in 0..10 {
            let lhs_pts = LatinHypercube::new(seed).points(n, d);
            let mut rng = Rng::new(seed + 1000);
            let rnd_pts: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.f64()).collect())
                .collect();
            if min_dist(&lhs_pts) > min_dist(&rnd_pts) {
                lhs_wins += 1;
            }
        }
        assert!(lhs_wins >= 7, "LHS beat random only {lhs_wins}/10 times");
    }

    #[test]
    fn run_uses_exact_budget_in_one_batch() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj =
            FnObjective(move |c: &HadoopConfig| sp.encode(c).iter().sum::<f64>());
        let out = Driver::new(25)
            .run(&mut LatinHypercube::new(1), &space, &mut obj)
            .unwrap();
        assert_eq!(out.evals(), 25);
        // round 0 is the canonical design; a follow-up ask (chunked
        // early-stop runs) re-stratifies under a derived seed
        let mut l = LatinHypercube::new(1);
        let first = l.ask(&space, 25);
        let second = l.ask(&space, 25);
        assert_eq!(first.len(), 25);
        assert_eq!(second.len(), 25);
        assert_ne!(first[0].unit_x, second[0].unit_x);
    }

    #[test]
    fn unconstrained_ask_is_the_canonical_design() {
        // no constraints -> ask proposes exactly points_seeded(seed)
        let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
        let batch = LatinHypercube::new(6).ask(&space, 17);
        let reference = points_seeded(6, 17, space.dims());
        for (c, r) in batch.iter().zip(&reference) {
            assert_eq!(&c.unit_x, r);
        }
    }

    #[test]
    fn constrained_design_rejects_into_the_feasible_region() {
        let spec = TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 16 2048\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             constraint io.sort.mb <= 0.25*map.memory.mb\n",
        )
        .unwrap();
        let space = ParamSpace::new(spec, HadoopConfig::default());
        let a = LatinHypercube::new(9).ask(&space, 48);
        let b = LatinHypercube::new(9).ask(&space, 48);
        let mut scratch = space.base.clone();
        let mut feasible = 0usize;
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.unit_x, y.unit_x, "constrained design not deterministic");
            if space.unit_feasible(&x.unit_x, &mut scratch) {
                feasible += 1;
            }
        }
        // the raw stratified design lands infeasible ~72% of the time on
        // this spec; rejection must make feasible draws the rule
        assert!(feasible >= 44, "only {feasible}/48 design points feasible");
        // feasible stratified points keep their strata: points that were
        // feasible in the canonical design appear unchanged
        let canonical = points_seeded(9, 48, space.dims());
        for (c, orig) in a.iter().zip(&canonical) {
            if space.unit_feasible(orig, &mut scratch) {
                assert_eq!(&c.unit_x, orig, "feasible design point was perturbed");
            }
        }
    }
}

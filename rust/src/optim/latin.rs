//! Latin hypercube sampling (LHS) — space-filling one-shot design, the
//! standard initialization for surrogate-based tuners and a stronger
//! budget-for-budget baseline than uniform random search.

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LatinHypercube {
    pub seed: u64,
}

impl LatinHypercube {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// Generate `n` LHS points in the unit cube of dimension `d`: each
    /// dimension is split into n strata, each stratum hit exactly once.
    pub fn points(&self, n: usize, d: usize) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(self.seed);
        // per-dimension stratum permutations
        let mut perms: Vec<Vec<usize>> = Vec::with_capacity(d);
        for _ in 0..d {
            let mut p: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut p);
            perms.push(p);
        }
        (0..n)
            .map(|i| {
                (0..d)
                    .map(|j| (perms[j][i] as f64 + rng.f64()) / n as f64)
                    .collect()
            })
            .collect()
    }

    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let mut rec = Recorder::new();
        for x in self.points(max_evals, space.dims()) {
            let cfg = space.decode(&x);
            let v = obj(&cfg);
            rec.record(x, cfg, v);
        }
        rec.finish("latin-hypercube")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;

    #[test]
    fn stratification_holds_per_dimension() {
        let lhs = LatinHypercube::new(4);
        let n = 16;
        let pts = lhs.points(n, 3);
        assert_eq!(pts.len(), n);
        for j in 0..3 {
            let mut strata: Vec<usize> =
                pts.iter().map(|p| (p[j] * n as f64) as usize).collect();
            strata.sort_unstable();
            assert_eq!(strata, (0..n).collect::<Vec<_>>(), "dim {j} not stratified");
        }
    }

    #[test]
    fn better_coverage_than_random_on_average() {
        // min pairwise distance of LHS should beat uniform random
        let d = 4;
        let n = 20;
        let min_dist = |pts: &[Vec<f64>]| -> f64 {
            let mut m = f64::MAX;
            for i in 0..pts.len() {
                for j in i + 1..pts.len() {
                    let d2: f64 = pts[i]
                        .iter()
                        .zip(&pts[j])
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    m = m.min(d2.sqrt());
                }
            }
            m
        };
        let mut lhs_wins = 0;
        for seed in 0..10 {
            let lhs_pts = LatinHypercube::new(seed).points(n, d);
            let mut rng = Rng::new(seed + 1000);
            let rnd_pts: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.f64()).collect())
                .collect();
            if min_dist(&lhs_pts) > min_dist(&rnd_pts) {
                lhs_wins += 1;
            }
        }
        assert!(lhs_wins >= 7, "LHS beat random only {lhs_wins}/10 times");
    }

    #[test]
    fn run_uses_exact_budget() {
        let space = ParamSpace::new(TuningSpec::fig2(), HadoopConfig::default());
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| sp.encode(c).iter().sum::<f64>();
        let out = LatinHypercube::new(1).run(&space, &mut obj, 25);
        assert_eq!(out.evals(), 25);
    }
}

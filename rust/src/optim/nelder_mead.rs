//! Nelder–Mead simplex with box constraints (clamping to the unit cube),
//! the classic derivative-free workhorse and a baseline for the DFO
//! family the paper integrates.
//!
//! Ask/tell port: a singleton-ask state machine over the classic phases —
//! initial simplex, reflect, expand, contract, shrink. The simplex keeps
//! the *unclamped* vertices (as the old loop did); candidates handed to
//! the driver are clamped to the cube, so every recorded point is
//! feasible.

use crate::optim::core::{BestSeen, Candidate, Optimizer};
use crate::optim::result::EvalRecord;
use crate::optim::space::ParamSpace;

#[derive(Clone, Debug)]
pub struct NelderMead {
    pub init_scale: f64,
    pub start: Option<Vec<f64>>,
    /// Stop when the simplex collapses below this diameter.
    pub min_diameter: f64,
    st: Option<State>,
    best: BestSeen,
}

impl Default for NelderMead {
    fn default() -> Self {
        Self {
            init_scale: 0.3,
            start: None,
            min_diameter: 1e-3,
            st: None,
            best: BestSeen::default(),
        }
    }
}

impl NelderMead {
    pub fn with_start(mut self, start: Vec<f64>) -> Self {
        self.start = Some(start);
        self
    }
}

const ALPHA: f64 = 1.0; // reflection
const GAMMA: f64 = 2.0; // expansion
const RHO: f64 = 0.5; // contraction
const SIGMA: f64 = 0.5; // shrink

#[derive(Clone, Debug)]
enum Phase {
    /// Init vertex `k` computed, ready to be asked.
    ProposeInit { k: usize, x: Vec<f64> },
    /// Waiting for init vertex `k`'s value (the vertex is the pending vec).
    AwaitInit { k: usize, x: Vec<f64> },
    /// Ready to start an iteration: sort, converge-check, reflect.
    IterStart,
    AwaitReflect {
        worst: (Vec<f64>, f64),
        centroid: Vec<f64>,
        reflect: Vec<f64>,
    },
    AwaitExpand {
        reflect: (Vec<f64>, f64),
        expand: Vec<f64>,
    },
    AwaitContract {
        worst_f: f64,
        reflect_f: f64,
        contract: Vec<f64>,
    },
    /// Shrinking vertex `k` toward `best_x`; `pending` is the new vertex.
    Shrink {
        k: usize,
        best_x: Vec<f64>,
        pending: Option<Vec<f64>>,
    },
    Done,
}

#[derive(Clone, Debug)]
struct State {
    simplex: Vec<(Vec<f64>, f64)>,
    phase: Phase,
}

fn clamped(x: &[f64]) -> Vec<f64> {
    x.iter().map(|u| u.clamp(0.0, 1.0)).collect()
}

impl Optimizer for NelderMead {
    fn name(&self) -> &str {
        "nelder-mead"
    }

    fn ask(&mut self, space: &ParamSpace, _budget_left: usize) -> Vec<Candidate> {
        let d = space.dims();
        let st = match &mut self.st {
            None => {
                let x0 = self.start.clone().unwrap_or_else(|| vec![0.5; d]);
                self.st = Some(State {
                    simplex: Vec::with_capacity(d + 1),
                    phase: Phase::AwaitInit { k: 0, x: x0.clone() },
                });
                return vec![Candidate::new(clamped(&x0))];
            }
            Some(st) => st,
        };
        loop {
            match &mut st.phase {
                Phase::AwaitInit { .. }
                | Phase::AwaitReflect { .. }
                | Phase::AwaitExpand { .. }
                | Phase::AwaitContract { .. } => return Vec::new(), // tell pending
                Phase::Done => return Vec::new(),
                Phase::ProposeInit { k, x } => {
                    let (k, x) = (*k, x.clone());
                    st.phase = Phase::AwaitInit { k, x: x.clone() };
                    return vec![Candidate::new(clamped(&x))];
                }
                Phase::IterStart => {
                    if st.simplex.len() != d + 1 {
                        // defensive: init was interrupted
                        st.phase = Phase::Done;
                        return Vec::new();
                    }
                    st.simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
                    let diameter = st
                        .simplex
                        .iter()
                        .skip(1)
                        .map(|(x, _)| {
                            x.iter()
                                .zip(&st.simplex[0].0)
                                .map(|(a, b)| (a - b).abs())
                                .fold(0.0, f64::max)
                        })
                        .fold(0.0, f64::max);
                    if diameter < self.min_diameter {
                        st.phase = Phase::Done;
                        return Vec::new();
                    }
                    // centroid of all but the worst vertex
                    let worst = st.simplex[d].clone();
                    let centroid: Vec<f64> = (0..d)
                        .map(|i| {
                            st.simplex[..d].iter().map(|(x, _)| x[i]).sum::<f64>()
                                / d as f64
                        })
                        .collect();
                    let reflect: Vec<f64> = centroid
                        .iter()
                        .zip(&worst.0)
                        .map(|(c, w)| c + ALPHA * (c - w))
                        .collect();
                    let probe = clamped(&reflect);
                    st.phase = Phase::AwaitReflect {
                        worst,
                        centroid,
                        reflect,
                    };
                    return vec![Candidate::new(probe)];
                }
                Phase::Shrink { k, best_x, pending } => {
                    if *k > d {
                        st.phase = Phase::IterStart;
                        continue;
                    }
                    let xs: Vec<f64> = st.simplex[*k]
                        .0
                        .iter()
                        .zip(best_x.iter())
                        .map(|(x, b)| b + SIGMA * (x - b))
                        .collect();
                    *pending = Some(xs.clone());
                    return vec![Candidate::new(clamped(&xs))];
                }
            }
        }
    }

    fn tell(&mut self, evals: &[EvalRecord]) {
        self.best.update(evals);
        let st = match &mut self.st {
            // told before the first ask (resume replay): seed the start
            None => {
                if let Some((x, _)) = self.best.get() {
                    self.start = Some(x);
                }
                return;
            }
            Some(st) => st,
        };
        for r in evals {
            let v = r.value;
            match std::mem::replace(&mut st.phase, Phase::IterStart) {
                Phase::AwaitInit { k, x } => {
                    st.simplex.push((x.clone(), v));
                    let dims = x.len();
                    if k == dims {
                        st.phase = Phase::IterStart;
                    } else {
                        // next offset vertex, exactly as the old init loop
                        let x0 = &st.simplex[0].0;
                        let mut xi = x0.clone();
                        xi[k] = (xi[k] + self.init_scale).min(1.0);
                        if (xi[k] - x0[k]).abs() < 1e-9 {
                            xi[k] = (x0[k] - self.init_scale).max(0.0);
                        }
                        st.phase = Phase::ProposeInit { k: k + 1, x: xi };
                    }
                }
                Phase::ProposeInit { k, x } => {
                    // defensive: an unsolicited tell — keep the proposal
                    st.phase = Phase::ProposeInit { k, x };
                }
                Phase::AwaitReflect {
                    worst,
                    centroid,
                    reflect,
                } => {
                    let fr = v;
                    let dlen = st.simplex.len() - 1;
                    if fr < st.simplex[0].1 {
                        let expand: Vec<f64> = centroid
                            .iter()
                            .zip(&worst.0)
                            .map(|(c, w)| c + GAMMA * ALPHA * (c - w))
                            .collect();
                        st.phase = Phase::AwaitExpand {
                            reflect: (reflect, fr),
                            expand,
                        };
                    } else if fr < st.simplex[dlen - 1].1 {
                        st.simplex[dlen] = (reflect, fr);
                        st.phase = Phase::IterStart;
                    } else {
                        // contraction (outside if fr beats the worst, else inside)
                        let toward = if fr < worst.1 { &reflect } else { &worst.0 };
                        let contract: Vec<f64> = centroid
                            .iter()
                            .zip(toward)
                            .map(|(c, t)| c + RHO * (t - c))
                            .collect();
                        st.phase = Phase::AwaitContract {
                            worst_f: worst.1,
                            reflect_f: fr,
                            contract,
                        };
                    }
                }
                Phase::AwaitExpand { reflect, expand } => {
                    let dlen = st.simplex.len() - 1;
                    st.simplex[dlen] = if v < reflect.1 { (expand, v) } else { reflect };
                    st.phase = Phase::IterStart;
                }
                Phase::AwaitContract {
                    worst_f,
                    reflect_f,
                    contract,
                } => {
                    let dlen = st.simplex.len() - 1;
                    if v < worst_f.min(reflect_f) {
                        st.simplex[dlen] = (contract, v);
                        st.phase = Phase::IterStart;
                    } else {
                        st.phase = Phase::Shrink {
                            k: 1,
                            best_x: st.simplex[0].0.clone(),
                            pending: None,
                        };
                    }
                }
                Phase::Shrink { k, best_x, pending } => {
                    let xs = pending.expect("shrink tell without probe");
                    st.simplex[k] = (xs, v);
                    st.phase = Phase::Shrink {
                        k: k + 1,
                        best_x,
                        pending: None,
                    };
                }
                other @ (Phase::IterStart | Phase::Done) => st.phase = other,
            }
        }
    }

    fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.best.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use crate::optim::core::{Driver, FnObjective};

    fn space4() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    #[test]
    fn converges_on_quadratic() {
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.6).powi(2)).sum()
        });
        let out = Driver::new(250)
            .run(&mut NelderMead::default(), &space, &mut obj)
            .unwrap();
        assert!(out.best_value < 0.02, "NM stuck at {}", out.best_value);
    }

    #[test]
    fn converges_on_rosenbrock_like() {
        // a curved valley — harder than a separable bowl
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            let u = sp.encode(c);
            let mut s = 0.0;
            for i in 0..u.len() - 1 {
                s += 10.0 * (u[i + 1] - u[i] * u[i]).powi(2) + (1.0 - u[i]).powi(2);
            }
            s
        });
        let out = Driver::new(400)
            .run(&mut NelderMead::default(), &space, &mut obj)
            .unwrap();
        // integer rounding limits precision; just demand real progress
        let first = out.records[0].value;
        assert!(
            out.best_value < first * 0.25,
            "NM {} vs start {first}",
            out.best_value
        );
    }

    #[test]
    fn all_proposals_in_cube() {
        let space = space4();
        let sp = space.clone();
        let mut obj = FnObjective(move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 1.2).powi(2)).sum() // optimum outside
        });
        let out = Driver::new(120)
            .run(&mut NelderMead::default(), &space, &mut obj)
            .unwrap();
        for r in &out.records {
            assert!(
                r.unit_x.iter().all(|&u| (0.0..=1.0).contains(&u)),
                "{:?}",
                r.unit_x
            );
        }
    }

    #[test]
    fn budget_respected() {
        let space = space4();
        let mut obj = FnObjective(|_: &HadoopConfig| 1.0);
        let out = Driver::new(30)
            .run(&mut NelderMead::default(), &space, &mut obj)
            .unwrap();
        assert!(out.evals() <= 30);
    }
}

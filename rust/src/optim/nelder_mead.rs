//! Nelder–Mead simplex with box constraints (clamping to the unit cube),
//! the classic derivative-free workhorse and a baseline for the DFO
//! family the paper integrates.

use crate::optim::result::{Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::ObjectiveFn;

#[derive(Clone, Debug)]
pub struct NelderMead {
    pub init_scale: f64,
    pub start: Option<Vec<f64>>,
    /// Restart the simplex when it collapses below this diameter.
    pub min_diameter: f64,
}

impl Default for NelderMead {
    fn default() -> Self {
        Self {
            init_scale: 0.3,
            start: None,
            min_diameter: 1e-3,
        }
    }
}

const ALPHA: f64 = 1.0; // reflection
const GAMMA: f64 = 2.0; // expansion
const RHO: f64 = 0.5; // contraction
const SIGMA: f64 = 0.5; // shrink

impl NelderMead {
    pub fn run(
        &self,
        space: &ParamSpace,
        obj: &mut ObjectiveFn<'_>,
        max_evals: usize,
    ) -> TuningOutcome {
        let d = space.dims();
        let mut rec = Recorder::new();
        let mut eval = |rec: &mut Recorder, x: &[f64]| -> f64 {
            let x: Vec<f64> = x.iter().map(|u| u.clamp(0.0, 1.0)).collect();
            let cfg = space.decode(&x);
            let v = obj(&cfg);
            rec.record(x, cfg, v);
            v
        };

        // initial simplex: start + scaled unit offsets
        let x0 = self.start.clone().unwrap_or_else(|| vec![0.5; d]);
        let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(d + 1);
        let f0 = eval(&mut rec, &x0);
        simplex.push((x0.clone(), f0));
        for i in 0..d {
            if rec.evals() >= max_evals {
                break;
            }
            let mut xi = x0.clone();
            xi[i] = (xi[i] + self.init_scale).min(1.0);
            if (xi[i] - x0[i]).abs() < 1e-9 {
                xi[i] = (x0[i] - self.init_scale).max(0.0);
            }
            let fi = eval(&mut rec, &xi);
            simplex.push((xi, fi));
        }

        while rec.evals() < max_evals && simplex.len() == d + 1 {
            simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
            let diameter = simplex
                .iter()
                .skip(1)
                .map(|(x, _)| {
                    x.iter()
                        .zip(&simplex[0].0)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0, f64::max)
                })
                .fold(0.0, f64::max);
            if diameter < self.min_diameter {
                break;
            }

            // centroid of all but worst
            let worst = simplex[d].clone();
            let centroid: Vec<f64> = (0..d)
                .map(|i| simplex[..d].iter().map(|(x, _)| x[i]).sum::<f64>() / d as f64)
                .collect();
            let reflect: Vec<f64> = centroid
                .iter()
                .zip(&worst.0)
                .map(|(c, w)| c + ALPHA * (c - w))
                .collect();
            let fr = eval(&mut rec, &reflect);

            if fr < simplex[0].1 {
                // try expansion
                if rec.evals() >= max_evals {
                    simplex[d] = (reflect, fr);
                    break;
                }
                let expand: Vec<f64> = centroid
                    .iter()
                    .zip(&worst.0)
                    .map(|(c, w)| c + GAMMA * ALPHA * (c - w))
                    .collect();
                let fe = eval(&mut rec, &expand);
                simplex[d] = if fe < fr { (expand, fe) } else { (reflect, fr) };
            } else if fr < simplex[d - 1].1 {
                simplex[d] = (reflect, fr);
            } else {
                // contraction (outside if fr better than worst, else inside)
                if rec.evals() >= max_evals {
                    break;
                }
                let toward = if fr < worst.1 { &reflect } else { &worst.0 };
                let contract: Vec<f64> = centroid
                    .iter()
                    .zip(toward)
                    .map(|(c, t)| c + RHO * (t - c))
                    .collect();
                let fc = eval(&mut rec, &contract);
                if fc < worst.1.min(fr) {
                    simplex[d] = (contract, fc);
                } else {
                    // shrink toward the best
                    let best = simplex[0].0.clone();
                    for k in 1..=d {
                        if rec.evals() >= max_evals {
                            break;
                        }
                        let xs: Vec<f64> = simplex[k]
                            .0
                            .iter()
                            .zip(&best)
                            .map(|(x, b)| b + SIGMA * (x - b))
                            .collect();
                        let fs = eval(&mut rec, &xs);
                        simplex[k] = (xs, fs);
                    }
                }
            }
        }
        rec.finish("nelder-mead")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;

    fn space4() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    #[test]
    fn converges_on_quadratic() {
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 0.6).powi(2)).sum()
        };
        let out = NelderMead::default().run(&space, &mut obj, 250);
        assert!(out.best_value < 0.02, "NM stuck at {}", out.best_value);
    }

    #[test]
    fn converges_on_rosenbrock_like() {
        // a curved valley — harder than a separable bowl
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            let u = sp.encode(c);
            let mut s = 0.0;
            for i in 0..u.len() - 1 {
                s += 10.0 * (u[i + 1] - u[i] * u[i]).powi(2) + (1.0 - u[i]).powi(2);
            }
            s
        };
        let out = NelderMead::default().run(&space, &mut obj, 400);
        // integer rounding limits precision; just demand real progress
        let first = out.records[0].value;
        assert!(out.best_value < first * 0.25, "NM {} vs start {first}", out.best_value);
    }

    #[test]
    fn all_proposals_in_cube() {
        let space = space4();
        let sp = space.clone();
        let mut obj = move |c: &HadoopConfig| -> f64 {
            sp.encode(c).iter().map(|u| (u - 1.2).powi(2)).sum() // optimum outside
        };
        let out = NelderMead::default().run(&space, &mut obj, 120);
        for r in &out.records {
            assert!(r.unit_x.iter().all(|&u| (0.0..=1.0).contains(&u)), "{:?}", r.unit_x);
        }
    }

    #[test]
    fn budget_respected() {
        let space = space4();
        let mut obj = |_: &HadoopConfig| 1.0;
        let out = NelderMead::default().run(&space, &mut obj, 30);
        assert!(out.evals() <= 30);
    }
}

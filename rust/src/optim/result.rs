//! Tuning-run bookkeeping: per-evaluation records, best-so-far tracking,
//! and the outcome summary Catla's history/visualization layers consume.

use crate::config::params::HadoopConfig;

/// One cluster evaluation during a tuning run.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// 1-based evaluation index ("iteration" in the paper's Fig. 3).
    pub iter: usize,
    pub config: HadoopConfig,
    /// Unit-cube coordinates the optimizer proposed.
    pub unit_x: Vec<f64>,
    /// Measured job running time, seconds.
    pub value: f64,
    /// min(value) over evaluations 1..=iter.
    pub best_so_far: f64,
}

/// Result of a whole tuning run.
#[derive(Clone, Debug)]
pub struct TuningOutcome {
    pub optimizer: String,
    pub records: Vec<EvalRecord>,
    pub best_config: HadoopConfig,
    pub best_value: f64,
}

impl TuningOutcome {
    pub fn evals(&self) -> usize {
        self.records.len()
    }

    /// Evaluations needed to first reach within `(1+tol)` of `target`
    /// (e.g. the grid optimum) — the ABL1 comparison metric.
    pub fn evals_to_within(&self, target: f64, tol: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.best_so_far <= target * (1.0 + tol))
            .map(|r| r.iter)
    }

    /// (iteration, best_so_far) convergence series for Fig. 3.
    pub fn convergence(&self) -> Vec<(usize, f64)> {
        self.records.iter().map(|r| (r.iter, r.best_so_far)).collect()
    }

    /// (iteration, raw value) series — the paper plots raw running time
    /// per iteration, fluctuations included.
    pub fn raw_series(&self) -> Vec<(usize, f64)> {
        self.records.iter().map(|r| (r.iter, r.value)).collect()
    }
}

/// Incremental recorder used by every optimizer implementation.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    records: Vec<EvalRecord>,
    best: Option<(HadoopConfig, f64)>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, unit_x: Vec<f64>, config: HadoopConfig, value: f64) {
        let best_so_far = match &self.best {
            Some((_, b)) => b.min(value),
            None => value,
        };
        if self.best.as_ref().map(|(_, b)| value < *b).unwrap_or(true) {
            self.best = Some((config.clone(), value));
        }
        self.records.push(EvalRecord {
            iter: self.records.len() + 1,
            config,
            unit_x,
            value,
            best_so_far,
        });
    }

    pub fn evals(&self) -> usize {
        self.records.len()
    }

    /// Everything recorded so far, in evaluation order — the serve
    /// daemon's incremental checkpointing reads this mid-run.
    pub fn records(&self) -> &[EvalRecord] {
        &self.records
    }

    /// The most recently recorded evaluation (the `Driver` clones it for
    /// observer hooks and `tell` batches).
    pub fn last(&self) -> Option<&EvalRecord> {
        self.records.last()
    }

    pub fn best_value(&self) -> Option<f64> {
        self.best.as_ref().map(|(_, v)| *v)
    }

    pub fn finish(self, optimizer: &str) -> TuningOutcome {
        let (best_config, best_value) = self
            .best
            .expect("tuning run recorded no evaluations");
        TuningOutcome {
            optimizer: optimizer.to_string(),
            records: self.records,
            best_config,
            best_value,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HadoopConfig {
        HadoopConfig::default()
    }

    #[test]
    fn best_so_far_monotone() {
        let mut r = Recorder::new();
        for v in [5.0, 3.0, 4.0, 2.0, 6.0] {
            r.record(vec![0.5], cfg(), v);
        }
        let out = r.finish("test");
        let bsf: Vec<f64> = out.records.iter().map(|x| x.best_so_far).collect();
        assert_eq!(bsf, vec![5.0, 3.0, 3.0, 2.0, 2.0]);
        assert_eq!(out.best_value, 2.0);
    }

    #[test]
    fn evals_to_within() {
        let mut r = Recorder::new();
        for v in [10.0, 8.0, 5.5, 5.0] {
            r.record(vec![0.0], cfg(), v);
        }
        let out = r.finish("test");
        assert_eq!(out.evals_to_within(5.0, 0.10), Some(3)); // 5.5 <= 5.5
        assert_eq!(out.evals_to_within(5.0, 0.0), Some(4));
        assert_eq!(out.evals_to_within(1.0, 0.0), None);
    }

    #[test]
    #[should_panic(expected = "no evaluations")]
    fn empty_run_panics() {
        Recorder::new().finish("test");
    }
}

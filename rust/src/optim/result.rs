//! Tuning-run bookkeeping: per-evaluation records, best-so-far tracking,
//! and the outcome summary Catla's history/visualization layers consume.

use crate::config::params::HadoopConfig;

/// How much evidence stands behind an [`EvalRecord::value`].
///
/// Every record of a non-racing run is `Full`. With multi-fidelity
/// racing enabled (`racing.enabled=true`), candidates pruned before
/// reaching full fidelity carry the cheaper tier their value came from:
/// `CostModel` (tier 0, the analytic oracle — zero simulations) or
/// `Seeds(k)` (mean over the first `k < repeats` seeds of the config's
/// reserved seed block). Best-so-far tracking, early stopping, and
/// summary "best" selection all consider `Full` records only, so a
/// low-fidelity score can never be declared the winner of a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    /// Tier 0: `costmodel::predict_runtime` — no simulation behind it.
    CostModel,
    /// Mean over this many DES seeds, fewer than the run's `repeats`.
    Seeds(u32),
    /// Mean over the config's whole reserved seed block (every record
    /// of a racing-off run).
    Full,
}

impl Fidelity {
    pub fn is_full(self) -> bool {
        matches!(self, Fidelity::Full)
    }

    /// Number of DES runs behind a value at this fidelity, given the
    /// run's `repeats` setting.
    pub fn sims(self, repeats: usize) -> usize {
        match self {
            Fidelity::CostModel => 0,
            Fidelity::Seeds(k) => k as usize,
            Fidelity::Full => repeats.max(1),
        }
    }

    /// Tuning-log / journal rendering: `model`, the seed count, or
    /// `full`.
    pub fn label(self) -> String {
        match self {
            Fidelity::CostModel => "model".to_string(),
            Fidelity::Seeds(k) => k.to_string(),
            Fidelity::Full => "full".to_string(),
        }
    }

    /// Inverse of [`Fidelity::label`].
    pub fn parse(s: &str) -> Result<Fidelity, String> {
        match s {
            "model" => Ok(Fidelity::CostModel),
            "full" => Ok(Fidelity::Full),
            other => other
                .parse::<u32>()
                .map(Fidelity::Seeds)
                .map_err(|_| format!("unknown fidelity {other:?} (expected model|full|<seeds>)")),
        }
    }
}

/// One cluster evaluation during a tuning run.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// 1-based evaluation index ("iteration" in the paper's Fig. 3).
    pub iter: usize,
    pub config: HadoopConfig,
    /// Unit-cube coordinates the optimizer proposed.
    pub unit_x: Vec<f64>,
    /// Measured job running time, seconds (or a cheaper-tier estimate —
    /// see `fidelity`).
    pub value: f64,
    /// min(value) over full-fidelity evaluations 1..=iter.
    pub best_so_far: f64,
    /// Evidence tier behind `value`; `Full` unless racing pruned this
    /// candidate early.
    pub fidelity: Fidelity,
}

/// Result of a whole tuning run.
#[derive(Clone, Debug)]
pub struct TuningOutcome {
    pub optimizer: String,
    pub records: Vec<EvalRecord>,
    pub best_config: HadoopConfig,
    pub best_value: f64,
}

impl TuningOutcome {
    pub fn evals(&self) -> usize {
        self.records.len()
    }

    /// Evaluations needed to first reach within `(1+tol)` of `target`
    /// (e.g. the grid optimum) — the ABL1 comparison metric.
    pub fn evals_to_within(&self, target: f64, tol: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.best_so_far <= target * (1.0 + tol))
            .map(|r| r.iter)
    }

    /// (iteration, best_so_far) convergence series for Fig. 3.
    pub fn convergence(&self) -> Vec<(usize, f64)> {
        self.records.iter().map(|r| (r.iter, r.best_so_far)).collect()
    }

    /// (iteration, raw value) series — the paper plots raw running time
    /// per iteration, fluctuations included.
    pub fn raw_series(&self) -> Vec<(usize, f64)> {
        self.records.iter().map(|r| (r.iter, r.value)).collect()
    }
}

/// Incremental recorder used by every optimizer implementation.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    records: Vec<EvalRecord>,
    best: Option<(HadoopConfig, f64)>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, unit_x: Vec<f64>, config: HadoopConfig, value: f64) {
        self.record_tiered(unit_x, config, value, Fidelity::Full);
    }

    /// Record an evaluation at an explicit fidelity tier. Only `Full`
    /// records compete for `best` / `best_so_far`: a low-fidelity row
    /// shows the current full-fidelity best (or, before the first full
    /// record exists, its own value as a provisional placeholder).
    pub fn record_tiered(
        &mut self,
        unit_x: Vec<f64>,
        config: HadoopConfig,
        value: f64,
        fidelity: Fidelity,
    ) {
        let best_so_far = match &self.best {
            Some((_, b)) => {
                if fidelity.is_full() {
                    b.min(value)
                } else {
                    *b
                }
            }
            None => value,
        };
        if fidelity.is_full() && self.best.as_ref().map(|(_, b)| value < *b).unwrap_or(true) {
            self.best = Some((config.clone(), value));
        }
        self.records.push(EvalRecord {
            iter: self.records.len() + 1,
            config,
            unit_x,
            value,
            best_so_far,
            fidelity,
        });
    }

    pub fn evals(&self) -> usize {
        self.records.len()
    }

    /// Everything recorded so far, in evaluation order — the serve
    /// daemon's incremental checkpointing reads this mid-run.
    pub fn records(&self) -> &[EvalRecord] {
        &self.records
    }

    /// The most recently recorded evaluation (the `Driver` clones it for
    /// observer hooks and `tell` batches).
    pub fn last(&self) -> Option<&EvalRecord> {
        self.records.last()
    }

    pub fn best_value(&self) -> Option<f64> {
        self.best.as_ref().map(|(_, v)| *v)
    }

    pub fn finish(self, optimizer: &str) -> TuningOutcome {
        let (best_config, best_value) = self
            .best
            .or_else(|| {
                // Defensive: a run whose every record is low-fidelity
                // (cannot happen through the racing layer, which always
                // promotes at least one candidate per slice) still gets
                // a best rather than a panic.
                self.records
                    .iter()
                    .min_by(|a, b| a.value.total_cmp(&b.value))
                    .map(|r| (r.config.clone(), r.value))
            })
            .expect("tuning run recorded no evaluations");
        TuningOutcome {
            optimizer: optimizer.to_string(),
            records: self.records,
            best_config,
            best_value,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HadoopConfig {
        HadoopConfig::default()
    }

    #[test]
    fn best_so_far_monotone() {
        let mut r = Recorder::new();
        for v in [5.0, 3.0, 4.0, 2.0, 6.0] {
            r.record(vec![0.5], cfg(), v);
        }
        let out = r.finish("test");
        let bsf: Vec<f64> = out.records.iter().map(|x| x.best_so_far).collect();
        assert_eq!(bsf, vec![5.0, 3.0, 3.0, 2.0, 2.0]);
        assert_eq!(out.best_value, 2.0);
    }

    #[test]
    fn evals_to_within() {
        let mut r = Recorder::new();
        for v in [10.0, 8.0, 5.5, 5.0] {
            r.record(vec![0.0], cfg(), v);
        }
        let out = r.finish("test");
        assert_eq!(out.evals_to_within(5.0, 0.10), Some(3)); // 5.5 <= 5.5
        assert_eq!(out.evals_to_within(5.0, 0.0), Some(4));
        assert_eq!(out.evals_to_within(1.0, 0.0), None);
    }

    #[test]
    #[should_panic(expected = "no evaluations")]
    fn empty_run_panics() {
        Recorder::new().finish("test");
    }

    #[test]
    fn low_fidelity_records_never_win_best() {
        let mut r = Recorder::new();
        r.record_tiered(vec![0.1], cfg(), 9.0, Fidelity::Full);
        // cheaper tiers report smaller values but must not displace best
        r.record_tiered(vec![0.2], cfg(), 1.0, Fidelity::CostModel);
        r.record_tiered(vec![0.3], cfg(), 2.0, Fidelity::Seeds(1));
        r.record_tiered(vec![0.4], cfg(), 7.0, Fidelity::Full);
        let out = r.finish("test");
        assert_eq!(out.best_value, 7.0);
        let bsf: Vec<f64> = out.records.iter().map(|x| x.best_so_far).collect();
        assert_eq!(bsf, vec![9.0, 9.0, 9.0, 7.0]);
    }

    #[test]
    fn all_low_fidelity_falls_back_to_min_value() {
        let mut r = Recorder::new();
        r.record_tiered(vec![0.1], cfg(), 4.0, Fidelity::Seeds(1));
        r.record_tiered(vec![0.2], cfg(), 3.0, Fidelity::CostModel);
        let out = r.finish("test");
        assert_eq!(out.best_value, 3.0);
    }

    #[test]
    fn fidelity_label_roundtrip() {
        for f in [Fidelity::CostModel, Fidelity::Seeds(1), Fidelity::Seeds(7), Fidelity::Full] {
            assert_eq!(Fidelity::parse(&f.label()).unwrap(), f);
        }
        assert!(Fidelity::parse("half").is_err());
        assert_eq!(Fidelity::CostModel.sims(5), 0);
        assert_eq!(Fidelity::Seeds(2).sims(5), 2);
        assert_eq!(Fidelity::Full.sims(5), 5);
    }
}

//! The ask/tell optimizer core: every search method behind one batched
//! protocol, driven by a shared [`Driver`].
//!
//! * [`Optimizer`] — `ask` proposes a batch of unit-cube candidates,
//!   `tell` feeds the measured results back. Population methods (random,
//!   latin) ask in large batches, grid streams chunk-bounded batches off
//!   its cursor; sequential methods (bobyqa, hooke-jeeves, …) ask
//!   singletons and behave exactly like their old monolithic loops.
//! * [`BatchObjective`] — scores a whole ask-batch in one call.
//!   [`ClusterObjective`] fans a batch out over the thread pool against
//!   the simulated cluster (byte-identical to serial submission order:
//!   simulation seeds are reserved up front), with `repeats`
//!   noise-averaging folded in. [`ScorerObjective`] routes a batch
//!   through a [`CandidateScorer`] — the AOT/Pallas batch scorer when
//!   built with the `pjrt` feature.
//! * [`Driver`] — owns the evaluation budget (an over-sized ask-batch is
//!   truncated, never overspent), optional early stopping, per-eval
//!   [`Observer`] hooks, and checkpoint replay
//!   ([`Driver::run_with_history`] re-`tell`s prior evaluations into a
//!   fresh optimizer).
//! * [`DriverSession`] — the same loop inverted into a non-blocking
//!   `next_slice`/`tell_values` stepper, so the serve daemon can
//!   interleave many concurrent sessions over one pool; `Driver` itself
//!   runs on top of it, so the two cannot drift apart.
//!
//! # The chunked-ask protocol
//!
//! The driver carries a streaming chunk size (`batch.chunk` in
//! `tuning.properties`, default [`DEFAULT_BATCH_CHUNK`]) with two roles:
//!
//! 1. Before the first `ask` it is handed to the optimizer through
//!    [`Optimizer::set_chunk`]. Methods whose proposals form a stream
//!    (grid) bound each ask-batch to it, so an exhaustive sweep over a
//!    10^6-point space never materializes more than one chunk of
//!    candidates. One-shot designs (latin's stratification, bobyqa's
//!    init set) may ignore the hint — their batch *shape* is part of the
//!    method.
//! 2. Every ask-batch is **evaluated and told in chunk-sized slices**,
//!    bounding the decoded-config buffer the same way. Early stopping is
//!    decided per evaluation, never per slice, so it cannot observe the
//!    slicing.
//!
//! Both roles only re-slice the identical candidate stream: for every
//! method the evaluation order, seeds and records are byte-identical
//! under any chunk size, with or without early stopping
//! (regression-tested across all eight methods in
//! `rust/tests/ask_tell.rs`).

use crate::config::params::HadoopConfig;
use crate::hadoop::{simulate_runtime, simulate_runtime_in, SimArena, SimCluster};
use crate::optim::result::{EvalRecord, Fidelity, Recorder, TuningOutcome};
use crate::optim::space::ParamSpace;
use crate::optim::surrogate::CandidateScorer;
use crate::util::pool::{default_threads, ThreadPool};
use crate::workloads::WorkloadSpec;

/// Default streaming chunk: ask-batches are proposed (by streaming
/// methods) and evaluated in slices of at most this many candidates.
pub const DEFAULT_BATCH_CHUNK: usize = 1024;

/// One proposed configuration, in unit-cube coordinates.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub unit_x: Vec<f64>,
    /// Pre-decoded configuration, set when the proposing optimizer
    /// already decoded the point (grid's constraint dedup does): the
    /// driver consumes it instead of decoding a second time.
    pub config: Option<HadoopConfig>,
}

impl Candidate {
    pub fn new(unit_x: Vec<f64>) -> Candidate {
        Candidate {
            unit_x,
            config: None,
        }
    }

    /// Attach the decoded configuration (decode-once optimization).
    pub fn with_config(mut self, config: HadoopConfig) -> Candidate {
        self.config = Some(config);
        self
    }
}

impl From<Vec<f64>> for Candidate {
    fn from(unit_x: Vec<f64>) -> Candidate {
        Candidate::new(unit_x)
    }
}

/// The ask/tell protocol every search method implements.
///
/// Contract: the [`Driver`] alternates `ask` → evaluate → `tell`; every
/// evaluated candidate from the last ask-batch is told back (in ask
/// order) before the next `ask`. An empty ask-batch means the method has
/// converged or exhausted its proposals. `tell` may also be called
/// *before* the first `ask` to replay a checkpoint — methods use that to
/// skip known points (grid) or seed their start at the best prior point.
pub trait Optimizer {
    /// Label recorded into [`TuningOutcome::optimizer`].
    fn name(&self) -> &str;

    /// Propose up to `budget_left` candidates (more are truncated by the
    /// driver; fewer is fine).
    fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate>;

    /// Streaming hint, called once per run before the first `ask`:
    /// propose at most `chunk` candidates per ask when the method's
    /// proposals form a resumable stream (grid's cursor does). One-shot
    /// designs whose batch shape is part of the method (latin, bobyqa's
    /// init set) ignore it — the driver evaluates any batch in
    /// chunk-sized slices regardless. Default: ignored.
    fn set_chunk(&mut self, _chunk: usize) {}

    /// Absorb measured results, in the order they were asked.
    fn tell(&mut self, evals: &[EvalRecord]);

    /// The method's incumbent: best (unit coordinates, value) it has been
    /// told so far.
    fn best(&self) -> Option<(Vec<f64>, f64)>;
}

/// Track the best told point — the default [`Optimizer::best`] backing
/// store shared by all method implementations.
#[derive(Clone, Debug, Default)]
pub struct BestSeen {
    best: Option<(Vec<f64>, f64)>,
}

impl BestSeen {
    pub fn update(&mut self, evals: &[EvalRecord]) {
        for r in evals {
            // A low-fidelity (raced-out) value is evidence for the
            // method's search state, not for the incumbent: only
            // full-fidelity measurements may become `best`.
            if !r.fidelity.is_full() {
                continue;
            }
            if self.best.as_ref().map(|(_, b)| r.value < *b).unwrap_or(true) {
                self.best = Some((r.unit_x.clone(), r.value));
            }
        }
    }

    pub fn get(&self) -> Option<(Vec<f64>, f64)> {
        self.best.clone()
    }
}

/// A batched black-box objective: score a whole ask-batch in one call.
pub trait BatchObjective {
    fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String>;

    /// Score a batch and report the evidence tier behind each value.
    /// The default forwards to [`BatchObjective::eval_batch`] and labels
    /// everything [`Fidelity::Full`] — only the multi-fidelity
    /// [`crate::optim::racing::RacingObjective`] overrides this, so a
    /// driver calling `eval_batch_tiered` on a plain objective takes the
    /// exact same path as before racing existed.
    fn eval_batch_tiered(
        &mut self,
        cfgs: &[HadoopConfig],
    ) -> Result<(Vec<f64>, Vec<Fidelity>), String> {
        let vals = self.eval_batch(cfgs)?;
        let fids = vec![Fidelity::Full; vals.len()];
        Ok((vals, fids))
    }
}

/// Adapter for plain per-config closures (`FnMut(&HadoopConfig) -> f64`):
/// the batch is scored serially, one config at a time.
pub struct FnObjective<F>(pub F);

impl<F: FnMut(&HadoopConfig) -> f64> BatchObjective for FnObjective<F> {
    fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        Ok(cfgs.iter().map(|c| (self.0)(c)).collect())
    }
}

/// Batched objective against the simulated cluster.
///
/// Each candidate is measured `repeats` times and the runtimes averaged
/// (repeats > 1 trades cluster time for noise reduction). Simulation
/// seeds are reserved from the cluster up front in submission order, so
/// the returned values are byte-identical whether the batch runs on one
/// thread or many — determinism is independent of scheduling.
///
/// The evaluation hot loop is allocation-free per run: workers borrow the
/// configs in place through [`ThreadPool::scoped_run_with`] (no per-item
/// `HadoopConfig`/`Arc` clones), simulate through the runtime-only
/// [`simulate_runtime_in`] path (no task-record materialization) inside a
/// per-worker [`SimArena`] that is reset — not reallocated — between
/// runs, and the pool itself is created once and reused across every
/// `eval_batch` of the run. Sequential DFO methods ask thousands of
/// singletons: those go through the serial path with the same warm
/// arena (slot 0), so a 10^4-eval run does zero steady-state allocation
/// inside the simulator.
pub struct ClusterObjective<'a> {
    cluster: &'a mut SimCluster,
    workload: WorkloadSpec,
    repeats: usize,
    threads: usize,
    /// Persistent worker pool, created lazily on the first batch that
    /// wants parallelism and reused for the rest of the run.
    pool: Option<ThreadPool>,
    /// Per-worker simulation arenas, grown lazily to the worker count
    /// and reused for the whole run; slot 0 doubles as the serial-path
    /// arena.
    arenas: Vec<SimArena>,
    /// When false, every run simulates in fresh buffers — the identity
    /// baseline the arena path is regression-tested against.
    reuse_arenas: bool,
}

impl<'a> ClusterObjective<'a> {
    pub fn new(
        cluster: &'a mut SimCluster,
        workload: &WorkloadSpec,
        repeats: usize,
    ) -> ClusterObjective<'a> {
        ClusterObjective {
            cluster,
            workload: workload.clone(),
            repeats: repeats.max(1),
            threads: default_threads(),
            pool: None,
            arenas: Vec::new(),
            reuse_arenas: true,
        }
    }

    /// Force one-at-a-time evaluation (baseline for the batch benches).
    pub fn serial(mut self) -> ClusterObjective<'a> {
        self.threads = 1;
        self.pool = None;
        self
    }

    /// Cap the worker count.
    pub fn with_threads(mut self, threads: usize) -> ClusterObjective<'a> {
        self.threads = threads.max(1);
        self.pool = None;
        self
    }

    /// Disable arena reuse: every simulation allocates fresh buffers.
    /// Byte-identical to the arena path (regression-tested across all
    /// eight methods in `rust/tests/ask_tell.rs`) — kept for those tests
    /// and the `sim_core` bench's arena-on/off comparison.
    pub fn without_arena(mut self) -> ClusterObjective<'a> {
        self.reuse_arenas = false;
        self.arenas = Vec::new();
        self
    }

    pub fn repeats(&self) -> usize {
        self.repeats
    }

    /// Reserve the full `n_cfgs * repeats` seed block for a slice and
    /// return its first seed. Config `c`, repeat `r` owns seed
    /// `first + c * repeats + r` — the same layout `eval_batch` uses, so
    /// callers that simulate only part of the block (the racing layer)
    /// advance the cluster's seed stream exactly as a full evaluation
    /// would.
    pub fn reserve_block(&mut self, n_cfgs: usize) -> u64 {
        self.cluster.reserve_seeds((n_cfgs * self.repeats) as u64)
    }

    /// Simulate an explicit `(config index, seed)` job list through the
    /// same pool/arena machinery as `eval_batch`, returning runtimes in
    /// job order. Results depend only on each job's `(config, seed)`
    /// pair, never on thread count or scheduling.
    pub fn run_jobs(&mut self, cfgs: &[HadoopConfig], jobs: &[(usize, u64)]) -> Vec<f64> {
        if jobs.is_empty() {
            return Vec::new();
        }
        let reuse = self.reuse_arenas;
        let spec = &self.cluster.spec;
        let wl = &self.workload;
        let run_one = |arena: &mut SimArena, i: usize| {
            let (c, seed) = jobs[i];
            let cfg = &cfgs[c];
            if reuse {
                simulate_runtime_in(arena, spec, wl, cfg, seed)
            } else {
                simulate_runtime(spec, wl, cfg, seed)
            }
        };
        let runs = jobs.len();
        let workers = self.threads.min(runs);
        let arenas = &mut self.arenas;
        if workers <= 1 {
            if arenas.is_empty() {
                arenas.push(SimArena::new());
            }
            let arena = &mut arenas[0];
            (0..runs).map(|i| run_one(&mut *arena, i)).collect()
        } else {
            let threads = self.threads;
            self.pool
                .get_or_insert_with(|| ThreadPool::new(threads))
                .scoped_run_with(runs, workers, arenas, SimArena::new, run_one)
        }
    }
}

impl BatchObjective for ClusterObjective<'_> {
    fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        if cfgs.is_empty() {
            return Ok(Vec::new());
        }
        let repeats = self.repeats;
        let first_seed = self.reserve_block(cfgs.len());
        let runs = cfgs.len() * repeats;
        let jobs: Vec<(usize, u64)> = (0..runs)
            .map(|i| (i / repeats, first_seed.wrapping_add(i as u64)))
            .collect();
        let runtimes = self.run_jobs(cfgs, &jobs);
        Ok(runtimes
            .chunks(repeats)
            .map(|c| c.iter().sum::<f64>() / repeats as f64)
            .collect())
    }
}

/// Batched objective through a surrogate [`CandidateScorer`] — the whole
/// ask-batch goes to the (possibly AOT/Pallas-compiled) model in one
/// call. Used for model-driven search and the batch benches.
pub struct ScorerObjective<S: CandidateScorer> {
    pub scorer: S,
}

impl<S: CandidateScorer> ScorerObjective<S> {
    pub fn new(scorer: S) -> ScorerObjective<S> {
        ScorerObjective { scorer }
    }
}

impl<S: CandidateScorer> BatchObjective for ScorerObjective<S> {
    fn eval_batch(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        let scores = self.scorer.score(cfgs)?;
        if scores.len() != cfgs.len() {
            return Err(format!(
                "scorer {} returned {} scores for {} configs",
                self.scorer.name(),
                scores.len(),
                cfgs.len()
            ));
        }
        Ok(scores)
    }
}

/// Per-evaluation hook (history streaming, dashboards, metrics).
pub trait Observer {
    fn on_eval(&mut self, rec: &EvalRecord);
}

impl<F: FnMut(&EvalRecord)> Observer for F {
    fn on_eval(&mut self, rec: &EvalRecord) {
        self(rec)
    }
}

/// Convergence check: stop at the first evaluation that completes
/// `patience` consecutive evaluations in which the best value failed to
/// improve by at least `min_rel` (relative).
#[derive(Clone, Copy, Debug)]
pub struct EarlyStop {
    pub patience: usize,
    pub min_rel: f64,
}

impl EarlyStop {
    pub fn new(patience: usize) -> EarlyStop {
        EarlyStop {
            patience,
            min_rel: 1e-3,
        }
    }
}

/// An evaluated-slice in flight: the decoded configs of
/// `batch[from..from + cfgs.len()]`, waiting for their measured values.
struct PendingSlice {
    from: usize,
    cfgs: Vec<HadoopConfig>,
}

/// The [`Driver`] loop, inverted into a non-blocking ask/tell stepper so
/// one caller can interleave many tuning sessions (the serve daemon
/// multiplexes hundreds of these over one thread pool).
///
/// Protocol: [`DriverSession::next_slice`] hands out the next chunk of
/// decoded configs to evaluate (or `None` when the run is over);
/// [`DriverSession::tell_values`] feeds the measured values back,
/// records them, fires observers and tells the optimizer. The stepper
/// body is the exact `Driver::run_with_history` loop — same budget
/// truncation, chunk slicing, early-stop-per-eval and replay semantics —
/// so a session stepped to completion produces a [`TuningOutcome`]
/// byte-identical to `Driver::run` on the same inputs, regardless of how
/// its steps interleave with other sessions (regression-tested in
/// `rust/tests/serve.rs` across all eight methods).
pub struct DriverSession {
    budget: usize,
    early_stop: Option<EarlyStop>,
    batch_chunk: usize,
    chunk_size: usize,
    rec: Recorder,
    stall: usize,
    best: f64,
    batch: Vec<Candidate>,
    start: usize,
    pending: Option<PendingSlice>,
    primed: bool,
    done: bool,
}

impl DriverSession {
    pub fn new(budget: usize, early_stop: Option<EarlyStop>, batch_chunk: usize) -> DriverSession {
        let early_stop = early_stop.filter(|es| es.patience > 0);
        // Evaluate in `batch.chunk`-sized slices; with early stopping the
        // slice shrinks to the patience, bounding the evals discarded
        // when a stop fires mid-slice (see the Driver loop docs).
        let chunk_size = early_stop
            .map(|es| es.patience.max(1))
            .unwrap_or(usize::MAX)
            .min(batch_chunk.max(1));
        DriverSession {
            budget,
            early_stop,
            batch_chunk: batch_chunk.max(1),
            chunk_size,
            rec: Recorder::new(),
            stall: 0,
            best: f64::INFINITY,
            batch: Vec::new(),
            start: 0,
            pending: None,
            primed: false,
            done: false,
        }
    }

    /// One-time streaming hint, fired before the first `ask` or replay
    /// `tell` — exactly once per session, however the session is driven.
    fn prime<O: Optimizer + ?Sized>(&mut self, opt: &mut O) {
        if !self.primed {
            self.primed = true;
            opt.set_chunk(self.batch_chunk);
        }
    }

    /// Replay checkpointed evaluations: recorded into the outcome,
    /// counted against the (total) budget, told to the fresh optimizer.
    /// Call before the first [`DriverSession::next_slice`].
    pub fn replay<O: Optimizer + ?Sized>(&mut self, opt: &mut O, prior: &[EvalRecord]) {
        self.prime(opt);
        if prior.is_empty() {
            return;
        }
        let mut replayed = Vec::with_capacity(prior.len());
        for p in prior.iter().take(self.budget) {
            self.rec
                .record_tiered(p.unit_x.clone(), p.config.clone(), p.value, p.fidelity);
            let r = self.rec.last().expect("just recorded").clone();
            if r.fidelity.is_full() {
                self.best = self.best.min(r.value);
            }
            replayed.push(r);
        }
        opt.tell(&replayed);
    }

    /// The next slice of configs to evaluate, decoded once per candidate.
    /// Returns `None` when the run is over (budget exhausted, optimizer
    /// converged on an empty ask, or early-stopped). Idempotent while a
    /// slice is outstanding: calling again before
    /// [`DriverSession::tell_values`] returns the same slice.
    pub fn next_slice<O: Optimizer + ?Sized>(
        &mut self,
        opt: &mut O,
        space: &ParamSpace,
    ) -> Option<&[HadoopConfig]> {
        if self.pending.is_some() {
            return self.pending.as_ref().map(|p| p.cfgs.as_slice());
        }
        if self.done {
            return None;
        }
        self.prime(opt);
        if self.start >= self.batch.len() {
            if self.rec.evals() >= self.budget {
                self.done = true;
                return None;
            }
            let left = self.budget - self.rec.evals();
            let mut batch = opt.ask(space, left);
            if batch.is_empty() {
                self.done = true; // converged / proposals exhausted
                return None;
            }
            // Budget accounting: an over-sized ask-batch is truncated,
            // never overspent. Everything recorded is also told.
            batch.truncate(left);
            self.batch = batch;
            self.start = 0;
        }
        let from = self.start;
        let end = from.saturating_add(self.chunk_size).min(self.batch.len());
        // decode once per candidate: grid attaches the config it already
        // decoded for dedup, everything else decodes here
        let cfgs: Vec<HadoopConfig> = self.batch[from..end]
            .iter_mut()
            .map(|c| c.config.take().unwrap_or_else(|| space.decode(&c.unit_x)))
            .collect();
        self.pending = Some(PendingSlice { from, cfgs });
        self.pending.as_ref().map(|p| p.cfgs.as_slice())
    }

    /// Feed back the measured values for the outstanding slice, in slice
    /// order: record each evaluation, fire observers, update early-stop
    /// state, and tell the optimizer. Every value is full-fidelity; the
    /// racing layer uses [`DriverSession::tell_values_tiered`].
    pub fn tell_values<O: Optimizer + ?Sized>(
        &mut self,
        opt: &mut O,
        vals: &[f64],
        observers: &mut [Box<dyn Observer + '_>],
    ) -> Result<(), String> {
        self.tell_values_tiered(opt, vals, &vec![Fidelity::Full; vals.len()], observers)
    }

    /// [`DriverSession::tell_values`] with an explicit evidence tier per
    /// value. Early-stop stall accounting and the session's running best
    /// consider full-fidelity evaluations only — a raced-out candidate's
    /// cheap score can neither reset nor advance the stall counter, so a
    /// racing-off run (all `Full`) behaves exactly as before.
    pub fn tell_values_tiered<O: Optimizer + ?Sized>(
        &mut self,
        opt: &mut O,
        vals: &[f64],
        fids: &[Fidelity],
        observers: &mut [Box<dyn Observer + '_>],
    ) -> Result<(), String> {
        let PendingSlice { from, cfgs } = self
            .pending
            .take()
            .ok_or_else(|| "tell_values without an outstanding candidate slice".to_string())?;
        if vals.len() != cfgs.len() {
            return Err(format!(
                "objective returned {} values for a batch of {}",
                vals.len(),
                cfgs.len()
            ));
        }
        if fids.len() != vals.len() {
            return Err(format!(
                "objective returned {} fidelities for {} values",
                fids.len(),
                vals.len()
            ));
        }
        let end = from + cfgs.len();
        let mut told = Vec::with_capacity(vals.len());
        let mut stopped = false;
        for (((cand, cfg), v), f) in self.batch[from..end]
            .iter()
            .zip(cfgs)
            .zip(vals.iter().copied())
            .zip(fids.iter().copied())
        {
            self.rec.record_tiered(cand.unit_x.clone(), cfg, v, f);
            let r = self.rec.last().expect("just recorded").clone();
            for ob in observers.iter_mut() {
                ob.on_eval(&r);
            }
            if f.is_full() {
                if let Some(es) = self.early_stop {
                    if r.value < self.best * (1.0 - es.min_rel) {
                        self.stall = 0;
                    } else {
                        self.stall += 1;
                    }
                }
                self.best = self.best.min(r.value);
            }
            told.push(r);
            if let Some(es) = self.early_stop {
                if self.stall >= es.patience {
                    // stop at exactly this eval — later slice-mates stay
                    // unrecorded, so the stopping point does not depend
                    // on how the batch was sliced
                    stopped = true;
                    break;
                }
            }
        }
        // tell covers every recorded candidate, even when the run is
        // about to stop
        opt.tell(&told);
        if stopped {
            self.done = true;
        } else {
            self.start = end;
            if self.start >= self.batch.len() {
                self.batch.clear();
                self.start = 0;
            }
        }
        Ok(())
    }

    pub fn evals(&self) -> usize {
        self.rec.evals()
    }

    /// Everything recorded so far, in evaluation order (for incremental
    /// checkpointing mid-run).
    pub fn records(&self) -> &[EvalRecord] {
        self.rec.records()
    }

    pub fn best_value(&self) -> Option<f64> {
        self.rec.best_value()
    }

    /// True once [`DriverSession::next_slice`] has returned `None` (and
    /// will keep returning `None`).
    pub fn is_done(&self) -> bool {
        self.done && self.pending.is_none()
    }

    /// Snapshot the outcome without consuming the session.
    pub fn outcome(&self, optimizer: &str) -> Result<TuningOutcome, String> {
        if self.rec.evals() == 0 {
            return Err(format!(
                "optimizer {} produced no evaluations (budget {})",
                optimizer, self.budget
            ));
        }
        Ok(self.rec.clone().finish(optimizer))
    }

    pub fn into_outcome(self, optimizer: &str) -> Result<TuningOutcome, String> {
        if self.rec.evals() == 0 {
            return Err(format!(
                "optimizer {} produced no evaluations (budget {})",
                optimizer, self.budget
            ));
        }
        Ok(self.rec.finish(optimizer))
    }
}

/// The shared tuning loop: owns the budget, evaluates ask-batches through
/// a [`BatchObjective`], records every evaluation, fires observers, and
/// tells results back to the optimizer.
pub struct Driver<'a> {
    pub budget: usize,
    pub early_stop: Option<EarlyStop>,
    /// Streaming chunk (`batch.chunk`): streaming optimizers bound each
    /// ask to it, and every ask-batch is evaluated/told in slices of at
    /// most this many candidates. See the module docs.
    pub batch_chunk: usize,
    observers: Vec<Box<dyn Observer + 'a>>,
}

impl<'a> Driver<'a> {
    pub fn new(budget: usize) -> Driver<'a> {
        Driver {
            budget,
            early_stop: None,
            batch_chunk: DEFAULT_BATCH_CHUNK,
            observers: Vec::new(),
        }
    }

    /// Override the streaming chunk size (`batch.chunk`).
    pub fn chunk(mut self, chunk: usize) -> Driver<'a> {
        self.batch_chunk = chunk.max(1);
        self
    }

    pub fn early_stop(mut self, es: EarlyStop) -> Driver<'a> {
        self.early_stop = if es.patience > 0 { Some(es) } else { None };
        self
    }

    pub fn observe(mut self, ob: impl Observer + 'a) -> Driver<'a> {
        self.observers.push(Box::new(ob));
        self
    }

    /// Run a fresh tuning loop to budget exhaustion, optimizer
    /// convergence (empty ask), or early stop.
    pub fn run<O, B>(
        &mut self,
        opt: &mut O,
        space: &ParamSpace,
        obj: &mut B,
    ) -> Result<TuningOutcome, String>
    where
        O: Optimizer + ?Sized,
        B: BatchObjective + ?Sized,
    {
        self.run_with_history(opt, space, obj, &[])
    }

    /// Resume from a checkpoint: `prior` evaluations are replayed —
    /// recorded into the outcome, counted against the (total) budget and
    /// told to the fresh optimizer — then the loop continues normally.
    /// No objective calls are spent on replayed evaluations.
    ///
    /// The loop body lives in [`DriverSession`] (the serve daemon steps
    /// the same machine non-blockingly); this method just drives it to
    /// completion against one [`BatchObjective`]:
    ///
    /// Ask-batches are EVALUATED in `batch.chunk`-sized slices, which
    /// bounds the decoded-config buffer. The early-stop decision is
    /// made per evaluation (the run ends at exactly the first eval
    /// whose stall count reaches the patience), so the stopping point
    /// — and therefore the whole outcome — is independent of the
    /// slice size. The optimizer still sees the true remaining budget
    /// in `ask` (bobyqa's one-shot init design and latin's
    /// stratification need it); candidates past a triggered stop are
    /// never recorded or told (slice-mates already evaluated when the
    /// stop fires are discarded — the session shrinks the slice to the
    /// patience, bounding that waste without moving the stop).
    pub fn run_with_history<O, B>(
        &mut self,
        opt: &mut O,
        space: &ParamSpace,
        obj: &mut B,
        prior: &[EvalRecord],
    ) -> Result<TuningOutcome, String>
    where
        O: Optimizer + ?Sized,
        B: BatchObjective + ?Sized,
    {
        let mut session = DriverSession::new(self.budget, self.early_stop, self.batch_chunk);
        session.replay(opt, prior);
        loop {
            let (vals, fids) = match session.next_slice(opt, space) {
                None => break,
                Some(cfgs) => obj.eval_batch_tiered(cfgs)?,
            };
            session.tell_values_tiered(opt, &vals, &fids, &mut self.observers)?;
        }
        session.into_outcome(opt.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::TuningSpec;
    use crate::hadoop::ClusterSpec;
    use crate::optim::Method;
    use crate::workloads::wordcount;

    fn space() -> ParamSpace {
        ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default())
    }

    /// A pathological optimizer that always over-asks its budget.
    struct OverAsker {
        asked: usize,
        told: usize,
        best: BestSeen,
    }

    impl Optimizer for OverAsker {
        fn name(&self) -> &str {
            "over-asker"
        }
        fn ask(&mut self, space: &ParamSpace, budget_left: usize) -> Vec<Candidate> {
            let d = space.dims();
            let n = budget_left * 2 + 3; // deliberately over budget
            self.asked += n;
            (0..n)
                .map(|i| Candidate::new(vec![(i % 10) as f64 / 10.0; d]))
                .collect()
        }
        fn tell(&mut self, evals: &[EvalRecord]) {
            self.told += evals.len();
            self.best.update(evals);
        }
        fn best(&self) -> Option<(Vec<f64>, f64)> {
            self.best.get()
        }
    }

    #[test]
    fn oversized_ask_batch_is_truncated_never_overspent() {
        let sp = space();
        let mut opt = OverAsker {
            asked: 0,
            told: 0,
            best: BestSeen::default(),
        };
        let mut obj = FnObjective(|c: &HadoopConfig| c.values.iter().sum::<f64>());
        let out = Driver::new(17).run(&mut opt, &sp, &mut obj).unwrap();
        assert_eq!(out.evals(), 17, "budget overspent or undershot");
        // tell was called for every evaluated candidate, and only those
        assert_eq!(opt.told, 17);
        assert!(opt.asked > 17);
        assert!(opt.best().is_some());
    }

    #[test]
    fn zero_budget_is_an_error_not_a_panic() {
        let sp = space();
        let mut opt = Method::Random { seed: 1 }.build();
        let mut obj = FnObjective(|_: &HadoopConfig| 1.0);
        assert!(Driver::new(0).run(opt.as_mut(), &sp, &mut obj).is_err());
    }

    #[test]
    fn early_stop_halts_on_flat_objective() {
        let sp = space();
        let mut opt = Method::Random { seed: 3 }.build();
        let mut obj = FnObjective(|_: &HadoopConfig| 42.0);
        let out = Driver::new(500)
            .early_stop(EarlyStop::new(10))
            .run(opt.as_mut(), &sp, &mut obj)
            .unwrap();
        assert!(
            out.evals() < 500,
            "early stop never fired: {} evals",
            out.evals()
        );
    }

    #[test]
    fn cluster_objective_batched_matches_serial_bitwise() {
        let wl = wordcount(2048.0);
        let sp = space();
        let xs: Vec<Vec<f64>> = (0..12)
            .map(|i| vec![i as f64 / 12.0; sp.dims()])
            .collect();
        let cfgs: Vec<HadoopConfig> = xs.iter().map(|x| sp.decode(x)).collect();

        let mut c1 = SimCluster::new(ClusterSpec::default());
        let serial = ClusterObjective::new(&mut c1, &wl, 2)
            .serial()
            .eval_batch(&cfgs)
            .unwrap();
        let mut c2 = SimCluster::new(ClusterSpec::default());
        let parallel = ClusterObjective::new(&mut c2, &wl, 2)
            .with_threads(8)
            .eval_batch(&cfgs)
            .unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.to_bits(), b.to_bits(), "batched eval not deterministic");
        }
    }

    #[test]
    fn cluster_objective_arena_matches_fresh_allocation_bitwise() {
        let wl = wordcount(2048.0);
        let sp = space();
        let cfgs: Vec<HadoopConfig> = (0..9)
            .map(|i| sp.decode(&vec![i as f64 / 9.0; sp.dims()]))
            .collect();

        // batched: per-worker arenas vs fresh buffers every run
        let mut c1 = SimCluster::new(ClusterSpec::default());
        let arena = ClusterObjective::new(&mut c1, &wl, 2).eval_batch(&cfgs).unwrap();
        let mut c2 = SimCluster::new(ClusterSpec::default());
        let fresh = ClusterObjective::new(&mut c2, &wl, 2)
            .without_arena()
            .eval_batch(&cfgs)
            .unwrap();
        for (a, b) in arena.iter().zip(&fresh) {
            assert_eq!(a.to_bits(), b.to_bits(), "arena reuse changed a runtime");
        }

        // DFO shape: a singleton-ask stream through ONE objective, the
        // slot-0 arena getting dirtier every call
        let mut c3 = SimCluster::new(ClusterSpec::default());
        let mut warm = ClusterObjective::new(&mut c3, &wl, 2).serial();
        let mut c4 = SimCluster::new(ClusterSpec::default());
        let mut cold = ClusterObjective::new(&mut c4, &wl, 2).serial().without_arena();
        for cfg in &cfgs {
            let a = warm.eval_batch(std::slice::from_ref(cfg)).unwrap()[0];
            let b = cold.eval_batch(std::slice::from_ref(cfg)).unwrap()[0];
            assert_eq!(a.to_bits(), b.to_bits(), "singleton arena path diverged");
        }
    }

    #[test]
    fn cluster_objective_advances_cluster_seed_like_serial_submission() {
        let wl = wordcount(1024.0);
        let sp = space();
        let cfgs: Vec<HadoopConfig> = (0..5).map(|_| sp.decode(&vec![0.5; sp.dims()])).collect();

        // batch-eval then single job
        let mut c1 = SimCluster::new(ClusterSpec::default());
        ClusterObjective::new(&mut c1, &wl, 1).eval_batch(&cfgs).unwrap();
        let a = ClusterObjective::new(&mut c1, &wl, 1)
            .eval_batch(&cfgs[..1])
            .unwrap()[0];

        // five serial jobs then the same single job
        let mut c2 = SimCluster::new(ClusterSpec::default());
        for cfg in &cfgs {
            ClusterObjective::new(&mut c2, &wl, 1)
                .eval_batch(std::slice::from_ref(cfg))
                .unwrap();
        }
        let b = ClusterObjective::new(&mut c2, &wl, 1)
            .eval_batch(&cfgs[..1])
            .unwrap()[0];
        assert_eq!(a.to_bits(), b.to_bits(), "seed reservation out of sync");
    }

    #[test]
    fn observers_see_every_eval_in_order() {
        let sp = space();
        let mut opt = Method::Latin { seed: 5 }.build();
        let mut seen: Vec<usize> = Vec::new();
        let mut obj = FnObjective(|c: &HadoopConfig| c.values.iter().sum::<f64>());
        let out = Driver::new(20)
            .observe(|r: &EvalRecord| seen.push(r.iter))
            .run(opt.as_mut(), &sp, &mut obj)
            .unwrap();
        assert_eq!(seen, (1..=out.evals()).collect::<Vec<_>>());
    }

    #[test]
    fn replayed_history_counts_against_budget_and_is_not_reevaluated() {
        let sp = space();
        let calls = std::cell::Cell::new(0usize);
        let prior: Vec<EvalRecord> = (0..6)
            .map(|i| {
                let x = vec![i as f64 / 6.0; sp.dims()];
                EvalRecord {
                    iter: i + 1,
                    config: sp.decode(&x),
                    unit_x: x,
                    value: 100.0 - i as f64,
                    best_so_far: 0.0, // recomputed on replay
                    fidelity: Fidelity::Full,
                }
            })
            .collect();
        let mut opt = Method::Random { seed: 9 }.build();
        let mut obj = FnObjective(|_: &HadoopConfig| {
            calls.set(calls.get() + 1);
            1.0
        });
        let out = Driver::new(10)
            .run_with_history(opt.as_mut(), &sp, &mut obj, &prior)
            .unwrap();
        assert_eq!(out.evals(), 10);
        assert_eq!(calls.get(), 4, "prior evaluations were re-run");
        // best_so_far monotone across the replay/live boundary
        let mut prev = f64::INFINITY;
        for r in &out.records {
            assert!(r.best_so_far <= prev + 1e-12);
            prev = r.best_so_far;
        }
    }
}

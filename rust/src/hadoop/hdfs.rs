//! HDFS block placement with rack awareness.
//!
//! The simulator needs locality-accurate map scheduling: a map task reads
//! its split from a node holding a replica at disk speed, from the same
//! rack at a discount, or cross-rack at the remote rate. Placement follows
//! the classic HDFS policy: first replica on a random node, second on a
//! different rack, third on a different node of the second's rack.

use crate::util::rng::Rng;

/// One input split / block and the nodes holding its replicas.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    pub id: u64,
    pub replicas: Vec<usize>, // node ids
}

/// Immutable cluster topology: node -> rack.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    pub racks: Vec<usize>, // racks[node] = rack id
    pub n_racks: usize,
}

impl Topology {
    /// Spread `nodes` round-robin over `n_racks` racks.
    pub fn new(nodes: usize, n_racks: usize) -> Topology {
        let mut t = Topology {
            racks: Vec::with_capacity(nodes),
            n_racks: 0,
        };
        t.reset(nodes, n_racks);
        t
    }

    /// Re-derive the node→rack map in place (same layout as
    /// [`Topology::new`]), keeping the existing allocation — used by the
    /// simulation arena to rebuild per-run state without reallocating.
    pub fn reset(&mut self, nodes: usize, n_racks: usize) {
        let n_racks = n_racks.max(1).min(nodes.max(1));
        self.racks.clear();
        self.racks.extend((0..nodes).map(|n| n % n_racks));
        self.n_racks = n_racks;
    }

    pub fn nodes(&self) -> usize {
        self.racks.len()
    }

    pub fn same_rack(&self, a: usize, b: usize) -> bool {
        self.racks[a] == self.racks[b]
    }
}

/// Read-locality class of a (task node, block) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Locality {
    NodeLocal,
    RackLocal,
    OffRack,
}

impl Locality {
    /// Effective read-rate multiplier vs. local disk.
    pub fn rate_factor(self) -> f64 {
        match self {
            Locality::NodeLocal => 1.0,
            Locality::RackLocal => 0.8,
            Locality::OffRack => 0.6,
        }
    }
}

/// Place `n_blocks` blocks with `replication` replicas each.
pub fn place_blocks(
    topo: &Topology,
    n_blocks: u64,
    replication: usize,
    rng: &mut Rng,
) -> Vec<Block> {
    let mut out = Vec::new();
    place_blocks_into(topo, n_blocks, replication, rng, &mut out);
    out
}

/// [`place_blocks`] into a caller-owned buffer: the outer Vec AND each
/// block's replica Vec are reused in place (same policy, same RNG draw
/// sequence, bit-identical placements). The simulation arena calls this
/// every run without allocating once warm.
pub fn place_blocks_into(
    topo: &Topology,
    n_blocks: u64,
    replication: usize,
    rng: &mut Rng,
    out: &mut Vec<Block>,
) {
    let nodes = topo.nodes();
    let replication = replication.max(1).min(nodes.max(1));
    out.truncate(n_blocks as usize);
    for id in 0..n_blocks {
        // reuse the slot's replica storage when the slot exists
        if (id as usize) < out.len() {
            let b = &mut out[id as usize];
            b.id = id;
            b.replicas.clear();
        } else {
            out.push(Block {
                id,
                replicas: Vec::with_capacity(replication),
            });
        }
        let replicas = &mut out[id as usize].replicas;
        // 1st replica: uniform random node
        let first = rng.below(nodes);
        replicas.push(first);
        if replication >= 2 {
            // 2nd: a node on a different rack if one exists.
            // Rejection sampling (bounded), then deterministic scan —
            // avoids building a candidate Vec per block (§Perf).
            let mut second = None;
            if topo.n_racks > 1 {
                for _ in 0..8 {
                    let n = rng.below(nodes);
                    if !topo.same_rack(n, first) {
                        second = Some(n);
                        break;
                    }
                }
                if second.is_none() {
                    second = (0..nodes).find(|&n| !topo.same_rack(n, first));
                }
            }
            let second = second.unwrap_or((first + 1) % nodes);
            if !replicas.contains(&second) {
                replicas.push(second);
            }
        }
        while replicas.len() < replication {
            // 3rd+: same rack as the last replica, different node;
            // fall back to any unused node
            let anchor = *replicas.last().unwrap();
            let mut pick = None;
            for _ in 0..8 {
                let n = rng.below(nodes);
                if topo.same_rack(n, anchor) && !replicas.contains(&n) {
                    pick = Some(n);
                    break;
                }
            }
            if pick.is_none() {
                pick = (0..nodes)
                    .find(|&n| topo.same_rack(n, anchor) && !replicas.contains(&n))
                    .or_else(|| (0..nodes).find(|n| !replicas.contains(n)));
            }
            match pick {
                Some(n) => replicas.push(n),
                None => break,
            }
        }
    }
}

/// Locality of reading `block` from `node`.
pub fn locality(topo: &Topology, block: &Block, node: usize) -> Locality {
    locality_with_down(topo, block, node, &[])
}

/// [`locality`] with node liveness: replicas on currently-down nodes are
/// unreachable and drop out of the preference order, so a task whose
/// only same-rack replica just died reads cross-rack. The reading node
/// itself is always up (YARN never places containers on down nodes); its
/// local copy — if it holds one — survives the outage (DataNode disks
/// persist across restarts). `down` may be shorter than the cluster
/// (missing entries mean "up"), so the no-fault path can pass `&[]`.
pub fn locality_with_down(topo: &Topology, block: &Block, node: usize, down: &[bool]) -> Locality {
    let is_down = |n: usize| down.get(n).copied().unwrap_or(false);
    if block.replicas.contains(&node) {
        Locality::NodeLocal
    } else if block
        .replicas
        .iter()
        .any(|&r| !is_down(r) && topo.same_rack(r, node))
    {
        Locality::RackLocal
    } else {
        Locality::OffRack
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicas_are_distinct() {
        let topo = Topology::new(16, 2);
        let mut rng = Rng::new(1);
        for b in place_blocks(&topo, 200, 3, &mut rng) {
            let mut r = b.replicas.clone();
            r.sort_unstable();
            r.dedup();
            assert_eq!(r.len(), b.replicas.len(), "dup replicas in {b:?}");
            assert_eq!(b.replicas.len(), 3);
        }
    }

    #[test]
    fn second_replica_crosses_racks() {
        let topo = Topology::new(16, 2);
        let mut rng = Rng::new(2);
        for b in place_blocks(&topo, 100, 3, &mut rng) {
            assert!(
                !topo.same_rack(b.replicas[0], b.replicas[1]),
                "replicas 0/1 same rack: {b:?}"
            );
        }
    }

    #[test]
    fn replication_capped_by_cluster_size() {
        let topo = Topology::new(2, 1);
        let mut rng = Rng::new(3);
        let blocks = place_blocks(&topo, 10, 3, &mut rng);
        for b in blocks {
            assert!(b.replicas.len() <= 2);
        }
    }

    #[test]
    fn locality_classes() {
        let topo = Topology::new(4, 2); // racks: 0,1,0,1
        let block = Block { id: 0, replicas: vec![0] };
        assert_eq!(locality(&topo, &block, 0), Locality::NodeLocal);
        assert_eq!(locality(&topo, &block, 2), Locality::RackLocal); // rack 0
        assert_eq!(locality(&topo, &block, 1), Locality::OffRack); // rack 1
    }

    #[test]
    fn placement_roughly_balanced() {
        let topo = Topology::new(8, 2);
        let mut rng = Rng::new(4);
        let blocks = place_blocks(&topo, 800, 3, &mut rng);
        let mut counts = vec![0usize; 8];
        for b in &blocks {
            for &r in &b.replicas {
                counts[r] += 1;
            }
        }
        let mean = counts.iter().sum::<usize>() as f64 / 8.0;
        for (n, c) in counts.iter().enumerate() {
            assert!(
                (*c as f64) > mean * 0.5 && (*c as f64) < mean * 1.5,
                "node {n} has {c} replicas vs mean {mean}"
            );
        }
    }

    #[test]
    fn place_blocks_into_reuses_a_dirty_buffer_identically() {
        let topo = Topology::new(16, 2);
        let fresh = place_blocks(&topo, 50, 3, &mut Rng::new(9));
        // dirty buffer from a BIGGER previous run, different topology
        let mut buf = place_blocks(&Topology::new(7, 3), 200, 2, &mut Rng::new(1));
        place_blocks_into(&topo, 50, 3, &mut Rng::new(9), &mut buf);
        assert_eq!(buf, fresh, "reused placement diverged");
        // and a smaller→bigger reuse too
        let mut buf2 = place_blocks(&topo, 3, 3, &mut Rng::new(2));
        place_blocks_into(&topo, 50, 3, &mut Rng::new(9), &mut buf2);
        assert_eq!(buf2, fresh);
    }

    #[test]
    fn topology_reset_matches_new() {
        let mut t = Topology::new(31, 4);
        t.reset(16, 2);
        assert_eq!(t, Topology::new(16, 2));
        t.reset(64, 5);
        assert_eq!(t, Topology::new(64, 5));
    }

    #[test]
    fn down_replicas_leave_the_preference_order() {
        let topo = Topology::new(4, 2); // racks: 0,1,0,1
        let block = Block { id: 0, replicas: vec![0, 1] };
        // healthy: node 2 shares rack 0 with replica 0
        assert_eq!(locality_with_down(&topo, &block, 2, &[]), Locality::RackLocal);
        // replica 0 down: node 2's only same-rack replica is gone
        assert_eq!(
            locality_with_down(&topo, &block, 2, &[true, false, false, false]),
            Locality::OffRack
        );
        // the reader's own copy survives an earlier outage
        assert_eq!(
            locality_with_down(&topo, &block, 0, &[false, true, false, false]),
            Locality::NodeLocal
        );
        // empty down-slice is exactly the legacy function
        assert_eq!(locality(&topo, &block, 2), locality_with_down(&topo, &block, 2, &[]));
    }

    #[test]
    fn rate_factors_ordered() {
        assert!(Locality::NodeLocal.rate_factor() > Locality::RackLocal.rate_factor());
        assert!(Locality::RackLocal.rate_factor() > Locality::OffRack.rate_factor());
    }
}

//! Workload traces: a day-in-the-life job stream for the cluster.
//!
//! The tuning system's real payoff is *tune once, run the trace faster*:
//! a configuration chosen by the Optimizer Runner is applied to a whole
//! arrival stream of heterogeneous jobs. The generator produces a
//! Poisson-arrival trace over a mixed workload; the replayer runs it
//! through the job simulator behind a FIFO queue (small shared clusters
//! commonly run MapReduce jobs back to back) and reports makespan, waits
//! and utilization.

use crate::config::params::HadoopConfig;
use crate::hadoop::{simulate_runtime_in, ClusterSpec, SimArena};
use crate::util::rng::Rng;
use crate::workloads::{self, WorkloadSpec};

/// One trace entry.
#[derive(Clone, Debug)]
pub struct TraceJob {
    pub arrival_s: f64,
    pub workload: WorkloadSpec,
}

/// Mixed-workload trace generator.
#[derive(Clone, Debug)]
pub struct TraceGen {
    /// Mean inter-arrival seconds (Poisson process).
    pub mean_interarrival_s: f64,
    /// (workload name, weight) mixture.
    pub mix: Vec<(String, f64)>,
    /// Log-normal input-size distribution (log-space mean of MB, sigma).
    pub size_mu_mb: f64,
    pub size_sigma: f64,
}

impl Default for TraceGen {
    fn default() -> Self {
        Self {
            mean_interarrival_s: 120.0,
            mix: vec![
                ("wordcount".into(), 0.35),
                ("grep".into(), 0.25),
                ("terasort".into(), 0.15),
                ("join".into(), 0.15),
                ("pagerank".into(), 0.10),
            ],
            size_mu_mb: 2048.0,
            size_sigma: 0.8,
        }
    }
}

impl TraceGen {
    /// Generate `n` jobs (deterministic per seed).
    pub fn generate(&self, n: usize, seed: u64) -> Vec<TraceJob> {
        let mut rng = Rng::new(seed);
        let total_w: f64 = self.mix.iter().map(|(_, w)| w).sum();
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                // exponential inter-arrival
                t += -self.mean_interarrival_s * (1.0 - rng.f64()).ln();
                // weighted workload pick
                let mut pick = rng.f64() * total_w;
                let mut name = self.mix[0].0.as_str();
                for (w_name, w) in &self.mix {
                    if pick < *w {
                        name = w_name;
                        break;
                    }
                    pick -= w;
                }
                let size_mb = (self.size_mu_mb
                    * rng.lognormal(-self.size_sigma * self.size_sigma / 2.0, self.size_sigma))
                .clamp(64.0, 262_144.0);
                TraceJob {
                    arrival_s: t,
                    workload: workloads::by_name(name, size_mb).expect("mix has known names"),
                }
            })
            .collect()
    }
}

/// Replay report.
#[derive(Clone, Debug, Default)]
pub struct ReplayReport {
    pub jobs: usize,
    /// Completion time of the last job.
    pub makespan_s: f64,
    /// Total job running time (cluster busy seconds).
    pub busy_s: f64,
    pub mean_wait_s: f64,
    pub p95_wait_s: f64,
    pub mean_runtime_s: f64,
    /// busy / makespan.
    pub utilization: f64,
}

/// Replay a trace through the cluster with one shared configuration,
/// FIFO and exclusive (one job owns the cluster at a time).
pub fn replay(
    cl: &ClusterSpec,
    trace: &[TraceJob],
    cfg: &HadoopConfig,
    seed: u64,
) -> ReplayReport {
    let mut clock: f64 = 0.0;
    let mut waits = Vec::with_capacity(trace.len());
    let mut runtimes = Vec::with_capacity(trace.len());
    let mut busy = 0.0;
    // the replay only reads runtimes: lean engine, one reused arena
    let mut arena = SimArena::new();
    for (i, j) in trace.iter().enumerate() {
        let start = clock.max(j.arrival_s);
        let rt = simulate_runtime_in(&mut arena, cl, &j.workload, cfg, seed.wrapping_add(i as u64));
        waits.push(start - j.arrival_s);
        runtimes.push(rt);
        busy += rt;
        clock = start + rt;
    }
    let n = trace.len().max(1);
    let mut sorted_waits = waits.clone();
    sorted_waits.sort_by(|a, b| a.total_cmp(b));
    ReplayReport {
        jobs: trace.len(),
        makespan_s: clock,
        busy_s: busy,
        mean_wait_s: waits.iter().sum::<f64>() / n as f64,
        p95_wait_s: sorted_waits
            .get(((n as f64 * 0.95) as usize).min(n - 1))
            .copied()
            .unwrap_or(0.0),
        mean_runtime_s: runtimes.iter().sum::<f64>() / n as f64,
        utilization: if clock > 0.0 { busy / clock } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{P_IO_SORT_MB, P_REDUCES};

    #[test]
    fn generator_deterministic_and_sorted() {
        let g = TraceGen::default();
        let a = g.generate(50, 9);
        let b = g.generate(50, 9);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.workload.name, y.workload.name);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s, "arrivals out of order");
        }
    }

    #[test]
    fn mixture_respects_weights_roughly() {
        let g = TraceGen::default();
        let trace = g.generate(2000, 3);
        let wc = trace.iter().filter(|j| j.workload.name == "wordcount").count();
        let frac = wc as f64 / 2000.0;
        assert!((frac - 0.35).abs() < 0.05, "wordcount fraction {frac}");
    }

    #[test]
    fn replay_accounting_consistent() {
        let g = TraceGen {
            mean_interarrival_s: 10.0, // heavy load -> queueing
            ..TraceGen::default()
        };
        let trace = g.generate(30, 5);
        let r = replay(&ClusterSpec::default(), &trace, &HadoopConfig::default(), 1);
        assert_eq!(r.jobs, 30);
        assert!(r.makespan_s >= r.busy_s, "makespan < busy time");
        assert!(r.utilization > 0.0 && r.utilization <= 1.0 + 1e-9);
        assert!(r.p95_wait_s >= r.mean_wait_s * 0.5);
        assert!(r.makespan_s >= trace.last().unwrap().arrival_s);
    }

    #[test]
    fn tuned_config_improves_trace_makespan() {
        // the headline story at trace scale: the Fig.2 "good corner"
        // config beats defaults over a whole arrival stream
        let g = TraceGen {
            mean_interarrival_s: 5.0,
            size_sigma: 0.3,
            ..TraceGen::default()
        };
        let trace = g.generate(25, 11);
        let cl = ClusterSpec::default();
        let default = replay(&cl, &trace, &HadoopConfig::default(), 2);
        let mut tuned_cfg = HadoopConfig::default();
        tuned_cfg.set(P_REDUCES, 24.0);
        tuned_cfg.set(P_IO_SORT_MB, 512.0);
        let tuned = replay(&cl, &trace, &tuned_cfg, 2);
        assert!(
            tuned.makespan_s < default.makespan_s,
            "tuned {:.0}s vs default {:.0}s",
            tuned.makespan_s,
            default.makespan_s
        );
    }
}

//! The cluster boundary: an SSH-shaped job-submission API.
//!
//! A real Catla talks to the master host over SSH: upload jar, `hadoop
//! jar ... -Dk=v`, poll, `yarn logs`, `hdfs dfs -get`. `Cluster` is that
//! boundary as a trait; `SimCluster` is the simulated implementation
//! (DESIGN.md substitution table row 1). A real SSH implementation could
//! be dropped in without touching any Catla code.

use std::collections::{BTreeMap, VecDeque};

use crate::config::params::HadoopConfig;
use crate::hadoop::joblogs;
use crate::hadoop::mapreduce::{simulate_job_in, JobResult, SimArena};
use crate::hadoop::ClusterSpec;
use crate::workloads::WorkloadSpec;

/// What Catla submits: "run this jar (workload) with this configuration".
#[derive(Clone, Debug)]
pub struct JobSubmission {
    pub name: String,
    pub workload: WorkloadSpec,
    pub config: HadoopConfig,
}

impl JobSubmission {
    /// The full command line a real Catla would run over SSH for this
    /// submission — the decoded config's typed `-D` arguments (bools as
    /// `true`/`false`, categoricals by label) between jar and job name.
    pub fn command_line(&self) -> String {
        format!(
            "hadoop jar {}.jar {} {}",
            self.workload.name,
            self.config.to_d_args().join(" "),
            self.name
        )
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    Running { progress: f64 },
    Succeeded { runtime_s: f64 },
    Failed { reason: String },
}

/// Downloaded artifacts for one finished job.
#[derive(Clone, Debug)]
pub struct JobArtifacts {
    /// `history.json` — the job-history document.
    pub history_json: String,
    /// (filename, content) container logs.
    pub container_logs: Vec<(String, String)>,
    /// (filename, content) job output files (part-r-*).
    pub outputs: Vec<(String, String)>,
}

/// The SSH-shaped cluster API.
pub trait Cluster {
    /// Submit a job; returns the cluster-assigned job id.
    fn submit_job(&mut self, job: JobSubmission) -> Result<String, String>;
    /// Poll job status (non-blocking).
    fn poll(&mut self, job_id: &str) -> Result<JobStatus, String>;
    /// Download history + logs + outputs after completion.
    fn fetch_artifacts(&mut self, job_id: &str) -> Result<JobArtifacts, String>;
    /// Human-readable description for logs/README.
    fn describe(&self) -> String;
}

/// How many fetched job ids the cluster remembers: `poll` on a job whose
/// artifacts were already downloaded errors with "already fetched"
/// instead of the (misleading) "unknown job", without the retired list
/// itself becoming a leak.
const RETIRED_JOBS_KEPT: usize = 64;

/// Simulated Hadoop 2.x cluster.
///
/// Jobs complete in *virtual* time immediately on submission; `poll`
/// reveals completion after `polls_until_done` calls so the Task Runner's
/// poll loop is genuinely exercised. The job table holds only in-flight
/// results: `fetch_artifacts` EVICTS the entry it downloads (a tuning
/// run submits thousands of jobs — an append-only table was an unbounded
/// leak), keeping a small LRU of recently fetched ids for clean errors.
pub struct SimCluster {
    pub spec: ClusterSpec,
    seed_counter: u64,
    pub polls_until_done: u32,
    /// In-flight job table. Ordered map (detlint `hash-collections`):
    /// keyed access only, and job ids are assigned in submission order,
    /// so any future iteration is submission-ordered too.
    jobs: BTreeMap<String, (JobResult, u32)>,
    /// Recently fetched (evicted) job ids, oldest first, bounded by
    /// [`RETIRED_JOBS_KEPT`].
    retired: VecDeque<String>,
    /// Monotone count of jobs ever submitted (survives eviction).
    completed: usize,
    next_id: u64,
    /// Reusable engine workspace: submissions simulate in warm buffers.
    arena: SimArena,
}

impl SimCluster {
    pub fn new(spec: ClusterSpec) -> Self {
        let seed = spec.seed;
        Self {
            spec,
            seed_counter: seed,
            polls_until_done: 2,
            jobs: BTreeMap::new(),
            retired: VecDeque::new(),
            completed: 0,
            next_id: 1,
            arena: SimArena::new(),
        }
    }

    /// Direct, synchronous evaluation used by optimizer hot loops and
    /// benches (skips the poll dance, still fully deterministic). Runs
    /// in the cluster's own reused [`SimArena`].
    pub fn run_job(&mut self, job: &JobSubmission) -> JobResult {
        self.seed_counter = self.seed_counter.wrapping_add(1);
        simulate_job_in(
            &mut self.arena,
            &self.spec,
            &job.workload,
            &job.config,
            self.seed_counter,
        )
    }

    /// Reserve `n` consecutive simulation seeds and return the first.
    /// Batched evaluation (`optim::core::ClusterObjective`) uses this to
    /// run a whole ask-batch in parallel while each job still gets the
    /// exact seed serial submission would have given it.
    pub fn reserve_seeds(&mut self, n: u64) -> u64 {
        let first = self.seed_counter.wrapping_add(1);
        self.seed_counter = self.seed_counter.wrapping_add(n);
        first
    }

    /// Jobs ever submitted through the `Cluster` API (monotone — fetched
    /// jobs are evicted from the table but still counted).
    pub fn jobs_completed(&self) -> usize {
        self.completed
    }

    /// Jobs whose results are still held (submitted, artifacts not yet
    /// fetched) — the quantity the eviction policy bounds.
    pub fn jobs_in_flight(&self) -> usize {
        self.jobs.len()
    }
}

impl Cluster for SimCluster {
    fn submit_job(&mut self, job: JobSubmission) -> Result<String, String> {
        job.config
            .validate()
            .map_err(|e| format!("cluster rejected configuration: {e}"))?;
        job.workload.validate()?;
        let result = self.run_job(&job);
        let id = format!("job_{:013}_{:04}", 1_577_000_000 + self.next_id, self.next_id);
        self.next_id += 1;
        self.completed += 1;
        self.jobs.insert(id.clone(), (result, 0));
        Ok(id)
    }

    fn poll(&mut self, job_id: &str) -> Result<JobStatus, String> {
        let until = self.polls_until_done;
        let (result, polls) = match self.jobs.get_mut(job_id) {
            Some(entry) => entry,
            None if self.retired.iter().any(|id| id == job_id) => {
                return Err(format!(
                    "job {job_id} already fetched (its result was released)"
                ))
            }
            None => return Err(format!("unknown job {job_id}")),
        };
        *polls += 1;
        if *polls >= until {
            if let Some(reason) = &result.failed {
                return Ok(JobStatus::Failed {
                    reason: reason.clone(),
                });
            }
            Ok(JobStatus::Succeeded {
                runtime_s: result.runtime_s,
            })
        } else {
            Ok(JobStatus::Running {
                progress: (*polls as f64 / until as f64).min(0.99),
            })
        }
    }

    fn fetch_artifacts(&mut self, job_id: &str) -> Result<JobArtifacts, String> {
        // downloading retires the job: the result leaves the table (the
        // table would otherwise grow for the whole tuning run) and the id
        // moves onto the bounded retired list
        let (result, _) = match self.jobs.remove(job_id) {
            Some(entry) => entry,
            None if self.retired.iter().any(|id| id == job_id) => {
                return Err(format!(
                    "job {job_id} already fetched (artifacts are downloaded once)"
                ))
            }
            None => return Err(format!("unknown job {job_id}")),
        };
        self.retired.push_back(job_id.to_string());
        while self.retired.len() > RETIRED_JOBS_KEPT {
            self.retired.pop_front();
        }
        let result = &result;
        let history_json = joblogs::to_history_json(job_id, result).to_string();
        let container_logs = result
            .tasks
            .iter()
            .map(|t| {
                let kind = match t.kind {
                    crate::hadoop::mapreduce::TaskKind::Map => "m",
                    crate::hadoop::mapreduce::TaskKind::Reduce => "r",
                };
                (
                    format!("container_{job_id}_{kind}_{:06}.log", t.id),
                    joblogs::container_log(job_id, t),
                )
            })
            .collect();
        // synthesize a small part-r-00000 per reducer
        let outputs = (0..result.counters.total_reduces.min(4))
            .map(|r| {
                (
                    format!("part-r-{r:05}"),
                    format!(
                        "# simulated output of {} reducer {r}\nrecords\t{}\n",
                        result.workload,
                        (result.counters.hdfs_write_mb * 1024.0) as u64
                    ),
                )
            })
            .collect();
        Ok(JobArtifacts {
            history_json,
            container_logs,
            outputs,
        })
    }

    fn describe(&self) -> String {
        format!(
            "SimCluster: {} nodes x ({} MB, {} vcores), {} racks, disk {} MB/s, net {} MB/s, noise σ={}",
            self.spec.nodes,
            self.spec.mem_per_node_mb,
            self.spec.vcores_per_node,
            self.spec.racks,
            self.spec.disk_mbps,
            self.spec.net_mbps,
            self.spec.noise.sigma
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::wordcount;

    fn submission() -> JobSubmission {
        JobSubmission {
            name: "wc".into(),
            workload: wordcount(2048.0),
            config: HadoopConfig::default(),
        }
    }

    #[test]
    fn submit_poll_fetch_lifecycle() {
        let mut c = SimCluster::new(ClusterSpec::default());
        let id = c.submit_job(submission()).unwrap();
        assert!(matches!(c.poll(&id).unwrap(), JobStatus::Running { .. }));
        let st = c.poll(&id).unwrap();
        match st {
            JobStatus::Succeeded { runtime_s } => assert!(runtime_s > 0.0),
            other => panic!("expected success, got {other:?}"),
        }
        let art = c.fetch_artifacts(&id).unwrap();
        assert!(art.history_json.contains("SUCCEEDED"));
        assert!(!art.container_logs.is_empty());
        assert!(!art.outputs.is_empty());
    }

    #[test]
    fn command_line_renders_typed_d_args() {
        let s = submission();
        let cmd = s.command_line();
        assert!(cmd.starts_with("hadoop jar wordcount.jar "));
        assert!(cmd.contains("-Dmapreduce.map.output.compress=false"));
        assert!(cmd.contains("-Dmapreduce.task.io.sort.mb=100"));
        assert!(cmd.ends_with(" wc"));
    }

    #[test]
    fn rejects_invalid_config() {
        let mut c = SimCluster::new(ClusterSpec::default());
        let mut s = submission();
        s.config.values[0] = 1e9; // bypass setters
        assert!(c.submit_job(s).is_err());
    }

    #[test]
    fn unknown_job_errors() {
        let mut c = SimCluster::new(ClusterSpec::default());
        assert!(c.poll("job_nope").is_err());
        assert!(c.fetch_artifacts("job_nope").is_err());
    }

    #[test]
    fn fetch_evicts_the_job_and_later_calls_error_cleanly() {
        let mut c = SimCluster::new(ClusterSpec::default());
        let id = c.submit_job(submission()).unwrap();
        c.poll(&id).unwrap();
        c.fetch_artifacts(&id).unwrap();
        assert_eq!(c.jobs_in_flight(), 0, "fetched job not evicted");
        assert_eq!(c.jobs_completed(), 1, "completed count must survive eviction");
        // the id is retired, not forgotten: both calls mention the fetch
        let e = c.poll(&id).unwrap_err();
        assert!(e.contains("already fetched"), "poll error: {e}");
        let e = c.fetch_artifacts(&id).unwrap_err();
        assert!(e.contains("already fetched"), "fetch error: {e}");
        // a genuinely unknown id still says so
        assert!(c.poll("job_nope").unwrap_err().contains("unknown job"));
    }

    #[test]
    fn job_table_stays_bounded_across_a_tuning_length_run() {
        let mut c = SimCluster::new(ClusterSpec::default());
        let n = super::RETIRED_JOBS_KEPT * 3;
        for i in 0..n {
            let id = c.submit_job(submission()).unwrap();
            c.fetch_artifacts(&id).unwrap();
            assert_eq!(c.jobs_in_flight(), 0);
            assert!(
                c.retired.len() <= super::RETIRED_JOBS_KEPT,
                "retired list grew past its bound at job {i}"
            );
        }
        assert_eq!(c.jobs_completed(), n);
    }

    #[test]
    fn failed_jobs_surface_through_poll() {
        // a cluster where every attempt almost surely fails, with a tight
        // retry budget: poll must report Hadoop's FAILED terminal state
        let mut spec = ClusterSpec::default();
        spec.noise.failure_prob = 0.9;
        spec.noise.max_attempts = 2;
        spec.speculative = false;
        let mut c = SimCluster::new(spec);
        let id = c.submit_job(submission()).unwrap();
        c.poll(&id).unwrap(); // still "running"
        match c.poll(&id).unwrap() {
            JobStatus::Failed { reason } => {
                assert!(reason.contains("attempts"), "reason: {reason}")
            }
            other => panic!("expected FAILED, got {other:?}"),
        }
        // artifacts of a failed job are still downloadable (logs matter
        // most when the job died)
        let art = c.fetch_artifacts(&id).unwrap();
        assert!(art.history_json.contains("FAILED"));
    }

    #[test]
    fn repeat_submissions_vary_by_seed() {
        // the same configuration resubmitted gives a *different* noisy
        // runtime — the exact phenomenon DFO must cope with
        let mut c = SimCluster::new(ClusterSpec::default());
        let a = c.run_job(&submission()).runtime_s;
        let b = c.run_job(&submission()).runtime_s;
        assert_ne!(a, b);
    }
}

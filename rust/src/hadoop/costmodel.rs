//! Analytic MapReduce phase-cost model — the rust mirror of
//! `python/compile/kernels/ref.py::phase_math`.
//!
//! Two consumers:
//!   * the discrete-event simulator samples *per-task* durations from the
//!     per-task components here (plus noise), and
//!   * `predict_phases` gives the noiseless whole-job expectation, which
//!     must track the AOT JAX artifact to float tolerance
//!     (`rust/tests/runtime_integration.rs` asserts it).
//!
//! Keep formulas in lockstep with ref.py. Units: MB and seconds.
//!
//! # Extended (post-AOT-prefix) parameters
//!
//! ref.py and the AOT artifacts consume exactly the 10-slot builtin
//! prefix ([`crate::config::space::N_AOT_PARAMS`]). Spec-declared extras
//! used to be invisible to the model; the mapped subset below now moves
//! the per-task cost structs — and, because the DES samples its per-task
//! durations from those same structs, the simulator moves in lockstep
//! automatically. A config whose registry declares none of these is
//! bit-identical to the pre-extension model. Extras the model still
//! cannot interpret are *blind*
//! ([`crate::catla::optimizer_runner::cost_model_blind_params`] lists
//! them precisely), and blind params disable racing's tier 0.

use crate::config::params::*;
use crate::config::space::ParamDef;
use crate::hadoop::ClusterSpec;
use crate::workloads::WorkloadSpec;

pub const N_PHASES: usize = 8;
pub const PH_READ: usize = 0;
pub const PH_MAP_CPU: usize = 1;
pub const PH_MAP_IO: usize = 2;
pub const PH_SHUFFLE: usize = 3;
pub const PH_RED_IO: usize = 4;
pub const PH_RED_CPU: usize = 5;
pub const PH_WRITE: usize = 6;
pub const PH_OVERHEAD: usize = 7;

pub const PHASE_NAMES: [&str; N_PHASES] = [
    "read", "map_cpu", "map_io", "shuffle", "red_io", "red_cpu", "write", "overhead",
];

const EPS: f64 = 1e-6;

/// Default in-memory merge threshold: a reducer merges purely in memory
/// when its shuffled partition fits in this fraction of its heap
/// (Hadoop's `mapreduce.reduce.shuffle.input.buffer.percent` default).
const DEFAULT_SHUFFLE_BUFFER_PCT: f64 = 0.70;

/// Map-output codec character: how a named codec reshapes the
/// workload's baseline compress ratio and the compression CPU cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CodecEffect {
    /// `false` for the `none` codec: compression is off regardless of
    /// the boolean compress knob.
    pub enabled: bool,
    /// Multiplier on `WorkloadSpec::compress_ratio` (output-size ratio:
    /// below 1.0 compresses harder than the workload baseline).
    pub ratio_mult: f64,
    /// Multiplier on the compress/decompress CPU terms.
    pub cpu_mult: f64,
}

/// Codec table for `mapreduce.map.output.compress.codec`. Labels
/// outside this table make the parameter blind (no guessing).
pub fn codec_effect(label: &str) -> Option<CodecEffect> {
    let (enabled, ratio_mult, cpu_mult) = match label {
        "none" => (false, 1.0, 0.0),
        "snappy" => (true, 1.0, 0.6),
        "lz4" => (true, 1.05, 0.45),
        "zstd" => (true, 0.85, 1.1),
        "gzip" => (true, 0.8, 2.2),
        "deflate" => (true, 0.8, 2.0),
        "bzip2" => (true, 0.7, 5.0),
        _ => return None,
    };
    Some(CodecEffect {
        enabled,
        ratio_mult,
        cpu_mult,
    })
}

/// Effects of the mapped extended parameters a config's registry
/// declares. Every field defaults to "absent": a builtin-only config
/// takes identical code paths (and bit-identical results) to the
/// pre-extension model.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExtEffects {
    /// `mapreduce.map.output.compress.codec` (categorical).
    pub codec: Option<CodecEffect>,
    /// `mapreduce.reduce.shuffle.input.buffer.percent` — replaces
    /// [`DEFAULT_SHUFFLE_BUFFER_PCT`] as the in-memory merge threshold.
    pub shuffle_buffer_pct: Option<f64>,
}

/// Look up the mapped extended parameters in `cfg`'s registry. An
/// unknown codec label degrades to "absent" (identity) — the blind-param
/// gate in the optimizer runner keeps such specs out of tier 0, so this
/// is only a defensive fallback.
pub fn ext_effects(cfg: &HadoopConfig) -> ExtEffects {
    let reg = cfg.registry();
    let codec = reg
        .by_name("mapreduce.map.output.compress.codec")
        .and_then(|(i, def)| def.category_name(cfg.get(i)))
        .and_then(codec_effect);
    let shuffle_buffer_pct = reg
        .by_name("mapreduce.reduce.shuffle.input.buffer.percent")
        .map(|(i, _)| cfg.get(i).clamp(0.05, 1.0));
    ExtEffects {
        codec,
        shuffle_buffer_pct,
    }
}

/// Can the cost model interpret this spec-declared parameter? Builtin
/// (AOT-prefix) params are always covered; extras are covered only when
/// listed here — `cost_model_blind_params` inverts this to produce the
/// precise blind list that gates surrogate prescreening and racing's
/// tier 0.
pub fn extended_param_mapped(def: &ParamDef) -> bool {
    match def.name.as_str() {
        "mapreduce.reduce.shuffle.input.buffer.percent" => true,
        "mapreduce.map.output.compress.codec" => def
            .categories()
            .is_some_and(|cats| cats.iter().all(|c| codec_effect(c).is_some())),
        _ => false,
    }
}

/// Task-count / slot geometry for a (config, workload, cluster) triple.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobGeometry {
    pub maps: u64,
    pub reduces: u64,
    pub map_slots: u64,
    pub red_slots: u64,
    pub map_waves: u64,
    pub red_waves: u64,
    pub mb_per_map: f64,
}

pub fn geometry(cfg: &HadoopConfig, wl: &WorkloadSpec, cl: &ClusterSpec) -> JobGeometry {
    let input_mb = wl.input_mb.max(1.0);
    let split_mb = cfg.get(P_SPLIT_MB).max(1.0);
    let maps = (input_mb / split_mb).ceil().max(1.0);
    let node_mem = (cl.mem_per_node_mb as f64).max(256.0);
    let vcores = (cl.vcores_per_node as f64).max(1.0);
    let nodes = (cl.nodes as f64).max(1.0);
    let map_mem = cfg.get(P_MAP_MEM_MB).max(128.0);
    let red_mem = cfg.get(P_RED_MEM_MB).max(128.0);
    let map_slots = nodes * ((node_mem / map_mem).floor().min(vcores)).max(1.0);
    let red_slots = nodes * ((node_mem / red_mem).floor().min(vcores)).max(1.0);
    let reduces = cfg.get(P_REDUCES).max(1.0);
    JobGeometry {
        maps: maps as u64,
        reduces: reduces as u64,
        map_slots: map_slots as u64,
        red_slots: red_slots as u64,
        map_waves: (maps / map_slots).ceil() as u64,
        red_waves: (reduces / red_slots).ceil() as u64,
        mb_per_map: input_mb / maps,
    }
}

/// Per-map-task cost components (noiseless, node-local read).
#[derive(Clone, Copy, Debug, Default)]
pub struct MapTaskCost {
    pub t_read_local: f64,
    pub t_read_remote: f64,
    pub t_cpu: f64, // map fn + sort + compress
    pub t_spill_io: f64,
    pub t_merge_io: f64,
    pub spills: u64,
    /// Map output (logical MB) and on-disk/wire MB after compression.
    pub map_out_mb: f64,
    pub disk_out_mb: f64,
}

impl MapTaskCost {
    /// Total duration with the given read-locality.
    pub fn total(&self, local: bool) -> f64 {
        let read = if local { self.t_read_local } else { self.t_read_remote };
        read + self.t_cpu + self.t_spill_io + self.t_merge_io
    }
}

pub fn map_task_cost(cfg: &HadoopConfig, wl: &WorkloadSpec, cl: &ClusterSpec) -> MapTaskCost {
    let g = geometry(cfg, wl, cl);
    let b = g.mb_per_map;
    let disk = (cl.disk_mbps).max(EPS);
    let mut compress = cfg.get(P_COMPRESS).clamp(0.0, 1.0);
    let cpu_map = wl.cpu_per_mb_map;

    // mapped extended params: the codec reshapes the compress ratio and
    // CPU; the `none` codec turns compression off outright. Identity
    // (bit-exact original formulas) when the registry declares no codec.
    let ext = ext_effects(cfg);
    let mut ratio = wl.compress_ratio;
    let mut codec_cpu = 1.0;
    match ext.codec {
        Some(c) if c.enabled => {
            ratio = (wl.compress_ratio * c.ratio_mult).min(1.0);
            codec_cpu = c.cpu_mult;
        }
        Some(_) => compress = 0.0,
        None => {}
    }

    // ref.py blends locality into one rate; the DES resolves locality per
    // task, so expose both and let predict_phases() blend identically.
    let t_read_local = b / disk;
    let t_read_remote = b / (disk * 0.6);

    let t_map_fn = b * cpu_map;
    let map_out = b * wl.map_selectivity;
    let disk_out = map_out * (1.0 - compress * (1.0 - ratio));

    let buf = cfg.get(P_IO_SORT_MB).max(1.0) * cfg.get(P_SPILL_PERCENT).clamp(0.05, 1.0);
    let spills = (map_out / buf.max(EPS)).ceil().max(1.0);
    let buf_records = (map_out.min(buf) * 1024.0 / wl.record_kb.max(1e-4)).max(2.0);
    let t_sort = map_out * cpu_map * 0.25 * buf_records.log2() / 20.0;
    let t_compress = map_out * cpu_map * 0.30 * compress * codec_cpu;

    let t_spill_io = disk_out / disk;
    let sort_factor = cfg.get(P_SORT_FACTOR).max(2.0);
    let merge_passes = if spills > 1.0 {
        (spills.ln() / sort_factor.ln()).ceil()
    } else {
        0.0
    };
    let t_merge_io = merge_passes * 2.0 * disk_out / disk;

    MapTaskCost {
        t_read_local,
        t_read_remote,
        t_cpu: t_map_fn + t_sort + t_compress,
        t_spill_io,
        t_merge_io,
        spills: spills as u64,
        map_out_mb: map_out,
        disk_out_mb: disk_out,
    }
}

/// Shuffle cost for one average reducer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShuffleCost {
    /// Copy seconds for the mean partition at the achievable rate.
    pub t_copy: f64,
    /// Mean shuffled MB per reducer (on-wire, possibly compressed).
    pub per_red_mb: f64,
    /// Mean logical (uncompressed) MB per reducer.
    pub per_red_logical_mb: f64,
}

pub fn shuffle_cost(cfg: &HadoopConfig, wl: &WorkloadSpec, cl: &ClusterSpec) -> ShuffleCost {
    let g = geometry(cfg, wl, cl);
    let m = map_task_cost(cfg, wl, cl);
    let net = cl.net_mbps.max(EPS);
    let reduces = g.reduces as f64;
    let total_shuffle = g.maps as f64 * m.disk_out_mb;
    let per_red = total_shuffle / reduces;
    let pcopies = cfg.get(P_PARALLEL_COPIES).max(1.0);
    let copy_eff = net * (0.4 + 0.6 * pcopies.min(16.0) / 16.0);
    let active_red = reduces.min(g.red_slots as f64);
    let fair_share = net * cl.nodes as f64 / active_red.max(1.0);
    let rate = copy_eff.min(fair_share);
    ShuffleCost {
        t_copy: per_red / rate.max(EPS),
        per_red_mb: per_red,
        per_red_logical_mb: g.maps as f64 * m.map_out_mb / reduces,
    }
}

/// Per-reduce-task cost components (mean partition).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReduceTaskCost {
    pub t_merge_io: f64,
    pub t_cpu: f64, // reduce fn + decompress
    pub t_write: f64,
}

impl ReduceTaskCost {
    pub fn total(&self) -> f64 {
        self.t_merge_io + self.t_cpu + self.t_write
    }
}

pub fn reduce_task_cost(cfg: &HadoopConfig, wl: &WorkloadSpec, cl: &ClusterSpec) -> ReduceTaskCost {
    let g = geometry(cfg, wl, cl);
    let sh = shuffle_cost(cfg, wl, cl);
    let disk = cl.disk_mbps.max(EPS);
    let mut compress = cfg.get(P_COMPRESS).clamp(0.0, 1.0);
    let sort_factor = cfg.get(P_SORT_FACTOR).max(2.0);

    // mapped extended params (identity when absent): codec CPU scales
    // decompression, the shuffle input buffer percent replaces the
    // default in-memory merge threshold. Wire-size effects already
    // arrived through map_task_cost's disk_out.
    let ext = ext_effects(cfg);
    let mut codec_cpu = 1.0;
    match ext.codec {
        Some(c) if c.enabled => codec_cpu = c.cpu_mult,
        Some(_) => compress = 0.0,
        None => {}
    }
    let buffer_pct = ext.shuffle_buffer_pct.unwrap_or(DEFAULT_SHUFFLE_BUFFER_PCT);

    let t_decompress = sh.per_red_logical_mb * wl.cpu_per_mb_map * 0.10 * compress * codec_cpu;
    let merge_passes = (((g.maps as f64).max(2.0).ln() / sort_factor.ln()).ceil() - 1.0).max(0.0);
    let in_memory = sh.per_red_mb <= buffer_pct * cfg.get(P_RED_MEM_MB);
    let t_merge_io = if in_memory {
        0.0
    } else {
        merge_passes * 2.0 * sh.per_red_mb / disk
    };
    let t_red_fn = sh.per_red_logical_mb * wl.cpu_per_mb_red;
    let out_mb = sh.per_red_logical_mb * wl.output_selectivity;
    let t_write = out_mb * cl.replication.max(1) as f64 / disk;
    ReduceTaskCost {
        t_merge_io,
        t_cpu: t_red_fn + t_decompress,
        t_write,
    }
}

/// Noiseless whole-job phase expectation — must match ref.py/the AOT
/// artifact bit-for-float. Returns wave-multiplied channel seconds.
pub fn predict_phases(cfg: &HadoopConfig, wl: &WorkloadSpec, cl: &ClusterSpec) -> [f64; N_PHASES] {
    let g = geometry(cfg, wl, cl);
    let m = map_task_cost(cfg, wl, cl);
    let sh = shuffle_cost(cfg, wl, cl);
    let r = reduce_task_cost(cfg, wl, cl);
    let map_waves = g.map_waves as f64;
    let red_waves = g.red_waves as f64;
    let slowstart = cfg.get(P_SLOWSTART).clamp(0.0, 1.0);

    // blended read rate, as in ref.py
    let loc = cl.locality.clamp(0.0, 1.0);
    let read_rate_blend = cl.disk_mbps.max(EPS) * (loc + (1.0 - loc) * 0.6);
    let t_read = g.mb_per_map / read_rate_blend;

    let map_phase = map_waves * (t_read + m.t_cpu + m.t_spill_io + m.t_merge_io);
    let overlap = (1.0 - slowstart) * map_phase;
    let shuffle_tail = (sh.t_copy - overlap).max(sh.t_copy * 0.05);
    let squat = (1.0 - slowstart)
        * 0.05
        * map_phase
        * (g.reduces as f64 / (g.red_slots as f64).max(1.0)).min(1.0);

    [
        map_waves * t_read,
        map_waves * m.t_cpu,
        map_waves * (m.t_spill_io + m.t_merge_io),
        shuffle_tail + squat,
        red_waves * r.t_merge_io,
        red_waves * r.t_cpu,
        red_waves * r.t_write,
        cl.am_overhead_s + (map_waves + red_waves) * cl.task_overhead_s,
    ]
}

/// Calibration matrix — mirror of spec.default_weights().
pub fn default_weights() -> [[f64; N_PHASES]; N_PHASES] {
    let mut w = [[0.0; N_PHASES]; N_PHASES];
    for (i, row) in w.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    w[PH_MAP_CPU][PH_MAP_IO] = -0.08;
    w[PH_RED_CPU][PH_RED_IO] = -0.05;
    w
}

/// Noiseless runtime prediction: sum(phases @ W).
pub fn predict_runtime(cfg: &HadoopConfig, wl: &WorkloadSpec, cl: &ClusterSpec) -> f64 {
    let ph = predict_phases(cfg, wl, cl);
    let w = default_weights();
    let mut total = 0.0;
    for (i, &p) in ph.iter().enumerate() {
        for j in 0..N_PHASES {
            total += p * w[i][j];
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::wordcount;

    fn cl() -> ClusterSpec {
        ClusterSpec::default()
    }

    #[test]
    fn geometry_basics() {
        let cfg = HadoopConfig::default();
        let wl = wordcount(10240.0);
        let g = geometry(&cfg, &wl, &cl());
        assert_eq!(g.maps, 80); // 10240 / 128
        assert_eq!(g.reduces, 1);
        assert!(g.map_slots >= 16);
        assert_eq!(g.map_waves, 1);
    }

    #[test]
    fn bigger_sort_buffer_fewer_spills() {
        let wl = wordcount(10240.0);
        let mut lo = HadoopConfig::default();
        lo.set(P_IO_SORT_MB, 16.0);
        let mut hi = lo.clone();
        hi.set(P_IO_SORT_MB, 2048.0);
        let c_lo = map_task_cost(&lo, &wl, &cl());
        let c_hi = map_task_cost(&hi, &wl, &cl());
        assert!(c_hi.spills <= c_lo.spills);
        assert!(c_hi.t_merge_io <= c_lo.t_merge_io);
    }

    #[test]
    fn compression_shrinks_wire_bytes_adds_cpu() {
        let wl = wordcount(10240.0);
        let mut plain = HadoopConfig::default();
        plain.set(P_COMPRESS, 0.0);
        let mut comp = plain.clone();
        comp.set(P_COMPRESS, 1.0);
        let a = map_task_cost(&plain, &wl, &cl());
        let b = map_task_cost(&comp, &wl, &cl());
        assert!(b.disk_out_mb < a.disk_out_mb);
        assert!(b.t_cpu > a.t_cpu);
    }

    #[test]
    fn more_reducers_less_per_red() {
        let wl = wordcount(10240.0);
        let mut few = HadoopConfig::default();
        few.set(P_REDUCES, 2.0);
        let mut many = few.clone();
        many.set(P_REDUCES, 32.0);
        let a = shuffle_cost(&few, &wl, &cl());
        let b = shuffle_cost(&many, &wl, &cl());
        assert!(b.per_red_mb < a.per_red_mb);
    }

    #[test]
    fn predict_runtime_positive_and_finite() {
        let wl = wordcount(10240.0);
        let cfg = HadoopConfig::default();
        let rt = predict_runtime(&cfg, &wl, &cl());
        assert!(rt.is_finite() && rt > 0.0, "rt = {rt}");
    }

    #[test]
    fn wave_boundary_increases_runtime() {
        // 4 nodes x 8 vcores -> 32 reduce slots; 33 reducers = 2 waves
        let mut cl = ClusterSpec::default();
        cl.nodes = 4;
        let wl = wordcount(10240.0);
        let mut c32 = HadoopConfig::default();
        c32.set(P_REDUCES, 32.0);
        c32.set(P_IO_SORT_MB, 256.0);
        let mut c33 = c32.clone();
        c33.set(P_REDUCES, 33.0);
        assert!(predict_runtime(&c33, &wl, &cl) > predict_runtime(&c32, &wl, &cl));
    }

    fn registry_with(extras: Vec<crate::config::space::ParamDef>) -> HadoopConfig {
        let reg = crate::config::space::ParamRegistry::with_extras(extras).unwrap();
        HadoopConfig::for_registry(reg)
    }

    #[test]
    fn builtin_configs_are_bit_identical_to_pre_extension_model() {
        // the extension is identity for registries without mapped extras:
        // ext_effects must resolve to "absent" on the builtin table
        let cfg = HadoopConfig::default();
        let e = ext_effects(&cfg);
        assert!(e.codec.is_none());
        assert!(e.shuffle_buffer_pct.is_none());
    }

    #[test]
    fn codec_choice_moves_wire_bytes_and_cpu() {
        use crate::config::space::ParamDef;
        let wl = wordcount(10240.0);
        let codecs = ["none", "snappy", "gzip"];
        let mk = |label: &str| {
            let mut cfg = registry_with(vec![ParamDef::cat(
                "mapreduce.map.output.compress.codec",
                &codecs,
                "snappy",
            )]);
            cfg.set(P_COMPRESS, 1.0);
            let idx = codecs.iter().position(|c| *c == label).unwrap() as f64;
            cfg.set_by_name("mapreduce.map.output.compress.codec", idx)
                .unwrap();
            cfg
        };
        let none = map_task_cost(&mk("none"), &wl, &cl());
        let snappy = map_task_cost(&mk("snappy"), &wl, &cl());
        let gzip = map_task_cost(&mk("gzip"), &wl, &cl());
        // `none` disables compression even with the compress knob on
        assert_eq!(none.disk_out_mb, none.map_out_mb);
        assert!(snappy.disk_out_mb < none.disk_out_mb);
        assert!(gzip.disk_out_mb < snappy.disk_out_mb, "gzip compresses harder");
        assert!(gzip.t_cpu > snappy.t_cpu, "gzip costs more CPU");
        // and the effect reaches predict_runtime (tier-0 can rank codecs)
        let p_snappy = predict_runtime(&mk("snappy"), &wl, &cl());
        let p_gzip = predict_runtime(&mk("gzip"), &wl, &cl());
        assert!(p_snappy.is_finite() && p_gzip.is_finite());
        assert!(p_snappy != p_gzip, "codec choice invisible to the model");
    }

    #[test]
    fn shuffle_buffer_percent_gates_reduce_merge_io() {
        use crate::config::space::ParamDef;
        let wl = wordcount(10240.0);
        let mk = |pct: f64| {
            let mut cfg = registry_with(vec![ParamDef::float(
                "mapreduce.reduce.shuffle.input.buffer.percent",
                0.05,
                1.0,
                0.70,
            )]);
            cfg.set(P_REDUCES, 2.0);
            cfg.set_by_name("mapreduce.reduce.shuffle.input.buffer.percent", pct)
                .unwrap();
            cfg
        };
        // with 2 reducers over 10 GiB wordcount the partition exceeds a
        // small buffer fraction but fits memory-resident thresholds >= 1.0
        let tight = reduce_task_cost(&mk(0.05), &wl, &cl());
        let roomy = reduce_task_cost(&mk(1.0), &wl, &cl());
        assert!(tight.t_merge_io >= roomy.t_merge_io);
    }

    #[test]
    fn extended_param_mapped_is_precise() {
        use crate::config::space::ParamDef;
        assert!(extended_param_mapped(&ParamDef::float(
            "mapreduce.reduce.shuffle.input.buffer.percent",
            0.05,
            1.0,
            0.70
        )));
        assert!(extended_param_mapped(&ParamDef::cat(
            "mapreduce.map.output.compress.codec",
            &["none", "snappy", "lz4"],
            "none"
        )));
        // unknown codec label -> blind, no guessing
        assert!(!extended_param_mapped(&ParamDef::cat(
            "mapreduce.map.output.compress.codec",
            &["snappy", "quantum"],
            "snappy"
        )));
        assert!(!extended_param_mapped(&ParamDef::int(
            "x.shuffle.buffer.kb",
            1.0,
            1024.0,
            64.0
        )));
    }

    #[test]
    fn phase_channels_nonnegative() {
        let wl = wordcount(4096.0);
        for reduces in [1.0, 8.0, 64.0] {
            let mut cfg = HadoopConfig::default();
            cfg.set(P_REDUCES, reduces);
            for (i, p) in predict_phases(&cfg, &wl, &cl()).iter().enumerate() {
                assert!(*p >= 0.0, "phase {} negative: {p}", PHASE_NAMES[i]);
            }
        }
    }
}

//! Deterministic node failure/recovery injection for the DES.
//!
//! Real clusters lose whole nodes mid-job, and the failure modes that
//! dominate production tail latency — killed in-flight attempts, lost
//! map output forcing re-execution, capacity draining out of YARN — are
//! invisible to the task-level noise model in [`noise`](super::noise).
//! This module generates the *when/which-node* half of that story; the
//! event loop in [`mapreduce`](super::mapreduce) owns the consequences
//! (`NodeDown`/`NodeUp` events).
//!
//! Determinism contract (docs/DETERMINISM.md): the failure chain draws
//! exclusively from its own forked child stream (`root.fork(5)` in
//! `simulate_core`), so enabling faults never perturbs HDFS placement,
//! node speed factors, partition weights, or task noise — and when
//! `mttf_s == 0` (the default) the chain draws **nothing**, making fault
//! injection exactly zero-cost-zero-drift when disabled.

use crate::config::params::HadoopConfig;
use crate::util::rng::Rng;

/// Per-cluster fault-injection knobs (`HadoopEnv.txt` `sim.fault.*`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultModel {
    /// Per-node mean time to failure, seconds. `0` (default) disables
    /// fault injection entirely; the cluster-level failure rate is
    /// `nodes / mttf_s`.
    pub mttf_s: f64,
    /// Downtime before a failed node rejoins with full capacity, seconds.
    pub recovery_s: f64,
    /// Cap on simultaneously-down nodes; a failure drawn while the cap
    /// is reached (or for an already-down node) is skipped, not deferred.
    pub max_concurrent: u32,
}

impl Default for FaultModel {
    fn default() -> Self {
        Self {
            mttf_s: 0.0,
            recovery_s: 90.0,
            max_concurrent: 1,
        }
    }
}

impl FaultModel {
    pub fn enabled(&self) -> bool {
        self.mttf_s > 0.0
    }

    /// The effective model for one simulation: cluster defaults
    /// overridden by spec-declared config params, so failure parameters
    /// are *tunable dimensions* like any other knob. A `params.spec`
    /// that declares `fault.node.mttf.s` / `fault.node.recovery.s` /
    /// `fault.node.max.concurrent` hands the optimizer control of the
    /// scenario; projects that do not declare them pay nothing (the
    /// registry lookup misses and the cluster model is used verbatim).
    pub fn effective(&self, cfg: &HadoopConfig) -> FaultModel {
        FaultModel {
            mttf_s: cfg_override(cfg, "fault.node.mttf.s").unwrap_or(self.mttf_s),
            recovery_s: cfg_override(cfg, "fault.node.recovery.s").unwrap_or(self.recovery_s),
            max_concurrent: cfg_override(cfg, "fault.node.max.concurrent")
                .map(|v| v.max(0.0).round() as u32)
                .unwrap_or(self.max_concurrent),
        }
    }
}

/// Value of a spec-declared config param, if the project's registry
/// declares it (spec-declared params extend the vector past the AOT
/// prefix with zero Rust changes — this is the read side).
pub(crate) fn cfg_override(cfg: &HadoopConfig, name: &str) -> Option<f64> {
    cfg.registry().by_name(name).map(|(i, _)| cfg.get(i))
}

/// The failure chain: a self-scheduling sequence of `(gap, node)` draws.
///
/// `simulate_core` schedules one `NodeDown` ahead at all times: the
/// chain is advanced exactly once at job start and once per `NodeDown`
/// event, so the number and order of draws is a pure function of the
/// model and the fork seed — independent of cluster load, engine
/// variant, or how the failure was resolved (applied or skipped).
pub struct FaultChain {
    model: FaultModel,
    rng: Rng,
    nodes: usize,
}

impl FaultChain {
    pub fn new(model: FaultModel, rng: Rng, nodes: usize) -> FaultChain {
        FaultChain { model, rng, nodes }
    }

    pub fn model(&self) -> &FaultModel {
        &self.model
    }

    /// Draw the next failure as `(gap_from_now_s, node)`, or `None` when
    /// injection is disabled or the cluster has a single node (killing
    /// the only node would just stall the job until recovery — not a
    /// scenario worth modeling). Draws exactly two values from the
    /// dedicated fault stream per call, and none at all when disabled.
    pub fn next_failure(&mut self) -> Option<(f64, usize)> {
        if !self.model.enabled() || self.nodes < 2 {
            return None;
        }
        let mean_gap = self.model.mttf_s / self.nodes as f64;
        let u = self.rng.f64();
        // inverse-CDF exponential; u < 1 so ln(1-u) is finite
        let gap = -mean_gap * (1.0 - u).ln();
        let node = self.rng.below(self.nodes);
        Some((gap, node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(mttf: f64) -> FaultChain {
        FaultChain::new(
            FaultModel {
                mttf_s: mttf,
                ..FaultModel::default()
            },
            Rng::new(99),
            16,
        )
    }

    #[test]
    fn disabled_chain_draws_nothing() {
        let mut c = chain(0.0);
        for _ in 0..8 {
            assert!(c.next_failure().is_none());
        }
    }

    #[test]
    fn single_node_cluster_never_fails() {
        let mut c = FaultChain::new(
            FaultModel {
                mttf_s: 100.0,
                ..FaultModel::default()
            },
            Rng::new(99),
            1,
        );
        assert!(c.next_failure().is_none());
    }

    #[test]
    fn chain_is_deterministic_and_in_range() {
        let mut c1 = chain(400.0);
        let mut c2 = chain(400.0);
        for _ in 0..32 {
            let (g1, n1) = c1.next_failure().unwrap();
            let (g2, n2) = c2.next_failure().unwrap();
            assert_eq!(g1.to_bits(), g2.to_bits());
            assert_eq!(n1, n2);
            assert!(g1.is_finite() && g1 >= 0.0);
            assert!(n1 < 16);
        }
    }

    #[test]
    fn mean_gap_tracks_mttf_over_nodes() {
        let mut c = chain(1600.0); // 16 nodes -> mean gap 100s
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| c.next_failure().unwrap().0).sum();
        let mean = sum / n as f64;
        assert!((mean - 100.0).abs() < 3.0, "mean gap {mean}");
    }

    #[test]
    fn config_override_wins_over_cluster_model() {
        // the default registry declares no fault params: overrides miss
        let cfg = HadoopConfig::default();
        let m = FaultModel {
            mttf_s: 300.0,
            ..FaultModel::default()
        };
        assert_eq!(m.effective(&cfg), m);
    }
}

//! The simulated Hadoop 2.x substrate (DESIGN.md §2, substitution row 1).
//!
//! Everything Catla needs from "a Hadoop cluster" lives here: HDFS block
//! placement, YARN containers, the MapReduce discrete-event engine, the
//! noise model, counters, job-history logs, and the SSH-shaped `Cluster`
//! boundary.

pub mod cluster;
pub mod costmodel;
pub mod counters;
pub mod events;
pub mod faults;
pub mod hdfs;
pub mod joblogs;
pub mod mapreduce;
pub mod noise;
pub mod trace;
pub mod yarn;

pub use cluster::{Cluster, JobArtifacts, JobStatus, JobSubmission, SimCluster};
pub use faults::FaultModel;
pub use mapreduce::{
    simulate_job, simulate_job_in, simulate_runtime, simulate_runtime_in, JobResult, SimArena,
};
pub use noise::NoiseModel;

use crate::config::env::HadoopEnv;

/// Static description of the simulated cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub nodes: u32,
    pub racks: u32,
    pub mem_per_node_mb: u32,
    pub vcores_per_node: u32,
    pub disk_mbps: f64,
    pub net_mbps: f64,
    /// HDFS replication of job output.
    pub replication: u32,
    /// Container launch + JVM start per task, seconds.
    pub task_overhead_s: f64,
    /// Job setup/teardown (ApplicationMaster), seconds.
    pub am_overhead_s: f64,
    /// Expected fraction of node-local map reads (analytic model only;
    /// the DES resolves locality per task from actual placement).
    pub locality: f64,
    pub noise: NoiseModel,
    /// Node failure/recovery injection (off by default).
    pub fault: FaultModel,
    /// Hadoop speculative execution (mapreduce.map.speculative).
    pub speculative: bool,
    /// Base seed; every submitted job gets a distinct derived seed.
    pub seed: u64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            nodes: 16,
            racks: 2,
            mem_per_node_mb: 8192,
            vcores_per_node: 8,
            disk_mbps: 120.0,
            net_mbps: 110.0,
            replication: 3,
            task_overhead_s: 1.2,
            am_overhead_s: 8.0,
            locality: 0.85,
            noise: NoiseModel::default(),
            fault: FaultModel::default(),
            speculative: true,
            seed: 42,
        }
    }
}

impl ClusterSpec {
    /// Build from a project's `HadoopEnv.txt` `sim.*` keys.
    pub fn from_env(env: &HadoopEnv) -> ClusterSpec {
        let d = ClusterSpec::default();
        ClusterSpec {
            nodes: env.get_u64("sim.nodes", d.nodes as u64) as u32,
            racks: env.get_u64("sim.racks", d.racks as u64) as u32,
            mem_per_node_mb: env.get_u64("sim.mem.per.node.mb", d.mem_per_node_mb as u64) as u32,
            vcores_per_node: env.get_u64("sim.vcores.per.node", d.vcores_per_node as u64) as u32,
            disk_mbps: env.get_f64("sim.disk.mbps", d.disk_mbps),
            net_mbps: env.get_f64("sim.net.mbps", d.net_mbps),
            replication: env.get_u64("sim.replication", d.replication as u64) as u32,
            task_overhead_s: env.get_f64("sim.task.overhead.s", d.task_overhead_s),
            am_overhead_s: env.get_f64("sim.am.overhead.s", d.am_overhead_s),
            locality: env.get_f64("sim.locality", d.locality),
            noise: NoiseModel {
                sigma: env.get_f64("sim.noise.sigma", d.noise.sigma),
                straggler_prob: env.get_f64("sim.straggler.prob", d.noise.straggler_prob),
                failure_prob: env.get_f64("sim.failure.prob", d.noise.failure_prob),
                ..d.noise
            },
            fault: FaultModel {
                mttf_s: env.get_f64("sim.fault.node.mttf.s", d.fault.mttf_s),
                recovery_s: env.get_f64("sim.fault.node.recovery.s", d.fault.recovery_s),
                max_concurrent: env
                    .get_u64("sim.fault.node.max.concurrent", d.fault.max_concurrent as u64)
                    as u32,
            },
            speculative: env.get("sim.speculative").map(|v| v == "true").unwrap_or(d.speculative),
            seed: env.get_u64("sim.seed", d.seed),
        }
    }

    /// The consts vector consumed by the AOT cost-model artifact —
    /// layout mirrors python/compile/spec.py (C_* indices).
    pub fn to_consts(&self, wl: &crate::workloads::WorkloadSpec) -> [f32; 16] {
        [
            wl.input_mb as f32,            // C_INPUT_MB
            wl.map_selectivity as f32,     // C_MAP_SELECTIVITY
            wl.cpu_per_mb_map as f32,      // C_CPU_PER_MB_MAP
            wl.cpu_per_mb_red as f32,      // C_CPU_PER_MB_RED
            self.nodes as f32,             // C_NODES
            self.mem_per_node_mb as f32,   // C_MEM_PER_NODE_MB
            self.vcores_per_node as f32,   // C_VCORES
            self.disk_mbps as f32,         // C_DISK_MBS
            self.net_mbps as f32,          // C_NET_MBS
            wl.compress_ratio as f32,      // C_COMPRESS_RATIO
            wl.output_selectivity as f32,  // C_OUTPUT_SELECTIVITY
            self.replication as f32,       // C_REPLICATION
            self.task_overhead_s as f32,   // C_TASK_OVERHEAD_S
            self.am_overhead_s as f32,     // C_AM_OVERHEAD_S
            wl.record_kb as f32,           // C_RECORD_KB
            self.locality as f32,          // C_LOCALITY
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::wordcount;

    #[test]
    fn from_env_roundtrip() {
        let mut env = HadoopEnv::default();
        env.set("sim.nodes", "32");
        env.set("sim.noise.sigma", "0.3");
        env.set("sim.fault.node.mttf.s", "1200");
        env.set("sim.fault.node.max.concurrent", "3");
        let spec = ClusterSpec::from_env(&env);
        assert_eq!(spec.nodes, 32);
        assert_eq!(spec.noise.sigma, 0.3);
        assert_eq!(spec.racks, 2); // default preserved
        assert_eq!(spec.fault.mttf_s, 1200.0);
        assert_eq!(spec.fault.max_concurrent, 3);
        assert_eq!(spec.fault.recovery_s, FaultModel::default().recovery_s);
    }

    #[test]
    fn fault_injection_defaults_off() {
        assert!(!ClusterSpec::default().fault.enabled());
    }

    #[test]
    fn consts_layout_matches_python_spec() {
        let cl = ClusterSpec::default();
        let wl = wordcount(10240.0);
        let c = cl.to_consts(&wl);
        assert_eq!(c[0], 10240.0); // C_INPUT_MB
        assert_eq!(c[4], 16.0); // C_NODES
        assert_eq!(c[11], 3.0); // C_REPLICATION
        assert!((c[15] as f64 - 0.85).abs() < 1e-6); // C_LOCALITY
    }
}

//! YARN-style container accounting.
//!
//! Each node exposes memory (MB) and vcores; a container consumes
//! (mem, 1 vcore) until released. Map and reduce containers share the
//! same pools, which is what produces the paper's "reducer slowstart
//! squats on map containers" pathology.

/// Mutable per-node resource state.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeState {
    pub mem_free_mb: f64,
    pub vcores_free: u32,
}

#[derive(Clone, Debug)]
pub struct YarnState {
    pub nodes: Vec<NodeState>,
}

/// A granted container.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Container {
    pub node: usize,
    pub mem_mb: f64,
}

impl YarnState {
    pub fn new(nodes: usize, mem_per_node_mb: f64, vcores_per_node: u32) -> Self {
        Self {
            nodes: (0..nodes)
                .map(|_| NodeState {
                    mem_free_mb: mem_per_node_mb,
                    vcores_free: vcores_per_node,
                })
                .collect(),
        }
    }

    /// Can `node` host a container of `mem_mb`?
    pub fn fits(&self, node: usize, mem_mb: f64) -> bool {
        let n = &self.nodes[node];
        n.mem_free_mb + 1e-9 >= mem_mb && n.vcores_free >= 1
    }

    /// Allocate on a specific node. Panics if it does not fit (caller
    /// must check `fits` — keeps the scheduler logic explicit).
    pub fn allocate_on(&mut self, node: usize, mem_mb: f64) -> Container {
        assert!(self.fits(node, mem_mb), "allocate_on({node}) without capacity");
        let n = &mut self.nodes[node];
        n.mem_free_mb -= mem_mb;
        n.vcores_free -= 1;
        Container { node, mem_mb }
    }

    /// Allocate anywhere, preferring the nodes in `preferred` order, then
    /// the node with the most free memory (a crude capacity scheduler).
    pub fn allocate(&mut self, mem_mb: f64, preferred: &[usize]) -> Option<Container> {
        for &p in preferred {
            if self.fits(p, mem_mb) {
                return Some(self.allocate_on(p, mem_mb));
            }
        }
        let best = (0..self.nodes.len())
            .filter(|&n| self.fits(n, mem_mb))
            .max_by(|&a, &b| {
                self.nodes[a]
                    .mem_free_mb
                    .total_cmp(&self.nodes[b].mem_free_mb)
            })?;
        Some(self.allocate_on(best, mem_mb))
    }

    pub fn release(&mut self, c: Container) {
        let n = &mut self.nodes[c.node];
        n.mem_free_mb += c.mem_mb;
        n.vcores_free += 1;
    }

    /// Total containers of `mem_mb` the cluster could host when idle.
    pub fn capacity(&self, mem_mb: f64) -> usize {
        self.nodes
            .iter()
            .map(|n| ((n.mem_free_mb / mem_mb).floor() as usize).min(n.vcores_free as usize))
            .sum()
    }

    /// Invariant check used by property tests: no negative resources.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.mem_free_mb < -1e-9 {
                return Err(format!("node {i} mem_free {} < 0", n.mem_free_mb));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut y = YarnState::new(2, 4096.0, 4);
        let c1 = y.allocate(1024.0, &[]).unwrap();
        let c2 = y.allocate(1024.0, &[]).unwrap();
        assert_ne!((c1.node, 0), (c2.node, 1)); // distinct or same — just sanity
        y.release(c1);
        y.release(c2);
        assert_eq!(y.capacity(1024.0), 8);
    }

    #[test]
    fn prefers_requested_node() {
        let mut y = YarnState::new(4, 4096.0, 4);
        let c = y.allocate(1024.0, &[2]).unwrap();
        assert_eq!(c.node, 2);
    }

    #[test]
    fn vcores_limit_containers() {
        let mut y = YarnState::new(1, 100_000.0, 2);
        assert!(y.allocate(512.0, &[]).is_some());
        assert!(y.allocate(512.0, &[]).is_some());
        assert!(y.allocate(512.0, &[]).is_none(), "vcores exhausted");
    }

    #[test]
    fn memory_limits_containers() {
        let mut y = YarnState::new(1, 2048.0, 8);
        assert!(y.allocate(1024.0, &[]).is_some());
        assert!(y.allocate(1024.0, &[]).is_some());
        assert!(y.allocate(1024.0, &[]).is_none(), "memory exhausted");
    }

    #[test]
    fn capacity_math() {
        let y = YarnState::new(3, 8192.0, 8);
        assert_eq!(y.capacity(1024.0), 24);
        assert_eq!(y.capacity(4096.0), 6);
        assert_eq!(y.capacity(8192.0), 3);
    }

    #[test]
    fn invariants_hold_after_churn() {
        let mut y = YarnState::new(4, 4096.0, 4);
        let mut live = Vec::new();
        for i in 0..100 {
            if i % 3 == 0 && !live.is_empty() {
                y.release(live.pop().unwrap());
            } else if let Some(c) = y.allocate(700.0, &[]) {
                live.push(c);
            }
            y.check_invariants().unwrap();
        }
    }
}

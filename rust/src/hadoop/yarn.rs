//! YARN-style container accounting.
//!
//! Each node exposes memory (MB) and vcores; a container consumes
//! (mem, 1 vcore) until released. Map and reduce containers share the
//! same pools, which is what produces the paper's "reducer slowstart
//! squats on map containers" pathology.
//!
//! # The allocation index
//!
//! `allocate`'s fallback ("node with the most free memory") used to be a
//! linear scan over every node on every allocation — O(nodes) per event
//! in the simulator's hottest loop. It is now served by a lazily-rebuilt
//! max-heap over (free mem, node id): every state change pushes a fresh
//! entry, stale entries (whose recorded mem no longer matches the node)
//! are discarded when they surface, and the heap is rebuilt from scratch
//! once garbage accumulates. The chosen node is IDENTICAL to the old
//! linear `max_by` — including its tie-breaking (equal free mem → the
//! highest node index, because `max_by` keeps the last maximum) — which
//! [`YarnState::allocate_linear`] preserves verbatim as the equivalence
//! oracle (see `indexed_allocate_matches_linear_oracle_under_churn`).
//!
//! `release_epoch` counts releases; the simulator's saturation latch
//! uses it to skip re-scanning a cluster that cannot have gained
//! capacity since an allocation last failed (capacity only ever grows
//! on release).

use std::collections::BinaryHeap;

use crate::util::ord::TotalF64;

/// Mutable per-node resource state.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeState {
    pub mem_free_mb: f64,
    pub vcores_free: u32,
}

/// One (free mem, node) observation in the allocation index. Derived
/// ordering is lexicographic: free mem first ([`TotalF64`]'s total
/// order), then node id — so the max-heap surfaces exactly the node the
/// linear `max_by` scan would have picked, ties included (last max =
/// highest node id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct MemEntry {
    mem_free_mb: TotalF64,
    node: usize,
}

#[derive(Clone, Debug)]
pub struct YarnState {
    pub nodes: Vec<NodeState>,
    /// Lazy max-(free mem, node) heap. Invariant: every node always has
    /// at least one entry matching its CURRENT free mem (pushed by the
    /// last state change); entries that no longer match are stale and
    /// discarded when popped.
    index: BinaryHeap<MemEntry>,
    /// Valid-but-vcore-blocked entries set aside during one fallback
    /// search, re-pushed before it returns (kept here to reuse storage).
    side: Vec<MemEntry>,
    /// Monotone count of releases — the only operation that can grow
    /// capacity. See [`YarnState::release_epoch`].
    epoch: u64,
    /// When false ([`YarnState::disable_index`]), `allocate_on`/`release`
    /// skip index maintenance entirely — the baseline engine's honest
    /// pre-index cost profile. An indexed `allocate` self-heals by
    /// rebuilding before its fallback search.
    index_enabled: bool,
}

/// A granted container.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Container {
    pub node: usize,
    pub mem_mb: f64,
}

impl YarnState {
    pub fn new(nodes: usize, mem_per_node_mb: f64, vcores_per_node: u32) -> Self {
        let mut y = Self {
            nodes: Vec::with_capacity(nodes),
            index: BinaryHeap::with_capacity(nodes * 2),
            side: Vec::new(),
            epoch: 0,
            index_enabled: true,
        };
        y.reset(nodes, mem_per_node_mb, vcores_per_node);
        y
    }

    /// Re-initialize to a fresh idle cluster, KEEPING the node table and
    /// index allocations — the simulation arena calls this between runs.
    /// Re-enables the allocation index.
    pub fn reset(&mut self, nodes: usize, mem_per_node_mb: f64, vcores_per_node: u32) {
        self.nodes.clear();
        self.nodes.extend((0..nodes).map(|_| NodeState {
            mem_free_mb: mem_per_node_mb,
            vcores_free: vcores_per_node,
        }));
        self.epoch = 0;
        self.index_enabled = true;
        self.rebuild_index();
    }

    /// Switch OFF allocation-index maintenance: from here on the state
    /// mutates exactly like the pre-index implementation (no heap pushes
    /// on alloc/release, no rebuilds), so `simulate_runtime_baseline`
    /// measures an honest "before". A later indexed [`YarnState::allocate`]
    /// self-heals by rebuilding the index from current state.
    pub fn disable_index(&mut self) {
        self.index_enabled = false;
        self.index.clear();
    }

    /// Discard every stale entry: one fresh entry per node.
    fn rebuild_index(&mut self) {
        self.index.clear();
        for (node, n) in self.nodes.iter().enumerate() {
            self.index.push(MemEntry {
                mem_free_mb: TotalF64(n.mem_free_mb),
                node,
            });
        }
    }

    /// Record `node`'s new free mem in the index; rebuild once the lazy
    /// garbage outweighs the live entries.
    fn index_touch(&mut self, node: usize) {
        if !self.index_enabled {
            return;
        }
        if self.index.len() >= 64.max(self.nodes.len() * 8) {
            self.rebuild_index();
        } else {
            self.index.push(MemEntry {
                mem_free_mb: TotalF64(self.nodes[node].mem_free_mb),
                node,
            });
        }
    }

    /// Count of `release` calls so far. Allocation strictly shrinks free
    /// resources, so if an allocation of some size failed and this value
    /// has not changed, the same allocation must still fail — the
    /// simulator's `schedule_tasks` latches on it instead of re-scanning.
    pub fn release_epoch(&self) -> u64 {
        self.epoch
    }

    /// Can `node` host a container of `mem_mb`?
    pub fn fits(&self, node: usize, mem_mb: f64) -> bool {
        let n = &self.nodes[node];
        n.mem_free_mb + 1e-9 >= mem_mb && n.vcores_free >= 1
    }

    /// Allocate on a specific node. Panics if it does not fit (caller
    /// must check `fits` — keeps the scheduler logic explicit).
    pub fn allocate_on(&mut self, node: usize, mem_mb: f64) -> Container {
        assert!(self.fits(node, mem_mb), "allocate_on({node}) without capacity");
        {
            let n = &mut self.nodes[node];
            n.mem_free_mb -= mem_mb;
            n.vcores_free -= 1;
        }
        self.index_touch(node);
        Container { node, mem_mb }
    }

    /// Allocate anywhere, preferring the nodes in `preferred` order, then
    /// the node with the most free memory (a crude capacity scheduler).
    /// The fallback walks the allocation index — O(log nodes) amortized —
    /// and picks the exact node [`YarnState::allocate_linear`] would.
    pub fn allocate(&mut self, mem_mb: f64, preferred: &[usize]) -> Option<Container> {
        for &p in preferred {
            if self.fits(p, mem_mb) {
                return Some(self.allocate_on(p, mem_mb));
            }
        }
        if !self.index_enabled {
            // self-heal after a disable_index() phase: one rebuild makes
            // every node's current state observable again
            self.index_enabled = true;
            self.rebuild_index();
        }
        let mut pick = None;
        while let Some(&top) = self.index.peek() {
            let cur = self.nodes[top.node].mem_free_mb;
            if top.mem_free_mb.0.to_bits() != cur.to_bits() {
                self.index.pop(); // stale observation
                continue;
            }
            if top.mem_free_mb.0 + 1e-9 < mem_mb {
                break; // max valid free mem is below the request: no node fits
            }
            if self.nodes[top.node].vcores_free >= 1 {
                pick = Some(top.node);
                break;
            }
            // valid entry, but the node is out of vcores: set it aside so
            // the search can continue, restore it afterwards (the entry
            // stays the node's live observation)
            let e = self.index.pop().expect("peeked entry");
            self.side.push(e);
        }
        while let Some(e) = self.side.pop() {
            self.index.push(e);
        }
        pick.map(|n| self.allocate_on(n, mem_mb))
    }

    /// The pre-index fallback scan, preserved verbatim: max free mem over
    /// all fitting nodes, ties to the HIGHEST node id (`max_by` keeps the
    /// last maximum). Kept as the byte-identity oracle for `allocate` and
    /// as the baseline engine's allocator (`simulate_runtime_baseline`).
    pub fn allocate_linear(&mut self, mem_mb: f64, preferred: &[usize]) -> Option<Container> {
        for &p in preferred {
            if self.fits(p, mem_mb) {
                return Some(self.allocate_on(p, mem_mb));
            }
        }
        let best = (0..self.nodes.len())
            .filter(|&n| self.fits(n, mem_mb))
            .max_by(|&a, &b| {
                self.nodes[a]
                    .mem_free_mb
                    .total_cmp(&self.nodes[b].mem_free_mb)
            })?;
        Some(self.allocate_on(best, mem_mb))
    }

    pub fn release(&mut self, c: Container) {
        {
            let n = &mut self.nodes[c.node];
            n.mem_free_mb += c.mem_mb;
            n.vcores_free += 1;
        }
        self.epoch += 1;
        self.index_touch(c.node);
    }

    /// Take a failed node out of allocation: its free capacity drops to
    /// zero so both allocation paths (preferred-list `fits` and the
    /// fallback search, indexed or linear) skip it naturally, keeping
    /// the indexed/linear oracle equivalence intact. The caller must
    /// release or kill the node's in-flight containers FIRST — releasing
    /// into a drained node would resurrect phantom capacity. Draining
    /// only shrinks capacity, so the release epoch does not move and
    /// saturation latches stay valid.
    pub fn drain(&mut self, node: usize) {
        {
            let n = &mut self.nodes[node];
            n.mem_free_mb = 0.0;
            n.vcores_free = 0;
        }
        self.index_touch(node);
    }

    /// Bring a recovered node back at full idle capacity (the restarted
    /// NodeManager re-registers with nothing running). Capacity grows, so
    /// this counts as a release for the epoch — any saturation latch
    /// keyed on [`YarnState::release_epoch`] re-scans.
    pub fn restore(&mut self, node: usize, mem_per_node_mb: f64, vcores_per_node: u32) {
        {
            let n = &mut self.nodes[node];
            n.mem_free_mb = mem_per_node_mb;
            n.vcores_free = vcores_per_node;
        }
        self.epoch += 1;
        self.index_touch(node);
    }

    /// Total containers of `mem_mb` the cluster could host when idle.
    pub fn capacity(&self, mem_mb: f64) -> usize {
        self.nodes
            .iter()
            .map(|n| ((n.mem_free_mb / mem_mb).floor() as usize).min(n.vcores_free as usize))
            .sum()
    }

    /// Invariant check used by property tests: no negative resources,
    /// and (while the index is enabled) every node still has a live
    /// observation in the allocation index.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.mem_free_mb < -1e-9 {
                return Err(format!("node {i} mem_free {} < 0", n.mem_free_mb));
            }
            if self.index_enabled
                && !self
                    .index
                    .iter()
                    .any(|e| e.node == i && e.mem_free_mb.0.to_bits() == n.mem_free_mb.to_bits())
            {
                return Err(format!("node {i} has no live index entry"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut y = YarnState::new(2, 4096.0, 4);
        let c1 = y.allocate(1024.0, &[]).unwrap();
        let c2 = y.allocate(1024.0, &[]).unwrap();
        assert_ne!((c1.node, 0), (c2.node, 1)); // distinct or same — just sanity
        y.release(c1);
        y.release(c2);
        assert_eq!(y.capacity(1024.0), 8);
    }

    #[test]
    fn prefers_requested_node() {
        let mut y = YarnState::new(4, 4096.0, 4);
        let c = y.allocate(1024.0, &[2]).unwrap();
        assert_eq!(c.node, 2);
    }

    #[test]
    fn vcores_limit_containers() {
        let mut y = YarnState::new(1, 100_000.0, 2);
        assert!(y.allocate(512.0, &[]).is_some());
        assert!(y.allocate(512.0, &[]).is_some());
        assert!(y.allocate(512.0, &[]).is_none(), "vcores exhausted");
    }

    #[test]
    fn memory_limits_containers() {
        let mut y = YarnState::new(1, 2048.0, 8);
        assert!(y.allocate(1024.0, &[]).is_some());
        assert!(y.allocate(1024.0, &[]).is_some());
        assert!(y.allocate(1024.0, &[]).is_none(), "memory exhausted");
    }

    #[test]
    fn capacity_math() {
        let y = YarnState::new(3, 8192.0, 8);
        assert_eq!(y.capacity(1024.0), 24);
        assert_eq!(y.capacity(4096.0), 6);
        assert_eq!(y.capacity(8192.0), 3);
    }

    #[test]
    fn invariants_hold_after_churn() {
        let mut y = YarnState::new(4, 4096.0, 4);
        let mut live = Vec::new();
        for i in 0..100 {
            if i % 3 == 0 && !live.is_empty() {
                y.release(live.pop().unwrap());
            } else if let Some(c) = y.allocate(700.0, &[]) {
                live.push(c);
            }
            y.check_invariants().unwrap();
        }
    }

    #[test]
    fn indexed_allocate_matches_linear_oracle_under_churn() {
        // drive two identical clusters through a mixed request stream —
        // varying sizes, preference lists, exhaustion, vcore starvation —
        // and demand the SAME container from the indexed and linear paths
        // at every step (tie-breaking included)
        let mut rng = crate::util::rng::Rng::new(0xA110C);
        for (nodes, mem, vcores) in [(1usize, 2048.0, 2u32), (5, 4096.0, 3), (16, 8192.0, 8)] {
            let mut fast = YarnState::new(nodes, mem, vcores);
            let mut slow = YarnState::new(nodes, mem, vcores);
            let mut live: Vec<Container> = Vec::new();
            for step in 0..2000 {
                if rng.f64() < 0.55 || live.is_empty() {
                    let req = [512.0, 700.0, 1024.0, 1536.0][rng.below(4)];
                    let pref: Vec<usize> =
                        (0..rng.below(3)).map(|_| rng.below(nodes)).collect();
                    let a = fast.allocate(req, &pref);
                    let b = slow.allocate_linear(req, &pref);
                    assert_eq!(a, b, "divergence at step {step} ({nodes} nodes)");
                    if let Some(c) = a {
                        live.push(c);
                    }
                } else {
                    let c = live.swap_remove(rng.below(live.len()));
                    fast.release(c);
                    slow.release(c);
                }
                fast.check_invariants().unwrap();
                assert_eq!(fast.nodes, slow.nodes, "state drift at step {step}");
            }
            // the 2000-op churn on a small cluster forces many lazy
            // rebuilds — the index must stay bounded
            assert!(
                fast.index.len() <= 64.max(nodes * 8),
                "index grew unbounded: {}",
                fast.index.len()
            );
        }
    }

    #[test]
    fn release_epoch_counts_only_releases() {
        let mut y = YarnState::new(2, 4096.0, 4);
        assert_eq!(y.release_epoch(), 0);
        let c1 = y.allocate(1024.0, &[]).unwrap();
        let c2 = y.allocate(1024.0, &[]).unwrap();
        assert_eq!(y.release_epoch(), 0, "allocation must not bump the epoch");
        y.release(c1);
        assert_eq!(y.release_epoch(), 1);
        y.release(c2);
        assert_eq!(y.release_epoch(), 2);
    }

    #[test]
    fn reset_returns_to_idle_and_can_resize() {
        let mut y = YarnState::new(4, 4096.0, 4);
        let _ = y.allocate(1024.0, &[]).unwrap();
        y.reset(2, 2048.0, 2);
        assert_eq!(y.nodes.len(), 2);
        assert_eq!(y.capacity(1024.0), 4);
        assert_eq!(y.release_epoch(), 0);
        y.check_invariants().unwrap();
        // growing again also works
        y.reset(8, 8192.0, 8);
        assert_eq!(y.nodes.len(), 8);
        y.check_invariants().unwrap();
    }

    #[test]
    fn disabled_index_self_heals_on_indexed_allocate() {
        // the baseline engine runs with index maintenance off; if an
        // indexed allocate later hits the same state it must rebuild and
        // pick exactly what the linear scan would
        let mut y = YarnState::new(4, 4096.0, 2);
        y.disable_index();
        let a = y.allocate_linear(1024.0, &[]).unwrap(); // raw, unobserved
        let b = y.allocate(700.0, &[]).unwrap(); // self-heals first

        let mut oracle = YarnState::new(4, 4096.0, 2);
        oracle.allocate_linear(1024.0, &[]).unwrap();
        let expect = oracle.allocate_linear(700.0, &[]).unwrap();
        assert_eq!(b, expect, "self-healed index diverged from linear");
        y.check_invariants().unwrap();
        y.release(a);
        y.release(b);
        assert_eq!(y.capacity(4096.0), 4);
    }

    #[test]
    fn drain_and_restore_roundtrip() {
        let mut y = YarnState::new(4, 4096.0, 4);
        y.drain(2);
        y.check_invariants().unwrap();
        assert!(!y.fits(2, 1.0), "drained node must refuse any container");
        // preferred and fallback paths both avoid the drained node
        assert_ne!(y.allocate(1024.0, &[2]).unwrap().node, 2);
        for _ in 0..11 {
            assert_ne!(y.allocate(1024.0, &[]).unwrap().node, 2);
        }
        assert!(y.allocate(1024.0, &[]).is_none(), "3 live nodes hold 12 containers");
        // draining shrinks capacity: the epoch must not move
        let epoch = y.release_epoch();
        y.drain(3);
        assert_eq!(y.release_epoch(), epoch);
        // restore grows capacity: epoch bumps, node is allocatable again
        y.restore(2, 4096.0, 4);
        assert_eq!(y.release_epoch(), epoch + 1);
        y.check_invariants().unwrap();
        assert_eq!(y.allocate(4096.0, &[2]).unwrap().node, 2);

        // the linear oracle sees the same drained state
        let mut lin = YarnState::new(2, 2048.0, 2);
        lin.drain(1);
        assert_eq!(lin.allocate_linear(1024.0, &[]).unwrap().node, 0);
        assert_eq!(lin.allocate_linear(1024.0, &[]).unwrap().node, 0);
        assert!(lin.allocate_linear(1024.0, &[]).is_none());
    }

    #[test]
    fn vcore_starved_nodes_are_set_aside_not_lost() {
        // both nodes out of vcores (node 1 with the most free mem sits on
        // top of the heap): the fallback walks past BOTH, fails, and must
        // leave every live observation in place for later allocations
        let mut y = YarnState::new(2, 4096.0, 1);
        let a0 = y.allocate_on(0, 2048.0); // node 0: 2048 MB free, 0 vcores
        let a1 = y.allocate_on(1, 512.0); // node 1: 3584 MB free, 0 vcores
        assert!(y.allocate(1024.0, &[]).is_none(), "all vcores busy");
        y.check_invariants().unwrap(); // side-buffer entries restored
        y.release(a1);
        let c = y.allocate(1024.0, &[]).unwrap();
        assert_eq!(c.node, 1, "node 1 must come back once its vcore frees");
        y.release(a0);
        y.release(c);
        assert_eq!(y.capacity(4096.0), 2);
    }
}

//! Stochastic components of the cluster simulator.
//!
//! The paper motivates DFO precisely because "running time of MapReduce
//! jobs [is noisy] due to dynamic and complicated context of Hadoop
//! cluster" — the noise model is therefore load-bearing: per-task
//! multiplicative lognormal jitter, per-node slowdown factors, rare
//! stragglers, and task failures with retry.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct NoiseModel {
    /// Lognormal sigma of per-task jitter (0 disables noise entirely).
    pub sigma: f64,
    /// Lognormal sigma of the static per-node slowdown factor.
    pub node_sigma: f64,
    /// Probability a task becomes a straggler.
    pub straggler_prob: f64,
    /// Straggler duration multiplier range [lo, hi].
    pub straggler_mult: (f64, f64),
    /// Probability a task attempt fails midway and is retried.
    pub failure_prob: f64,
    /// Max attempts per task (mapreduce.map.maxattempts default 4).
    pub max_attempts: u32,
}

impl Default for NoiseModel {
    fn default() -> Self {
        Self {
            sigma: 0.12,
            node_sigma: 0.05,
            straggler_prob: 0.02,
            straggler_mult: (2.0, 4.0),
            failure_prob: 0.002,
            max_attempts: 4,
        }
    }
}

impl NoiseModel {
    /// A completely deterministic cluster (for model-vs-sim validation).
    pub fn noiseless() -> Self {
        Self {
            sigma: 0.0,
            node_sigma: 0.0,
            straggler_prob: 0.0,
            straggler_mult: (1.0, 1.0),
            failure_prob: 0.0,
            max_attempts: 1,
        }
    }

    /// Sample the static slowdown factors for `n` nodes (mean ~1).
    pub fn node_factors(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.node_factors_into(rng, n, &mut out);
        out
    }

    /// [`NoiseModel::node_factors`] into a reused buffer (identical RNG
    /// draw sequence) — the simulation arena's allocation-free path.
    #[allow(clippy::float_cmp)] // sigma == 0.0 is the exact noise-off switch; it must not draw from the RNG
    pub fn node_factors_into(&self, rng: &mut Rng, n: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..n).map(|_| {
            if self.node_sigma == 0.0 {
                1.0
            } else {
                rng.lognormal(-self.node_sigma * self.node_sigma / 2.0, self.node_sigma)
            }
        }));
    }

    /// Sample one task attempt's duration multiplier (jitter x straggler).
    #[allow(clippy::float_cmp)] // sigma == 0.0 is the exact noise-off switch; it must not draw from the RNG
    pub fn task_multiplier(&self, rng: &mut Rng) -> f64 {
        let jitter = if self.sigma == 0.0 {
            1.0
        } else {
            // mean-1 lognormal: mu = -sigma^2/2
            rng.lognormal(-self.sigma * self.sigma / 2.0, self.sigma)
        };
        let straggle = if self.straggler_prob > 0.0 && rng.bernoulli(self.straggler_prob) {
            rng.range_f64(self.straggler_mult.0, self.straggler_mult.1)
        } else {
            1.0
        };
        jitter * straggle
    }

    /// Does this attempt fail, and if so at what fraction of its duration?
    pub fn attempt_failure(&self, rng: &mut Rng) -> Option<f64> {
        if self.failure_prob > 0.0 && rng.bernoulli(self.failure_prob) {
            Some(rng.range_f64(0.2, 0.8))
        } else {
            None
        }
    }
}

/// Reduce-partition skew weights: `reduces` weights with mean exactly 1,
/// spread controlled by `key_skew` in [0,1]. Deterministic per seed.
pub fn partition_weights(rng: &mut Rng, reduces: usize, key_skew: f64) -> Vec<f64> {
    let mut out = Vec::new();
    partition_weights_into(rng, reduces, key_skew, &mut out);
    out
}

/// [`partition_weights`] into a reused buffer (identical RNG draw
/// sequence and normalization) — the simulation arena's allocation-free
/// path.
pub fn partition_weights_into(rng: &mut Rng, reduces: usize, key_skew: f64, out: &mut Vec<f64>) {
    out.clear();
    if reduces == 0 {
        return;
    }
    if key_skew <= 0.0 {
        out.resize(reduces, 1.0);
        return;
    }
    out.extend((0..reduces).map(|_| (1.0 + key_skew * rng.normal().abs() * 1.2).max(0.1)));
    let mean = out.iter().sum::<f64>() / reduces as f64;
    for w in out.iter_mut() {
        *w /= mean;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_is_exactly_one() {
        let nm = NoiseModel::noiseless();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(nm.task_multiplier(&mut rng), 1.0);
            assert!(nm.attempt_failure(&mut rng).is_none());
        }
        assert!(nm.node_factors(&mut rng, 8).iter().all(|&f| f == 1.0));
    }

    #[test]
    fn jitter_mean_near_one() {
        let nm = NoiseModel { straggler_prob: 0.0, ..NoiseModel::default() };
        let mut rng = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| nm.task_multiplier(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn stragglers_occur_at_configured_rate() {
        let nm = NoiseModel {
            sigma: 0.0,
            straggler_prob: 0.1,
            ..NoiseModel::default()
        };
        let mut rng = Rng::new(3);
        let n = 50_000;
        let count = (0..n).filter(|_| nm.task_multiplier(&mut rng) > 1.5).count();
        let rate = count as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn partition_weights_mean_one_and_spread() {
        let mut rng = Rng::new(4);
        let w = partition_weights(&mut rng, 64, 0.7);
        let mean = w.iter().sum::<f64>() / w.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12);
        let spread = w.iter().cloned().fold(f64::MIN, f64::max)
            - w.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.1, "no skew spread: {spread}");
        // uniform case
        let u = partition_weights(&mut rng, 8, 0.0);
        assert!(u.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn into_variants_match_allocating_ones_bitwise() {
        let nm = NoiseModel::default();
        let fresh_nf = nm.node_factors(&mut Rng::new(31), 16);
        let mut buf = vec![9.9; 64]; // dirty, oversized
        nm.node_factors_into(&mut Rng::new(31), 16, &mut buf);
        assert_eq!(buf.len(), 16);
        for (a, b) in fresh_nf.iter().zip(&buf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        let fresh_w = partition_weights(&mut Rng::new(32), 24, 0.6);
        let mut wbuf = vec![0.0; 3]; // dirty, undersized
        partition_weights_into(&mut Rng::new(32), 24, 0.6, &mut wbuf);
        assert_eq!(wbuf.len(), 24);
        for (a, b) in fresh_w.iter().zip(&wbuf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // zero-skew and zero-reduce edges
        partition_weights_into(&mut Rng::new(33), 8, 0.0, &mut wbuf);
        assert_eq!(wbuf, vec![1.0; 8]);
        partition_weights_into(&mut Rng::new(33), 0, 0.5, &mut wbuf);
        assert!(wbuf.is_empty());
    }

    #[test]
    fn failures_at_configured_rate() {
        let nm = NoiseModel {
            failure_prob: 0.05,
            ..NoiseModel::default()
        };
        let mut rng = Rng::new(5);
        let n = 50_000;
        let fails = (0..n).filter(|_| nm.attempt_failure(&mut rng).is_some()).count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.05).abs() < 0.006, "rate {rate}");
    }
}

//! Discrete-event queue for the cluster simulator.
//!
//! Time is `f64` seconds of simulated cluster time. Ties are broken by
//! insertion sequence so simulation is fully deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event: fires at `time`, carries a payload `E`.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-time event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// A queue whose heap can hold `capacity` events before growing —
    /// the simulator pre-sizes to its task count so the steady state
    /// never reallocates.
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(capacity),
            seq: 0,
            now: 0.0,
        }
    }

    /// Grow the heap so it can hold at least `additional` more events
    /// without reallocating.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    /// Events the heap can hold without reallocating.
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Reset to a brand-new queue — clock back to 0, tie-break sequence
    /// restarted — while KEEPING the heap's allocation. This is what
    /// lets a simulation arena reuse one queue across runs.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = 0.0;
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn schedule(&mut self, at: f64, payload: E) {
        debug_assert!(at.is_finite(), "non-finite event time");
        debug_assert!(
            at >= self.now - 1e-9,
            "scheduling into the past: {at} < {}",
            self.now
        );
        self.heap.push(Scheduled {
            time: at.max(self.now),
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, payload: E) {
        let now = self.now;
        self.schedule(now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let s = self.heap.pop()?;
        self.now = s.time;
        Some((s.time, s.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(2.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 7.5);
    }

    #[test]
    fn empty_pop_is_none() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn clear_resets_clock_and_sequence_but_keeps_capacity() {
        let mut q = EventQueue::with_capacity(16);
        let cap = q.capacity();
        assert!(cap >= 16);
        q.schedule(9.0, 1);
        q.schedule(9.0, 2);
        q.pop();
        assert_eq!(q.now(), 9.0);

        q.clear();
        // the clock is back at 0: scheduling an "early" event is legal
        // again (would have tripped the into-the-past debug_assert)
        assert_eq!(q.now(), 0.0);
        assert!(q.is_empty());
        assert_eq!(q.capacity(), cap, "clear must keep the heap storage");
        assert_eq!(q.seq, 0, "tie-break sequence must restart on clear");
        q.schedule(1.0, 10);
        q.schedule(1.0, 11);
        q.schedule(1.0, 12);
        // seq restarted from 0: ties break by post-clear insertion order
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![10, 11, 12]);
        assert_eq!(q.now(), 1.0);
    }

    #[test]
    fn reserve_grows_capacity() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.reserve(100);
        assert!(q.capacity() >= 100);
    }
}

//! Hadoop job counters, the metrics surface a real Catla scrapes from the
//! job-history server after completion.

use crate::util::json::Json;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobCounters {
    pub total_maps: u64,
    pub total_reduces: u64,
    pub failed_task_attempts: u64,
    pub speculative_attempts: u64,
    pub spilled_records: u64,
    pub map_input_mb: f64,
    pub map_output_mb: f64,
    pub shuffle_mb: f64,
    pub hdfs_write_mb: f64,
    pub file_write_mb: f64,
    pub data_local_maps: u64,
    pub rack_local_maps: u64,
    pub off_rack_maps: u64,
    /// Nodes lost mid-job (fault injection).
    pub node_failures: u64,
    /// Completed maps re-executed because their intermediate output
    /// lived on a failed node (lost shuffle output).
    pub reexecuted_maps: u64,
    /// In-flight attempts killed by a node failure (Hadoop KILLED, as
    /// distinct from FAILED — kills never count toward max attempts).
    pub killed_attempts: u64,
}

impl JobCounters {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("TOTAL_LAUNCHED_MAPS", Json::from(self.total_maps))
            .set("TOTAL_LAUNCHED_REDUCES", Json::from(self.total_reduces))
            .set("NUM_FAILED_ATTEMPTS", Json::from(self.failed_task_attempts))
            .set("NUM_SPECULATIVE_ATTEMPTS", Json::from(self.speculative_attempts))
            .set("SPILLED_RECORDS", Json::from(self.spilled_records))
            .set("MAP_INPUT_MB", Json::from(self.map_input_mb))
            .set("MAP_OUTPUT_MB", Json::from(self.map_output_mb))
            .set("REDUCE_SHUFFLE_MB", Json::from(self.shuffle_mb))
            .set("HDFS_BYTES_WRITTEN_MB", Json::from(self.hdfs_write_mb))
            .set("FILE_BYTES_WRITTEN_MB", Json::from(self.file_write_mb))
            .set("DATA_LOCAL_MAPS", Json::from(self.data_local_maps))
            .set("RACK_LOCAL_MAPS", Json::from(self.rack_local_maps))
            .set("OTHER_LOCAL_MAPS", Json::from(self.off_rack_maps))
            .set("NUM_NODE_FAILURES", Json::from(self.node_failures))
            .set("NUM_REEXECUTED_MAPS", Json::from(self.reexecuted_maps))
            .set("NUM_KILLED_ATTEMPTS", Json::from(self.killed_attempts));
        j
    }

    pub fn from_json(j: &Json) -> Option<JobCounters> {
        let f = |k: &str| j.get(k).and_then(Json::as_f64);
        Some(JobCounters {
            total_maps: f("TOTAL_LAUNCHED_MAPS")? as u64,
            total_reduces: f("TOTAL_LAUNCHED_REDUCES")? as u64,
            failed_task_attempts: f("NUM_FAILED_ATTEMPTS")? as u64,
            speculative_attempts: f("NUM_SPECULATIVE_ATTEMPTS")? as u64,
            spilled_records: f("SPILLED_RECORDS")? as u64,
            map_input_mb: f("MAP_INPUT_MB")?,
            map_output_mb: f("MAP_OUTPUT_MB")?,
            shuffle_mb: f("REDUCE_SHUFFLE_MB")?,
            hdfs_write_mb: f("HDFS_BYTES_WRITTEN_MB")?,
            file_write_mb: f("FILE_BYTES_WRITTEN_MB")?,
            data_local_maps: f("DATA_LOCAL_MAPS")? as u64,
            rack_local_maps: f("RACK_LOCAL_MAPS")? as u64,
            off_rack_maps: f("OTHER_LOCAL_MAPS")? as u64,
            // fault counters arrived after the first histories were
            // written: absent keys parse as zero so old logs stay loadable
            node_failures: f("NUM_NODE_FAILURES").unwrap_or(0.0) as u64,
            reexecuted_maps: f("NUM_REEXECUTED_MAPS").unwrap_or(0.0) as u64,
            killed_attempts: f("NUM_KILLED_ATTEMPTS").unwrap_or(0.0) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = JobCounters {
            total_maps: 80,
            total_reduces: 8,
            failed_task_attempts: 1,
            speculative_attempts: 2,
            spilled_records: 123456,
            map_input_mb: 10240.0,
            map_output_mb: 3072.0,
            shuffle_mb: 1075.2,
            hdfs_write_mb: 307.2,
            file_write_mb: 3072.0,
            data_local_maps: 70,
            rack_local_maps: 8,
            off_rack_maps: 2,
            node_failures: 2,
            reexecuted_maps: 5,
            killed_attempts: 3,
        };
        let back = JobCounters::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn missing_fields_reject() {
        assert!(JobCounters::from_json(&Json::obj()).is_none());
    }

    #[test]
    fn pre_fault_histories_parse_with_zero_fault_counters() {
        let mut old = JobCounters {
            total_maps: 4,
            node_failures: 9,
            reexecuted_maps: 9,
            killed_attempts: 9,
            ..JobCounters::default()
        }
        .to_json();
        // a history written before the fault counters existed
        if let Json::Obj(m) = &mut old {
            m.remove("NUM_NODE_FAILURES");
            m.remove("NUM_REEXECUTED_MAPS");
            m.remove("NUM_KILLED_ATTEMPTS");
        }
        let back = JobCounters::from_json(&old).unwrap();
        assert_eq!(back.total_maps, 4);
        assert_eq!(back.node_failures, 0);
        assert_eq!(back.reexecuted_maps, 0);
        assert_eq!(back.killed_attempts, 0);
    }
}

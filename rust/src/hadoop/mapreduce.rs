//! Discrete-event simulation of one MapReduce job on a YARN cluster.
//!
//! Models, per task: container allocation (shared map/reduce pools),
//! HDFS read locality, spill/merge IO, shuffle with slowstart overlap,
//! partition skew, per-task noise, stragglers, task failure + retry and
//! speculative execution. The noiseless expectation of this engine is
//! `costmodel::predict_phases`; `rust/tests/sim_vs_model.rs` keeps the
//! two within tolerance.
//!
//! # The engine as the fast path
//!
//! Search-based tuners live or die by evaluations per second, and after
//! the batch-eval work everything *around* the simulator is already
//! allocation-free — so the engine itself is optimized three ways, with
//! the hard rule that **no simulated timeline changes**: `runtime_s` is
//! bit-identical for every (cluster, workload, config, seed).
//!
//! * [`SimArena`] owns every per-run buffer (task state, pending queues,
//!   event-heap storage, block placements, preference lists, node
//!   factors, partition weights, the completed-duration feed) and is
//!   reset — never reallocated — between runs. One arena per pool worker
//!   makes a 10^4-eval DFO run allocation-free inside the simulator.
//! * The straggler median is an incremental two-heap [`RunningMedian`]
//!   (the old `median_of` cloned and sorted the full duration vec on
//!   every MapFinish in the speculation window — O(n² log n) over the
//!   map phase) and straggler candidates come from a live not-done set
//!   instead of a scan over all map states.
//! * YARN allocation is served by `yarn.rs`'s lazy max-free-mem index,
//!   and a saturation latch (keyed on [`YarnState`]'s release epoch)
//!   stops `schedule_tasks!` from re-scanning a full cluster on every
//!   event once allocation has failed and nothing was released.
//!
//! [`simulate_runtime_baseline`] keeps the pre-index engine (linear
//! allocation scan, clone-and-sort median, full-state straggler scan,
//! no latch) alive as the byte-identity oracle and the benchmark
//! baseline; `runtime_fast_path_is_byte_identical_to_full_simulation`
//! pins all paths to the same bits. Throughput numbers live in
//! `EXPERIMENTS.md` §Perf (`cargo bench --bench sim_core`).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::params::*;
use crate::hadoop::costmodel::{self, N_PHASES};
use crate::hadoop::counters::JobCounters;
use crate::hadoop::events::EventQueue;
use crate::hadoop::faults::{cfg_override, FaultChain};
use crate::hadoop::hdfs::{self, Block, Locality, Topology};
use crate::hadoop::noise::partition_weights_into;
use crate::hadoop::yarn::{Container, YarnState};
use crate::hadoop::ClusterSpec;
use crate::util::ord::TotalF64;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// Completed-task record for the job-history log.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub kind: TaskKind,
    pub id: u64,
    pub node: usize,
    pub start: f64,
    pub finish: f64,
    pub attempts: u32,
    pub speculative: bool,
    pub locality: Option<Locality>,
}

/// Everything Catla's metrics parser wants to know about one run.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Wall-clock job running time in simulated seconds — THE tuning metric.
    pub runtime_s: f64,
    /// Time the last map task finished.
    pub map_phase_end_s: f64,
    pub tasks: Vec<TaskRecord>,
    pub counters: JobCounters,
    /// Task-second aggregates per analytic phase channel (diagnostics).
    pub phase_task_seconds: [f64; N_PHASES],
    pub workload: String,
    pub config: HadoopConfig,
    pub seed: u64,
    /// `Some(reason)` when the job terminated in Hadoop's FAILED state
    /// (a task exhausted its max attempts); `runtime_s` is `+inf` then,
    /// so tuners see a config that cannot finish as infinitely bad.
    pub failed: Option<String>,
}

enum Ev {
    Start,
    /// (task id, attempt epoch, attempt ordinal)
    MapFinish(u64, u32, u32),
    MapFail(u64, u32, u32),
    /// (reduce id, attempt epoch)
    ReduceFinish(u64, u32),
    ReduceFail(u64, u32),
    /// Fault injection: a node leaves / rejoins the cluster.
    NodeDown(usize),
    NodeUp(usize),
}

/// One live (scheduled, unresolved) map attempt.
struct LiveAttempt {
    /// 1-based ordinal of this attempt within its task. Carried in the
    /// attempt's event payload so the handler identifies the finishing
    /// attempt EXACTLY — the old code matched on float finish time
    /// (`(f - t).abs() < 1e-9`) and could pick the wrong attempt if two
    /// finished within a nanosecond of each other.
    attempt: u32,
    container: Container,
    /// Expected finish time (the speculation heuristic reads it).
    finish: f64,
    speculative: bool,
}

struct MapTaskState {
    block: usize,
    attempts: u32,
    /// FAILED attempts only (Hadoop semantics: node-loss KILLED attempts
    /// never count toward `mapreduce.map.maxattempts`).
    fails: u32,
    epoch: u32,
    done: bool,
    start: f64,
    /// Node that ran the winning attempt — where the intermediate map
    /// output lives until every reducer has fetched it. Losing this node
    /// forces re-execution of the completed map.
    out_node: usize,
    live: Vec<LiveAttempt>,
    locality: Option<Locality>,
}

struct ReduceTaskState {
    alloc_t: f64,
    container: Option<Container>,
    node: usize,
    started: bool,
    /// Bumped on every failure reset and node-loss kill: a scheduled
    /// `ReduceFinish`/`ReduceFail` carrying a stale epoch is inert. Also
    /// indexes the attempt's noise fork, so retries draw fresh noise.
    epoch: u32,
    /// FAILED attempts only (kills excluded), drives max-attempt
    /// exhaustion.
    fails: u32,
    done: bool,
    /// Pre-drawn failure point of the current attempt (fraction of its
    /// duration), sampled from the attempt's own noise fork.
    fail_frac: Option<f64>,
    weight: f64,
    mult: f64,
}

/// Incremental running median over the completed-map-duration stream.
///
/// Produces EXACTLY the statistic the clone-and-sort [`median_of`]
/// produces — `sorted[len / 2]`, the upper median — in O(log n) per
/// insert instead of O(n log n) per query: [`TotalF64`] keys equal under
/// `total_cmp` are bit-identical, so any valid two-heap partition yields
/// the sort-selected element. `lo` (a max-heap) holds the `floor(n/2)`
/// smallest durations, `hi` (a min-heap) the rest, so the median is
/// always `hi`'s minimum. Cleared-not-dropped between runs so the heap
/// storage lives in the arena.
#[derive(Clone, Debug, Default)]
struct RunningMedian {
    lo: BinaryHeap<TotalF64>,
    hi: BinaryHeap<Reverse<TotalF64>>,
}

impl RunningMedian {
    fn push(&mut self, x: f64) {
        let x = TotalF64(x);
        match self.hi.peek() {
            Some(&Reverse(m)) if x < m => self.lo.push(x),
            _ => self.hi.push(Reverse(x)),
        }
        // rebalance: hi holds ceil(n/2), lo holds floor(n/2)
        if self.lo.len() > self.hi.len() {
            let v = self.lo.pop().expect("lo nonempty");
            self.hi.push(Reverse(v));
        } else if self.hi.len() > self.lo.len() + 1 {
            let Reverse(v) = self.hi.pop().expect("hi nonempty");
            self.lo.push(v);
        }
    }

    /// `sorted[len / 2]`, or 0.0 when empty — [`median_of`]'s contract.
    fn median(&self) -> f64 {
        self.hi.peek().map(|&Reverse(TotalF64(v))| v).unwrap_or(0.0)
    }

    fn clear(&mut self) {
        self.lo.clear();
        self.hi.clear();
    }
}

/// Reusable per-run workspace for the discrete-event engine.
///
/// Owns every buffer a simulation needs and is reset in place at the
/// start of each run — the buffers (including nested ones: block replica
/// lists, per-block preference lists, per-task live-attempt lists, the
/// event heap, the median heaps) keep their allocations, so steady-state
/// simulation does not touch the allocator at all. One arena serves runs
/// of ANY shape back to back: different workloads, cluster sizes and
/// configs (see `dirty_arena_reuse_is_byte_identical`).
///
/// `ClusterObjective` threads one arena per pool worker through
/// `ThreadPool::scoped_run_with`, which is what makes a long DFO run
/// allocation-free inside the simulator.
pub struct SimArena {
    topo: Topology,
    yarn: YarnState,
    queue: EventQueue<Ev>,
    blocks: Vec<Block>,
    preferred_nodes: Vec<Vec<usize>>,
    node_factor: Vec<f64>,
    weights: Vec<f64>,
    map_states: Vec<MapTaskState>,
    red_states: Vec<ReduceTaskState>,
    pending_maps: VecDeque<u64>,
    pending_reds: VecDeque<u64>,
    fetching_reds: Vec<u64>,
    /// Straggler-candidate live set: map ids not yet known done,
    /// ascending. Compacted lazily during speculation walks (indexed
    /// engine only; the baseline scans all map states).
    not_done: Vec<u64>,
    /// Straggler candidates picked by the current event (scratch).
    spec_buf: Vec<u64>,
    /// Per-node liveness under fault injection (all `false` without it).
    node_down: Vec<bool>,
    /// Completed-duration feed, incremental (indexed engine)...
    durs: RunningMedian,
    /// ...or raw, for the baseline's clone-and-sort median.
    durs_vec: Vec<f64>,
}

impl SimArena {
    /// An empty arena; every buffer grows to its working size on the
    /// first run and is reused from then on.
    pub fn new() -> SimArena {
        SimArena {
            topo: Topology::new(0, 1),
            yarn: YarnState::new(0, 0.0, 0),
            queue: EventQueue::new(),
            blocks: Vec::new(),
            preferred_nodes: Vec::new(),
            node_factor: Vec::new(),
            weights: Vec::new(),
            map_states: Vec::new(),
            red_states: Vec::new(),
            pending_maps: VecDeque::new(),
            pending_reds: VecDeque::new(),
            fetching_reds: Vec::new(),
            not_done: Vec::new(),
            spec_buf: Vec::new(),
            node_down: Vec::new(),
            durs: RunningMedian::default(),
            durs_vec: Vec::new(),
        }
    }
}

impl Default for SimArena {
    fn default() -> SimArena {
        SimArena::new()
    }
}

/// What [`simulate_core`] produced, before the (optional) packaging into
/// a [`JobResult`]. With `RECORD = false` the `tasks`/`counters`/
/// `phase_secs` fields stay empty/zero — only the timeline is computed.
struct SimCore {
    runtime_s: f64,
    map_phase_end_s: f64,
    tasks: Vec<TaskRecord>,
    counters: JobCounters,
    phase_secs: [f64; N_PHASES],
    failed: Option<String>,
}

/// Simulate one job. Deterministic for a given (cluster, workload,
/// config, seed) quadruple regardless of host threading.
pub fn simulate_job(
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
) -> JobResult {
    simulate_job_in(&mut SimArena::new(), cl, wl, cfg, seed)
}

/// [`simulate_job`] running inside a caller-owned [`SimArena`] — same
/// result, but the engine's buffers are reused across calls.
pub fn simulate_job_in(
    arena: &mut SimArena,
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
) -> JobResult {
    let core = simulate_core::<true, true>(cl, wl, cfg, seed, arena);
    JobResult {
        runtime_s: core.runtime_s,
        map_phase_end_s: core.map_phase_end_s,
        tasks: core.tasks,
        counters: core.counters,
        phase_task_seconds: core.phase_secs,
        workload: wl.name.clone(),
        config: cfg.clone(),
        seed,
        failed: core.failed,
    }
}

/// Runtime-only fast path for optimizer hot loops: the same simulation
/// as [`simulate_job`] — identical RNG stream, event schedule and
/// scheduling decisions, so `runtime_s` is byte-identical — but skips
/// materializing per-task records, counters, phase aggregates and the
/// result struct (no config/workload clones). The batched
/// `ClusterObjective` consumes only `runtime_s`, which makes this the
/// innermost call of every tuning run; artifact-producing paths
/// (submit/poll/fetch) keep the full [`simulate_job`].
pub fn simulate_runtime(cl: &ClusterSpec, wl: &WorkloadSpec, cfg: &HadoopConfig, seed: u64) -> f64 {
    simulate_core::<false, true>(cl, wl, cfg, seed, &mut SimArena::new()).runtime_s
}

/// [`simulate_runtime`] inside a caller-owned [`SimArena`]: the steady
/// state of this call allocates nothing — THE innermost call of every
/// tuning run.
pub fn simulate_runtime_in(
    arena: &mut SimArena,
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
) -> f64 {
    simulate_core::<false, true>(cl, wl, cfg, seed, arena).runtime_s
}

/// The pre-index engine — linear YARN allocation scan, clone-and-sort
/// straggler median, full-state straggler scan, no saturation latch,
/// fresh buffers every call. Kept (hidden) as the byte-identity oracle
/// for the optimized engine and as the honest "before" measurement in
/// `benches/sim_core.rs`; not for production use.
#[doc(hidden)]
pub fn simulate_runtime_baseline(
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
) -> f64 {
    simulate_core::<false, false>(cl, wl, cfg, seed, &mut SimArena::new()).runtime_s
}

/// The discrete-event engine behind every entry point.
///
/// `RECORD` gates every side channel (task records, counters, phase
/// task-seconds) at compile time. `INDEXED` selects the optimized
/// decision structures (yarn allocation index + saturation latch,
/// incremental median, not-done straggler set) vs the pre-index
/// baseline. Neither flag feeds anything back into the timeline, so all
/// four instantiations walk the identical event sequence — enforced by
/// `runtime_fast_path_is_byte_identical_to_full_simulation`.
fn simulate_core<const RECORD: bool, const INDEXED: bool>(
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
    arena: &mut SimArena,
) -> SimCore {
    let mut root = Rng::new(seed ^ 0xCA71A);
    let geo = costmodel::geometry(cfg, wl, cl);
    let map_cost = costmodel::map_task_cost(cfg, wl, cl);
    let shuffle = costmodel::shuffle_cost(cfg, wl, cl);
    let red_cost = costmodel::reduce_task_cost(cfg, wl, cl);

    let maps = geo.maps as usize;
    let reduces = geo.reduces as usize;

    // ---- rebuild per-run state inside the arena (reset, don't alloc) --
    arena.topo.reset(cl.nodes as usize, cl.racks as usize);
    hdfs::place_blocks_into(
        &arena.topo,
        geo.maps,
        cl.replication as usize,
        &mut root.fork(1),
        &mut arena.blocks,
    );
    cl.noise
        .node_factors_into(&mut root.fork(2), arena.topo.nodes(), &mut arena.node_factor);
    partition_weights_into(&mut root.fork(3), reduces, wl.key_skew, &mut arena.weights);
    // per-block container preference: replica nodes, then same-rack
    // nodes (lists precomputed once per job, inner buffers reused — the
    // event loop is allocation-free, see EXPERIMENTS.md §Perf)
    arena.preferred_nodes.truncate(maps);
    for i in 0..maps {
        if i == arena.preferred_nodes.len() {
            arena.preferred_nodes.push(Vec::new());
        }
        let b = &arena.blocks[i];
        let p = &mut arena.preferred_nodes[i];
        p.clear();
        p.extend_from_slice(&b.replicas);
        p.extend((0..arena.topo.nodes()).filter(|&n| {
            !b.replicas.contains(&n) && b.replicas.iter().any(|&r| arena.topo.same_rack(r, n))
        }));
    }

    let map_mem = cfg.get(P_MAP_MEM_MB);
    let red_mem = cfg.get(P_RED_MEM_MB);
    let slowstart = cfg.get(P_SLOWSTART).clamp(0.0, 1.0);
    let slowstart_maps = ((slowstart * maps as f64).ceil() as usize).min(maps);

    arena.yarn.reset(
        arena.topo.nodes(),
        cl.mem_per_node_mb as f64,
        cl.vcores_per_node as u32,
    );
    if !INDEXED {
        // honest baseline: the pre-index engine never maintained an
        // allocation index, so its alloc/release must not pay for one
        arena.yarn.disable_index();
    }
    arena.queue.clear();
    arena.queue.reserve(maps + reduces); // pre-size to the task count
    let mut noise_rng = root.fork(4);
    // fault stream: fork(5), taken unconditionally so the fork layout is
    // frozen; a disabled chain draws nothing from it, which is what makes
    // fault injection exactly zero-drift when `fault.*` is off
    let fault = cl.fault.effective(cfg);
    let mut fault_chain = FaultChain::new(fault, root.fork(5), cl.nodes as usize);
    // reduce retry budget: a spec-declared `mapreduce.reduce.maxattempts`
    // is a tunable dimension; otherwise the noise model's shared max
    let red_max_attempts = cfg_override(cfg, "mapreduce.reduce.maxattempts")
        .map(|v| v.round().max(1.0) as u32)
        .unwrap_or(cl.noise.max_attempts);

    arena.map_states.truncate(maps);
    for i in 0..maps {
        if i < arena.map_states.len() {
            let st = &mut arena.map_states[i];
            st.block = i;
            st.attempts = 0;
            st.fails = 0;
            st.epoch = 0;
            st.done = false;
            st.start = f64::NAN;
            st.out_node = 0;
            st.live.clear();
            st.locality = None;
        } else {
            arena.map_states.push(MapTaskState {
                block: i,
                attempts: 0,
                fails: 0,
                epoch: 0,
                done: false,
                start: f64::NAN,
                out_node: 0,
                live: Vec::new(),
                locality: None,
            });
        }
    }
    arena.pending_maps.clear();
    arena.pending_maps.extend(0..maps as u64);
    arena.red_states.truncate(reduces);
    for i in 0..reduces {
        let fresh = ReduceTaskState {
            alloc_t: f64::NAN,
            container: None,
            node: 0,
            started: false,
            epoch: 0,
            fails: 0,
            done: false,
            fail_frac: None,
            weight: 1.0,
            mult: 1.0,
        };
        if i < arena.red_states.len() {
            arena.red_states[i] = fresh;
        } else {
            arena.red_states.push(fresh);
        }
    }
    arena.pending_reds.clear();
    arena.pending_reds.extend(0..reduces as u64);
    arena.fetching_reds.clear();
    arena.spec_buf.clear();
    arena.node_down.clear();
    arena.node_down.resize(arena.topo.nodes(), false);
    if INDEXED {
        arena.not_done.clear();
        arena.not_done.extend(0..maps as u64);
        arena.durs.clear();
    } else {
        arena.durs_vec.clear();
    }

    // ---- the event loop proper, over disjoint arena fields ------------
    let SimArena {
        topo,
        yarn,
        queue: q,
        blocks,
        preferred_nodes,
        node_factor,
        weights,
        map_states,
        red_states,
        pending_maps,
        pending_reds,
        fetching_reds,
        not_done,
        spec_buf,
        node_down,
        durs,
        durs_vec,
    } = arena;

    let mut maps_done = 0usize;
    let mut reds_done = 0usize;
    let mut map_phase_end = 0.0f64;
    let mut last_finish = 0.0f64;
    let mut tasks: Vec<TaskRecord> = if RECORD {
        Vec::with_capacity(maps + reduces)
    } else {
        Vec::new()
    };
    let mut counters = JobCounters {
        total_maps: geo.maps,
        total_reduces: geo.reduces,
        map_input_mb: wl.input_mb,
        map_output_mb: geo.maps as f64 * map_cost.map_out_mb,
        shuffle_mb: geo.maps as f64 * map_cost.disk_out_mb,
        spilled_records: 0,
        ..JobCounters::default()
    };
    let mut phase_secs = [0.0f64; N_PHASES];
    // saturation latches: `Some(epoch)` = allocation of this pool's size
    // failed at that release epoch; while the epoch is unchanged nothing
    // was released, so the same allocation MUST still fail and the scan
    // is skipped (cheap decisions only — the timeline cannot change)
    let mut map_sat: Option<u64> = None;
    let mut red_sat: Option<u64> = None;
    // Hadoop FAILED terminal state: set when a task exhausts its max
    // attempts; the event loop stops and `runtime_s` becomes +inf
    let mut failed: Option<String> = None;
    // fault-injection bookkeeping (all zero / idle when faults are off)
    let mut down_count = 0usize;
    let mut failures_injected = 0u64;
    // hard cap on injected failures per run: bounds pathological knob
    // settings (mttf far below task duration) that would otherwise keep
    // the event loop alive indefinitely
    const FAULT_CAP: u64 = 10_000;

    // --- helpers as closures over the mutable state are painful in rust;
    //     use a small macro instead ---------------------------------------
    macro_rules! sample_map_attempt {
        ($q:expr, $tid:expr, $spec:expr) => {{
            let tid = $tid as usize;
            let st = &mut map_states[tid];
            // locality-aware container: prefer replica nodes, then rack
            let alloc = if INDEXED {
                yarn.allocate(map_mem, &preferred_nodes[st.block])
            } else {
                yarn.allocate_linear(map_mem, &preferred_nodes[st.block])
            };
            match alloc {
                None => false,
                Some(container) => {
                    let node = container.node;
                    let loc = hdfs::locality_with_down(topo, &blocks[st.block], node, node_down);
                    let mut rng = noise_rng.fork(($tid as u64) * 8 + st.attempts as u64);
                    let mult = cl.noise.task_multiplier(&mut rng) * node_factor[node];
                    let read = map_cost.t_read_local / loc.rate_factor();
                    let dur = (read + map_cost.t_cpu + map_cost.t_spill_io
                        + map_cost.t_merge_io)
                        * mult
                        + cl.task_overhead_s;
                    st.attempts += 1;
                    let attempt = st.attempts; // 1-based ordinal, event payload
                    if !$spec {
                        // epoch invalidates *replaced* attempts (failure
                        // retries); a speculative copy RACES the original,
                        // so both events stay valid and the first one wins
                        st.epoch += 1;
                    }
                    if st.start.is_nan() {
                        st.start = $q.now();
                        st.locality = Some(loc);
                    }
                    let epoch = st.epoch;
                    // every non-speculative attempt can fail — including
                    // the last one, which is what makes the FAILED job
                    // state reachable (speculative copies never fail on
                    // their own; they can only be killed)
                    let failure = if !$spec {
                        cl.noise.attempt_failure(&mut rng)
                    } else {
                        None
                    };
                    st.live.push(LiveAttempt {
                        attempt,
                        container,
                        finish: $q.now() + dur,
                        speculative: $spec,
                    });
                    match failure {
                        Some(frac) => {
                            $q.schedule_in(dur * frac, Ev::MapFail($tid as u64, epoch, attempt))
                        }
                        None => $q.schedule_in(dur, Ev::MapFinish($tid as u64, epoch, attempt)),
                    }
                    true
                }
            }
        }};
    }

    macro_rules! schedule_reduce_finish {
        ($q:expr, $rid:expr, $last_map_t:expr) => {{
            let rid = $rid as usize;
            let rs = &mut red_states[rid];
            if !rs.started {
                rs.started = true;
                let w = rs.weight;
                let t_copy = shuffle.t_copy * w * rs.mult;
                let fetch_done = ($last_map_t + 0.05 * t_copy).max(rs.alloc_t + t_copy);
                let post = (red_cost.t_merge_io + red_cost.t_cpu + red_cost.t_write)
                    * w
                    * rs.mult
                    + cl.task_overhead_s;
                let finish = fetch_done + post;
                match rs.fail_frac {
                    // the attempt dies partway through its timeline
                    Some(frac) => {
                        let fail_t = rs.alloc_t + (finish - rs.alloc_t) * frac;
                        $q.schedule(fail_t.max($q.now()), Ev::ReduceFail(rid as u64, rs.epoch));
                    }
                    None => {
                        $q.schedule(finish.max($q.now()), Ev::ReduceFinish(rid as u64, rs.epoch))
                    }
                }
            }
        }};
    }

    macro_rules! schedule_tasks {
        ($q:expr) => {{
            // maps first (FIFO with locality preference); while latched
            // (a map allocation failed, nothing released since) the scan
            // is provably futile and skipped
            if !INDEXED || map_sat != Some(yarn.release_epoch()) {
                while let Some(&tid) = pending_maps.front() {
                    if sample_map_attempt!($q, tid, false) {
                        pending_maps.pop_front();
                    } else {
                        map_sat = Some(yarn.release_epoch());
                        break; // no capacity anywhere
                    }
                }
            }
            // reducers once slowstart reached
            if maps_done >= slowstart_maps
                && (!INDEXED || red_sat != Some(yarn.release_epoch()))
            {
                while let Some(&rid) = pending_reds.front() {
                    let alloc = if INDEXED {
                        yarn.allocate(red_mem, &[])
                    } else {
                        yarn.allocate_linear(red_mem, &[])
                    };
                    match alloc {
                        None => {
                            red_sat = Some(yarn.release_epoch());
                            break;
                        }
                        Some(container) => {
                            pending_reds.pop_front();
                            let rs = &mut red_states[rid as usize];
                            rs.alloc_t = $q.now();
                            rs.node = container.node;
                            rs.container = Some(container);
                            // per-attempt noise fork, indexed by epoch so
                            // retries draw fresh noise; attempt 1 (epoch 0)
                            // keeps the historical `1_000_000 + rid` stream
                            let mut rng =
                                noise_rng.fork((rs.epoch as u64 + 1) * 1_000_000 + rid);
                            rs.mult =
                                cl.noise.task_multiplier(&mut rng) * node_factor[rs.node];
                            rs.weight = weights[rid as usize];
                            rs.fail_frac = cl.noise.attempt_failure(&mut rng);
                            fetching_reds.push(rid);
                            if maps_done == maps {
                                schedule_reduce_finish!($q, rid, map_phase_end);
                            }
                        }
                    }
                }
            }
        }};
    }

    q.schedule(cl.am_overhead_s, Ev::Start);
    // exactly one failure draw is in flight at all times: the chain is
    // advanced here and once per NodeDown event, so the schedule is a
    // pure function of (fault model, seed) — not of cluster load
    if let Some((gap, node)) = fault_chain.next_failure() {
        q.schedule(gap, Ev::NodeDown(node));
    }

    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::Start => {
                schedule_tasks!(q);
            }
            Ev::MapFail(tid, epoch, att) => {
                let st = &mut map_states[tid as usize];
                if st.done || epoch != st.epoch {
                    continue;
                }
                // the failing attempt must still be live — a node-loss
                // kill removes attempts from `live`, which is what turns
                // their in-flight events inert
                let Some(pos) = st.live.iter().position(|a| a.attempt == att) else {
                    continue;
                };
                if RECORD {
                    counters.failed_task_attempts += 1;
                }
                // release this attempt's container, requeue the task
                let a = st.live.remove(pos);
                yarn.release(a.container);
                st.fails += 1;
                if st.fails >= cl.noise.max_attempts {
                    failed = Some(format!(
                        "map task {tid} failed {} attempts (mapreduce.map.maxattempts {})",
                        st.fails, cl.noise.max_attempts
                    ));
                    break;
                }
                pending_maps.push_back(tid);
                schedule_tasks!(q);
            }
            Ev::MapFinish(tid, epoch, att) => {
                let st = &mut map_states[tid as usize];
                if st.done {
                    continue; // lost the speculation race; container already freed
                }
                // the event names its attempt — no float-time matching.
                // An attempt absent from `live` was killed by a node
                // failure: its finish event is inert.
                let Some(win) = st.live.iter().find(|a| a.attempt == att) else {
                    continue;
                };
                let (win_node, win_spec) = (win.container.node, win.speculative);
                if epoch != st.epoch && !win_spec {
                    continue; // stale attempt (superseded by retry)
                }
                st.done = true;
                st.out_node = win_node;
                maps_done += 1;
                map_phase_end = map_phase_end.max(t);
                // free ALL live attempt containers (speculative copy is
                // killed); drain keeps the list's storage in the arena
                let n_live = st.live.len();
                for a in st.live.drain(..) {
                    if RECORD && a.speculative {
                        counters.speculative_attempts += 1;
                    }
                    yarn.release(a.container);
                }
                let loc = st.locality.unwrap_or(Locality::NodeLocal);
                if RECORD {
                    match loc {
                        Locality::NodeLocal => counters.data_local_maps += 1,
                        Locality::RackLocal => counters.rack_local_maps += 1,
                        Locality::OffRack => counters.off_rack_maps += 1,
                    }
                    counters.spilled_records += map_cost.spills
                        * ((map_cost.map_out_mb * 1024.0 / wl.record_kb.max(1e-4)) as u64
                            / map_cost.spills.max(1));
                    counters.file_write_mb += map_cost.disk_out_mb;
                    phase_secs[costmodel::PH_READ] += map_cost.t_read_local / loc.rate_factor();
                    phase_secs[costmodel::PH_MAP_CPU] += map_cost.t_cpu;
                    phase_secs[costmodel::PH_MAP_IO] += map_cost.t_spill_io + map_cost.t_merge_io;
                    tasks.push(TaskRecord {
                        kind: TaskKind::Map,
                        id: tid,
                        node: win_node,
                        start: st.start,
                        finish: t,
                        attempts: st.attempts,
                        speculative: n_live > 1,
                        locality: Some(loc),
                    });
                }
                // the duration feed stays on in both modes: speculation
                // decisions below read the completed-duration median
                // (not_done is compacted lazily in the speculation walk —
                // an eager sorted remove here would memmove O(maps) per
                // completion, more than the scan it replaces)
                if INDEXED {
                    durs.push(t - st.start);
                } else {
                    durs_vec.push(t - st.start);
                }
                last_finish = last_finish.max(t);

                // speculative execution: when the map phase is nearly done,
                // duplicate the slowest stragglers
                if cl.speculative && pending_maps.is_empty() && maps_done * 4 >= maps * 3 {
                    let median = if INDEXED { durs.median() } else { median_of(durs_vec) };
                    // LATE-style: duplicate tasks whose *total* expected
                    // duration is an outlier vs the completed median and
                    // whose remaining work still makes a copy worthwhile
                    let candidate = |s: &MapTaskState| {
                        s.live.len() == 1
                            && !s.live[0].speculative
                            && s.live[0].finish - s.start > 1.5 * median
                            && s.live[0].finish - t > 0.5 * median
                    };
                    spec_buf.clear();
                    if INDEXED {
                        // walk the not-done live set, compacting finished
                        // tasks out as we go (retain keeps the ascending
                        // order, so candidates come out exactly as the
                        // full scan would emit them; done tasks have no
                        // live attempt, so dropping them changes nothing)
                        not_done.retain(|&i| {
                            let s = &map_states[i as usize];
                            if s.done {
                                return false;
                            }
                            if candidate(s) {
                                spec_buf.push(i);
                            }
                            true
                        });
                    } else {
                        spec_buf.extend(
                            map_states
                                .iter()
                                .enumerate()
                                .filter(|(_, s)| !s.done && candidate(s))
                                .map(|(i, _)| i as u64),
                        );
                    }
                    for &stid in spec_buf.iter() {
                        sample_map_attempt!(q, stid, true);
                    }
                }
                if maps_done == maps {
                    // release reducers waiting on the last map; drain
                    // keeps the buffer in the arena
                    for rid in fetching_reds.drain(..) {
                        schedule_reduce_finish!(q, rid, map_phase_end);
                    }
                }
                schedule_tasks!(q);
            }
            Ev::ReduceFinish(rid, epoch) => {
                let rs = &mut red_states[rid as usize];
                if rs.done || epoch != rs.epoch {
                    continue; // stale attempt (killed or failure-reset)
                }
                rs.done = true;
                if let Some(c) = rs.container.take() {
                    yarn.release(c);
                }
                reds_done += 1;
                if RECORD {
                    let w = rs.weight;
                    phase_secs[costmodel::PH_SHUFFLE] += shuffle.t_copy * w;
                    phase_secs[costmodel::PH_RED_IO] += red_cost.t_merge_io * w;
                    phase_secs[costmodel::PH_RED_CPU] += red_cost.t_cpu * w;
                    phase_secs[costmodel::PH_WRITE] += red_cost.t_write * w;
                    counters.hdfs_write_mb +=
                        shuffle.per_red_logical_mb * w * wl.output_selectivity;
                    tasks.push(TaskRecord {
                        kind: TaskKind::Reduce,
                        id: rid,
                        node: rs.node,
                        start: rs.alloc_t,
                        finish: t,
                        attempts: rs.fails + 1,
                        speculative: false,
                        locality: None,
                    });
                }
                last_finish = last_finish.max(t);
                schedule_tasks!(q);
            }
            Ev::ReduceFail(rid, epoch) => {
                let rs = &mut red_states[rid as usize];
                if rs.done || epoch != rs.epoch {
                    continue;
                }
                if RECORD {
                    counters.failed_task_attempts += 1;
                }
                if let Some(c) = rs.container.take() {
                    yarn.release(c);
                }
                rs.fails += 1;
                if rs.fails >= red_max_attempts {
                    failed = Some(format!(
                        "reduce task {rid} failed {} attempts \
                         (mapreduce.reduce.maxattempts {red_max_attempts})",
                        rs.fails
                    ));
                    break;
                }
                // reset for a fresh attempt; the epoch bump both
                // invalidates stale events and indexes the retry's
                // noise fork
                rs.epoch += 1;
                rs.started = false;
                rs.alloc_t = f64::NAN;
                rs.fail_frac = None;
                fetching_reds.retain(|&r| r != rid);
                pending_reds.push_back(rid);
                schedule_tasks!(q);
            }
            Ev::NodeDown(node) => {
                // chain the next draw NOW — whether or not this failure
                // applies — so the schedule stays a pure function of the
                // fault stream; the cap bounds pathological settings
                failures_injected += 1;
                if failures_injected < FAULT_CAP {
                    if let Some((gap, next)) = fault_chain.next_failure() {
                        q.schedule_in(gap, Ev::NodeDown(next));
                    }
                }
                if node_down[node]
                    || down_count >= fault.max_concurrent as usize
                    || down_count + 1 >= topo.nodes()
                {
                    continue; // already down, cap reached, or last node standing
                }
                node_down[node] = true;
                down_count += 1;
                if RECORD {
                    counters.node_failures += 1;
                }
                // 1) kill in-flight map attempts on the node (Hadoop
                //    KILLED, not FAILED — kills never count toward max
                //    attempts); removing them from `live` turns their
                //    scheduled events inert
                for tid in 0..maps {
                    let st = &mut map_states[tid];
                    if st.done || st.live.is_empty() {
                        continue;
                    }
                    let had = st.live.len();
                    let mut k = 0;
                    while k < st.live.len() {
                        if st.live[k].container.node == node {
                            let a = st.live.remove(k);
                            yarn.release(a.container);
                            if RECORD {
                                counters.killed_attempts += 1;
                            }
                        } else {
                            k += 1;
                        }
                    }
                    if had != st.live.len() && st.live.is_empty() {
                        // every running copy died: back to the queue
                        pending_maps.push_back(tid as u64);
                    }
                }
                // 2) kill reduce attempts on the node; the epoch bump
                //    invalidates their scheduled Finish/Fail events and
                //    the task re-queues (kills don't count as failures)
                for rid in 0..reduces {
                    let rs = &mut red_states[rid];
                    if rs.done {
                        continue;
                    }
                    match &rs.container {
                        Some(c) if c.node == node => {}
                        _ => continue,
                    }
                    let c = rs.container.take().expect("matched Some above");
                    yarn.release(c);
                    if RECORD {
                        counters.killed_attempts += 1;
                    }
                    rs.epoch += 1;
                    rs.started = false;
                    rs.alloc_t = f64::NAN;
                    rs.fail_frac = None;
                    fetching_reds.retain(|&r| r != rid as u64);
                    pending_reds.push_back(rid as u64);
                }
                // 3) lost shuffle output: a completed map's intermediate
                //    data lived on the node that ran it; while reducers
                //    still need to fetch, the map must re-execute (Hadoop
                //    re-launches completed maps on node loss for exactly
                //    this reason). Reducers already mid-fetch keep their
                //    timeline — modeled as having fetched early.
                if reds_done < reduces {
                    for tid in 0..maps {
                        let st = &mut map_states[tid];
                        if !(st.done && st.out_node == node) {
                            continue;
                        }
                        st.done = false;
                        st.epoch += 1;
                        st.start = f64::NAN;
                        st.locality = None;
                        maps_done -= 1;
                        pending_maps.push_back(tid as u64);
                        if RECORD {
                            counters.reexecuted_maps += 1;
                        }
                        if INDEXED {
                            // back into the straggler live set (it may
                            // still be present — compaction is lazy)
                            if let Err(p) = not_done.binary_search(&(tid as u64)) {
                                not_done.insert(p, tid as u64);
                            }
                        }
                    }
                }
                // 4) drain the node from YARN (its containers were all
                //    released above) and schedule its recovery
                yarn.drain(node);
                q.schedule_in(fault.recovery_s.max(0.0), Ev::NodeUp(node));
                schedule_tasks!(q);
            }
            Ev::NodeUp(node) => {
                if !node_down[node] {
                    continue;
                }
                node_down[node] = false;
                down_count -= 1;
                // counts as a release: saturation latches re-scan
                yarn.restore(node, cl.mem_per_node_mb as f64, cl.vcores_per_node);
                schedule_tasks!(q);
            }
        }
        if maps_done == maps && reds_done == reduces && pending_maps.is_empty() {
            break;
        }
    }
    debug_assert!(yarn.check_invariants().is_ok());

    if RECORD {
        phase_secs[costmodel::PH_OVERHEAD] =
            cl.am_overhead_s + (maps + reduces) as f64 * cl.task_overhead_s;
    }

    let runtime_s = if failed.is_some() {
        // Hadoop FAILED: there is no completion time. Tuners must see a
        // config that cannot finish as infinitely bad, never as fast.
        f64::INFINITY
    } else {
        last_finish + cl.am_overhead_s * 0.25 // AM teardown
    };
    SimCore {
        runtime_s,
        map_phase_end_s: map_phase_end,
        tasks,
        counters,
        phase_secs,
        failed,
    }
}

/// The baseline's straggler median: clone, sort, take `v[len / 2]`.
/// The optimized engine computes the same value incrementally through
/// [`RunningMedian`]; this stays as its oracle (and the baseline path).
fn median_of(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    v[v.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{grep, terasort, wordcount};

    fn run(cfg: &HadoopConfig, seed: u64) -> JobResult {
        let cl = ClusterSpec::default();
        simulate_job(&cl, &wordcount(10240.0), cfg, seed)
    }

    #[test]
    fn runtime_fast_path_is_byte_identical_to_full_simulation() {
        // every engine variant must walk the exact same event timeline:
        // same RNG stream, same scheduling, bit-equal runtime — across
        // workloads, failure/straggler settings and many seeds. Covered
        // paths: full simulate_job, lean simulate_runtime, the lean path
        // in a REUSED arena (reset-not-reallocate), and the pre-index
        // baseline engine (linear yarn scan + clone-and-sort median).
        let mut noisy = ClusterSpec::default();
        noisy.noise.failure_prob = 0.1;
        noisy.noise.straggler_prob = 0.15;
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 16.0);
        cfg.set(P_SLOWSTART, 0.4);
        let mut arena = SimArena::new();
        for cl in [ClusterSpec::default(), noisy] {
            for wl in [wordcount(6144.0), terasort(4096.0)] {
                for seed in 0..12 {
                    let full = simulate_job(&cl, &wl, &cfg, seed).runtime_s;
                    let lean = simulate_runtime(&cl, &wl, &cfg, seed);
                    let arena_lean = simulate_runtime_in(&mut arena, &cl, &wl, &cfg, seed);
                    let baseline = simulate_runtime_baseline(&cl, &wl, &cfg, seed);
                    assert_eq!(
                        full.to_bits(),
                        lean.to_bits(),
                        "lean path diverged: {} vs {lean} (wl {}, seed {seed})",
                        full,
                        wl.name
                    );
                    assert_eq!(
                        full.to_bits(),
                        arena_lean.to_bits(),
                        "arena path diverged: {} vs {arena_lean} (wl {}, seed {seed})",
                        full,
                        wl.name
                    );
                    assert_eq!(
                        full.to_bits(),
                        baseline.to_bits(),
                        "indexed engine diverged from the pre-index baseline: \
                         {} vs {baseline} (wl {}, seed {seed})",
                        full,
                        wl.name
                    );
                }
            }
        }
    }

    #[test]
    fn dirty_arena_reuse_is_byte_identical() {
        // one arena driven through wildly different shapes back to back —
        // big job, small job, different workload, different cluster size —
        // must reproduce what a fresh arena computes, bit for bit, AND
        // the full simulate_job record set
        let small = ClusterSpec {
            nodes: 4,
            racks: 1,
            ..ClusterSpec::default()
        };
        let big = ClusterSpec {
            nodes: 48,
            racks: 4,
            ..ClusterSpec::default()
        };
        let mut cfg_a = HadoopConfig::default();
        cfg_a.set(P_REDUCES, 24.0);
        let cfg_b = HadoopConfig::default();
        let runs: Vec<(&ClusterSpec, WorkloadSpec, &HadoopConfig, u64)> = vec![
            (&big, terasort(8192.0), &cfg_a, 3),
            (&small, wordcount(1024.0), &cfg_b, 4),
            (&big, grep(4096.0), &cfg_b, 5),
            (&small, terasort(2048.0), &cfg_a, 3), // same seed, new shape
            (&big, terasort(8192.0), &cfg_a, 3),   // exact repeat of run 0
        ];
        let mut arena = SimArena::new();
        for (i, (cl, wl, cfg, seed)) in runs.iter().enumerate() {
            let dirty = simulate_runtime_in(&mut arena, cl, wl, cfg, *seed);
            let fresh = simulate_runtime(cl, wl, cfg, *seed);
            assert_eq!(
                dirty.to_bits(),
                fresh.to_bits(),
                "dirty arena diverged on run {i}: {dirty} vs {fresh}"
            );
            // the record-producing path reuses the same arena too
            let job_dirty = simulate_job_in(&mut arena, cl, wl, cfg, *seed);
            let job_fresh = simulate_job(cl, wl, cfg, *seed);
            assert_eq!(job_dirty.runtime_s.to_bits(), job_fresh.runtime_s.to_bits());
            assert_eq!(job_dirty.tasks.len(), job_fresh.tasks.len());
            assert_eq!(job_dirty.counters, job_fresh.counters);
        }
    }

    #[test]
    fn running_median_matches_sort_median_bitwise() {
        // the incremental median must reproduce sorted[len/2] exactly,
        // duplicates and all — across many random streams
        let mut rng = crate::util::rng::Rng::new(0x4ED1A);
        for _ in 0..200 {
            let n = 1 + rng.below(120);
            let mut rm = RunningMedian::default();
            let mut xs: Vec<f64> = Vec::new();
            for _ in 0..n {
                // mix of continuous values and coarse duplicates
                let x = if rng.bernoulli(0.3) {
                    (rng.f64() * 8.0).round() * 0.5
                } else {
                    rng.f64() * 100.0
                };
                xs.push(x);
                rm.push(x);
                assert_eq!(
                    rm.median().to_bits(),
                    median_of(&xs).to_bits(),
                    "median diverged at len {}",
                    xs.len()
                );
            }
        }
        // empty contract matches median_of
        assert_eq!(RunningMedian::default().median(), 0.0);
        // clear() resets for reuse
        let mut rm = RunningMedian::default();
        rm.push(5.0);
        rm.clear();
        assert_eq!(rm.median(), 0.0);
        rm.push(2.0);
        assert_eq!(rm.median(), 2.0);
    }

    #[test]
    fn deterministic_under_seed() {
        let cfg = HadoopConfig::default();
        let a = run(&cfg, 7);
        let b = run(&cfg, 7);
        assert_eq!(a.runtime_s, b.runtime_s);
        assert_eq!(a.tasks.len(), b.tasks.len());
    }

    #[test]
    fn different_seeds_jitter() {
        let cfg = HadoopConfig::default();
        let a = run(&cfg, 1);
        let b = run(&cfg, 2);
        assert_ne!(a.runtime_s, b.runtime_s);
        // but not wildly: same config should stay within ~3x
        let ratio = a.runtime_s / b.runtime_s;
        assert!(ratio > 0.33 && ratio < 3.0, "ratio {ratio}");
    }

    #[test]
    fn all_tasks_complete() {
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 16.0);
        let r = run(&cfg, 3);
        let n_maps = r.tasks.iter().filter(|t| t.kind == TaskKind::Map).count();
        let n_reds = r.tasks.iter().filter(|t| t.kind == TaskKind::Reduce).count();
        assert_eq!(n_maps as u64, r.counters.total_maps);
        assert_eq!(n_reds as u64, 16);
    }

    #[test]
    fn task_times_ordered() {
        let r = run(&HadoopConfig::default(), 4);
        for t in &r.tasks {
            assert!(t.finish > t.start, "{t:?}");
            assert!(t.start >= 0.0);
            assert!(t.finish <= r.runtime_s + 1e-9);
        }
    }

    #[test]
    fn locality_mostly_node_local() {
        let r = run(&HadoopConfig::default(), 5);
        let c = &r.counters;
        let total = c.data_local_maps + c.rack_local_maps + c.off_rack_maps;
        assert_eq!(total, c.total_maps);
        assert!(
            c.data_local_maps * 2 > total,
            "node-local {} of {total}",
            c.data_local_maps
        );
    }

    #[test]
    fn noiseless_sim_tracks_model() {
        let mut cl = ClusterSpec::default();
        cl.noise = crate::hadoop::noise::NoiseModel::noiseless();
        cl.speculative = false;
        let wl = wordcount(10240.0);
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 8.0);
        cfg.set(P_SLOWSTART, 0.95);
        let sim = simulate_job(&cl, &wl, &cfg, 1);
        let model = costmodel::predict_runtime(&cfg, &wl, &cl);
        let ratio = sim.runtime_s / model;
        assert!(
            (0.5..2.0).contains(&ratio),
            "sim {} vs model {model} (ratio {ratio})",
            sim.runtime_s
        );
    }

    #[test]
    fn terasort_slower_than_grep_same_input() {
        let cl = ClusterSpec::default();
        let cfg = HadoopConfig::default();
        let t = simulate_job(&cl, &terasort(4096.0), &cfg, 9).runtime_s;
        let g = simulate_job(&cl, &crate::workloads::grep(4096.0), &cfg, 9).runtime_s;
        assert!(t > g, "terasort {t} <= grep {g}");
    }

    #[test]
    fn speculation_recovers_straggler_time() {
        // map-bound config + heavy stragglers: speculative copies must
        // reduce the mean runtime (regression test for the epoch-race bug)
        let wl = wordcount(10240.0);
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 32.0);
        cfg.set(P_IO_SORT_MB, 256.0);
        let mean = |speculative: bool| -> f64 {
            let cl = ClusterSpec {
                speculative,
                noise: crate::hadoop::noise::NoiseModel {
                    straggler_prob: 0.2,
                    ..Default::default()
                },
                ..ClusterSpec::default()
            };
            (0..30).map(|s| simulate_job(&cl, &wl, &cfg, s).runtime_s).sum::<f64>() / 30.0
        };
        let off = mean(false);
        let on = mean(true);
        assert!(on < off, "speculation did not help: on {on:.2} vs off {off:.2}");
    }

    #[test]
    fn disabled_fault_model_is_bit_identical_to_default() {
        // with mttf 0 the chain draws nothing: recovery/concurrency knobs
        // must be completely inert, bit for bit
        let mut cl = ClusterSpec::default();
        cl.fault.recovery_s = 7.0;
        cl.fault.max_concurrent = 5;
        let cfg = HadoopConfig::default();
        for seed in 0..6 {
            let wl = wordcount(4096.0);
            let a = simulate_job(&ClusterSpec::default(), &wl, &cfg, seed);
            let b = simulate_job(&cl, &wl, &cfg, seed);
            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits(), "seed {seed}");
            assert_eq!(a.counters, b.counters, "seed {seed}");
            assert_eq!(a.counters.node_failures, 0);
        }
    }

    #[test]
    fn node_failures_reexecute_completed_maps_deterministically() {
        // a flaky cluster: frequent failures, quick recovery. Two runs of
        // every seed must match bit for bit, and at least one seed must
        // demonstrate the full lost-shuffle path: node failures that kill
        // attempts AND force completed maps to re-execute
        let mut cl = ClusterSpec::default();
        cl.fault.mttf_s = 250.0;
        cl.fault.recovery_s = 45.0;
        let cfg = HadoopConfig::default();
        let wl = wordcount(10240.0);
        let mut reexecuted = false;
        for seed in 0..8 {
            let a = simulate_job(&cl, &wl, &cfg, seed);
            let b = simulate_job(&cl, &wl, &cfg, seed);
            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits(), "seed {seed}");
            assert_eq!(a.counters, b.counters, "seed {seed}");
            assert!(a.counters.node_failures > 0, "seed {seed}: no failures injected");
            if a.counters.reexecuted_maps > 0 && a.counters.killed_attempts > 0 {
                reexecuted = true;
            }
            // and the engine variants stay in lockstep under faults
            let lean = simulate_runtime(&cl, &wl, &cfg, seed);
            let baseline = simulate_runtime_baseline(&cl, &wl, &cfg, seed);
            assert_eq!(a.runtime_s.to_bits(), lean.to_bits(), "lean diverged, seed {seed}");
            assert_eq!(a.runtime_s.to_bits(), baseline.to_bits(), "baseline diverged, seed {seed}");
        }
        assert!(reexecuted, "no seed exercised lost-shuffle re-execution");
    }

    #[test]
    fn node_failures_slow_the_job_down() {
        let wl = wordcount(10240.0);
        let cfg = HadoopConfig::default();
        let mean = |mttf: f64| -> f64 {
            let mut cl = ClusterSpec::default();
            cl.fault.mttf_s = mttf;
            cl.fault.recovery_s = 60.0;
            (0..10).map(|s| simulate_job(&cl, &wl, &cfg, s).runtime_s).sum::<f64>() / 10.0
        };
        let healthy = mean(0.0);
        let flaky = mean(300.0);
        assert!(
            flaky > healthy,
            "losing nodes did not hurt: flaky {flaky:.1} vs healthy {healthy:.1}"
        );
    }

    #[test]
    fn attempt_exhaustion_fails_the_job() {
        // satellite: JobState::Failed is reachable — with near-certain
        // attempt failure and a tight retry budget the job must die
        let mut cl = ClusterSpec::default();
        cl.noise.failure_prob = 0.9;
        cl.noise.max_attempts = 2;
        cl.speculative = false;
        let r = simulate_job(&cl, &wordcount(4096.0), &HadoopConfig::default(), 1);
        let reason = r.failed.as_deref().expect("job should have failed");
        assert!(reason.contains("attempts"), "reason: {reason}");
        assert!(r.runtime_s.is_infinite());
        // and a healthy run reports no failure
        let ok = simulate_job(
            &ClusterSpec::default(),
            &wordcount(4096.0),
            &HadoopConfig::default(),
            1,
        );
        assert!(ok.failed.is_none());
        assert!(ok.runtime_s.is_finite());
    }

    #[test]
    fn failures_increase_counter() {
        let mut cl = ClusterSpec::default();
        cl.noise.failure_prob = 0.2;
        let r = simulate_job(&cl, &wordcount(10240.0), &HadoopConfig::default(), 11);
        assert!(r.counters.failed_task_attempts > 0);
    }

    #[test]
    fn more_reducers_speed_up_shuffle_heavy_job() {
        let cl = ClusterSpec::default();
        let wl = terasort(8192.0);
        let mut few = HadoopConfig::default();
        few.set(P_REDUCES, 1.0);
        let mut many = few.clone();
        many.set(P_REDUCES, 32.0);
        // average over seeds to beat noise
        let avg = |cfg: &HadoopConfig| -> f64 {
            (0..5).map(|s| simulate_job(&cl, &wl, cfg, s).runtime_s).sum::<f64>() / 5.0
        };
        assert!(avg(&many) < avg(&few));
    }
}

//! Discrete-event simulation of one MapReduce job on a YARN cluster.
//!
//! Models, per task: container allocation (shared map/reduce pools),
//! HDFS read locality, spill/merge IO, shuffle with slowstart overlap,
//! partition skew, per-task noise, stragglers, task failure + retry and
//! speculative execution. The noiseless expectation of this engine is
//! `costmodel::predict_phases`; `rust/tests/sim_vs_model.rs` keeps the
//! two within tolerance.

use crate::config::params::*;
use crate::hadoop::costmodel::{self, N_PHASES};
use crate::hadoop::counters::JobCounters;
use crate::hadoop::events::EventQueue;
use crate::hadoop::hdfs::{self, Block, Locality, Topology};
use crate::hadoop::noise::partition_weights;
use crate::hadoop::yarn::{Container, YarnState};
use crate::hadoop::ClusterSpec;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// Completed-task record for the job-history log.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub kind: TaskKind,
    pub id: u64,
    pub node: usize,
    pub start: f64,
    pub finish: f64,
    pub attempts: u32,
    pub speculative: bool,
    pub locality: Option<Locality>,
}

/// Everything Catla's metrics parser wants to know about one run.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Wall-clock job running time in simulated seconds — THE tuning metric.
    pub runtime_s: f64,
    /// Time the last map task finished.
    pub map_phase_end_s: f64,
    pub tasks: Vec<TaskRecord>,
    pub counters: JobCounters,
    /// Task-second aggregates per analytic phase channel (diagnostics).
    pub phase_task_seconds: [f64; N_PHASES],
    pub workload: String,
    pub config: HadoopConfig,
    pub seed: u64,
}

enum Ev {
    Start,
    /// (task id, attempt epoch)
    MapFinish(u64, u32),
    MapFail(u64, u32),
    ReduceFinish(u64),
}

struct MapTaskState {
    block: usize,
    attempts: u32,
    epoch: u32,
    done: bool,
    start: f64,
    /// (container, node, expected finish, speculative?) per live attempt
    live: Vec<(Container, usize, f64, bool)>,
    locality: Option<Locality>,
}

struct ReduceTaskState {
    alloc_t: f64,
    container: Option<Container>,
    node: usize,
    started: bool,
    weight: f64,
    mult: f64,
}

/// What [`simulate_core`] produced, before the (optional) packaging into
/// a [`JobResult`]. With `RECORD = false` the `tasks`/`counters`/
/// `phase_secs` fields stay empty/zero — only the timeline is computed.
struct SimCore {
    runtime_s: f64,
    map_phase_end_s: f64,
    tasks: Vec<TaskRecord>,
    counters: JobCounters,
    phase_secs: [f64; N_PHASES],
}

/// Simulate one job. Deterministic for a given (cluster, workload,
/// config, seed) quadruple regardless of host threading.
pub fn simulate_job(
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
) -> JobResult {
    let core = simulate_core::<true>(cl, wl, cfg, seed);
    JobResult {
        runtime_s: core.runtime_s,
        map_phase_end_s: core.map_phase_end_s,
        tasks: core.tasks,
        counters: core.counters,
        phase_task_seconds: core.phase_secs,
        workload: wl.name.clone(),
        config: cfg.clone(),
        seed,
    }
}

/// Runtime-only fast path for optimizer hot loops: the same simulation
/// as [`simulate_job`] — identical RNG stream, event schedule and
/// scheduling decisions, so `runtime_s` is byte-identical — but skips
/// materializing per-task records, counters, phase aggregates and the
/// result struct (no config/workload clones). The batched
/// `ClusterObjective` consumes only `runtime_s`, which makes this the
/// innermost call of every tuning run; artifact-producing paths
/// (submit/poll/fetch) keep the full [`simulate_job`].
pub fn simulate_runtime(cl: &ClusterSpec, wl: &WorkloadSpec, cfg: &HadoopConfig, seed: u64) -> f64 {
    simulate_core::<false>(cl, wl, cfg, seed).runtime_s
}

/// The discrete-event engine behind both entry points. `RECORD` gates
/// every side channel (task records, counters, phase task-seconds) at
/// compile time; nothing it gates feeds back into the timeline, so both
/// instantiations walk the identical event sequence.
fn simulate_core<const RECORD: bool>(
    cl: &ClusterSpec,
    wl: &WorkloadSpec,
    cfg: &HadoopConfig,
    seed: u64,
) -> SimCore {
    let mut root = Rng::new(seed ^ 0xCA71A);
    let topo = Topology::new(cl.nodes as usize, cl.racks as usize);
    let geo = costmodel::geometry(cfg, wl, cl);
    let map_cost = costmodel::map_task_cost(cfg, wl, cl);
    let shuffle = costmodel::shuffle_cost(cfg, wl, cl);
    let red_cost = costmodel::reduce_task_cost(cfg, wl, cl);

    let maps = geo.maps as usize;
    let reduces = geo.reduces as usize;
    let blocks: Vec<Block> = hdfs::place_blocks(
        &topo,
        geo.maps,
        cl.replication as usize,
        &mut root.fork(1),
    );
    let node_factor = cl.noise.node_factors(&mut root.fork(2), topo.nodes());
    let weights = partition_weights(&mut root.fork(3), reduces, wl.key_skew);
    // per-block container preference: replica nodes, then same-rack nodes
    let preferred_nodes: Vec<Vec<usize>> = blocks
        .iter()
        .map(|b| {
            let mut p = b.replicas.clone();
            p.extend(
                (0..topo.nodes())
                    .filter(|&n| !b.replicas.contains(&n)
                        && b.replicas.iter().any(|&r| topo.same_rack(r, n))),
            );
            p
        })
        .collect();

    let map_mem = cfg.get(P_MAP_MEM_MB);
    let red_mem = cfg.get(P_RED_MEM_MB);
    let slowstart = cfg.get(P_SLOWSTART).clamp(0.0, 1.0);
    let slowstart_maps = ((slowstart * maps as f64).ceil() as usize).min(maps);

    let mut yarn = YarnState::new(
        topo.nodes(),
        cl.mem_per_node_mb as f64,
        cl.vcores_per_node as u32,
    );
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut noise_rng = root.fork(4);

    let mut map_states: Vec<MapTaskState> = (0..maps)
        .map(|i| MapTaskState {
            block: i,
            attempts: 0,
            epoch: 0,
            done: false,
            start: f64::NAN,
            live: Vec::new(),
            locality: None,
        })
        .collect();
    let mut pending_maps: std::collections::VecDeque<u64> = (0..maps as u64).collect();
    let mut red_states: Vec<ReduceTaskState> = (0..reduces)
        .map(|_| ReduceTaskState {
            alloc_t: f64::NAN,
            container: None,
            node: 0,
            started: false,
            weight: 1.0,
            mult: 1.0,
        })
        .collect();
    let mut pending_reds: std::collections::VecDeque<u64> = (0..reduces as u64).collect();
    let mut fetching_reds: Vec<u64> = Vec::new();

    let mut maps_done = 0usize;
    let mut reds_done = 0usize;
    let mut map_phase_end = 0.0f64;
    let mut last_finish = 0.0f64;
    let mut tasks: Vec<TaskRecord> = if RECORD {
        Vec::with_capacity(maps + reduces)
    } else {
        Vec::new()
    };
    let mut counters = JobCounters {
        total_maps: geo.maps,
        total_reduces: geo.reduces,
        map_input_mb: wl.input_mb,
        map_output_mb: geo.maps as f64 * map_cost.map_out_mb,
        shuffle_mb: geo.maps as f64 * map_cost.disk_out_mb,
        spilled_records: 0,
        ..JobCounters::default()
    };
    let mut completed_map_durs: Vec<f64> = Vec::with_capacity(maps);
    let mut phase_secs = [0.0f64; N_PHASES];

    // --- helpers as closures over the mutable state are painful in rust;
    //     use a small macro instead ---------------------------------------
    macro_rules! sample_map_attempt {
        ($q:expr, $tid:expr, $spec:expr) => {{
            let tid = $tid as usize;
            let st = &mut map_states[tid];
            // locality-aware container: prefer replica nodes, then rack
            // (preference lists precomputed once per job — hot path is
            // allocation-free, see EXPERIMENTS.md §Perf)
            match yarn.allocate(map_mem, &preferred_nodes[st.block]) {
                None => false,
                Some(container) => {
                    let node = container.node;
                    let loc = hdfs::locality(&topo, &blocks[st.block], node);
                    let mut rng = noise_rng.fork(($tid as u64) * 8 + st.attempts as u64);
                    let mult = cl.noise.task_multiplier(&mut rng) * node_factor[node];
                    let read = map_cost.t_read_local / loc.rate_factor();
                    let dur = (read + map_cost.t_cpu + map_cost.t_spill_io
                        + map_cost.t_merge_io)
                        * mult
                        + cl.task_overhead_s;
                    st.attempts += 1;
                    if !$spec {
                        // epoch invalidates *replaced* attempts (failure
                        // retries); a speculative copy RACES the original,
                        // so both events stay valid and the first one wins
                        st.epoch += 1;
                    }
                    if st.start.is_nan() {
                        st.start = $q.now();
                        st.locality = Some(loc);
                    }
                    let epoch = st.epoch;
                    let failure = if !$spec && st.attempts < cl.noise.max_attempts {
                        cl.noise.attempt_failure(&mut rng)
                    } else {
                        None
                    };
                    st.live.push((container, node, $q.now() + dur, $spec));
                    match failure {
                        Some(frac) => $q.schedule_in(dur * frac, Ev::MapFail($tid as u64, epoch)),
                        None => $q.schedule_in(dur, Ev::MapFinish($tid as u64, epoch)),
                    }
                    true
                }
            }
        }};
    }

    macro_rules! schedule_reduce_finish {
        ($q:expr, $rid:expr, $last_map_t:expr) => {{
            let rid = $rid as usize;
            let rs = &mut red_states[rid];
            if !rs.started {
                rs.started = true;
                let w = rs.weight;
                let t_copy = shuffle.t_copy * w * rs.mult;
                let fetch_done = ($last_map_t + 0.05 * t_copy).max(rs.alloc_t + t_copy);
                let post = (red_cost.t_merge_io + red_cost.t_cpu + red_cost.t_write)
                    * w
                    * rs.mult
                    + cl.task_overhead_s;
                let finish = fetch_done + post;
                $q.schedule(finish.max($q.now()), Ev::ReduceFinish(rid as u64));
            }
        }};
    }

    macro_rules! schedule_tasks {
        ($q:expr) => {{
            // maps first (FIFO with locality preference)
            while let Some(&tid) = pending_maps.front() {
                if sample_map_attempt!($q, tid, false) {
                    pending_maps.pop_front();
                } else {
                    break; // no capacity anywhere
                }
            }
            // reducers once slowstart reached
            if maps_done >= slowstart_maps {
                while let Some(&rid) = pending_reds.front() {
                    match yarn.allocate(red_mem, &[]) {
                        None => break,
                        Some(container) => {
                            pending_reds.pop_front();
                            let rs = &mut red_states[rid as usize];
                            rs.alloc_t = $q.now();
                            rs.node = container.node;
                            rs.container = Some(container);
                            let mut rng = noise_rng.fork(1_000_000 + rid);
                            rs.mult =
                                cl.noise.task_multiplier(&mut rng) * node_factor[rs.node];
                            rs.weight = weights[rid as usize];
                            fetching_reds.push(rid);
                            if maps_done == maps {
                                schedule_reduce_finish!($q, rid, map_phase_end);
                            }
                        }
                    }
                }
            }
        }};
    }

    q.schedule(cl.am_overhead_s, Ev::Start);

    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::Start => {
                schedule_tasks!(q);
            }
            Ev::MapFail(tid, epoch) => {
                let st = &mut map_states[tid as usize];
                if st.done || epoch != st.epoch {
                    continue;
                }
                if RECORD {
                    counters.failed_task_attempts += 1;
                }
                // release this attempt's container, requeue the task
                if let Some(pos) = st.live.iter().position(|(_, _, _, s)| !s) {
                    let (c, _, _, _) = st.live.remove(pos);
                    yarn.release(c);
                }
                pending_maps.push_back(tid);
                schedule_tasks!(q);
            }
            Ev::MapFinish(tid, epoch) => {
                let (was_done, spec_of_this) = {
                    let st = &map_states[tid as usize];
                    (
                        st.done,
                        st.live.iter().find(|(_, _, f, _)| (*f - t).abs() < 1e-9).map(|x| x.3),
                    )
                };
                let st = &mut map_states[tid as usize];
                if was_done {
                    continue; // lost the speculation race; container already freed
                }
                if epoch != st.epoch && spec_of_this != Some(true) {
                    continue; // stale attempt (superseded by retry)
                }
                st.done = true;
                maps_done += 1;
                map_phase_end = map_phase_end.max(t);
                // free ALL live attempt containers (speculative copy is killed)
                let lives = std::mem::take(&mut st.live);
                let n_live = lives.len();
                for (c, _, _, s) in lives {
                    if RECORD && s {
                        counters.speculative_attempts += 1;
                    }
                    yarn.release(c);
                }
                let loc = st.locality.unwrap_or(Locality::NodeLocal);
                if RECORD {
                    match loc {
                        Locality::NodeLocal => counters.data_local_maps += 1,
                        Locality::RackLocal => counters.rack_local_maps += 1,
                        Locality::OffRack => counters.off_rack_maps += 1,
                    }
                    counters.spilled_records += map_cost.spills
                        * ((map_cost.map_out_mb * 1024.0 / wl.record_kb.max(1e-4)) as u64
                            / map_cost.spills.max(1));
                    counters.file_write_mb += map_cost.disk_out_mb;
                    phase_secs[costmodel::PH_READ] += map_cost.t_read_local / loc.rate_factor();
                    phase_secs[costmodel::PH_MAP_CPU] += map_cost.t_cpu;
                    phase_secs[costmodel::PH_MAP_IO] += map_cost.t_spill_io + map_cost.t_merge_io;
                    tasks.push(TaskRecord {
                        kind: TaskKind::Map,
                        id: tid,
                        node: 0,
                        start: st.start,
                        finish: t,
                        attempts: st.attempts,
                        speculative: n_live > 1,
                        locality: Some(loc),
                    });
                }
                // the duration feed stays on in both modes: speculation
                // decisions below read the completed-duration median
                completed_map_durs.push(t - st.start);
                last_finish = last_finish.max(t);

                // speculative execution: when the map phase is nearly done,
                // duplicate the slowest stragglers
                if cl.speculative && pending_maps.is_empty() && maps_done * 4 >= maps * 3 {
                    let median = median_of(&completed_map_durs);
                    // LATE-style: duplicate tasks whose *total* expected
                    // duration is an outlier vs the completed median and
                    // whose remaining work still makes a copy worthwhile
                    let spec_candidates: Vec<u64> = map_states
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| {
                            !s.done
                                && s.live.len() == 1
                                && !s.live[0].3
                                && s.live[0].2 - s.start > 1.5 * median
                                && s.live[0].2 - t > 0.5 * median
                        })
                        .map(|(i, _)| i as u64)
                        .collect();
                    for stid in spec_candidates {
                        sample_map_attempt!(q, stid, true);
                    }
                }
                if maps_done == maps {
                    // release reducers waiting on the last map
                    let fetching = std::mem::take(&mut fetching_reds);
                    for rid in fetching {
                        schedule_reduce_finish!(q, rid, map_phase_end);
                    }
                }
                schedule_tasks!(q);
            }
            Ev::ReduceFinish(rid) => {
                let rs = &mut red_states[rid as usize];
                if let Some(c) = rs.container.take() {
                    yarn.release(c);
                }
                reds_done += 1;
                if RECORD {
                    let w = rs.weight;
                    phase_secs[costmodel::PH_SHUFFLE] += shuffle.t_copy * w;
                    phase_secs[costmodel::PH_RED_IO] += red_cost.t_merge_io * w;
                    phase_secs[costmodel::PH_RED_CPU] += red_cost.t_cpu * w;
                    phase_secs[costmodel::PH_WRITE] += red_cost.t_write * w;
                    counters.hdfs_write_mb +=
                        shuffle.per_red_logical_mb * w * wl.output_selectivity;
                    tasks.push(TaskRecord {
                        kind: TaskKind::Reduce,
                        id: rid,
                        node: rs.node,
                        start: rs.alloc_t,
                        finish: t,
                        attempts: 1,
                        speculative: false,
                        locality: None,
                    });
                }
                last_finish = last_finish.max(t);
                schedule_tasks!(q);
            }
        }
        if maps_done == maps && reds_done == reduces && pending_maps.is_empty() {
            break;
        }
    }
    debug_assert!(yarn.check_invariants().is_ok());

    if RECORD {
        phase_secs[costmodel::PH_OVERHEAD] =
            cl.am_overhead_s + (maps + reduces) as f64 * cl.task_overhead_s;
    }

    SimCore {
        runtime_s: last_finish + cl.am_overhead_s * 0.25, // AM teardown
        map_phase_end_s: map_phase_end,
        tasks,
        counters,
        phase_secs,
    }
}

fn median_of(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    v[v.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{terasort, wordcount};

    fn run(cfg: &HadoopConfig, seed: u64) -> JobResult {
        let cl = ClusterSpec::default();
        simulate_job(&cl, &wordcount(10240.0), cfg, seed)
    }

    #[test]
    fn runtime_fast_path_is_byte_identical_to_full_simulation() {
        // the lean path must walk the exact same event timeline: same
        // RNG stream, same scheduling, bit-equal runtime — across
        // workloads, failure/straggler settings and many seeds
        let mut noisy = ClusterSpec::default();
        noisy.noise.failure_prob = 0.1;
        noisy.noise.straggler_prob = 0.15;
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 16.0);
        cfg.set(P_SLOWSTART, 0.4);
        for cl in [ClusterSpec::default(), noisy] {
            for wl in [wordcount(6144.0), terasort(4096.0)] {
                for seed in 0..12 {
                    let full = simulate_job(&cl, &wl, &cfg, seed).runtime_s;
                    let lean = simulate_runtime(&cl, &wl, &cfg, seed);
                    assert_eq!(
                        full.to_bits(),
                        lean.to_bits(),
                        "lean path diverged: {} vs {lean} (wl {}, seed {seed})",
                        full,
                        wl.name
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let cfg = HadoopConfig::default();
        let a = run(&cfg, 7);
        let b = run(&cfg, 7);
        assert_eq!(a.runtime_s, b.runtime_s);
        assert_eq!(a.tasks.len(), b.tasks.len());
    }

    #[test]
    fn different_seeds_jitter() {
        let cfg = HadoopConfig::default();
        let a = run(&cfg, 1);
        let b = run(&cfg, 2);
        assert_ne!(a.runtime_s, b.runtime_s);
        // but not wildly: same config should stay within ~3x
        let ratio = a.runtime_s / b.runtime_s;
        assert!(ratio > 0.33 && ratio < 3.0, "ratio {ratio}");
    }

    #[test]
    fn all_tasks_complete() {
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 16.0);
        let r = run(&cfg, 3);
        let n_maps = r.tasks.iter().filter(|t| t.kind == TaskKind::Map).count();
        let n_reds = r.tasks.iter().filter(|t| t.kind == TaskKind::Reduce).count();
        assert_eq!(n_maps as u64, r.counters.total_maps);
        assert_eq!(n_reds as u64, 16);
    }

    #[test]
    fn task_times_ordered() {
        let r = run(&HadoopConfig::default(), 4);
        for t in &r.tasks {
            assert!(t.finish > t.start, "{t:?}");
            assert!(t.start >= 0.0);
            assert!(t.finish <= r.runtime_s + 1e-9);
        }
    }

    #[test]
    fn locality_mostly_node_local() {
        let r = run(&HadoopConfig::default(), 5);
        let c = &r.counters;
        let total = c.data_local_maps + c.rack_local_maps + c.off_rack_maps;
        assert_eq!(total, c.total_maps);
        assert!(
            c.data_local_maps * 2 > total,
            "node-local {} of {total}",
            c.data_local_maps
        );
    }

    #[test]
    fn noiseless_sim_tracks_model() {
        let mut cl = ClusterSpec::default();
        cl.noise = crate::hadoop::noise::NoiseModel::noiseless();
        cl.speculative = false;
        let wl = wordcount(10240.0);
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 8.0);
        cfg.set(P_SLOWSTART, 0.95);
        let sim = simulate_job(&cl, &wl, &cfg, 1);
        let model = costmodel::predict_runtime(&cfg, &wl, &cl);
        let ratio = sim.runtime_s / model;
        assert!(
            (0.5..2.0).contains(&ratio),
            "sim {} vs model {model} (ratio {ratio})",
            sim.runtime_s
        );
    }

    #[test]
    fn terasort_slower_than_grep_same_input() {
        let cl = ClusterSpec::default();
        let cfg = HadoopConfig::default();
        let t = simulate_job(&cl, &terasort(4096.0), &cfg, 9).runtime_s;
        let g = simulate_job(&cl, &crate::workloads::grep(4096.0), &cfg, 9).runtime_s;
        assert!(t > g, "terasort {t} <= grep {g}");
    }

    #[test]
    fn speculation_recovers_straggler_time() {
        // map-bound config + heavy stragglers: speculative copies must
        // reduce the mean runtime (regression test for the epoch-race bug)
        let wl = wordcount(10240.0);
        let mut cfg = HadoopConfig::default();
        cfg.set(P_REDUCES, 32.0);
        cfg.set(P_IO_SORT_MB, 256.0);
        let mean = |speculative: bool| -> f64 {
            let cl = ClusterSpec {
                speculative,
                noise: crate::hadoop::noise::NoiseModel {
                    straggler_prob: 0.2,
                    ..Default::default()
                },
                ..ClusterSpec::default()
            };
            (0..30).map(|s| simulate_job(&cl, &wl, &cfg, s).runtime_s).sum::<f64>() / 30.0
        };
        let off = mean(false);
        let on = mean(true);
        assert!(on < off, "speculation did not help: on {on:.2} vs off {off:.2}");
    }

    #[test]
    fn failures_increase_counter() {
        let mut cl = ClusterSpec::default();
        cl.noise.failure_prob = 0.2;
        let r = simulate_job(&cl, &wordcount(10240.0), &HadoopConfig::default(), 11);
        assert!(r.counters.failed_task_attempts > 0);
    }

    #[test]
    fn more_reducers_speed_up_shuffle_heavy_job() {
        let cl = ClusterSpec::default();
        let wl = terasort(8192.0);
        let mut few = HadoopConfig::default();
        few.set(P_REDUCES, 1.0);
        let mut many = few.clone();
        many.set(P_REDUCES, 32.0);
        // average over seeds to beat noise
        let avg = |cfg: &HadoopConfig| -> f64 {
            (0..5).map(|s| simulate_job(&cl, &wl, cfg, s).runtime_s).sum::<f64>() / 5.0
        };
        assert!(avg(&many) < avg(&few));
    }
}

//! Job-history log generation and parsing.
//!
//! A real Catla downloads YARN job-history + aggregated container logs
//! after completion and mines running times out of them. The simulator
//! emits the same artifact shape (a JSON history document plus plain-text
//! container logs) and `catla::metrics` parses it back — exercising the
//! full download→parse→summarize pipeline the paper describes.

use crate::hadoop::counters::JobCounters;
use crate::hadoop::mapreduce::{JobResult, TaskKind, TaskRecord};
use crate::util::json::{parse, Json};

/// Render a `JobResult` as the JSON history document.
pub fn to_history_json(job_id: &str, r: &JobResult) -> Json {
    let mut tasks = Vec::with_capacity(r.tasks.len());
    for t in &r.tasks {
        let mut o = Json::obj();
        o.set(
            "type",
            Json::from(match t.kind {
                TaskKind::Map => "MAP",
                TaskKind::Reduce => "REDUCE",
            }),
        )
        .set("id", Json::from(t.id))
        .set("node", Json::from(t.node))
        .set("start", Json::from(t.start))
        .set("finish", Json::from(t.finish))
        .set("attempts", Json::from(t.attempts as u64))
        .set("speculative", Json::from(t.speculative))
        .set(
            "locality",
            match t.locality {
                Some(l) => Json::from(format!("{l:?}")),
                None => Json::Null,
            },
        );
        tasks.push(o);
    }
    let mut j = Json::obj();
    j.set("jobId", Json::from(job_id))
        .set("workload", Json::from(r.workload.as_str()))
        .set(
            "state",
            Json::from(if r.failed.is_some() { "FAILED" } else { "SUCCEEDED" }),
        )
        .set(
            // a failed job has no completion time: `runtime_s` is +inf,
            // which JSON cannot carry — histories use the conventional
            // -1 sentinel instead
            "runtimeSeconds",
            Json::from(if r.failed.is_some() { -1.0 } else { r.runtime_s }),
        )
        .set("mapPhaseEndSeconds", Json::from(r.map_phase_end_s))
        .set("seed", Json::from(r.seed))
        .set("counters", r.counters.to_json())
        .set(
            "configuration",
            config_json(&r.config),
        )
        .set(
            // the exact `-D` arguments a real Catla would pass to
            // `hadoop jar` for this configuration (typed rendering:
            // bools as true/false, categoricals by label)
            "submitArgs",
            Json::Arr(r.config.to_d_args().into_iter().map(Json::from).collect()),
        )
        .set("tasks", Json::Arr(tasks));
    if let Some(reason) = &r.failed {
        j.set("failReason", Json::from(reason.as_str()));
    }
    j
}

#[allow(clippy::float_cmp)] // bools are stored as exactly 0.0/1.0 by construction
fn config_json(cfg: &crate::config::params::HadoopConfig) -> Json {
    use crate::config::space::ParamKind;
    let mut o = Json::obj();
    // typed rendering, consistent with submitArgs: a real job history
    // stores property values, not registry-relative category indices
    for (d, v) in cfg.registry().defs().iter().zip(&cfg.values) {
        let value = match &d.kind {
            ParamKind::Bool => Json::Bool(*v != 0.0),
            ParamKind::Categorical(_) => Json::from(d.format_value(*v)),
            _ => Json::from(*v),
        };
        o.set(&d.name, value);
    }
    o
}

/// The subset of a history document Catla's metrics care about.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedHistory {
    pub job_id: String,
    pub workload: String,
    pub runtime_s: f64,
    pub map_phase_end_s: f64,
    pub counters: JobCounters,
    pub n_map_tasks: usize,
    pub n_reduce_tasks: usize,
    pub config: Vec<(String, f64)>,
}

/// Parse a history JSON document (as downloaded text).
pub fn parse_history(text: &str) -> Result<ParsedHistory, String> {
    let j = parse(text)?;
    let s = |k: &str| -> Result<String, String> {
        j.get(k)
            .and_then(Json::as_str)
            .map(|x| x.to_string())
            .ok_or_else(|| format!("history missing {k}"))
    };
    let f = |k: &str| -> Result<f64, String> {
        j.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("history missing {k}"))
    };
    let counters = j
        .get("counters")
        .and_then(JobCounters::from_json)
        .ok_or("history missing counters")?;
    let tasks = j.get("tasks").and_then(Json::as_arr).ok_or("missing tasks")?;
    let n_map_tasks = tasks
        .iter()
        .filter(|t| t.get("type").and_then(Json::as_str) == Some("MAP"))
        .count();
    let n_reduce_tasks = tasks.len() - n_map_tasks;
    let mut config = Vec::new();
    if let Some(Json::Obj(m)) = j.get("configuration") {
        for (k, v) in m {
            if let Some(x) = v.as_f64() {
                config.push((k.clone(), x));
            } else if let Some(b) = v.as_bool() {
                config.push((k.clone(), if b { 1.0 } else { 0.0 }));
            }
            // categorical labels are strings: not representable as f64,
            // consumers read them from submitArgs
        }
    }
    Ok(ParsedHistory {
        job_id: s("jobId")?,
        workload: s("workload")?,
        runtime_s: f("runtimeSeconds")?,
        map_phase_end_s: f("mapPhaseEndSeconds")?,
        counters,
        n_map_tasks,
        n_reduce_tasks,
        config,
    })
}

/// Synthesize an aggregated container log (what `yarn logs` returns).
/// Plain text; the paper's log-aggregation tool re-collects these.
pub fn container_log(job_id: &str, t: &TaskRecord) -> String {
    let kind = match t.kind {
        TaskKind::Map => "m",
        TaskKind::Reduce => "r",
    };
    let mut s = String::new();
    s.push_str(&format!(
        "Container: container_{job_id}_{kind}_{:06}\n",
        t.id
    ));
    s.push_str(&format!(
        "LogType:syslog\nLog Upload Time:{:.3}\n",
        t.finish
    ));
    s.push_str(&format!(
        "INFO [main] org.apache.hadoop.mapred.{}Task: start={:.3} finish={:.3} attempts={}\n",
        if t.kind == TaskKind::Map { "Map" } else { "Reduce" },
        t.start,
        t.finish,
        t.attempts
    ));
    if let Some(loc) = t.locality {
        s.push_str(&format!("INFO [main] locality={loc:?}\n"));
    }
    if t.speculative {
        s.push_str("INFO [main] speculative attempt won\n");
    }
    s.push_str("INFO [main] Task done.\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::hadoop::{simulate_job, ClusterSpec};
    use crate::workloads::wordcount;

    fn sample() -> JobResult {
        simulate_job(
            &ClusterSpec::default(),
            &wordcount(2048.0),
            &HadoopConfig::default(),
            1,
        )
    }

    #[test]
    fn history_roundtrip() {
        let r = sample();
        let text = to_history_json("job_001", &r).to_string();
        let p = parse_history(&text).unwrap();
        assert_eq!(p.job_id, "job_001");
        assert_eq!(p.workload, "wordcount");
        assert!((p.runtime_s - r.runtime_s).abs() < 1e-9);
        assert_eq!(p.counters, r.counters);
        assert_eq!(p.n_map_tasks as u64, r.counters.total_maps);
        assert!(!p.config.is_empty());
    }

    #[test]
    fn failed_job_history_is_valid_json() {
        // runtime_s of a failed job is +inf, which must NOT leak into the
        // document (JSON can't carry it): -1 sentinel + FAILED + reason
        let mut cl = ClusterSpec::default();
        cl.noise.failure_prob = 0.9;
        cl.noise.max_attempts = 2;
        cl.speculative = false;
        let r = simulate_job(&cl, &wordcount(2048.0), &HadoopConfig::default(), 1);
        assert!(r.failed.is_some(), "setup: job should have failed");
        let text = to_history_json("job_f", &r).to_string();
        assert!(text.contains("\"state\":\"FAILED\""));
        assert!(text.contains("failReason"));
        let p = parse_history(&text).unwrap();
        assert_eq!(p.runtime_s, -1.0);
    }

    #[test]
    fn parse_rejects_truncated() {
        let r = sample();
        let text = to_history_json("job_001", &r).to_string();
        let cut = &text[..text.len() / 2];
        assert!(parse_history(cut).is_err());
    }

    #[test]
    fn container_log_mentions_times() {
        let r = sample();
        let log = container_log("job_001", &r.tasks[0]);
        assert!(log.contains("start="));
        assert!(log.contains("finish="));
        assert!(log.contains("Task done."));
    }
}

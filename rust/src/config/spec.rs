//! Tuning parameter-specification files.
//!
//! The Optimizer Runner "creates a series of MapReduce jobs with different
//! combinations of parameter values according to parameter configuration
//! files" (paper §II.A). A spec file (`params.spec` in a tuning project)
//! declares which Hadoop parameters to tune, over what ranges and scales,
//! and which validity constraints candidate configurations must satisfy:
//!
//! ```text
//! # name                           kind   lo    hi   [step <s>] [log]
//! param mapreduce.job.reduces      int    2     32   step 2
//! param mapreduce.task.io.sort.mb  int    50    800  step 50
//! param mapreduce.map.memory.mb    int    512   4096 log
//! param mapreduce.map.sort.spill.percent float 0.5 0.9
//! param mapreduce.map.output.compress    bool
//! param mapreduce.map.output.compress.codec cat none,snappy,lz4
//! constraint io.sort.mb <= 0.7*map.memory.mb
//! ```
//!
//! Parameters unknown to the builtin registry are *declared into* the
//! spec's [`ParamRegistry`] (appended after the stable AOT prefix), so
//! new categorical or log-scaled knobs need no rust changes. Constraint
//! names resolve by full property name or unambiguous dotted suffix.
//!
//! Spec files may additionally contain `workload <name> { ... }` blocks
//! scoping param/constraint lines to one workload suite — those are
//! handled one layer up by [`crate::config::scope::ScopedSpec`], which
//! reassembles global + block line sets and feeds each through
//! [`TuningSpec::parse_numbered`] here. A file with no blocks is a flat
//! spec, parsed exactly as before.

use std::fmt;
use std::path::Path;
use std::sync::Arc;

use crate::config::space::{
    is_dotted_suffix, Bound, Constraint, ParamDef, ParamKind, ParamRegistry, Transform,
};

/// One tunable dimension of a tuning project.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamRange {
    /// Index into the spec's [`ParamRegistry`] (== config-vector slot).
    pub index: usize,
    /// The registry definition this range tunes (cloned for access).
    pub def: ParamDef,
    pub lo: f64,
    pub hi: f64,
    /// Grid step for direct search; DFO treats the range continuously.
    pub step: Option<f64>,
    /// Scale for unit-cube traversal (defaults to the def's transform).
    pub transform: Transform,
}

impl ParamRange {
    pub fn name(&self) -> &str {
        &self.def.name
    }

    /// Grid values for exhaustive search. Index-based stepping: no
    /// float-accumulation drift, grid sizes are platform-stable, and the
    /// `hi` endpoint is included *exactly* whenever `hi - lo` is a
    /// multiple of the step. Bool/categorical ranges grid over every
    /// category regardless of step.
    pub fn grid(&self) -> Vec<f64> {
        if matches!(self.def.kind, ParamKind::Bool | ParamKind::Categorical(_)) {
            return ((self.lo.round() as i64)..=(self.hi.round() as i64))
                .map(|i| i as f64)
                .collect();
        }
        // a log range with no explicit step grids geometrically (equal
        // unit-cube spacing), matching the linear default's 9 points;
        // an explicit step always means value-space stepping
        if self.transform == Transform::Log && self.step.is_none() {
            const N: usize = 8;
            let mut vals: Vec<f64> = (0..=N)
                .map(|i| {
                    let v = match i {
                        0 => self.lo, // exact endpoints
                        N => self.hi,
                        _ => Transform::Log.from_unit(i as f64 / N as f64, self.lo, self.hi),
                    };
                    if self.def.kind.is_discrete() {
                        v.round()
                    } else {
                        v
                    }
                })
                .collect();
            vals.dedup(); // integer rounding can collide at the low end
            return vals;
        }
        let step = self.step.unwrap_or_else(|| {
            if self.def.kind.is_discrete() {
                1.0f64.max(((self.hi - self.lo) / 8.0).round())
            } else {
                (self.hi - self.lo) / 8.0
            }
        });
        let n = ((self.hi - self.lo) / step + 1e-9).floor() as usize;
        let eps = 1e-9 * step.max(1.0);
        let mut vals: Vec<f64> = (0..=n)
            .map(|i| {
                let v = self.lo + i as f64 * step;
                let v = if i == n && (v - self.hi).abs() <= eps {
                    self.hi // land on the endpoint exactly
                } else {
                    v
                };
                if self.def.kind.is_discrete() {
                    v.round()
                } else {
                    v
                }
            })
            .collect();
        vals.dedup(); // sub-integer steps can round to the same value
        vals
    }
}

/// The tunable subspace (+ constraints) for one tuning project.
#[derive(Clone, Debug)]
pub struct TuningSpec {
    /// Builtin prefix + any parameters this spec declared.
    pub registry: Arc<ParamRegistry>,
    pub ranges: Vec<ParamRange>,
    /// Validity predicates over registry indices, applied at decode.
    pub constraints: Vec<Constraint>,
    /// Non-fatal diagnostics collected while parsing — currently the
    /// typo guard: a newly declared parameter whose name sits within
    /// edit distance 2 of a builtin property name (e.g. `memory.mbb`)
    /// is almost always a misspelling that would otherwise become a
    /// silent no-op dimension. Declaring new knobs is the extensibility
    /// feature, so these stay warnings (printed by the CLI), never
    /// errors.
    pub warnings: Vec<String>,
}

impl Default for TuningSpec {
    fn default() -> Self {
        TuningSpec {
            registry: ParamRegistry::builtin(),
            ranges: Vec::new(),
            constraints: Vec::new(),
            warnings: Vec::new(),
        }
    }
}

/// Equality deliberately ignores `warnings`: they are parse diagnostics
/// (carrying source line numbers that shift across print→parse — the
/// printer adds a header line), not part of the spec's identity.
impl PartialEq for TuningSpec {
    fn eq(&self, other: &Self) -> bool {
        self.registry == other.registry
            && self.ranges == other.ranges
            && self.constraints == other.constraints
    }
}

impl TuningSpec {
    pub fn dims(&self) -> usize {
        self.ranges.len()
    }

    /// Total number of grid points for exhaustive search.
    pub fn grid_size(&self) -> usize {
        self.ranges.iter().map(|r| r.grid().len()).product()
    }

    /// Enforce the constraint list on a full registry-order value vector
    /// by pulling violating values down to their (snapped) bound.
    /// Sweeps to a fixpoint: lowering one parameter can re-violate a
    /// constraint whose bound it feeds (a <= b, b <= const). For acyclic
    /// chains one sweep per constraint suffices; the sweep bound also
    /// terminates degenerate cyclic/unsatisfiable systems. Every path
    /// that materializes a config from tuned values must use this —
    /// decode, resume replay, CLI log reconstruction — so they all
    /// rebuild the exact configs that were evaluated.
    pub fn repair(&self, values: &mut [f64]) {
        let defs = self.registry.defs();
        for _ in 0..self.constraints.len() {
            let mut dirty = false;
            for c in &self.constraints {
                if !c.satisfied(values) {
                    c.repair(values, defs);
                    dirty = true;
                }
            }
            if !dirty {
                break;
            }
        }
    }

    pub fn parse(text: &str) -> Result<TuningSpec, String> {
        let lines: Vec<(usize, &str)> = text
            .lines()
            .enumerate()
            .map(|(i, l)| (i + 1, l))
            .collect();
        Self::parse_numbered(&lines, false)
    }

    /// Parse pre-split `(line_number, text)` pairs — the scoped-spec
    /// parser (`config::scope`) reassembles global + workload-block line
    /// sets and feeds them through this with their ORIGINAL line numbers,
    /// so every diagnostic points at the real source line. `allow_empty`
    /// permits a spec with zero tunable ranges (a global section that
    /// only exists to be extended by workload blocks).
    pub(crate) fn parse_numbered(
        lines: &[(usize, &str)],
        allow_empty: bool,
    ) -> Result<TuningSpec, String> {
        // Pass 1: split lines into param declarations and constraint
        // lines; declare unknown params into the registry.
        let mut param_lines = Vec::new();
        let mut constraint_lines = Vec::new();
        for (no, raw) in lines {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks[0] {
                "param" => param_lines.push((*no, toks)),
                "constraint" => constraint_lines.push((*no, toks)),
                other => {
                    return Err(format!(
                        "params.spec line {no}: expected 'param' or 'constraint', got {other:?}",
                    ))
                }
            }
        }

        let builtin = ParamRegistry::builtin();
        let mut extras: Vec<ParamDef> = Vec::new();
        let mut warnings: Vec<String> = Vec::new();
        let mut decls = Vec::with_capacity(param_lines.len());
        for (no, toks) in &param_lines {
            let mut decl = parse_param_line(*no, toks)?;
            // Canonicalize: a declaration naming a known param (builtin
            // OR an extra declared earlier in this file) by an
            // unambiguous dotted suffix (`param io.sort.mb int ...`)
            // refers to that param — the same resolution constraints use
            // — rather than silently declaring a new no-op dimension.
            if builtin.index_of(&decl.name).is_none()
                && !extras.iter().any(|d| d.name == decl.name)
            {
                let full: Vec<&str> = builtin
                    .defs()
                    .iter()
                    .map(|d| d.name.as_str())
                    .chain(extras.iter().map(|d| d.name.as_str()))
                    .filter(|full| is_dotted_suffix(full, &decl.name))
                    .collect();
                match full[..] {
                    [hit] => decl.name = hit.to_string(),
                    [] => {} // a genuinely new parameter
                    _ => {
                        return Err(format!(
                            "params.spec line {no}: ambiguous parameter suffix {:?} (matches {})",
                            decl.name,
                            full.join(", ")
                        ))
                    }
                }
            }
            let known_builtin = builtin.by_name(&decl.name).map(|(_, d)| d.clone());
            let known_extra = extras.iter().find(|d| d.name == decl.name).cloned();
            match known_builtin.or(known_extra) {
                Some(def) => check_against_def(*no, &decl, &def)?,
                None => {
                    // typo guard: a genuinely-new name sitting within
                    // edit distance 2 of a builtin spelling is almost
                    // certainly a misspelled builtin becoming a silent
                    // no-op dimension — warn, don't reject (declaring
                    // new knobs is the feature)
                    if let Some((spelling, full)) = likely_builtin_typo(&decl.name, &builtin) {
                        warnings.push(format!(
                            "params.spec line {no}: parameter {:?} is within edit distance 2 \
                             of builtin {full:?} (spelling {spelling:?}); it was declared as a \
                             NEW tuning dimension with no effect on the simulator — if you \
                             meant the builtin, fix the name",
                            decl.name
                        ));
                    }
                    extras.push(decl.to_def());
                }
            }
            decls.push((*no, decl));
        }
        let registry = ParamRegistry::with_extras(extras)?;
        // Order-independent guard: no registered name may be a dotted
        // suffix of another (a suffix line before its full-name line
        // would otherwise register a phantom second parameter).
        for d in registry.defs() {
            if let Some(o) = registry
                .defs()
                .iter()
                .find(|o| is_dotted_suffix(&o.name, &d.name))
            {
                return Err(format!(
                    "params.spec: parameter {:?} is a dotted suffix of {:?} — use the full name",
                    d.name, o.name
                ));
            }
        }

        // Pass 2: resolve ranges and constraints against the registry.
        let mut ranges: Vec<ParamRange> = Vec::with_capacity(decls.len());
        for (no, decl) in decls {
            let err = |m: &str| format!("params.spec line {no}: {m}");
            let (index, def) = registry
                .by_name(&decl.name)
                .ok_or_else(|| err("declared parameter missing from registry"))?;
            if ranges.iter().any(|r| r.index == index) {
                return Err(err(&format!("parameter {:?} declared twice", decl.name)));
            }
            let (lo, hi) = match &decl.kind {
                ParamKind::Bool | ParamKind::Categorical(_) => (def.lo, def.hi),
                _ => (decl.lo, decl.hi),
            };
            ranges.push(ParamRange {
                index,
                def: def.clone(),
                lo,
                hi,
                step: decl.step,
                transform: if decl.log { Transform::Log } else { def.transform },
            });
        }
        if ranges.is_empty() && !allow_empty {
            return Err("params.spec declares no parameters".into());
        }
        for r in &ranges {
            if r.transform == Transform::Log && r.lo <= 0.0 {
                return Err(format!("{}: log scale needs lo > 0", r.name()));
            }
        }

        let mut constraints = Vec::with_capacity(constraint_lines.len());
        for (no, toks) in &constraint_lines {
            constraints.push(parse_constraint_line(*no, toks, &registry)?);
        }
        // Reject cyclic constraint chains (a <= b, b <= a): repair's
        // bounded sweep reaches a fixpoint only for acyclic systems, and
        // a cycle is almost always a broken spec.
        if has_constraint_cycle(&constraints) {
            return Err("params.spec constraints form a cycle".into());
        }
        // Reject statically unsatisfiable constraints: if even the
        // loosest achievable bound sits below the lhs's lower bound
        // (its declared tuning range when tuned, its definition bounds
        // otherwise), repair can never succeed and decode would silently
        // violate the constraint — or drag the whole dimension below the
        // user's declared range.
        for c in &constraints {
            let range_of = |idx: usize| ranges.iter().find(|r| r.index == idx);
            let lhs_lo = range_of(c.lhs).map(|r| r.lo).unwrap_or(registry.get(c.lhs).lo);
            let max_bound = match c.bound {
                Bound::Const(k) => k,
                Bound::Scaled { coef, index } => {
                    if coef >= 0.0 {
                        // rhs can reach at most its tuned-range hi (or
                        // def hi when untuned: the base may sit anywhere)
                        let rhs_hi =
                            range_of(index).map(|r| r.hi).unwrap_or(registry.get(index).hi);
                        coef * rhs_hi
                    } else {
                        // negative coef: loosest at the rhs minimum, and
                        // repair of the rhs can reach its def lo
                        coef * registry.get(index).lo
                    }
                }
            };
            if max_bound < lhs_lo {
                return Err(format!(
                    "params.spec: constraint on {} can never be satisfied \
                     (bound at most {max_bound}, lower bound {lhs_lo})",
                    registry.get(c.lhs).name
                ));
            }
            // ...and repair must be able to succeed in the WORST case
            // too: whatever the rhs ends up at, the bound must stay
            // above the lhs's definition lo, or decode would silently
            // return a config violating the declared constraint. The
            // rhs floor is its tuned-range lo when it is tuned and
            // never itself repaired; otherwise its definition lo (an
            // untuned base value, or repair, can sit anywhere above it).
            let min_bound = match c.bound {
                Bound::Const(k) => k,
                Bound::Scaled { coef, index } => {
                    let d = registry.get(index);
                    let rhs_repairable = constraints.iter().any(|o| o.lhs == index);
                    let (floor, ceil) = match range_of(index) {
                        Some(r) if !rhs_repairable => (r.lo, r.hi),
                        _ => (d.lo, d.hi),
                    };
                    if coef >= 0.0 {
                        coef * floor
                    } else {
                        coef * ceil
                    }
                }
            };
            if min_bound < registry.get(c.lhs).lo {
                return Err(format!(
                    "params.spec: constraint on {} cannot always be repaired \
                     (worst-case bound {min_bound} below definition lower bound {})",
                    registry.get(c.lhs).name,
                    registry.get(c.lhs).lo
                ));
            }
        }

        Ok(TuningSpec {
            registry,
            ranges,
            constraints,
            warnings,
        })
    }

    pub fn load(path: &Path) -> Result<TuningSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// The paper's Fig.2 two-parameter spec.
    pub fn fig2() -> TuningSpec {
        Self::parse(
            "param mapreduce.job.reduces int 2 32 step 2\n\
             param mapreduce.task.io.sort.mb int 50 800 step 50\n",
        )
        .unwrap()
    }

    /// The four-parameter spec used in the Fig.3 BOBYQA demo.
    pub fn fig3() -> TuningSpec {
        Self::parse(
            "param mapreduce.job.reduces int 1 64\n\
             param mapreduce.task.io.sort.mb int 16 2048\n\
             param mapreduce.task.io.sort.factor int 2 128\n\
             param mapreduce.reduce.shuffle.parallelcopies int 1 64\n",
        )
        .unwrap()
    }
}

/// Spec files print exactly what [`TuningSpec::parse`] accepts:
/// parse → print → parse is the identity.
impl fmt::Display for TuningSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "# Catla tuning parameter specification")?;
        for r in &self.ranges {
            match &r.def.kind {
                ParamKind::Bool => writeln!(f, "param {} bool", r.name())?,
                ParamKind::Categorical(cats) => {
                    writeln!(f, "param {} cat {}", r.name(), cats.join(","))?
                }
                kind => {
                    write!(f, "param {} {} {} {}", r.name(), kind.token(), r.lo, r.hi)?;
                    if let Some(s) = r.step {
                        write!(f, " step {s}")?;
                    }
                    if r.transform == Transform::Log {
                        write!(f, " log")?;
                    }
                    writeln!(f)?;
                }
            }
        }
        for c in &self.constraints {
            writeln!(f, "{}", c.display(&self.registry))?;
        }
        Ok(())
    }
}

/// One parsed `param` line, before registry resolution.
struct ParamDecl {
    name: String,
    kind: ParamKind,
    lo: f64,
    hi: f64,
    step: Option<f64>,
    log: bool,
}

impl ParamDecl {
    /// Definition for a parameter this spec introduces: the declared
    /// range *is* its bounds; numeric params default to their low end,
    /// bools to false, categoricals to the first category.
    fn to_def(&self) -> ParamDef {
        let mut def = ParamDef {
            name: self.name.clone(),
            kind: self.kind.clone(),
            lo: self.lo,
            hi: self.hi,
            default: self.lo,
            transform: Transform::Linear,
        };
        if self.log {
            def = def.log();
        }
        def
    }
}

fn parse_param_line(no: usize, toks: &[&str]) -> Result<ParamDecl, String> {
    let err = |m: &str| format!("params.spec line {no}: {m}");
    if toks.len() < 3 {
        return Err(err(
            "expected: param <name> <int|float> <lo> <hi> [step <s>] [log] | param <name> bool | param <name> cat <a,b,...>",
        ));
    }
    let name = toks[1].to_string();
    match toks[2] {
        "bool" => {
            if toks.len() > 3 {
                return Err(err(&format!("unexpected token {:?} after bool", toks[3])));
            }
            Ok(ParamDecl {
                name,
                kind: ParamKind::Bool,
                lo: 0.0,
                hi: 1.0,
                step: None,
                log: false,
            })
        }
        "cat" => {
            let cats: Vec<String> = toks
                .get(3)
                .ok_or_else(|| err("cat needs a comma-separated category list"))?
                .split(',')
                .map(|c| c.trim().to_string())
                .filter(|c| !c.is_empty())
                .collect();
            if cats.len() < 2 {
                return Err(err("cat needs >= 2 categories"));
            }
            if toks.len() > 4 {
                return Err(err(&format!("unexpected token {:?} after categories", toks[4])));
            }
            let hi = (cats.len() - 1) as f64;
            Ok(ParamDecl {
                name,
                kind: ParamKind::Categorical(cats),
                lo: 0.0,
                hi,
                step: None,
                log: false,
            })
        }
        kind @ ("int" | "float") => {
            if toks.len() < 5 {
                return Err(err("expected: param <name> <int|float> <lo> <hi> [step <s>] [log]"));
            }
            let lo: f64 = toks[3].parse().map_err(|_| err("bad lo"))?;
            let hi: f64 = toks[4].parse().map_err(|_| err("bad hi"))?;
            if lo >= hi {
                return Err(err("lo must be < hi"));
            }
            let mut step = None;
            let mut log = false;
            let mut i = 5;
            while i < toks.len() {
                match toks[i] {
                    "step" => {
                        let s: f64 = toks
                            .get(i + 1)
                            .ok_or_else(|| err("step needs a value"))?
                            .parse()
                            .map_err(|_| err("bad step"))?;
                        if s <= 0.0 {
                            return Err(err("step must be positive"));
                        }
                        step = Some(s);
                        i += 2;
                    }
                    "log" => {
                        log = true;
                        i += 1;
                    }
                    t => return Err(err(&format!("unexpected token {t:?}"))),
                }
            }
            if log && lo <= 0.0 {
                return Err(err("log scale needs lo > 0"));
            }
            Ok(ParamDecl {
                name,
                kind: if kind == "int" { ParamKind::Int } else { ParamKind::Float },
                lo,
                hi,
                step,
                log,
            })
        }
        k => Err(err(&format!("kind must be int|float|bool|cat, got {k:?}"))),
    }
}

/// Validate a declaration against an already-known definition (builtin
/// or declared earlier in the same file).
fn check_against_def(no: usize, decl: &ParamDecl, def: &ParamDef) -> Result<(), String> {
    let err = |m: &str| format!("params.spec line {no}: {m}");
    let kinds_match = match (&decl.kind, &def.kind) {
        (ParamKind::Categorical(a), ParamKind::Categorical(b)) => {
            if a != b {
                return Err(err(&format!(
                    "{} categories {:?} do not match registered {:?}",
                    def.name, a, b
                )));
            }
            true
        }
        (a, b) => a == b,
    };
    if !kinds_match {
        return Err(err(&format!(
            "{} is {} but declared {}",
            def.name,
            def.kind.token(),
            decl.kind.token()
        )));
    }
    if matches!(decl.kind, ParamKind::Int | ParamKind::Float)
        && (decl.lo < def.lo || decl.hi > def.hi)
    {
        return Err(err(&format!(
            "range [{}, {}] outside parameter bounds [{}, {}]",
            decl.lo, decl.hi, def.lo, def.hi
        )));
    }
    Ok(())
}

/// Typo guard: does `name` look like a misspelling of a builtin
/// property? Candidate spellings per builtin are its full name and every
/// dotted suffix distinctive enough to be a plausible shorthand (two or
/// more segments, or a single segment of >= 6 chars like `reduces` —
/// short fragments like `mb` would false-positive on every new knob).
/// Distance 0 cannot reach this check: an exact full name or suffix is
/// resolved (or rejected as ambiguous) by declaration canonicalization.
/// Returns (matched spelling, builtin full name) for the closest hit
/// within distance 2.
fn likely_builtin_typo(name: &str, builtin: &ParamRegistry) -> Option<(String, String)> {
    let mut best: Option<(usize, String, String)> = None;
    for def in builtin.defs() {
        let full = def.name.as_str();
        let mut consider = |spelling: &str| {
            if spelling.len().abs_diff(name.len()) > 2 {
                return; // distance is at least the length gap
            }
            let d = edit_distance(name, spelling);
            if (1..=2).contains(&d) && best.as_ref().map(|(b, _, _)| d < *b).unwrap_or(true) {
                best = Some((d, spelling.to_string(), full.to_string()));
            }
        };
        consider(full);
        let mut rest = full;
        while let Some(dot) = rest.find('.') {
            rest = &rest[dot + 1..];
            if rest.contains('.') || rest.len() >= 6 {
                consider(rest);
            }
        }
    }
    best.map(|(_, spelling, full)| (spelling, full))
}

/// Levenshtein distance over bytes (property names are ASCII).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Cycle check over the lhs→rhs dependency edges of scaled constraints:
/// repeatedly trim edges whose target has no outgoing edge (such edges
/// cannot be on a cycle); anything left implies a cycle. Also used by
/// `config::scope` on the union of per-workload constraint sets, where
/// individually-acyclic scopes can combine into a cross-scope cycle.
pub(crate) fn has_constraint_cycle(constraints: &[Constraint]) -> bool {
    let mut edges: Vec<(usize, usize)> = constraints
        .iter()
        .filter_map(|c| match c.bound {
            Bound::Scaled { index, .. } => Some((c.lhs, index)),
            Bound::Const(_) => None,
        })
        .collect();
    loop {
        let sources: std::collections::BTreeSet<usize> =
            edges.iter().map(|&(a, _)| a).collect();
        let before = edges.len();
        edges.retain(|&(_, b)| sources.contains(&b));
        if edges.is_empty() {
            return false;
        }
        if edges.len() == before {
            return true;
        }
    }
}

fn parse_constraint_line(
    no: usize,
    toks: &[&str],
    registry: &ParamRegistry,
) -> Result<Constraint, String> {
    let err = |m: &str| format!("params.spec line {no}: {m}");
    if toks.len() != 4 || toks[2] != "<=" {
        return Err(err("expected: constraint <param> <= [<coef>*]<param-or-const>"));
    }
    let (lhs, _) = registry.resolve(toks[1]).map_err(|e| err(&e))?;
    let rhs = toks[3];
    let bound = if let Ok(c) = rhs.parse::<f64>() {
        Bound::Const(c)
    } else if let Some((coef, name)) = rhs.split_once('*') {
        let coef: f64 = coef.parse().map_err(|_| err("bad coefficient"))?;
        let (index, _) = registry.resolve(name).map_err(|e| err(&e))?;
        Bound::Scaled { coef, index }
    } else {
        let (index, _) = registry.resolve(rhs).map_err(|e| err(&e))?;
        Bound::Scaled { coef: 1.0, index }
    };
    if matches!(bound, Bound::Scaled { index, .. } if index == lhs) {
        return Err(err("constraint references the same parameter on both sides"));
    }
    Ok(Constraint { lhs, bound })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let spec = TuningSpec::fig2();
        let back = TuningSpec::parse(&spec.to_string()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn rich_spec_roundtrip_exact() {
        let text = "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
                    param mapreduce.task.io.sort.mb int 64 1024 step 64\n\
                    param mapreduce.map.memory.mb int 512 4096 log\n\
                    param mapreduce.map.output.compress bool\n\
                    param mapreduce.map.sort.spill.percent float 0.5 0.9\n\
                    constraint io.sort.mb <= 0.7*map.memory.mb\n";
        let spec = TuningSpec::parse(text).unwrap();
        let printed = spec.to_string();
        let back = TuningSpec::parse(&printed).unwrap();
        assert_eq!(back, spec);
        // and printing is a fixed point
        assert_eq!(back.to_string(), printed);
    }

    #[test]
    fn fig2_grid_matches_paper_shape() {
        let spec = TuningSpec::fig2();
        assert_eq!(spec.dims(), 2);
        let g0 = spec.ranges[0].grid();
        let g1 = spec.ranges[1].grid();
        assert_eq!(g0, (1..=16).map(|i| (i * 2) as f64).collect::<Vec<_>>());
        assert_eq!(g1.len(), 16); // 50..800 step 50
        assert_eq!(spec.grid_size(), 256);
    }

    #[test]
    fn grid_includes_hi_exactly_without_drift() {
        // 0.1 steps accumulate error under `v += step`; index stepping
        // must land on 0.9 exactly
        let spec =
            TuningSpec::parse("param mapreduce.map.sort.spill.percent float 0.5 0.9 step 0.1\n")
                .unwrap();
        let g = spec.ranges[0].grid();
        assert_eq!(g.len(), 5);
        assert_eq!(*g.last().unwrap(), 0.9);
        assert_eq!(g[0], 0.5);
    }

    #[test]
    fn declares_new_params_into_the_registry() {
        let spec = TuningSpec::parse(
            "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
             param x.shuffle.buffer.kb int 32 4096 log\n",
        )
        .unwrap();
        assert_eq!(spec.registry.len(), crate::config::space::N_AOT_PARAMS + 2);
        assert_eq!(spec.ranges[0].grid(), vec![0.0, 1.0, 2.0]);
        assert_eq!(spec.ranges[1].transform, Transform::Log);
        // builtin prefix untouched
        assert_eq!(spec.registry.get(0).name, "mapreduce.job.reduces");
    }

    #[test]
    fn constraint_lines_parse_with_suffix_names() {
        let spec = TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024\n\
             constraint io.sort.mb <= 0.7*map.memory.mb\n\
             constraint reduces <= 48\n",
        )
        .unwrap();
        assert_eq!(spec.constraints.len(), 2);
        assert_eq!(spec.constraints[0].lhs, 1);
        assert_eq!(
            spec.constraints[0].bound,
            Bound::Scaled { coef: 0.7, index: 6 }
        );
        assert_eq!(spec.constraints[1].bound, Bound::Const(48.0));
    }

    #[test]
    fn suffix_declaration_refers_to_the_builtin_param() {
        // `param io.sort.mb ...` must canonicalize to the builtin, not
        // declare a new no-op dimension
        let spec = TuningSpec::parse("param io.sort.mb int 64 1024\n").unwrap();
        assert_eq!(spec.registry.len(), crate::config::space::N_AOT_PARAMS);
        assert_eq!(spec.ranges[0].index, 1);
        assert_eq!(spec.ranges[0].name(), "mapreduce.task.io.sort.mb");
        // and kind/bounds checks still apply through the suffix
        assert!(TuningSpec::parse("param io.sort.mb float 64 1024\n").is_err());
    }

    #[test]
    fn suffix_redeclaration_of_an_extra_is_a_duplicate() {
        // `buffer.kb` is a dotted suffix of the extra declared above it:
        // it must canonicalize to the same param and be rejected as a
        // duplicate, not silently become a second no-op dimension
        assert!(TuningSpec::parse(
            "param x.shuffle.buffer.kb int 32 4096\nparam buffer.kb int 32 4096\n"
        )
        .is_err());
        // ...and in the reversed order too (order-independent guard)
        assert!(TuningSpec::parse(
            "param buffer.kb int 32 4096\nparam x.shuffle.buffer.kb int 32 4096\n"
        )
        .is_err());
    }

    #[test]
    fn warns_on_probable_typo_of_builtin() {
        // the ROADMAP example: `memory.mbb` is a NON-suffix typo of
        // `memory.mb` — it parses (new knobs are the feature) but must
        // carry a warning naming the builtin it probably meant
        let spec = TuningSpec::parse("param memory.mbb int 512 4096\n").unwrap();
        assert_eq!(spec.warnings.len(), 1, "{:?}", spec.warnings);
        assert!(spec.warnings[0].contains("\"memory.mbb\""), "{}", spec.warnings[0]);
        assert!(
            spec.warnings[0].contains("mapreduce.map.memory.mb"),
            "{}",
            spec.warnings[0]
        );
        // the dimension still exists — warned, not rejected
        assert_eq!(spec.dims(), 1);

        // a full-name typo (transposition) warns too
        let spec = TuningSpec::parse("param mapreduce.job.reducse int 1 64\n").unwrap();
        assert_eq!(spec.warnings.len(), 1, "{:?}", spec.warnings);
        assert!(
            spec.warnings[0].contains("mapreduce.job.reduces"),
            "{}",
            spec.warnings[0]
        );
    }

    #[test]
    fn intentional_new_knobs_stay_silent() {
        // genuinely-new parameters and builtin declarations must NOT
        // trip the typo guard
        let spec = TuningSpec::parse(
            "param x.shuffle.buffer.kb int 32 4096 log\n\
             param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
             param y.other.knob float 0.1 0.9\n",
        )
        .unwrap();
        assert!(spec.warnings.is_empty(), "{:?}", spec.warnings);
        assert!(TuningSpec::fig3().warnings.is_empty());
        // warnings are recomputed on a print→parse roundtrip (the line
        // number shifts past the printed header, so equality ignores
        // warnings — but the guard itself must re-fire)
        let typo = TuningSpec::parse("param memory.mbb int 512 4096\n").unwrap();
        let back = TuningSpec::parse(&typo.to_string()).unwrap();
        assert_eq!(back, typo);
        assert_eq!(back.warnings.len(), 1, "{:?}", back.warnings);
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("memory.mb", "memory.mb"), 0);
        assert_eq!(edit_distance("memory.mbb", "memory.mb"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("ab", ""), 2);
    }

    #[test]
    fn rejects_self_referential_constraint() {
        assert!(TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024\n\
             constraint io.sort.mb <= 0.5*io.sort.mb\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_ambiguous_suffix_declaration() {
        // `memory.mb` suffixes both map.memory.mb and reduce.memory.mb:
        // must error, not silently declare a new no-op dimension
        let err = TuningSpec::parse("param memory.mb int 512 4096\n").unwrap_err();
        assert!(err.contains("ambiguous"), "{err}");
    }

    #[test]
    fn rejects_statically_unsatisfiable_constraint() {
        // bound below the lhs param's lower bound can never hold
        let err = TuningSpec::parse(
            "param mapreduce.job.reduces int 1 64\nconstraint reduces <= 0.5\n",
        )
        .unwrap_err();
        assert!(err.contains("never be satisfied"), "{err}");
    }

    #[test]
    fn rejects_constraint_that_repair_cannot_always_satisfy() {
        // map.memory.mb can sit at its def lo 512, making the bound
        // 25.6 — below x.knob's lower bound 100, so repair would fail
        // silently at decode time
        let err = TuningSpec::parse(
            "param x.knob int 100 200\n\
             constraint x.knob <= 0.05*map.memory.mb\n",
        )
        .unwrap_err();
        assert!(err.contains("cannot always be repaired"), "{err}");
        // but a tuned rhs that repair can never lower uses its range lo:
        // 0.05 * 2048 = 102.4 >= 100, so this spec is always satisfiable
        TuningSpec::parse(
            "param x.knob int 100 200\n\
             param mapreduce.map.memory.mb int 2048 4096\n\
             constraint x.knob <= 0.05*map.memory.mb\n",
        )
        .unwrap();
    }

    #[test]
    fn rejects_non_integral_bounds_on_int_declarations() {
        // a new int param with fractional bounds would make even its
        // default config fail validate()
        assert!(TuningSpec::parse("param x.foo int 1.2 3.8\n").is_err());
    }

    #[test]
    fn rejects_cyclic_constraints() {
        let err = TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024\n\
             constraint io.sort.mb <= 0.5*map.memory.mb\n\
             constraint map.memory.mb <= 0.5*io.sort.mb\n",
        )
        .unwrap_err();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn log_range_grids_geometrically_by_default() {
        let spec = TuningSpec::parse("param mapreduce.task.io.sort.mb int 16 2048 log\n").unwrap();
        let g = spec.ranges[0].grid();
        assert_eq!(*g.first().unwrap(), 16.0);
        assert_eq!(*g.last().unwrap(), 2048.0);
        // geometric: the midpoint is sqrt(16*2048) ≈ 181, not 1032
        let mid = g[g.len() / 2];
        assert!((150.0..250.0).contains(&mid), "grid not geometric: {g:?}");
        // an explicit step keeps value-space (linear) stepping
        let lin =
            TuningSpec::parse("param mapreduce.task.io.sort.mb int 16 2048 step 254 log\n")
                .unwrap();
        assert_eq!(lin.ranges[0].grid()[1], 270.0);
    }

    #[test]
    fn rejects_unknown_constraint_param() {
        assert!(TuningSpec::parse(
            "param mapreduce.job.reduces int 1 64\nconstraint nope <= 3\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_out_of_bounds_range() {
        assert!(TuningSpec::parse("param mapreduce.job.reduces int 0 200\n").is_err());
    }

    #[test]
    fn rejects_kind_mismatch() {
        assert!(TuningSpec::parse("param mapreduce.job.reduces float 1 8\n").is_err());
        assert!(TuningSpec::parse("param mapreduce.job.reduces bool\n").is_err());
    }

    #[test]
    fn rejects_duplicate_declaration() {
        assert!(TuningSpec::parse(
            "param mapreduce.job.reduces int 1 64\nparam mapreduce.job.reduces int 2 32\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(TuningSpec::parse("# nothing\n").is_err());
    }

    #[test]
    fn rejects_log_with_nonpositive_lo() {
        assert!(TuningSpec::parse("param x.scale float 0 1 log\n").is_err());
    }

    #[test]
    fn default_grid_without_step() {
        let spec = TuningSpec::parse("param mapreduce.job.reduces int 1 64\n").unwrap();
        let g = spec.ranges[0].grid();
        assert!(g.len() >= 8);
        assert_eq!(g[0], 1.0);
    }
}

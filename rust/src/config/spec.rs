//! Tuning parameter-specification files.
//!
//! The Optimizer Runner "creates a series of MapReduce jobs with different
//! combinations of parameter values according to parameter configuration
//! files" (paper §II.A). A spec file (`params.spec` in a tuning project)
//! declares which Hadoop parameters to tune and over what ranges:
//!
//! ```text
//! # name                          kind   lo    hi    [step]
//! param mapreduce.job.reduces     int    2     32    step 2
//! param mapreduce.task.io.sort.mb int    50    800   step 50
//! param mapreduce.map.sort.spill.percent float 0.5 0.9
//! ```

use crate::config::params::{by_name, ParamMeta};
use std::path::Path;

/// One tunable dimension of a tuning project.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamRange {
    pub meta: &'static ParamMeta,
    pub lo: f64,
    pub hi: f64,
    /// Grid step for direct search; DFO treats the range continuously.
    pub step: Option<f64>,
}

impl ParamRange {
    /// Grid values for exhaustive search (inclusive of hi when it lands
    /// on the grid).
    pub fn grid(&self) -> Vec<f64> {
        let step = self.step.unwrap_or_else(|| {
            if self.meta.integer {
                1.0f64.max(((self.hi - self.lo) / 8.0).round())
            } else {
                (self.hi - self.lo) / 8.0
            }
        });
        let mut vals = Vec::new();
        let mut v = self.lo;
        while v <= self.hi + 1e-9 {
            vals.push(if self.meta.integer { v.round() } else { v });
            v += step;
        }
        vals
    }
}

/// The tunable subspace for one tuning project.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuningSpec {
    pub ranges: Vec<ParamRange>,
}

impl TuningSpec {
    pub fn dims(&self) -> usize {
        self.ranges.len()
    }

    /// Total number of grid points for exhaustive search.
    pub fn grid_size(&self) -> usize {
        self.ranges.iter().map(|r| r.grid().len()).product()
    }

    pub fn parse(text: &str) -> Result<TuningSpec, String> {
        let mut ranges = Vec::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let err = |m: &str| format!("params.spec line {}: {m}", no + 1);
            if toks[0] != "param" {
                return Err(err("expected line to start with 'param'"));
            }
            if toks.len() < 5 {
                return Err(err("expected: param <name> <int|float> <lo> <hi> [step <s>]"));
            }
            let meta = by_name(toks[1]).ok_or_else(|| err(&format!("unknown parameter {:?}", toks[1])))?;
            let declared_int = match toks[2] {
                "int" => true,
                "float" => false,
                k => return Err(err(&format!("kind must be int|float, got {k:?}"))),
            };
            if declared_int != meta.integer {
                return Err(err(&format!(
                    "{} is {} but declared {}",
                    meta.name,
                    if meta.integer { "int" } else { "float" },
                    toks[2]
                )));
            }
            let lo: f64 = toks[3].parse().map_err(|_| err("bad lo"))?;
            let hi: f64 = toks[4].parse().map_err(|_| err("bad hi"))?;
            if lo >= hi {
                return Err(err("lo must be < hi"));
            }
            if lo < meta.lo || hi > meta.hi {
                return Err(err(&format!(
                    "range [{lo}, {hi}] outside parameter bounds [{}, {}]",
                    meta.lo, meta.hi
                )));
            }
            let step = match toks.get(5) {
                None => None,
                Some(&"step") => Some(
                    toks.get(6)
                        .ok_or_else(|| err("step needs a value"))?
                        .parse::<f64>()
                        .map_err(|_| err("bad step"))?,
                ),
                Some(t) => return Err(err(&format!("unexpected token {t:?}"))),
            };
            if let Some(s) = step {
                if s <= 0.0 {
                    return Err(err("step must be positive"));
                }
            }
            ranges.push(ParamRange { meta, lo, hi, step });
        }
        if ranges.is_empty() {
            return Err("params.spec declares no parameters".into());
        }
        Ok(TuningSpec { ranges })
    }

    pub fn load(path: &Path) -> Result<TuningSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::from("# Catla tuning parameter specification\n");
        for r in &self.ranges {
            let kind = if r.meta.integer { "int" } else { "float" };
            out.push_str(&format!("param {} {kind} {} {}", r.meta.name, r.lo, r.hi));
            if let Some(s) = r.step {
                out.push_str(&format!(" step {s}"));
            }
            out.push('\n');
        }
        out
    }

    /// The paper's Fig.2 two-parameter spec.
    pub fn fig2() -> TuningSpec {
        Self::parse(
            "param mapreduce.job.reduces int 2 32 step 2\n\
             param mapreduce.task.io.sort.mb int 50 800 step 50\n",
        )
        .unwrap()
    }

    /// The four-parameter spec used in the Fig.3 BOBYQA demo.
    pub fn fig3() -> TuningSpec {
        Self::parse(
            "param mapreduce.job.reduces int 1 64\n\
             param mapreduce.task.io.sort.mb int 16 2048\n\
             param mapreduce.task.io.sort.factor int 2 128\n\
             param mapreduce.reduce.shuffle.parallelcopies int 1 64\n",
        )
        .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let spec = TuningSpec::fig2();
        let back = TuningSpec::parse(&spec.to_string()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn fig2_grid_matches_paper_shape() {
        let spec = TuningSpec::fig2();
        assert_eq!(spec.dims(), 2);
        let g0 = spec.ranges[0].grid();
        let g1 = spec.ranges[1].grid();
        assert_eq!(g0, (1..=16).map(|i| (i * 2) as f64).collect::<Vec<_>>());
        assert_eq!(g1.len(), 16); // 50..800 step 50
        assert_eq!(spec.grid_size(), 256);
    }

    #[test]
    fn rejects_unknown_param() {
        assert!(TuningSpec::parse("param not.a.param int 1 2\n").is_err());
    }

    #[test]
    fn rejects_out_of_bounds_range() {
        assert!(TuningSpec::parse("param mapreduce.job.reduces int 0 200\n").is_err());
    }

    #[test]
    fn rejects_kind_mismatch() {
        assert!(TuningSpec::parse("param mapreduce.job.reduces float 1 8\n").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(TuningSpec::parse("# nothing\n").is_err());
    }

    #[test]
    fn default_grid_without_step() {
        let spec = TuningSpec::parse("param mapreduce.job.reduces int 1 64\n").unwrap();
        let g = spec.ranges[0].grid();
        assert!(g.len() >= 8);
        assert_eq!(g[0], 1.0);
    }
}

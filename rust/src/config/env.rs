//! `HadoopEnv.txt` — the per-project cluster connection + environment file
//! from the paper's Step 2 ("Change the master host's information defined
//! in 'HadoopEnv.txt' ... according to the users' actual Hadoop cluster").
//!
//! Plain `key=value` lines, `#` comments. Against a real cluster these feed
//! the SSH client; against the simulated cluster the `sim.*` keys describe
//! the cluster to synthesize.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct HadoopEnv {
    pub entries: BTreeMap<String, String>,
}

impl Default for HadoopEnv {
    fn default() -> Self {
        let mut entries = BTreeMap::new();
        for (k, v) in [
            ("master.host", "namenode.example.com"),
            ("master.port", "22"),
            ("master.user", "hadoop"),
            ("hadoop.home", "/opt/hadoop-2.7.2"),
            ("hdfs.workdir", "/user/hadoop/catla"),
            ("yarn.log.aggregation", "true"),
            // simulated-cluster description (see DESIGN.md substitution table)
            ("sim.nodes", "16"),
            ("sim.racks", "2"),
            ("sim.mem.per.node.mb", "8192"),
            ("sim.vcores.per.node", "8"),
            ("sim.disk.mbps", "120"),
            ("sim.net.mbps", "110"),
            ("sim.noise.sigma", "0.12"),
            ("sim.straggler.prob", "0.02"),
            ("sim.failure.prob", "0.002"),
            ("sim.seed", "42"),
        ] {
            entries.insert(k.to_string(), v.to_string());
        }
        Self { entries }
    }
}

impl HadoopEnv {
    pub fn parse(text: &str) -> Result<HadoopEnv, String> {
        let mut entries = BTreeMap::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("HadoopEnv.txt line {}: expected key=value", no + 1))?;
            entries.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(HadoopEnv { entries })
    }

    pub fn load(path: &Path) -> Result<HadoopEnv, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        crate::util::durable::atomic_write(path, self.to_string().as_bytes())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.entries.insert(key.to_string(), value.to_string());
    }
}

/// Prints exactly what [`HadoopEnv::parse`] accepts — parse → print →
/// parse round-trips.
impl fmt::Display for HadoopEnv {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "# Catla cluster environment")?;
        for (k, v) in &self.entries {
            writeln!(f, "{k}={v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let env = HadoopEnv::default();
        let back = HadoopEnv::parse(&env.to_string()).unwrap();
        assert_eq!(back, env);
    }

    #[test]
    fn parse_with_comments_and_blank_lines() {
        let e = HadoopEnv::parse("# hi\n\nmaster.host = node1 \n sim.nodes=4\n").unwrap();
        assert_eq!(e.get("master.host"), Some("node1"));
        assert_eq!(e.get_u64("sim.nodes", 0), 4);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(HadoopEnv::parse("no-equals-sign").is_err());
    }

    #[test]
    fn typed_getters_fall_back() {
        let e = HadoopEnv::parse("a=xyz\n").unwrap();
        assert_eq!(e.get_f64("a", 1.5), 1.5);
        assert_eq!(e.get_f64("missing", 2.5), 2.5);
    }
}

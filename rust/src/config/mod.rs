//! Configuration layer: Hadoop parameter metadata, the `HadoopEnv.txt`
//! project environment file, and tuning parameter-spec files.

pub mod env;
pub mod params;
pub mod spec;

pub use env::HadoopEnv;
pub use params::{HadoopConfig, ParamMeta, N_PARAMS, PARAMS};
pub use spec::{ParamRange, TuningSpec};

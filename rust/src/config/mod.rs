//! Configuration layer: the typed parameter-space core (`space`), Hadoop
//! configuration values over it (`params`), the `HadoopEnv.txt` project
//! environment file, and tuning parameter-spec files.

pub mod env;
pub mod params;
pub mod space;
pub mod spec;

pub use env::HadoopEnv;
pub use params::{HadoopConfig, N_AOT_PARAMS};
pub use space::{Bound, Constraint, ParamDef, ParamKind, ParamRegistry, Transform};
pub use spec::{ParamRange, TuningSpec};

//! Configuration layer: the typed parameter-space core (`space`), Hadoop
//! configuration values over it (`params`), the `HadoopEnv.txt` project
//! environment file, tuning parameter-spec files (`spec`), and scoped
//! per-workload spaces merged through one typed layer (`scope`).

pub mod env;
pub mod params;
pub mod scope;
pub mod space;
pub mod spec;

pub use env::HadoopEnv;
pub use params::{HadoopConfig, N_AOT_PARAMS};
pub use scope::{DimRoute, MergedSpace, ScopedSpec, WorkloadScope};
pub use space::{Bound, Constraint, ParamDef, ParamKind, ParamRegistry, Transform};
pub use spec::{ParamRange, TuningSpec};

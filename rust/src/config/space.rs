//! The typed parameter-space core: what a tunable Hadoop parameter *is*.
//!
//! BestConfig-style tuners work on heterogeneous spaces — integer counts,
//! continuous fractions, booleans, categorical choices (codec, scheduler)
//! — and DFO methods want all of them behind one normalized unit-cube
//! contract. This module owns the typed side of that contract:
//!
//! * [`ParamDef`] — one tunable parameter: [`ParamKind`] (int / float /
//!   bool / categorical), inclusive value bounds, Hadoop default, and the
//!   [`Transform`] (linear or log) its ranges default to.
//! * [`ParamRegistry`] — the ordered parameter table. The first
//!   [`N_AOT_PARAMS`] entries are the **stable AOT-artifact prefix**
//!   mirrored by `python/compile/spec.py` (never reorder or renumber
//!   them: the compiled cost-model artifacts consume config rows in
//!   exactly this layout). New parameters declared in `params.spec`
//!   files are appended after the prefix without touching rust code.
//! * [`Constraint`] — a validity predicate `value[lhs] <= bound`
//!   (`constraint io.sort.mb <= 0.7*map.memory.mb`), repaired at decode
//!   so optimizers only ever see valid configurations.
//!
//! `optim::space::ParamSpace` builds on these to provide the *only*
//! unit-cube ⇄ `HadoopConfig` path in the system.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

/// Width of the AOT cost-model feature row: the builtin-prefix length.
/// Keep in sync with `N_PARAMS` in `python/compile/spec.py`.
pub const N_AOT_PARAMS: usize = 10;

/// Value type of one tunable parameter.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamKind {
    /// Integer-valued; snapped by rounding.
    Int,
    /// Continuous.
    Float,
    /// 0/1 valued; rendered as `false`/`true` in Hadoop `-D` args.
    Bool,
    /// One of a fixed set of choices; the config vector stores the
    /// 0-based category index.
    Categorical(Vec<String>),
}

impl ParamKind {
    /// Discrete kinds are snapped to whole numbers at decode.
    pub fn is_discrete(&self) -> bool {
        !matches!(self, ParamKind::Float)
    }

    /// Spec-file keyword for this kind.
    pub fn token(&self) -> &'static str {
        match self {
            ParamKind::Int => "int",
            ParamKind::Float => "float",
            ParamKind::Bool => "bool",
            ParamKind::Categorical(_) => "cat",
        }
    }
}

/// Scale on which a range is traversed in unit space. Log-scaled ranges
/// spend equal unit-cube distance per multiplicative step — the right
/// geometry for memory sizes spanning orders of magnitude.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transform {
    Linear,
    Log,
}

impl Transform {
    /// Map a unit coordinate onto `[lo, hi]`.
    pub fn from_unit(self, u: f64, lo: f64, hi: f64) -> f64 {
        match self {
            Transform::Linear => lo + u * (hi - lo),
            Transform::Log => (lo.ln() + u * (hi.ln() - lo.ln())).exp(),
        }
    }

    /// Map a value in `[lo, hi]` back to a unit coordinate (clamped).
    pub fn to_unit(self, v: f64, lo: f64, hi: f64) -> f64 {
        let u = match self {
            Transform::Linear => (v - lo) / (hi - lo),
            Transform::Log => (v.ln() - lo.ln()) / (hi.ln() - lo.ln()),
        };
        u.clamp(0.0, 1.0)
    }
}

/// Static description of one tunable Hadoop parameter.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamDef {
    /// Full Hadoop property name, e.g. `mapreduce.task.io.sort.mb`.
    pub name: String,
    pub kind: ParamKind,
    /// Inclusive bounds in value space (categorical: `0 ..= n-1`).
    pub lo: f64,
    pub hi: f64,
    /// Hadoop 2.7.2 default value (categorical: default index).
    pub default: f64,
    /// Scale hint: ranges over this parameter default to this transform.
    pub transform: Transform,
}

impl ParamDef {
    pub fn int(name: &str, lo: f64, hi: f64, default: f64) -> ParamDef {
        ParamDef {
            name: name.to_string(),
            kind: ParamKind::Int,
            lo,
            hi,
            default,
            transform: Transform::Linear,
        }
    }

    pub fn float(name: &str, lo: f64, hi: f64, default: f64) -> ParamDef {
        ParamDef {
            name: name.to_string(),
            kind: ParamKind::Float,
            lo,
            hi,
            default,
            transform: Transform::Linear,
        }
    }

    pub fn bool(name: &str, default: bool) -> ParamDef {
        ParamDef {
            name: name.to_string(),
            kind: ParamKind::Bool,
            lo: 0.0,
            hi: 1.0,
            default: if default { 1.0 } else { 0.0 },
            transform: Transform::Linear,
        }
    }

    pub fn cat(name: &str, categories: &[&str], default: &str) -> ParamDef {
        let cats: Vec<String> = categories.iter().map(|c| c.to_string()).collect();
        // an unknown default label yields -1, which bounds-validation
        // rejects at registry construction instead of silently using
        // the first category
        let default_idx = cats
            .iter()
            .position(|c| c == default)
            .map(|i| i as f64)
            .unwrap_or(-1.0);
        let hi = (cats.len().max(1) - 1) as f64;
        ParamDef {
            name: name.to_string(),
            kind: ParamKind::Categorical(cats),
            lo: 0.0,
            hi,
            default: default_idx,
            transform: Transform::Linear,
        }
    }

    /// Builder: switch the default transform to log scale.
    pub fn log(mut self) -> ParamDef {
        self.transform = Transform::Log;
        self
    }

    /// Clamp to bounds and snap discrete kinds to whole numbers.
    /// Idempotent: `snap(snap(v)) == snap(v)`.
    pub fn snap(&self, v: f64) -> f64 {
        let v = v.clamp(self.lo, self.hi);
        if self.kind.is_discrete() {
            v.round()
        } else {
            v
        }
    }

    /// Largest valid value not exceeding `v` (used by constraint repair,
    /// where rounding *up* could re-violate the bound).
    pub fn snap_down(&self, v: f64) -> f64 {
        let v = v.clamp(self.lo, self.hi);
        if self.kind.is_discrete() {
            v.floor().max(self.lo)
        } else {
            v
        }
    }

    /// Categories of a categorical parameter.
    pub fn categories(&self) -> Option<&[String]> {
        match &self.kind {
            ParamKind::Categorical(c) => Some(c),
            _ => None,
        }
    }

    /// Category label for a stored value (categorical params only).
    /// Out-of-range values yield `None` — never a plausible wrong label.
    pub fn category_name(&self, v: f64) -> Option<&str> {
        let cats = self.categories()?;
        let i = v.round();
        if i < 0.0 || i >= cats.len() as f64 {
            return None;
        }
        cats.get(i as usize).map(|s| s.as_str())
    }

    /// Index of a category label (categorical params only).
    pub fn category_index(&self, label: &str) -> Option<usize> {
        self.categories()?.iter().position(|c| c == label)
    }

    /// Parse the `-D`-argument payload form back into a stored value —
    /// the inverse of [`ParamDef::format_value`], so everything the
    /// system prints can be fed back in (`true`/`false` for bools,
    /// labels for categoricals, numbers otherwise).
    pub fn parse_value(&self, s: &str) -> Result<f64, String> {
        match &self.kind {
            ParamKind::Bool => match s {
                "true" => Ok(1.0),
                "false" => Ok(0.0),
                other => other
                    .parse()
                    .map_err(|_| format!("{}: bad bool value {s:?}", self.name)),
            },
            ParamKind::Categorical(_) => {
                self.category_index(s).map(|i| i as f64).ok_or_else(|| {
                    format!(
                        "{}: unknown category {s:?} (known: {:?})",
                        self.name,
                        self.categories().unwrap_or(&[])
                    )
                })
            }
            _ => s
                .parse()
                .map_err(|_| format!("{}: bad value {s:?}", self.name)),
        }
    }

    /// Render a stored value as the Hadoop `-D` argument payload.
    #[allow(clippy::float_cmp)] // bools are stored as exactly 0.0/1.0 by construction
    pub fn format_value(&self, v: f64) -> String {
        match &self.kind {
            ParamKind::Bool => format!("{}", v != 0.0),
            ParamKind::Categorical(_) => self
                .category_name(v)
                .unwrap_or("<bad-category>")
                .to_string(),
            ParamKind::Int => format!("{}", v as i64),
            ParamKind::Float => format!("{v}"),
        }
    }

    #[allow(clippy::float_cmp)] // fract() != 0.0 is the exact integrality check for discrete params
    fn validate(&self) -> Result<(), String> {
        if let ParamKind::Categorical(cats) = &self.kind {
            if cats.len() < 2 {
                return Err(format!("{}: categorical needs >= 2 categories", self.name));
            }
            let mut uniq = cats.clone();
            uniq.sort();
            uniq.dedup();
            if uniq.len() != cats.len() {
                return Err(format!("{}: duplicate categories", self.name));
            }
        }
        if self.lo >= self.hi {
            return Err(format!("{}: lo {} must be < hi {}", self.name, self.lo, self.hi));
        }
        if self.kind.is_discrete()
            && (self.lo.fract() != 0.0 || self.hi.fract() != 0.0 || self.default.fract() != 0.0)
        {
            return Err(format!(
                "{}: discrete parameter needs integral lo/hi/default (got [{}, {}] default {})",
                self.name, self.lo, self.hi, self.default
            ));
        }
        if self.transform == Transform::Log && self.lo <= 0.0 {
            return Err(format!("{}: log transform needs lo > 0", self.name));
        }
        if !(self.lo..=self.hi).contains(&self.default) {
            return Err(format!(
                "{}: default {} outside [{}, {}]",
                self.name, self.default, self.lo, self.hi
            ));
        }
        Ok(())
    }
}

/// The builtin parameter table, in config-vector order. The first
/// [`N_AOT_PARAMS`] rows are the stable AOT-artifact prefix mirrored by
/// `python/compile/spec.py` — `python/tests/test_spec_sync.py` parses
/// this function's source, so keep one constructor call per line.
pub fn builtin_defs() -> Vec<ParamDef> {
    vec![
        ParamDef::int("mapreduce.job.reduces", 1.0, 64.0, 1.0),
        ParamDef::int("mapreduce.task.io.sort.mb", 16.0, 2048.0, 100.0),
        ParamDef::int("mapreduce.task.io.sort.factor", 2.0, 128.0, 10.0),
        ParamDef::float("mapreduce.map.sort.spill.percent", 0.50, 0.95, 0.80),
        ParamDef::int("mapreduce.reduce.shuffle.parallelcopies", 1.0, 64.0, 5.0),
        ParamDef::float("mapreduce.job.reduce.slowstart.completedmaps", 0.05, 1.0, 0.05),
        ParamDef::int("mapreduce.map.memory.mb", 512.0, 4096.0, 1024.0),
        ParamDef::int("mapreduce.reduce.memory.mb", 512.0, 8192.0, 1024.0),
        ParamDef::bool("mapreduce.map.output.compress", false),
        ParamDef::int("mapreduce.input.fileinputformat.split.mb", 32.0, 512.0, 128.0),
    ]
}

/// Ordered parameter table: the builtin prefix (stable AOT layout) plus
/// any parameters declared in spec files. Shared immutably via `Arc` —
/// every `HadoopConfig` carries the registry its value vector is laid
/// out against.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamRegistry {
    defs: Vec<ParamDef>,
    by_name: BTreeMap<String, usize>,
}

impl ParamRegistry {
    fn from_defs(defs: Vec<ParamDef>) -> Result<ParamRegistry, String> {
        let mut by_name = BTreeMap::new();
        for (i, d) in defs.iter().enumerate() {
            d.validate()?;
            if by_name.insert(d.name.clone(), i).is_some() {
                return Err(format!("duplicate parameter {:?}", d.name));
            }
        }
        Ok(ParamRegistry { defs, by_name })
    }

    /// The builtin 10-parameter table (the stable AOT-artifact prefix).
    pub fn builtin() -> Arc<ParamRegistry> {
        static REG: OnceLock<Arc<ParamRegistry>> = OnceLock::new();
        REG.get_or_init(|| {
            Arc::new(ParamRegistry::from_defs(builtin_defs()).expect("builtin registry valid"))
        })
        .clone()
    }

    /// Builtin prefix plus extra declared parameters (spec files). With
    /// no extras this is the shared builtin instance.
    pub fn with_extras(extras: Vec<ParamDef>) -> Result<Arc<ParamRegistry>, String> {
        if extras.is_empty() {
            return Ok(Self::builtin());
        }
        let mut defs = builtin_defs();
        defs.extend(extras);
        Ok(Arc::new(Self::from_defs(defs)?))
    }

    pub fn len(&self) -> usize {
        self.defs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.defs.is_empty()
    }

    pub fn defs(&self) -> &[ParamDef] {
        &self.defs
    }

    pub fn get(&self, index: usize) -> &ParamDef {
        &self.defs[index]
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn by_name(&self, name: &str) -> Option<(usize, &ParamDef)> {
        self.index_of(name).map(|i| (i, &self.defs[i]))
    }

    /// Resolve a full property name, or an unambiguous dotted suffix
    /// (`io.sort.mb` → `mapreduce.task.io.sort.mb`).
    pub fn resolve(&self, name: &str) -> Result<(usize, &ParamDef), String> {
        if let Some(hit) = self.by_name(name) {
            return Ok(hit);
        }
        let matches: Vec<usize> = self
            .defs
            .iter()
            .enumerate()
            .filter(|(_, d)| is_dotted_suffix(&d.name, name))
            .map(|(i, _)| i)
            .collect();
        match matches[..] {
            [i] => Ok((i, &self.defs[i])),
            [] => Err(format!("unknown parameter {name:?}")),
            _ => Err(format!(
                "ambiguous parameter suffix {name:?} (matches {})",
                matches
                    .iter()
                    .map(|&i| self.defs[i].name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )),
        }
    }
}

/// Is `suffix` a strict dotted suffix of `full` (`io.sort.mb` of
/// `mapreduce.task.io.sort.mb`)? The shared rule behind every
/// short-name resolution (registry lookups, spec canonicalization).
pub fn is_dotted_suffix(full: &str, suffix: &str) -> bool {
    full.len() > suffix.len()
        && full.ends_with(suffix)
        && full.as_bytes()[full.len() - suffix.len() - 1] == b'.'
}

/// Right-hand side of a [`Constraint`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Bound {
    /// `coef * value[index]`.
    Scaled { coef: f64, index: usize },
    /// A plain constant.
    Const(f64),
}

/// A validity predicate `value[lhs] <= bound`, declared by a
/// `constraint <param> <= [<coef>*]<param-or-const>` spec line.
/// Indices are registry indices (the rhs parameter need not be tuned).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Constraint {
    pub lhs: usize,
    pub bound: Bound,
}

/// Slack tolerance when testing constraints (float-noise guard).
const CONSTRAINT_EPS: f64 = 1e-9;

impl Constraint {
    pub fn bound_value(&self, values: &[f64]) -> f64 {
        match self.bound {
            Bound::Scaled { coef, index } => coef * values[index],
            Bound::Const(c) => c,
        }
    }

    pub fn satisfied(&self, values: &[f64]) -> bool {
        values[self.lhs] <= self.bound_value(values) + CONSTRAINT_EPS
    }

    /// Repair in place: pull a violating lhs down to its bound, snapped
    /// *downward* so discrete kinds cannot round back over the bound.
    pub fn repair(&self, values: &mut [f64], defs: &[ParamDef]) {
        let b = self.bound_value(values);
        if values[self.lhs] > b + CONSTRAINT_EPS {
            values[self.lhs] = defs[self.lhs].snap_down(b);
        }
    }

    /// Render as a spec line body using full parameter names.
    #[allow(clippy::float_cmp)] // coef == 1.0 only elides the parsed-back-exactly "1*" prefix
    pub fn display(&self, registry: &ParamRegistry) -> String {
        let lhs = &registry.get(self.lhs).name;
        match self.bound {
            Bound::Scaled { coef, index } if coef == 1.0 => {
                format!("constraint {lhs} <= {}", registry.get(index).name)
            }
            Bound::Scaled { coef, index } => {
                format!("constraint {lhs} <= {coef}*{}", registry.get(index).name)
            }
            Bound::Const(c) => format!("constraint {lhs} <= {c}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_is_shared_and_stable() {
        let a = ParamRegistry::builtin();
        let b = ParamRegistry::builtin();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), N_AOT_PARAMS);
        assert_eq!(a.get(0).name, "mapreduce.job.reduces");
        assert_eq!(a.get(8).kind, ParamKind::Bool);
    }

    #[test]
    fn extras_append_after_the_aot_prefix() {
        let reg = ParamRegistry::with_extras(vec![
            ParamDef::cat("x.codec", &["none", "snappy", "lz4"], "none"),
            ParamDef::int("x.mem.mb", 64.0, 8192.0, 256.0).log(),
        ])
        .unwrap();
        assert_eq!(reg.len(), N_AOT_PARAMS + 2);
        for (i, d) in builtin_defs().iter().enumerate() {
            assert_eq!(&reg.get(i).name, &d.name, "builtin prefix reordered");
        }
        assert_eq!(reg.index_of("x.codec"), Some(N_AOT_PARAMS));
        assert_eq!(reg.get(N_AOT_PARAMS + 1).transform, Transform::Log);
    }

    #[test]
    fn registry_rejects_duplicates_and_bad_defs() {
        assert!(ParamRegistry::with_extras(vec![ParamDef::int(
            "mapreduce.job.reduces",
            1.0,
            2.0,
            1.0
        )])
        .is_err());
        assert!(ParamRegistry::with_extras(vec![ParamDef::int("x", 5.0, 5.0, 5.0)]).is_err());
        assert!(ParamRegistry::with_extras(vec![ParamDef::cat("x", &["only"], "only")]).is_err());
        assert!(
            ParamRegistry::with_extras(vec![ParamDef::float("x", 0.0, 1.0, 0.5).log()]).is_err()
        );
        // a typo'd default label must not silently fall back to index 0
        assert!(ParamRegistry::with_extras(vec![ParamDef::cat(
            "x",
            &["none", "snappy"],
            "snapy"
        )])
        .is_err());
    }

    #[test]
    fn resolve_accepts_unique_dotted_suffixes() {
        let reg = ParamRegistry::builtin();
        let (i, d) = reg.resolve("io.sort.mb").unwrap();
        assert_eq!(i, 1);
        assert_eq!(d.name, "mapreduce.task.io.sort.mb");
        assert_eq!(reg.resolve("map.memory.mb").unwrap().0, 6);
        // "mb" alone matches several params
        assert!(reg.resolve("mb").unwrap_err().contains("ambiguous"));
        assert!(reg.resolve("not.a.param").unwrap_err().contains("unknown"));
        // a suffix must start at a dot boundary
        assert!(reg.resolve("ask.io.sort.mb").is_err());
    }

    #[test]
    fn transforms_are_inverse_pairs() {
        for t in [Transform::Linear, Transform::Log] {
            for u in [0.0, 0.25, 0.5, 1.0] {
                let v = t.from_unit(u, 16.0, 2048.0);
                assert!((t.to_unit(v, 16.0, 2048.0) - u).abs() < 1e-12, "{t:?} u={u}");
            }
        }
        // log hits the geometric midpoint
        let mid = Transform::Log.from_unit(0.5, 16.0, 1024.0);
        assert!((mid - 128.0).abs() < 1e-9, "geometric midpoint {mid}");
    }

    #[test]
    fn snap_and_snap_down() {
        let d = ParamDef::int("x", 2.0, 10.0, 2.0);
        assert_eq!(d.snap(7.6), 8.0);
        assert_eq!(d.snap_down(7.6), 7.0);
        assert_eq!(d.snap(100.0), 10.0);
        assert_eq!(d.snap_down(-5.0), 2.0);
        let f = ParamDef::float("y", 0.0, 1.0, 0.5);
        assert_eq!(f.snap(0.33), 0.33);
    }

    #[test]
    fn constraint_repair_keeps_discrete_under_bound() {
        let reg = ParamRegistry::builtin();
        let c = Constraint {
            lhs: 1, // io.sort.mb
            bound: Bound::Scaled { coef: 0.7, index: 6 }, // 0.7 * map.memory.mb
        };
        let mut values: Vec<f64> = builtin_defs().iter().map(|d| d.default).collect();
        values[1] = 2000.0;
        values[6] = 1024.0;
        assert!(!c.satisfied(&values));
        c.repair(&mut values, reg.defs());
        assert!(c.satisfied(&values));
        assert_eq!(values[1], (0.7f64 * 1024.0).floor());
        // idempotent
        let before = values.clone();
        c.repair(&mut values, reg.defs());
        assert_eq!(values, before);
    }

    #[test]
    fn constraint_display_uses_full_names() {
        let reg = ParamRegistry::builtin();
        let c = Constraint {
            lhs: 1,
            bound: Bound::Scaled { coef: 0.7, index: 6 },
        };
        assert_eq!(
            c.display(&reg),
            "constraint mapreduce.task.io.sort.mb <= 0.7*mapreduce.map.memory.mb"
        );
        let k = Constraint {
            lhs: 0,
            bound: Bound::Const(32.0),
        };
        assert_eq!(k.display(&reg), "constraint mapreduce.job.reduces <= 32");
    }

    #[test]
    fn format_value_by_kind() {
        let b = ParamDef::bool("b", false);
        assert_eq!(b.format_value(1.0), "true");
        assert_eq!(b.format_value(0.0), "false");
        let c = ParamDef::cat("c", &["none", "snappy"], "none");
        assert_eq!(c.format_value(1.0), "snappy");
        // out-of-range categorical values must not render as a plausible
        // wrong label
        assert_eq!(c.category_name(7.0), None);
        assert_eq!(c.category_name(-5.0), None);
        assert_eq!(c.format_value(7.0), "<bad-category>");
        let i = ParamDef::int("i", 0.0, 10.0, 1.0);
        assert_eq!(i.format_value(3.0), "3");
    }

    #[test]
    fn parse_value_inverts_format_value() {
        let defs = [
            (ParamDef::bool("b", true), 0.0),
            (ParamDef::bool("b", true), 1.0),
            (ParamDef::cat("c", &["none", "snappy", "lz4"], "none"), 2.0),
            (ParamDef::int("i", 0.0, 100.0, 1.0), 42.0),
            (ParamDef::float("f", 0.0, 1.0, 0.5), 0.25),
        ];
        for (d, v) in defs {
            let back = d.parse_value(&d.format_value(v)).unwrap();
            assert_eq!(back, v, "{} round-trip", d.name);
        }
        let c = ParamDef::cat("c", &["1", "2", "4"], "1");
        // numeric-looking labels parse as labels, not indices
        assert_eq!(c.parse_value("2").unwrap(), 1.0);
        assert!(c.parse_value("3").is_err());
        assert!(ParamDef::bool("b", false).parse_value("maybe").is_err());
    }
}

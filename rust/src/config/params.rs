//! Hadoop configuration-parameter metadata.
//!
//! This is the rust mirror of `python/compile/spec.py`: the parameter
//! order, bounds and integer-ness MUST stay in sync — the AOT cost-model
//! artifacts consume config vectors laid out exactly like this, and
//! `rust/tests/runtime_integration.rs` cross-checks the two.

/// Indices into a config vector. Keep in sync with python spec.py.
pub const P_REDUCES: usize = 0;
pub const P_IO_SORT_MB: usize = 1;
pub const P_SORT_FACTOR: usize = 2;
pub const P_SPILL_PERCENT: usize = 3;
pub const P_PARALLEL_COPIES: usize = 4;
pub const P_SLOWSTART: usize = 5;
pub const P_MAP_MEM_MB: usize = 6;
pub const P_RED_MEM_MB: usize = 7;
pub const P_COMPRESS: usize = 8;
pub const P_SPLIT_MB: usize = 9;
pub const N_PARAMS: usize = 10;

/// Static description of one tunable Hadoop parameter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParamMeta {
    pub index: usize,
    /// Full Hadoop property name, e.g. `mapreduce.task.io.sort.mb`.
    pub name: &'static str,
    pub lo: f64,
    pub hi: f64,
    /// Integer-valued parameters are rounded before use.
    pub integer: bool,
    /// Hadoop 2.7.2 default value.
    pub default: f64,
}

/// The parameter table, in config-vector order.
pub const PARAMS: [ParamMeta; N_PARAMS] = [
    ParamMeta { index: P_REDUCES, name: "mapreduce.job.reduces", lo: 1.0, hi: 64.0, integer: true, default: 1.0 },
    ParamMeta { index: P_IO_SORT_MB, name: "mapreduce.task.io.sort.mb", lo: 16.0, hi: 2048.0, integer: true, default: 100.0 },
    ParamMeta { index: P_SORT_FACTOR, name: "mapreduce.task.io.sort.factor", lo: 2.0, hi: 128.0, integer: true, default: 10.0 },
    ParamMeta { index: P_SPILL_PERCENT, name: "mapreduce.map.sort.spill.percent", lo: 0.50, hi: 0.95, integer: false, default: 0.80 },
    ParamMeta { index: P_PARALLEL_COPIES, name: "mapreduce.reduce.shuffle.parallelcopies", lo: 1.0, hi: 64.0, integer: true, default: 5.0 },
    ParamMeta { index: P_SLOWSTART, name: "mapreduce.job.reduce.slowstart.completedmaps", lo: 0.05, hi: 1.0, integer: false, default: 0.05 },
    ParamMeta { index: P_MAP_MEM_MB, name: "mapreduce.map.memory.mb", lo: 512.0, hi: 4096.0, integer: true, default: 1024.0 },
    ParamMeta { index: P_RED_MEM_MB, name: "mapreduce.reduce.memory.mb", lo: 512.0, hi: 8192.0, integer: true, default: 1024.0 },
    ParamMeta { index: P_COMPRESS, name: "mapreduce.map.output.compress", lo: 0.0, hi: 1.0, integer: true, default: 0.0 },
    ParamMeta { index: P_SPLIT_MB, name: "mapreduce.input.fileinputformat.split.mb", lo: 32.0, hi: 512.0, integer: true, default: 128.0 },
];

/// Look up a parameter by its Hadoop property name.
pub fn by_name(name: &str) -> Option<&'static ParamMeta> {
    PARAMS.iter().find(|p| p.name == name)
}

/// A concrete Hadoop configuration: one value per tunable parameter.
#[derive(Clone, Debug, PartialEq)]
pub struct HadoopConfig {
    pub values: [f64; N_PARAMS],
}

impl Default for HadoopConfig {
    fn default() -> Self {
        let mut values = [0.0; N_PARAMS];
        for p in PARAMS.iter() {
            values[p.index] = p.default;
        }
        Self { values }
    }
}

impl HadoopConfig {
    pub fn get(&self, index: usize) -> f64 {
        self.values[index]
    }

    /// Set by index, clamping to bounds and rounding integer params.
    pub fn set(&mut self, index: usize, value: f64) -> &mut Self {
        let meta = &PARAMS[index];
        let v = value.clamp(meta.lo, meta.hi);
        self.values[index] = if meta.integer { v.round() } else { v };
        self
    }

    pub fn set_by_name(&mut self, name: &str, value: f64) -> Result<&mut Self, String> {
        let meta = by_name(name).ok_or_else(|| format!("unknown parameter {name:?}"))?;
        Ok(self.set(meta.index, value))
    }

    /// All values within bounds and integer params integral?
    pub fn validate(&self) -> Result<(), String> {
        for p in PARAMS.iter() {
            let v = self.values[p.index];
            if !(p.lo..=p.hi).contains(&v) {
                return Err(format!("{} = {v} outside [{}, {}]", p.name, p.lo, p.hi));
            }
            if p.integer && v.fract() != 0.0 {
                return Err(format!("{} = {v} must be integral", p.name));
            }
        }
        Ok(())
    }

    /// Render as Hadoop `-D key=value` CLI arguments (what a real Catla
    /// passes to `hadoop jar`).
    pub fn to_d_args(&self) -> Vec<String> {
        PARAMS
            .iter()
            .map(|p| {
                let v = self.values[p.index];
                if p.index == P_COMPRESS {
                    format!("-D{}={}", p.name, v != 0.0)
                } else if p.integer {
                    format!("-D{}={}", p.name, v as i64)
                } else {
                    format!("-D{}={v}", p.name)
                }
            })
            .collect()
    }

    /// Render as f32 feature row for the AOT cost model.
    pub fn to_f32_row(&self) -> [f32; N_PARAMS] {
        let mut row = [0f32; N_PARAMS];
        for (i, v) in self.values.iter().enumerate() {
            row[i] = *v as f32;
        }
        row
    }

    /// Compact human-readable summary used in history CSVs.
    pub fn summary(&self) -> String {
        PARAMS
            .iter()
            .map(|p| {
                let short = p.name.rsplit('.').next().unwrap_or(p.name);
                if p.integer {
                    format!("{short}={}", self.values[p.index] as i64)
                } else {
                    format!("{short}={:.2}", self.values[p.index])
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        HadoopConfig::default().validate().unwrap();
    }

    #[test]
    fn set_clamps_and_rounds() {
        let mut c = HadoopConfig::default();
        c.set(P_REDUCES, 1000.0);
        assert_eq!(c.get(P_REDUCES), 64.0);
        c.set(P_IO_SORT_MB, 99.7);
        assert_eq!(c.get(P_IO_SORT_MB), 100.0);
        c.set(P_SPILL_PERCENT, 0.1);
        assert_eq!(c.get(P_SPILL_PERCENT), 0.50);
    }

    #[test]
    fn set_by_name_roundtrip() {
        let mut c = HadoopConfig::default();
        c.set_by_name("mapreduce.job.reduces", 8.0).unwrap();
        assert_eq!(c.get(P_REDUCES), 8.0);
        assert!(c.set_by_name("not.a.param", 1.0).is_err());
    }

    #[test]
    fn d_args_format() {
        let args = HadoopConfig::default().to_d_args();
        assert!(args.contains(&"-Dmapreduce.task.io.sort.mb=100".to_string()));
        assert!(args.contains(&"-Dmapreduce.map.output.compress=false".to_string()));
    }

    #[test]
    fn bounds_match_python_spec() {
        // spot-check the values mirrored from python/compile/spec.py
        assert_eq!(PARAMS[P_REDUCES].lo, 1.0);
        assert_eq!(PARAMS[P_REDUCES].hi, 64.0);
        assert_eq!(PARAMS[P_IO_SORT_MB].lo, 16.0);
        assert_eq!(PARAMS[P_IO_SORT_MB].hi, 2048.0);
        assert_eq!(PARAMS[P_SPLIT_MB].hi, 512.0);
    }

    #[test]
    fn validate_rejects_out_of_bounds() {
        let mut c = HadoopConfig::default();
        c.values[P_REDUCES] = 100.0; // bypass set()
        assert!(c.validate().is_err());
    }
}

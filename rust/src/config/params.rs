//! Hadoop configuration values over a [`ParamRegistry`].
//!
//! [`HadoopConfig`] is a dynamic, registry-owned value vector: one `f64`
//! slot per registered parameter, in registry order. The first
//! [`N_AOT_PARAMS`] slots are the stable AOT-artifact prefix mirrored by
//! `python/compile/spec.py` ([`HadoopConfig::to_f32_row`] exports exactly
//! that prefix; `rust/tests/runtime_integration.rs` and
//! `python/tests/test_spec_sync.py` cross-check the two sides).
//! Parameters declared in `params.spec` beyond the prefix simply extend
//! the vector — no rust change required.

use std::sync::Arc;

pub use crate::config::space::N_AOT_PARAMS;
use crate::config::space::{ParamDef, ParamKind, ParamRegistry};

/// Indices of the builtin parameters (the stable AOT prefix).
/// Keep in sync with python spec.py.
pub const P_REDUCES: usize = 0;
pub const P_IO_SORT_MB: usize = 1;
pub const P_SORT_FACTOR: usize = 2;
pub const P_SPILL_PERCENT: usize = 3;
pub const P_PARALLEL_COPIES: usize = 4;
pub const P_SLOWSTART: usize = 5;
pub const P_MAP_MEM_MB: usize = 6;
pub const P_RED_MEM_MB: usize = 7;
pub const P_COMPRESS: usize = 8;
pub const P_SPLIT_MB: usize = 9;

/// A concrete Hadoop configuration: one value per registered parameter,
/// laid out in the order of the [`ParamRegistry`] it was built against.
#[derive(Clone, Debug)]
pub struct HadoopConfig {
    registry: Arc<ParamRegistry>,
    /// Value vector in registry order (categorical params store the
    /// 0-based category index). Public for tests and hot loops; use
    /// [`HadoopConfig::set`] to keep values snapped and in bounds.
    pub values: Vec<f64>,
}

impl Default for HadoopConfig {
    fn default() -> Self {
        Self::for_registry(ParamRegistry::builtin())
    }
}

impl PartialEq for HadoopConfig {
    fn eq(&self, other: &Self) -> bool {
        self.values == other.values
            && (Arc::ptr_eq(&self.registry, &other.registry) || self.registry == other.registry)
    }
}

impl HadoopConfig {
    /// Defaults for every parameter in `registry`.
    pub fn for_registry(registry: Arc<ParamRegistry>) -> HadoopConfig {
        let values = registry.defs().iter().map(|d| d.default).collect();
        HadoopConfig { registry, values }
    }

    /// The registry this config's value vector is laid out against.
    pub fn registry(&self) -> &Arc<ParamRegistry> {
        &self.registry
    }

    /// Definition of the parameter at `index`.
    pub fn def(&self, index: usize) -> &ParamDef {
        self.registry.get(index)
    }

    /// Number of parameters (value-vector length).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Migrate onto another registry: parameters present in both keep
    /// their (re-snapped) values, new parameters take their defaults.
    /// Categorical values carry over by *label* (the stored index is
    /// registry-specific); a label missing from the target's category
    /// list falls back to the target's default.
    pub fn rebased(&self, registry: &Arc<ParamRegistry>) -> HadoopConfig {
        if Arc::ptr_eq(&self.registry, registry) {
            return self.clone();
        }
        let mut out = HadoopConfig::for_registry(registry.clone());
        for (i, d) in registry.defs().iter().enumerate() {
            if let Some((j, src)) = self.registry.by_name(&d.name) {
                out.values[i] = if matches!(d.kind, ParamKind::Categorical(_)) {
                    src.category_name(self.values[j])
                        .and_then(|label| d.category_index(label))
                        .map(|idx| idx as f64)
                        .unwrap_or(d.default)
                } else {
                    d.snap(self.values[j])
                };
            }
        }
        out
    }

    pub fn get(&self, index: usize) -> f64 {
        self.values[index]
    }

    /// Set by index, clamping to bounds and snapping discrete kinds.
    pub fn set(&mut self, index: usize, value: f64) -> &mut Self {
        self.values[index] = self.registry.get(index).snap(value);
        self
    }

    /// Look up by full property name or unambiguous dotted suffix.
    pub fn get_by_name(&self, name: &str) -> Result<f64, String> {
        let (i, _) = self.registry.resolve(name)?;
        Ok(self.values[i])
    }

    pub fn set_by_name(&mut self, name: &str, value: f64) -> Result<&mut Self, String> {
        let (i, _) = self.registry.resolve(name)?;
        Ok(self.set(i, value))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get_i64(&self, index: usize) -> i64 {
        self.values[index].round() as i64
    }

    #[allow(clippy::float_cmp)] // bools are stored as exactly 0.0/1.0 by construction
    pub fn get_bool(&self, index: usize) -> bool {
        self.values[index] != 0.0
    }

    /// Category label of a categorical parameter.
    pub fn get_category(&self, index: usize) -> Option<&str> {
        self.registry.get(index).category_name(self.values[index])
    }

    /// Set a categorical parameter by label.
    pub fn set_category(&mut self, name: &str, label: &str) -> Result<&mut Self, String> {
        let (i, d) = self.registry.resolve(name)?;
        let idx = d.category_index(label).ok_or_else(|| {
            format!(
                "{}: unknown category {label:?} (known: {:?})",
                d.name,
                d.categories().unwrap_or(&[])
            )
        })?;
        self.values[i] = idx as f64;
        Ok(self)
    }

    // ---- validity / rendering -------------------------------------------

    /// All values within bounds and discrete params integral?
    #[allow(clippy::float_cmp)] // fract() != 0.0 is the exact integrality check for discrete params
    pub fn validate(&self) -> Result<(), String> {
        if self.values.len() != self.registry.len() {
            return Err(format!(
                "config has {} values for {} registered parameters",
                self.values.len(),
                self.registry.len()
            ));
        }
        for (d, &v) in self.registry.defs().iter().zip(&self.values) {
            if !(d.lo..=d.hi).contains(&v) {
                return Err(format!("{} = {v} outside [{}, {}]", d.name, d.lo, d.hi));
            }
            if d.kind.is_discrete() && v.fract() != 0.0 {
                return Err(format!("{} = {v} must be integral", d.name));
            }
        }
        Ok(())
    }

    /// Render as Hadoop `-D key=value` CLI arguments (what a real Catla
    /// passes to `hadoop jar`) — bools as `true`/`false`, categoricals
    /// by label.
    pub fn to_d_args(&self) -> Vec<String> {
        self.registry
            .defs()
            .iter()
            .zip(&self.values)
            .map(|(d, &v)| format!("-D{}={}", d.name, d.format_value(v)))
            .collect()
    }

    /// Render as the f32 feature row the AOT cost model consumes: the
    /// stable builtin prefix, in registry order. Parameters beyond the
    /// prefix are not part of the artifact contract and are excluded.
    pub fn to_f32_row(&self) -> [f32; N_AOT_PARAMS] {
        let mut row = [0f32; N_AOT_PARAMS];
        for (r, v) in row.iter_mut().zip(&self.values) {
            *r = *v as f32;
        }
        row
    }

    /// Compact human-readable summary used in history CSVs and the CLI.
    pub fn summary(&self) -> String {
        self.registry
            .defs()
            .iter()
            .zip(&self.values)
            .map(|(d, &v)| {
                let short = d.name.rsplit('.').next().unwrap_or(&d.name);
                match &d.kind {
                    ParamKind::Float => format!("{short}={v:.2}"),
                    ParamKind::Categorical(_) => {
                        format!("{short}={}", d.category_name(v).unwrap_or("?"))
                    }
                    _ => format!("{short}={}", v as i64),
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::builtin_defs;

    #[test]
    fn defaults_validate() {
        HadoopConfig::default().validate().unwrap();
    }

    #[test]
    fn set_clamps_and_rounds() {
        let mut c = HadoopConfig::default();
        c.set(P_REDUCES, 1000.0);
        assert_eq!(c.get(P_REDUCES), 64.0);
        c.set(P_IO_SORT_MB, 99.7);
        assert_eq!(c.get(P_IO_SORT_MB), 100.0);
        c.set(P_SPILL_PERCENT, 0.1);
        assert_eq!(c.get(P_SPILL_PERCENT), 0.50);
    }

    #[test]
    fn set_by_name_roundtrip() {
        let mut c = HadoopConfig::default();
        c.set_by_name("mapreduce.job.reduces", 8.0).unwrap();
        assert_eq!(c.get(P_REDUCES), 8.0);
        // dotted-suffix resolution works too
        c.set_by_name("io.sort.mb", 256.0).unwrap();
        assert_eq!(c.get(P_IO_SORT_MB), 256.0);
        assert!(c.set_by_name("not.a.param", 1.0).is_err());
    }

    #[test]
    fn d_args_format() {
        let args = HadoopConfig::default().to_d_args();
        assert!(args.contains(&"-Dmapreduce.task.io.sort.mb=100".to_string()));
        assert!(args.contains(&"-Dmapreduce.map.output.compress=false".to_string()));
    }

    #[test]
    fn bounds_match_python_spec() {
        // spot-check the values mirrored from python/compile/spec.py
        let defs = builtin_defs();
        assert_eq!(defs[P_REDUCES].lo, 1.0);
        assert_eq!(defs[P_REDUCES].hi, 64.0);
        assert_eq!(defs[P_IO_SORT_MB].lo, 16.0);
        assert_eq!(defs[P_IO_SORT_MB].hi, 2048.0);
        assert_eq!(defs[P_SPLIT_MB].hi, 512.0);
        assert_eq!(defs.len(), N_AOT_PARAMS);
    }

    #[test]
    fn validate_rejects_out_of_bounds() {
        let mut c = HadoopConfig::default();
        c.values[P_REDUCES] = 100.0; // bypass set()
        assert!(c.validate().is_err());
    }

    #[test]
    fn extended_registry_configs() {
        let reg = ParamRegistry::with_extras(vec![
            ParamDef::cat(
                "mapreduce.map.output.compress.codec",
                &["none", "snappy", "lz4"],
                "none",
            ),
            ParamDef::int("x.shuffle.buffer.kb", 32.0, 4096.0, 128.0).log(),
        ])
        .unwrap();
        let mut c = HadoopConfig::for_registry(reg);
        assert_eq!(c.len(), N_AOT_PARAMS + 2);
        c.validate().unwrap();
        c.set_category("mapreduce.map.output.compress.codec", "snappy")
            .unwrap();
        assert_eq!(c.get_category(N_AOT_PARAMS), Some("snappy"));
        assert!(c.set_category("compress.codec", "gzip").is_err());
        let args = c.to_d_args();
        assert!(args.contains(&"-Dmapreduce.map.output.compress.codec=snappy".to_string()));
        // the AOT row still covers exactly the builtin prefix
        let row = c.to_f32_row();
        assert_eq!(row.len(), N_AOT_PARAMS);
        assert_eq!(row[P_IO_SORT_MB], 100.0);
    }

    #[test]
    fn rebased_keeps_shared_values_and_defaults_new_ones() {
        let mut base = HadoopConfig::default();
        base.set(P_REDUCES, 16.0);
        let reg = ParamRegistry::with_extras(vec![ParamDef::bool("x.jvm.reuse", true)]).unwrap();
        let moved = base.rebased(&reg);
        assert_eq!(moved.get(P_REDUCES), 16.0);
        assert_eq!(moved.get(N_AOT_PARAMS), 1.0); // new param at its default
        moved.validate().unwrap();
        // rebasing onto the same registry is the identity
        assert_eq!(base.rebased(base.registry()), base);
    }

    #[test]
    fn rebased_maps_categoricals_by_label() {
        let a = ParamRegistry::with_extras(vec![ParamDef::cat(
            "x.codec",
            &["none", "snappy", "lz4"],
            "none",
        )])
        .unwrap();
        let b = ParamRegistry::with_extras(vec![ParamDef::cat(
            "x.codec",
            &["lz4", "none"],
            "none",
        )])
        .unwrap();
        let mut cfg = HadoopConfig::for_registry(a);
        cfg.set_category("x.codec", "lz4").unwrap();
        let moved = cfg.rebased(&b);
        // index 2 in A must become index 0 ("lz4") in B, not clamp to 1
        assert_eq!(moved.get_category(N_AOT_PARAMS), Some("lz4"));
        // a label missing from the target falls back to its default
        cfg.set_category("x.codec", "snappy").unwrap();
        assert_eq!(cfg.rebased(&b).get_category(N_AOT_PARAMS), Some("none"));
    }

    #[test]
    fn typed_accessors() {
        let mut c = HadoopConfig::default();
        c.set(P_COMPRESS, 1.0);
        assert!(c.get_bool(P_COMPRESS));
        assert_eq!(c.get_i64(P_REDUCES), 1);
        assert_eq!(c.get_category(P_REDUCES), None); // not categorical
        assert_eq!(c.get_by_name("map.memory.mb").unwrap(), 1024.0);
    }
}

//! Scoped parameter spaces: per-workload tuning specs merged through one
//! typed layer.
//!
//! The paper's Catla workflow tunes *suites* of heterogeneous MapReduce
//! jobs; a shuffle-heavy terasort and a CPU-bound wordcount should not be
//! forced to share identical knobs and bounds. A `params.spec` may now
//! contain `workload <name> { ... }` blocks:
//!
//! ```text
//! # shared (global) block — tuned once, applied to every job
//! param mapreduce.job.reduces int 2 32
//!
//! workload terasort {
//!   param mapreduce.map.output.compress bool
//!   param mapreduce.reduce.shuffle.parallelcopies int 1 64
//! }
//! workload wordcount {
//!   param mapreduce.map.memory.mb int 512 4096 log
//!   param mapreduce.job.reduce.slowstart.completedmaps float 0.05 1.0
//! }
//! ```
//!
//! * [`ScopedSpec`] — parse result: the global (shared) [`TuningSpec`]
//!   plus one effective spec per workload block (global lines with the
//!   block's param lines overriding or extending them). A file with no
//!   blocks is a *flat* spec and behaves bit-identically to the
//!   pre-scoping system everywhere.
//! * [`ScopedSpec::scope`] — the effective flat spec for one workload
//!   (what single-job `tuning`/`resume` runs use).
//! * [`ScopedSpec::merge`] — the typed merge for multi-job/workflow
//!   tuning: ONE [`TuningSpec`] whose ranges are the shared dims plus one
//!   *aliased* dim per (workload, scoped param) (`<param>@<workload>`),
//!   so every ask/tell optimizer sees a single unit cube, unmodified.
//!   Per-workload constraints are remapped onto merged indices (a shared
//!   dim constrained by two workloads must satisfy both). Two blocks
//!   declaring the same NEW parameter with conflicting definitions are a
//!   hard error naming both blocks.
//! * [`MergedSpace::job_config`] — the projection: decode the merged
//!   unit cube once, then route shared dims to every job and scoped dims
//!   to their owner, yielding each job's own `HadoopConfig` (laid out on
//!   that workload's registry — a job's `-D` args never mention another
//!   workload's private knobs).

use std::collections::BTreeSet;
use std::path::Path;

use crate::config::params::HadoopConfig;
use crate::config::space::{Bound, Constraint, ParamRegistry, N_AOT_PARAMS};
use crate::config::spec::{has_constraint_cycle, ParamRange, TuningSpec};

/// One `workload <name> { ... }` block, resolved to its effective spec.
#[derive(Clone, Debug)]
pub struct WorkloadScope {
    /// Workload name the block scopes to (matches `jobs.list` /
    /// `job.properties` workload names; any name is accepted — blocks
    /// for suites a project never runs are simply unused).
    pub workload: String,
    /// The effective flat spec: global lines with this block's param
    /// lines overriding (same canonical name) or extending them, plus
    /// both sections' constraints.
    pub spec: TuningSpec,
    /// Canonical full names of the params this block declares, in block
    /// order — the *scoped* dims; every other range in `spec` is shared
    /// with the global block.
    pub owned: Vec<String>,
}

/// A parsed `params.spec` with optional per-workload blocks.
#[derive(Clone, Debug)]
pub struct ScopedSpec {
    /// The shared (top-level) spec. May have zero ranges when every
    /// tunable lives in a workload block.
    pub global: TuningSpec,
    /// One entry per `workload { ... }` block, in file order.
    pub scopes: Vec<WorkloadScope>,
    /// Aggregated non-fatal diagnostics (the typo guard), deduplicated
    /// across the global section and every block's effective re-parse.
    pub warnings: Vec<String>,
}

impl ScopedSpec {
    /// Wrap a flat spec (no workload blocks). Everything downstream
    /// treats this exactly like the pre-scoping system.
    pub fn flat(spec: TuningSpec) -> ScopedSpec {
        ScopedSpec {
            warnings: spec.warnings.clone(),
            global: spec,
            scopes: Vec::new(),
        }
    }

    /// Does this spec scope anything? Flat specs short-circuit every
    /// merge/projection path to the legacy behavior.
    pub fn is_flat(&self) -> bool {
        self.scopes.is_empty()
    }

    /// The effective flat spec for one workload: its block applied over
    /// the global section, or the global spec when it has no block.
    pub fn scope(&self, workload: &str) -> &TuningSpec {
        self.scopes
            .iter()
            .find(|s| s.workload == workload)
            .map(|s| &s.spec)
            .unwrap_or(&self.global)
    }

    pub fn load(path: &Path) -> Result<ScopedSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse a spec file with optional `workload <name> { ... }` blocks.
    /// Block syntax: the opening line is exactly `workload <name> {`,
    /// the closing line exactly `}`; blocks cannot nest.
    pub fn parse(text: &str) -> Result<ScopedSpec, String> {
        let mut global_lines: Vec<(usize, &str)> = Vec::new();
        // (opening line number, workload name, body lines)
        let mut blocks: Vec<(usize, String, Vec<(usize, &str)>)> = Vec::new();
        let mut open: Option<usize> = None;
        for (i, raw) in text.lines().enumerate() {
            let no = i + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks[0] {
                "workload" => {
                    if open.is_some() {
                        return Err(format!(
                            "params.spec line {no}: workload blocks cannot nest"
                        ));
                    }
                    if toks.len() != 3 || toks[2] != "{" {
                        return Err(format!(
                            "params.spec line {no}: expected `workload <name> {{`"
                        ));
                    }
                    let name = toks[1].to_string();
                    if blocks.iter().any(|(_, n, _)| *n == name) {
                        return Err(format!(
                            "params.spec line {no}: duplicate workload block {name:?}"
                        ));
                    }
                    blocks.push((no, name, Vec::new()));
                    open = Some(blocks.len() - 1);
                }
                "}" => {
                    if toks.len() != 1 {
                        return Err(format!(
                            "params.spec line {no}: unexpected tokens after `}}`"
                        ));
                    }
                    if open.take().is_none() {
                        return Err(format!(
                            "params.spec line {no}: `}}` without an open workload block"
                        ));
                    }
                }
                _ => match open {
                    Some(b) => blocks[b].2.push((no, line)),
                    None => global_lines.push((no, line)),
                },
            }
        }
        if let Some(b) = open {
            return Err(format!(
                "params.spec: workload block {:?} (line {}) is never closed",
                blocks[b].1, blocks[b].0
            ));
        }

        // The global section may be empty only when blocks carry the dims.
        let global = TuningSpec::parse_numbered(&global_lines, !blocks.is_empty())?;
        let mut warnings: Vec<String> = global.warnings.clone();

        let mut scopes = Vec::with_capacity(blocks.len());
        for (_, name, body) in &blocks {
            // Canonical names of the block's param declarations, for
            // override matching (mirrors parse's suffix canonicalization
            // against the global registry).
            let mut declared: Vec<String> = Vec::new();
            for (no, l) in body {
                let toks: Vec<&str> = l.split_whitespace().collect();
                if toks[0] == "param" {
                    let n = toks.get(1).ok_or_else(|| {
                        format!("params.spec line {no}: param needs a name")
                    })?;
                    declared.push(canonical_name(n, &global));
                }
            }
            // Effective line set: global lines minus overridden param
            // lines, then the block's lines. Ranges come out in that
            // order (kept globals first, then the block's own).
            let mut eff: Vec<(usize, &str)> = Vec::new();
            let mut kept_globals = 0usize;
            for (no, l) in &global_lines {
                let toks: Vec<&str> = l.split_whitespace().collect();
                if toks[0] == "param" {
                    if declared.contains(&canonical_name(toks[1], &global)) {
                        continue; // the block overrides this param
                    }
                    kept_globals += 1;
                }
                eff.push((*no, *l));
            }
            eff.extend(body.iter().copied());
            let spec = TuningSpec::parse_numbered(&eff, true).map_err(|e| {
                format!("workload block {name:?}: {e}")
            })?;
            for w in &spec.warnings {
                if !warnings.contains(w) {
                    warnings.push(w.clone());
                }
            }
            // Owned = the ranges contributed by the block (post-parse
            // canonical names, in block order).
            let owned: Vec<String> = spec.ranges[kept_globals..]
                .iter()
                .map(|r| r.name().to_string())
                .collect();
            scopes.push(WorkloadScope {
                workload: name.clone(),
                spec,
                owned,
            });
        }

        Ok(ScopedSpec {
            global,
            scopes,
            warnings,
        })
    }

    /// Merge the scopes of the given workloads (deduplicated, first-use
    /// order) into one typed space for multi-job/workflow tuning. For a
    /// flat spec this returns the global spec unchanged (same registry
    /// `Arc`, identity routes) — the legacy path, bit for bit.
    pub fn merge(&self, workloads: &[&str]) -> Result<MergedSpace, String> {
        let mut names: Vec<String> = Vec::new();
        for w in workloads {
            if !names.iter().any(|n| n == w) {
                names.push(w.to_string());
            }
        }
        if names.is_empty() {
            return Err("merge needs at least one workload".into());
        }
        if self.is_flat() {
            if self.global.dims() == 0 {
                return Err("params.spec declares no parameters".into());
            }
            let routes = self
                .global
                .ranges
                .iter()
                .map(|r| DimRoute {
                    workload: None,
                    param: r.name().to_string(),
                })
                .collect();
            return Ok(MergedSpace {
                spec: self.global.clone(),
                routes,
                scopes: names
                    .iter()
                    .map(|n| (n.clone(), self.global.clone(), BTreeSet::new()))
                    .collect(),
                workloads: names,
                global: self.global.clone(),
            });
        }

        // Per selected workload: effective spec + owned-name set.
        let selected: Vec<(String, TuningSpec, Vec<String>)> = names
            .iter()
            .map(|n| match self.scopes.iter().find(|s| s.workload == *n) {
                Some(s) => (n.clone(), s.spec.clone(), s.owned.clone()),
                None => (n.clone(), self.global.clone(), Vec::new()),
            })
            .collect();

        // Conflict check: the same NEW parameter declared in two blocks
        // must mean the same thing (builtin/global params always agree —
        // their definition is the shared one; only the declared RANGES
        // differ per block, which is the point of scoping).
        for i in 0..selected.len() {
            for j in i + 1..selected.len() {
                let (wa, sa, oa) = &selected[i];
                let (wb, sb, ob) = &selected[j];
                for p in oa.iter().filter(|p| ob.contains(p)) {
                    let da = sa.registry.by_name(p).map(|(_, d)| d.clone());
                    let db = sb.registry.by_name(p).map(|(_, d)| d.clone());
                    if let (Some(da), Some(db)) = (da, db) {
                        if da != db {
                            return Err(format!(
                                "workload blocks {wa:?} and {wb:?} declare parameter {p:?} \
                                 with conflicting definitions ({} [{}, {}] vs {} [{}, {}]) — \
                                 make the declarations identical or rename one knob",
                                da.kind.token(),
                                da.lo,
                                da.hi,
                                db.kind.token(),
                                db.lo,
                                db.hi
                            ));
                        }
                    }
                }
            }
        }

        // Shared dims: global ranges that at least one selected workload
        // still consumes (a param overridden by EVERY selected block
        // would route nowhere and is dropped).
        let kept: Vec<&ParamRange> = self
            .global
            .ranges
            .iter()
            .filter(|r| {
                selected
                    .iter()
                    .any(|(_, _, owned)| !owned.iter().any(|o| o == r.name()))
            })
            .collect();

        // Merged registry: builtin prefix + global extras + one aliased
        // def per (workload, scoped param).
        let mut extras: Vec<crate::config::space::ParamDef> =
            self.global.registry.defs()[N_AOT_PARAMS..].to_vec();
        // (workload, original range, alias name)
        let mut alias_protos: Vec<(String, ParamRange, String)> = Vec::new();
        for (wl, spec, owned) in &selected {
            for p in owned {
                let def = spec
                    .registry
                    .by_name(p)
                    .map(|(_, d)| d.clone())
                    .ok_or_else(|| format!("workload {wl:?}: owned param {p:?} missing"))?;
                let range = spec
                    .ranges
                    .iter()
                    .find(|r| r.name() == p)
                    .cloned()
                    .ok_or_else(|| format!("workload {wl:?}: owned param {p:?} untuned"))?;
                let alias = format!("{p}@{wl}");
                let mut adef = def;
                adef.name = alias.clone();
                extras.push(adef);
                alias_protos.push((wl.clone(), range, alias));
            }
        }
        let registry = ParamRegistry::with_extras(extras)?;

        let mut ranges: Vec<ParamRange> = Vec::new();
        let mut routes: Vec<DimRoute> = Vec::new();
        for r in kept {
            let (index, def) = registry
                .by_name(r.name())
                .ok_or_else(|| format!("merged registry missing shared param {:?}", r.name()))?;
            ranges.push(ParamRange {
                index,
                def: def.clone(),
                lo: r.lo,
                hi: r.hi,
                step: r.step,
                transform: r.transform,
            });
            routes.push(DimRoute {
                workload: None,
                param: r.name().to_string(),
            });
        }
        for (wl, orig, alias) in &alias_protos {
            let (index, def) = registry
                .by_name(alias)
                .ok_or_else(|| format!("merged registry missing alias {alias:?}"))?;
            ranges.push(ParamRange {
                index,
                def: def.clone(),
                lo: orig.lo,
                hi: orig.hi,
                step: orig.step,
                transform: orig.transform,
            });
            routes.push(DimRoute {
                workload: Some(wl.clone()),
                param: orig.name().to_string(),
            });
        }
        if ranges.is_empty() {
            return Err(format!(
                "params.spec declares no parameters for workloads {names:?}"
            ));
        }

        // Per-workload constraints, remapped onto merged indices: a param
        // the workload scopes maps to its alias, everything else to the
        // shared slot. The union is deduplicated; individually-acyclic
        // scopes can still combine into a cross-scope cycle — reject it.
        let mut constraints: Vec<Constraint> = Vec::new();
        for (wl, spec, owned) in &selected {
            let map_idx = |i: usize| -> Result<usize, String> {
                let name = &spec.registry.get(i).name;
                let target = if owned.iter().any(|o| o == name) {
                    format!("{name}@{wl}")
                } else {
                    name.clone()
                };
                registry
                    .index_of(&target)
                    .ok_or_else(|| format!("merged registry missing {target:?}"))
            };
            for c in &spec.constraints {
                let mc = Constraint {
                    lhs: map_idx(c.lhs)?,
                    bound: match c.bound {
                        Bound::Const(k) => Bound::Const(k),
                        Bound::Scaled { coef, index } => Bound::Scaled {
                            coef,
                            index: map_idx(index)?,
                        },
                    },
                };
                if !constraints.contains(&mc) {
                    constraints.push(mc);
                }
            }
        }
        if has_constraint_cycle(&constraints) {
            return Err("merged workload constraints form a cycle".into());
        }

        let spec = TuningSpec {
            registry,
            ranges,
            constraints,
            warnings: Vec::new(),
        };
        let scopes = selected
            .into_iter()
            .map(|(n, s, o)| (n, s, o.into_iter().collect::<BTreeSet<String>>()))
            .collect();
        Ok(MergedSpace {
            spec,
            routes,
            scopes,
            workloads: names,
            global: self.global.clone(),
        })
    }
}

/// Canonical full name of a param declaration, for override matching:
/// full/suffix resolution against the global registry, the raw name for
/// genuinely new knobs (ambiguity surfaces as an error when the block's
/// effective spec is parsed).
fn canonical_name(name: &str, global: &TuningSpec) -> String {
    global
        .registry
        .resolve(name)
        .map(|(_, d)| d.name.clone())
        .unwrap_or_else(|_| name.to_string())
}

/// Where one merged-space dimension routes at projection time.
#[derive(Clone, Debug, PartialEq)]
pub struct DimRoute {
    /// `None` = shared: the value reaches every job whose workload does
    /// not scope this parameter itself; `Some(w)` = owned by workload w.
    pub workload: Option<String>,
    /// Full underlying parameter name (unaliased).
    pub param: String,
}

/// The result of [`ScopedSpec::merge`]: one flat [`TuningSpec`] every
/// optimizer can drive (shared dims + `<param>@<workload>` aliases),
/// plus the routing needed to project a merged configuration down to
/// each job's own `HadoopConfig`.
#[derive(Clone, Debug)]
pub struct MergedSpace {
    /// The spec the optimizer sees — hand `ParamSpace::new(spec, base)`
    /// to any method; decode/repair, grid streaming, resume replay and
    /// history columns all work on it unchanged.
    pub spec: TuningSpec,
    /// Parallel to `spec.ranges`.
    pub routes: Vec<DimRoute>,
    /// Selected workload names, deduplicated, in first-use order.
    pub workloads: Vec<String>,
    /// (workload, effective spec, owned names) per selected workload —
    /// the projection targets.
    scopes: Vec<(String, TuningSpec, BTreeSet<String>)>,
    /// Fallback projection target for workloads outside the selection.
    global: TuningSpec,
}

impl MergedSpace {
    /// Dimensions of the merged unit cube.
    pub fn dims(&self) -> usize {
        self.spec.ranges.len()
    }

    /// The effective spec a given workload's jobs decode against.
    pub fn scope_spec(&self, workload: &str) -> &TuningSpec {
        self.scopes
            .iter()
            .find(|(n, _, _)| n == workload)
            .map(|(_, s, _)| s)
            .unwrap_or(&self.global)
    }

    /// Project a decoded merged configuration down to one job's own
    /// `HadoopConfig`: shared dims reach every job (unless the job's
    /// workload overrides the param), scoped dims reach only their
    /// owner. The result is laid out on the workload's effective
    /// registry and re-repaired against its constraints, so a job's
    /// rendered `-D` args contain exactly its shared + scoped params.
    /// For a flat spec this is the identity (bit for bit).
    pub fn job_config(&self, merged: &HadoopConfig, workload: &str) -> HadoopConfig {
        let (spec, owned) = self
            .scopes
            .iter()
            .find(|(n, _, _)| n == workload)
            .map(|(_, s, o)| (s, Some(o)))
            .unwrap_or((&self.global, None));
        // Rebasing copies every same-named value (untuned base values and
        // shared dims); aliased slots don't exist in the target registry
        // and are routed explicitly below.
        let mut out = merged.rebased(&spec.registry);
        for (r, route) in self.spec.ranges.iter().zip(&self.routes) {
            let applies = match &route.workload {
                Some(w) => w == workload,
                // a shared dim is masked for workloads that override it
                None => !owned.map(|o| o.contains(&route.param)).unwrap_or(false),
            };
            if !applies {
                continue;
            }
            if let Some((i, _)) = spec.registry.by_name(&route.param) {
                out.set(i, merged.get(r.index));
            }
        }
        spec.repair(&mut out.values);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::{ParamKind, Transform};

    const TWO_JOB: &str = "param mapreduce.job.reduces int 2 32\n\
         workload terasort {\n\
           param mapreduce.map.output.compress bool\n\
           param mapreduce.reduce.shuffle.parallelcopies int 1 64\n\
         }\n\
         workload wordcount {\n\
           param mapreduce.map.memory.mb int 512 4096 log\n\
           param mapreduce.job.reduce.slowstart.completedmaps float 0.05 1.0\n\
         }\n";

    #[test]
    fn flat_files_stay_flat() {
        let s = ScopedSpec::parse(TuningSpec::fig2().to_string().as_str()).unwrap();
        assert!(s.is_flat());
        assert_eq!(s.global, TuningSpec::fig2());
        assert_eq!(s.scope("terasort"), &s.global);
        let merged = s.merge(&["terasort", "wordcount"]).unwrap();
        assert_eq!(merged.spec, s.global);
        assert!(merged.routes.iter().all(|r| r.workload.is_none()));
    }

    #[test]
    fn blocks_extend_the_global_section() {
        let s = ScopedSpec::parse(TWO_JOB).unwrap();
        assert_eq!(s.scopes.len(), 2);
        let ts = s.scope("terasort");
        assert_eq!(ts.dims(), 3); // shared reduces + 2 scoped
        assert_eq!(ts.ranges[0].name(), "mapreduce.job.reduces");
        assert_eq!(
            s.scopes[0].owned,
            vec![
                "mapreduce.map.output.compress".to_string(),
                "mapreduce.reduce.shuffle.parallelcopies".to_string()
            ]
        );
        // a workload with no block sees the global spec
        assert_eq!(s.scope("grep"), &s.global);
    }

    #[test]
    fn block_overrides_replace_the_global_range() {
        let s = ScopedSpec::parse(
            "param mapreduce.task.io.sort.mb int 50 800\n\
             workload terasort {\n\
               param io.sort.mb int 100 400\n\
             }\n",
        )
        .unwrap();
        let ts = s.scope("terasort");
        assert_eq!(ts.dims(), 1, "override duplicated the dim");
        let r = &ts.ranges[0];
        assert_eq!(r.name(), "mapreduce.task.io.sort.mb");
        assert_eq!((r.lo, r.hi), (100.0, 400.0));
        assert_eq!(s.scopes[0].owned, vec!["mapreduce.task.io.sort.mb"]);
        // global untouched
        assert_eq!(s.global.ranges[0].hi, 800.0);
    }

    #[test]
    fn merge_builds_shared_plus_aliased_dims() {
        let s = ScopedSpec::parse(TWO_JOB).unwrap();
        let m = s.merge(&["terasort", "wordcount"]).unwrap();
        assert_eq!(m.dims(), 5);
        let names: Vec<&str> = m.spec.ranges.iter().map(|r| r.name()).collect();
        assert_eq!(
            names,
            vec![
                "mapreduce.job.reduces",
                "mapreduce.map.output.compress@terasort",
                "mapreduce.reduce.shuffle.parallelcopies@terasort",
                "mapreduce.map.memory.mb@wordcount",
                "mapreduce.job.reduce.slowstart.completedmaps@wordcount",
            ]
        );
        assert_eq!(m.routes[0].workload, None);
        assert_eq!(m.routes[3].workload.as_deref(), Some("wordcount"));
        // alias dims keep kind + transform
        assert_eq!(m.spec.ranges[1].def.kind, ParamKind::Bool);
        assert_eq!(m.spec.ranges[3].transform, Transform::Log);
        // builtin prefix untouched in the merged registry
        assert_eq!(m.spec.registry.get(0).name, "mapreduce.job.reduces");
    }

    #[test]
    fn projection_routes_shared_to_all_and_scoped_to_owner() {
        let s = ScopedSpec::parse(TWO_JOB).unwrap();
        let m = s.merge(&["terasort", "wordcount"]).unwrap();
        let space = crate::optim::ParamSpace::new(m.spec.clone(), HadoopConfig::default());
        let cfg = space.decode(&[1.0, 1.0, 1.0, 1.0, 1.0]);
        let ts = m.job_config(&cfg, "terasort");
        let wc = m.job_config(&cfg, "wordcount");
        use crate::config::params::*;
        // shared dim reaches both
        assert_eq!(ts.get(P_REDUCES), 32.0);
        assert_eq!(wc.get(P_REDUCES), 32.0);
        // terasort's scoped dims reach only terasort
        assert!(ts.get_bool(P_COMPRESS));
        assert_eq!(ts.get(P_PARALLEL_COPIES), 64.0);
        assert!(!wc.get_bool(P_COMPRESS), "scoped dim leaked to wordcount");
        assert_eq!(wc.get(P_PARALLEL_COPIES), 5.0); // Hadoop default
        // wordcount's scoped dims reach only wordcount
        assert_eq!(wc.get(P_MAP_MEM_MB), 4096.0);
        assert_eq!(wc.get(P_SLOWSTART), 1.0);
        assert_eq!(ts.get(P_MAP_MEM_MB), 1024.0); // default
        assert_eq!(ts.get(P_SLOWSTART), 0.05); // default
        ts.validate().unwrap();
        wc.validate().unwrap();
        // an unselected workload gets the shared dims only
        let other = m.job_config(&cfg, "grep");
        assert_eq!(other.get(P_REDUCES), 32.0);
        assert!(!other.get_bool(P_COMPRESS));
    }

    #[test]
    fn fully_overridden_shared_dim_is_dropped() {
        let s = ScopedSpec::parse(
            "param mapreduce.task.io.sort.mb int 50 800\n\
             workload terasort { param io.sort.mb int 100 400 }\n",
        );
        // `{` must end the workload line, body on its own lines — the
        // single-line form is a syntax error (kept strict)
        assert!(s.is_err());
        let s = ScopedSpec::parse(
            "param mapreduce.task.io.sort.mb int 50 800\n\
             workload terasort {\n param io.sort.mb int 100 400\n }\n",
        )
        .unwrap();
        let m = s.merge(&["terasort"]).unwrap();
        // the only selected workload overrides the only shared dim: the
        // shared slot routes nowhere and must not burn a dimension
        assert_eq!(m.dims(), 1);
        assert_eq!(m.spec.ranges[0].name(), "mapreduce.task.io.sort.mb@terasort");
    }

    #[test]
    fn conflicting_new_param_declarations_error_naming_both_blocks() {
        let err = ScopedSpec::parse(
            "workload terasort {\n param x.knob int 1 10\n }\n\
             workload wordcount {\n param x.knob int 5 20\n }\n",
        )
        .unwrap()
        .merge(&["terasort", "wordcount"])
        .unwrap_err();
        assert!(err.contains("terasort"), "{err}");
        assert!(err.contains("wordcount"), "{err}");
        assert!(err.contains("x.knob"), "{err}");
        // identical declarations are fine — each workload gets its alias
        let m = ScopedSpec::parse(
            "workload terasort {\n param x.knob int 1 10\n }\n\
             workload wordcount {\n param x.knob int 1 10\n }\n",
        )
        .unwrap()
        .merge(&["terasort", "wordcount"])
        .unwrap();
        assert_eq!(m.dims(), 2);
    }

    #[test]
    fn scoped_constraints_remap_onto_merged_indices() {
        let s = ScopedSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024\n\
             workload wordcount {\n\
               param mapreduce.map.memory.mb int 512 4096\n\
               constraint io.sort.mb <= 0.7*map.memory.mb\n\
             }\n",
        )
        .unwrap();
        let m = s.merge(&["wordcount", "terasort"]).unwrap();
        assert_eq!(m.dims(), 2);
        assert_eq!(m.spec.constraints.len(), 1);
        let c = &m.spec.constraints[0];
        // lhs = shared io.sort.mb slot, rhs = wordcount's alias
        assert_eq!(m.spec.registry.get(c.lhs).name, "mapreduce.task.io.sort.mb");
        match c.bound {
            Bound::Scaled { coef, index } => {
                assert_eq!(coef, 0.7);
                assert_eq!(
                    m.spec.registry.get(index).name,
                    "mapreduce.map.memory.mb@wordcount"
                );
            }
            b => panic!("unexpected bound {b:?}"),
        }
        // decode repairs through the remapped constraint: sort.mb at its
        // top with wordcount memory at its bottom must be pulled down
        let space = crate::optim::ParamSpace::new(m.spec.clone(), HadoopConfig::default());
        let cfg = space.decode(&[1.0, 0.0]);
        assert!(space.is_feasible(&cfg));
        let wc = m.job_config(&cfg, "wordcount");
        assert!(
            wc.get(crate::config::params::P_IO_SORT_MB)
                <= 0.7 * wc.get(crate::config::params::P_MAP_MEM_MB) + 1e-9
        );
    }

    #[test]
    fn cross_scope_constraint_cycles_are_rejected_at_merge() {
        // each block alone is acyclic; the union over the two shared
        // params is a cycle
        let s = ScopedSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024\n\
             param mapreduce.map.memory.mb int 512 4096\n\
             workload terasort {\n\
               constraint io.sort.mb <= 0.5*map.memory.mb\n\
             }\n\
             workload wordcount {\n\
               constraint map.memory.mb <= 16*io.sort.mb\n\
             }\n",
        )
        .unwrap();
        let err = s.merge(&["terasort", "wordcount"]).unwrap_err();
        assert!(err.contains("cycle"), "{err}");
        // one scope at a time is fine
        s.merge(&["terasort"]).unwrap();
        s.merge(&["wordcount"]).unwrap();
    }

    #[test]
    fn empty_workload_block_degrades_to_the_flat_space() {
        let s = ScopedSpec::parse(
            "param mapreduce.job.reduces int 2 32\n\
             workload terasort {\n\
             }\n",
        )
        .unwrap();
        assert_eq!(s.scope("terasort"), &s.global);
        assert!(s.scopes[0].owned.is_empty());
        let m = s.merge(&["terasort"]).unwrap();
        assert_eq!(m.spec, s.global);
        let space = crate::optim::ParamSpace::new(m.spec.clone(), HadoopConfig::default());
        let cfg = space.decode(&[0.5]);
        assert_eq!(m.job_config(&cfg, "terasort"), cfg);
    }

    #[test]
    fn scoped_typo_still_warns() {
        // the typo guard fires inside a workload block exactly like it
        // does at top level
        let s = ScopedSpec::parse(
            "param mapreduce.job.reduces int 2 32\n\
             workload terasort {\n\
               param memory.mbb int 512 4096\n\
             }\n",
        )
        .unwrap();
        assert_eq!(s.warnings.len(), 1, "{:?}", s.warnings);
        assert!(s.warnings[0].contains("memory.mbb"), "{}", s.warnings[0]);
        assert!(
            s.warnings[0].contains("mapreduce.map.memory.mb"),
            "{}",
            s.warnings[0]
        );
    }

    #[test]
    fn block_syntax_errors_name_the_line() {
        assert!(ScopedSpec::parse("workload t\n").is_err());
        assert!(ScopedSpec::parse("workload t {\n").unwrap_err().contains("never closed"));
        assert!(ScopedSpec::parse("}\n").is_err());
        assert!(ScopedSpec::parse(
            "workload a {\n workload b {\n }\n }\n"
        )
        .is_err());
        assert!(ScopedSpec::parse(
            "workload a {\n }\n workload a {\n }\n"
        )
        .unwrap_err()
        .contains("duplicate"));
    }

    #[test]
    fn merge_of_unknown_only_workloads_uses_global() {
        let s = ScopedSpec::parse(TWO_JOB).unwrap();
        let m = s.merge(&["grep", "join"]).unwrap();
        // no selected workload has a block: merged = shared dims only
        assert_eq!(m.dims(), 1);
        assert_eq!(m.spec.ranges[0].name(), "mapreduce.job.reduces");
    }

    #[test]
    fn spec_with_only_blocks_parses() {
        let s = ScopedSpec::parse(
            "workload terasort {\n param mapreduce.map.output.compress bool\n }\n",
        )
        .unwrap();
        assert_eq!(s.global.dims(), 0);
        assert_eq!(s.scope("terasort").dims(), 1);
        let m = s.merge(&["terasort"]).unwrap();
        assert_eq!(m.dims(), 1);
        // a selection with no tunables anywhere is an error
        assert!(s.merge(&["grep"]).is_err());
    }
}

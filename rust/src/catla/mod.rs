//! The Catla system proper — the paper's contribution (§II.A):
//! [`task_runner::TaskRunner`], [`project_runner::ProjectRunner`] and
//! [`optimizer_runner::OptimizerRunner`] over rule-based project
//! templates ([`project`]), with `/history` CSV summaries ([`history`]),
//! log re-aggregation ([`aggregate`]), metrics mining ([`metrics`]) and
//! terminal visualization ([`visualize`]).

pub mod aggregate;
pub mod dashboard;
pub mod fsck;
pub mod history;
pub mod journal;
pub mod metrics;
pub mod multi_job;
pub mod optimizer_runner;
pub mod project;
pub mod project_runner;
pub mod resume;
pub mod task_runner;
pub mod visualize;
pub mod workflow;

pub use history::History;
pub use metrics::JobMetrics;
pub use optimizer_runner::{OptimizerRunner, TuningSettings};
pub use project::{create_scoped_template, create_template, Project, ProjectKind};
pub use project_runner::ProjectRunner;
pub use task_runner::TaskRunner;

//! Workflow (job-DAG) execution — extension of the Project Runner for
//! multi-stage pipelines: iterative PageRank, ETL chains, map-side-join
//! preparation. `jobs.list` lines gain an optional `after=<name>[,<name>]`
//! clause; jobs run as soon as all dependencies succeeded, respecting the
//! cluster's virtual clock (a stage's input is its predecessors' output).
//!
//! ```text
//! prep   grep     4096
//! rank1  pagerank 2048 after=prep
//! rank2  pagerank 2048 after=rank1
//! merge  join     4096 after=rank1,rank2
//! ```

use std::collections::{BTreeMap, BTreeSet};

use crate::catla::project::Project;
use crate::catla::project_runner::{parse_job_line, GroupJob};
use crate::config::params::HadoopConfig;
use crate::config::scope::{MergedSpace, ScopedSpec};
use crate::hadoop::{JobSubmission, SimCluster};
use crate::optim::core::{Driver, FnObjective};
use crate::optim::{Method, ParamSpace, TuningOutcome};

/// One node of the workflow DAG.
#[derive(Clone, Debug)]
pub struct WorkflowJob {
    pub job: GroupJob,
    pub after: Vec<String>,
}

/// Parse a `jobs.list` line with an optional trailing `after=` clause.
pub fn parse_workflow_line(line: &str) -> Result<WorkflowJob, String> {
    let (core, after) = match line.find("after=") {
        Some(pos) => {
            let (a, b) = line.split_at(pos);
            let names = b["after=".len()..]
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            (a.trim(), names)
        }
        None => (line.trim(), Vec::new()),
    };
    Ok(WorkflowJob {
        job: parse_job_line(core)?,
        after,
    })
}

/// Scheduled result of one workflow stage.
#[derive(Clone, Debug)]
pub struct StageResult {
    pub name: String,
    /// Virtual time the stage could start (all deps done).
    pub start_s: f64,
    /// Virtual completion time.
    pub finish_s: f64,
    pub runtime_s: f64,
}

/// Whole-workflow outcome.
#[derive(Clone, Debug)]
pub struct WorkflowOutcome {
    pub stages: Vec<StageResult>,
    /// End-to-end makespan (critical path through the DAG).
    pub makespan_s: f64,
}

/// Validate the DAG: known dependencies, no duplicates, no cycles.
pub fn validate(jobs: &[WorkflowJob]) -> Result<(), String> {
    let names: BTreeSet<&str> = jobs.iter().map(|j| j.job.name.as_str()).collect();
    if names.len() != jobs.len() {
        return Err("duplicate job names in workflow".into());
    }
    for j in jobs {
        for d in &j.after {
            if !names.contains(d.as_str()) {
                return Err(format!("{}: unknown dependency {d:?}", j.job.name));
            }
        }
    }
    // Kahn's algorithm for cycle detection
    let mut indeg: BTreeMap<&str, usize> =
        jobs.iter().map(|j| (j.job.name.as_str(), j.after.len())).collect();
    let mut ready: Vec<&str> = indeg
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(&n, _)| n)
        .collect();
    let mut seen = 0;
    while let Some(n) = ready.pop() {
        seen += 1;
        for j in jobs {
            if j.after.iter().any(|a| a == n) {
                let e = indeg.get_mut(j.job.name.as_str()).unwrap();
                *e -= 1;
                if *e == 0 {
                    ready.push(&j.job.name);
                }
            }
        }
    }
    if seen != jobs.len() {
        return Err("workflow contains a dependency cycle".into());
    }
    Ok(())
}

/// Execute the workflow on the cluster. Stages whose dependencies are all
/// met run "in parallel" in virtual time (the cluster model is exclusive
/// per job, so parallel-ready stages at the same depth share their start
/// time but serialize cluster occupancy — conservative and simple).
pub fn run_workflow(
    cluster: &mut SimCluster,
    jobs: &[WorkflowJob],
) -> Result<WorkflowOutcome, String> {
    validate(jobs)?;
    let mut done: BTreeMap<String, f64> = BTreeMap::new(); // name -> finish time
    let mut stages = Vec::with_capacity(jobs.len());
    let mut remaining: Vec<&WorkflowJob> = jobs.iter().collect();
    let mut cluster_free_at = 0.0f64;

    while !remaining.is_empty() {
        // pick the first job whose deps are all done (stable order)
        let pos = remaining
            .iter()
            .position(|j| j.after.iter().all(|d| done.contains_key(d)))
            .ok_or("no runnable stage (cycle should have been caught)")?;
        let wj = remaining.remove(pos);
        let deps_done = wj
            .after
            .iter()
            .map(|d| done[d])
            .fold(0.0f64, f64::max);
        let start = deps_done.max(cluster_free_at);
        let result = cluster.run_job(&JobSubmission {
            name: wj.job.name.clone(),
            workload: wj.job.workload.clone(),
            config: wj.job.config.clone(),
        });
        let finish = start + result.runtime_s;
        cluster_free_at = finish;
        done.insert(wj.job.name.clone(), finish);
        stages.push(StageResult {
            name: wj.job.name.clone(),
            start_s: start,
            finish_s: finish,
            runtime_s: result.runtime_s,
        });
    }
    let makespan_s = stages.iter().map(|s| s.finish_s).fold(0.0, f64::max);
    Ok(WorkflowOutcome { stages, makespan_s })
}

/// Load a workflow from a project's `jobs.list`.
pub fn from_project(project: &Project) -> Result<Vec<WorkflowJob>, String> {
    if project.jobs.is_empty() {
        return Err("project has no jobs.list".into());
    }
    project.jobs.iter().map(|l| parse_workflow_line(l)).collect()
}

/// Tune a whole workflow DAG over the merged scoped space: the objective
/// is the end-to-end makespan of the pipeline with each stage running
/// its own projection of the candidate point — shared dims reach every
/// stage, `workload { ... }` dims only the stages of their workload.
/// For a flat spec this is exactly the old "one shared configuration"
/// behavior, bit for bit. The caller supplies the `Driver` (budget,
/// early stopping, observers) — `TuningSettings::driver()` builds one
/// from `tuning.properties`. Returns the outcome together with the
/// [`MergedSpace`] so callers can project the best point onto each job
/// and record the merged tuning log.
pub fn tune_workflow(
    cluster: &mut SimCluster,
    jobs: &[WorkflowJob],
    scoped: &ScopedSpec,
    base: HadoopConfig,
    method: &Method,
    driver: &mut Driver,
) -> Result<(TuningOutcome, MergedSpace), String> {
    validate(jobs)?;
    let names: Vec<&str> = jobs.iter().map(|j| j.job.workload.name.as_str()).collect();
    let merged = scoped.merge(&names)?;
    let space = ParamSpace::new(merged.spec.clone(), base);
    let mut opt = method.build();
    let n_stages = jobs.len();
    let mut outcome = {
        let mut obj = FnObjective(|cfg: &HadoopConfig| -> f64 {
            let tuned: Vec<WorkflowJob> = jobs
                .iter()
                .map(|j| {
                    let mut j2 = j.clone();
                    j2.job.config = merged.job_config(cfg, &j.job.workload.name);
                    j2
                })
                .collect();
            match run_workflow(cluster, &tuned) {
                Ok(o) => o.makespan_s,
                Err(_) => f64::INFINITY, // validated above; defensive
            }
        });
        driver.run(opt.as_mut(), &space, &mut obj)?
    };
    outcome.optimizer = format!("{}[workflow x{n_stages}]", outcome.optimizer);
    Ok((outcome, merged))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadoop::ClusterSpec;

    fn wf(lines: &[&str]) -> Vec<WorkflowJob> {
        lines.iter().map(|l| parse_workflow_line(l).unwrap()).collect()
    }

    #[test]
    fn parse_with_and_without_after() {
        let j = parse_workflow_line("prep grep 1024").unwrap();
        assert!(j.after.is_empty());
        let j = parse_workflow_line("rank pagerank 512 after=prep").unwrap();
        assert_eq!(j.after, vec!["prep"]);
        let j = parse_workflow_line(
            "merge join 1024 conf.mapreduce.job.reduces=8 after=a,b",
        )
        .unwrap();
        assert_eq!(j.after, vec!["a", "b"]);
        assert_eq!(j.job.config.get(crate::config::params::P_REDUCES), 8.0);
    }

    #[test]
    fn validate_catches_cycles_and_unknowns() {
        let jobs = wf(&["a grep 64 after=b", "b grep 64 after=a"]);
        assert!(validate(&jobs).unwrap_err().contains("cycle"));
        let jobs = wf(&["a grep 64 after=ghost"]);
        assert!(validate(&jobs).unwrap_err().contains("unknown dependency"));
        let jobs = wf(&["a grep 64", "a grep 64"]);
        assert!(validate(&jobs).unwrap_err().contains("duplicate"));
    }

    #[test]
    fn stages_respect_dependencies() {
        let jobs = wf(&[
            "prep grep 1024",
            "rank1 pagerank 512 after=prep",
            "rank2 pagerank 512 after=rank1",
            "merge join 1024 after=rank1,rank2",
        ]);
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = run_workflow(&mut cluster, &jobs).unwrap();
        assert_eq!(out.stages.len(), 4);
        let at = |n: &str| out.stages.iter().find(|s| s.name == n).unwrap().clone();
        assert!(at("rank1").start_s >= at("prep").finish_s - 1e-9);
        assert!(at("rank2").start_s >= at("rank1").finish_s - 1e-9);
        assert!(at("merge").start_s >= at("rank2").finish_s - 1e-9);
        assert!((out.makespan_s - at("merge").finish_s).abs() < 1e-9);
    }

    #[test]
    fn tune_workflow_beats_default_makespan() {
        let jobs = wf(&[
            "prep grep 1024",
            "rank pagerank 512 after=prep",
            "merge join 1024 after=rank",
        ]);
        let spec = ScopedSpec::flat(crate::config::spec::TuningSpec::fig3());
        let base = crate::config::params::HadoopConfig::default();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let (out, _merged) = tune_workflow(
            &mut cluster,
            &jobs,
            &spec,
            base.clone(),
            &crate::optim::Method::Bobyqa { seed: 3 },
            &mut Driver::new(30),
        )
        .unwrap();
        assert!(out.optimizer.contains("workflow x3"), "{}", out.optimizer);
        assert!(out.evals() <= 30);
        // averaged re-measurement: tuned shared config beats defaults
        let avg = |cluster: &mut SimCluster, cfg: &crate::config::params::HadoopConfig| -> f64 {
            (0..5)
                .map(|_| {
                    let tuned: Vec<WorkflowJob> = jobs
                        .iter()
                        .map(|j| {
                            let mut j2 = j.clone();
                            j2.job.config = cfg.clone();
                            j2
                        })
                        .collect();
                    run_workflow(cluster, &tuned).unwrap().makespan_s
                })
                .sum::<f64>()
                / 5.0
        };
        let mut verify = SimCluster::new(ClusterSpec::default());
        let tuned = avg(&mut verify, &out.best_config);
        let default = avg(&mut verify, &base);
        assert!(
            tuned < default,
            "workflow-tuned {tuned:.1}s vs default {default:.1}s"
        );
    }

    #[test]
    fn flat_spec_workflow_tuning_is_bit_identical_to_the_legacy_shared_config_loop() {
        // a flat (blockless) spec must tune exactly like the pre-scoping
        // system: same merged space (the spec itself), same per-stage
        // configs (the decoded candidate, verbatim), same RNG draws
        let jobs = wf(&["prep grep 512", "rank pagerank 512 after=prep"]);
        let spec = crate::config::spec::TuningSpec::fig2();
        let base = crate::config::params::HadoopConfig::default();
        let method = crate::optim::Method::Annealing { seed: 11 };

        let mut c1 = SimCluster::new(ClusterSpec::default());
        let (new_path, _) = tune_workflow(
            &mut c1,
            &jobs,
            &ScopedSpec::flat(spec.clone()),
            base.clone(),
            &method,
            &mut Driver::new(15),
        )
        .unwrap();

        // the legacy loop, inlined: candidate config cloned into every stage
        let mut c2 = SimCluster::new(ClusterSpec::default());
        let space = ParamSpace::new(spec, base);
        let mut opt = method.build();
        let legacy = {
            let mut obj = FnObjective(|cfg: &crate::config::params::HadoopConfig| -> f64 {
                let tuned: Vec<WorkflowJob> = jobs
                    .iter()
                    .map(|j| {
                        let mut j2 = j.clone();
                        j2.job.config = cfg.clone();
                        j2
                    })
                    .collect();
                run_workflow(&mut c2, &tuned).unwrap().makespan_s
            });
            Driver::new(15).run(opt.as_mut(), &space, &mut obj).unwrap()
        };
        assert_eq!(new_path.evals(), legacy.evals());
        for (a, b) in new_path.records.iter().zip(&legacy.records) {
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "flat workflow tuning diverged");
            assert_eq!(a.config, b.config);
        }
        assert_eq!(new_path.best_config, legacy.best_config);
    }

    #[test]
    fn independent_stages_run_in_any_order_deterministically() {
        let jobs = wf(&["a grep 512", "b grep 512", "c join 512 after=a,b"]);
        let mut c1 = SimCluster::new(ClusterSpec::default());
        let mut c2 = SimCluster::new(ClusterSpec::default());
        let o1 = run_workflow(&mut c1, &jobs).unwrap();
        let o2 = run_workflow(&mut c2, &jobs).unwrap();
        assert_eq!(o1.makespan_s, o2.makespan_s);
    }
}

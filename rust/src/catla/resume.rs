//! Resumable tuning (extension, DESIGN.md §7): continue an interrupted
//! run from `history/tuning_log.csv` instead of restarting from scratch.
//!
//! With the ask/tell core a checkpoint is just "replay the prior
//! evaluations as `tell`s into a fresh optimizer" and keep driving
//! (`Driver::run_with_history`):
//! * grid: told points are skipped, the sweep continues where it stopped;
//! * every sequential method (bobyqa, hooke-jeeves, …): the replay seeds
//!   the restart at the best logged configuration with the remaining
//!   budget — a documented divergence from a full internal-state
//!   checkpoint, now uniform across all DFO methods.

use crate::catla::history::History;
use crate::catla::optimizer_runner::{cost_model_blind_params, TuningSettings};
use crate::catla::project::Project;
use crate::config::params::HadoopConfig;
use crate::config::spec::TuningSpec;
use crate::hadoop::SimCluster;
use crate::optim::core::{BatchObjective, ClusterObjective, Driver};
use crate::optim::racing::RacingObjective;
use crate::optim::result::{EvalRecord, Fidelity};
use crate::optim::surrogate::{CandidateScorer, NativeScorer};
use crate::optim::{Method, ParamSpace, TuningOutcome};
use crate::util::csv::Csv;

/// Parsed prior evaluations from a tuning log.
#[derive(Clone, Debug, Default)]
pub struct PriorRuns {
    /// (config values per spec dimension, runtime, evidence tier) — the
    /// tier comes from the log's optional trailing `fidelity` column
    /// (racing runs only) and defaults to [`Fidelity::Full`], so logs
    /// written before racing existed replay unchanged.
    pub evals: Vec<(Vec<f64>, f64, Fidelity)>,
}

impl PriorRuns {
    pub fn from_log(csv: &Csv, spec: &TuningSpec) -> Result<PriorRuns, String> {
        let vi = csv.col_index("runtime_s").ok_or("log missing runtime_s")?;
        let fi = csv.col_index("fidelity");
        let dims: Vec<usize> = spec
            .ranges
            .iter()
            .map(|r| {
                csv.col_index(r.name())
                    .ok_or_else(|| format!("log missing column {}", r.name()))
            })
            .collect::<Result<_, _>>()?;
        let mut evals = Vec::with_capacity(csv.rows.len());
        for row in &csv.rows {
            let v: f64 = row[vi].parse().map_err(|_| "bad runtime cell")?;
            let fid = match fi {
                Some(i) => Fidelity::parse(&row[i])?,
                None => Fidelity::Full,
            };
            let xs: Vec<f64> = dims
                .iter()
                .map(|&i| row[i].parse::<f64>().map_err(|_| "bad param cell".to_string()))
                .collect::<Result<_, _>>()?;
            evals.push((xs, v, fid));
        }
        Ok(PriorRuns { evals })
    }

    /// Best prior evaluation — full-fidelity only, because a raced-out
    /// candidate's cheap score is not a measurement of the incumbent
    /// (mirrors the live `Recorder` best discipline). Falls back to the
    /// overall minimum only if the log holds no full evaluation at all.
    pub fn best(&self) -> Option<&(Vec<f64>, f64, Fidelity)> {
        self.evals
            .iter()
            .filter(|e| e.2.is_full())
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .or_else(|| self.evals.iter().min_by(|a, b| a.1.total_cmp(&b.1)))
    }

    /// Reconstruct replayable `EvalRecord`s against a parameter space.
    pub fn to_records(&self, space: &ParamSpace) -> Result<Vec<EvalRecord>, String> {
        let base = space.base.clone();
        Ok(self
            .evals
            .iter()
            .enumerate()
            .map(|(i, (xs, v, fid))| {
                let mut cfg = base.clone();
                for (r, x) in space.spec.ranges.iter().zip(xs) {
                    cfg.set(r.index, *x);
                }
                // same constraint repair as decode, so the rebuilt
                // config is exactly the one that was evaluated (grid's
                // resume dedup keys on it)
                space.spec.repair(&mut cfg.values);
                EvalRecord {
                    iter: i + 1,
                    unit_x: space.encode(&cfg),
                    config: cfg,
                    value: *v,
                    best_so_far: 0.0, // recomputed on replay
                    fidelity: *fid,
                }
            })
            .collect())
    }
}

/// The spec whose ranges match a stored tuning log's columns: the
/// project's effective flat spec for single-job `tuning` runs, or the
/// merged scoped space (re-merged from `jobs.list` workloads) for
/// `tuning-group` / `workflow --tune` runs — scoped dims are recorded in
/// the log as `<param>@<workload>` columns, so the column set itself
/// identifies which space produced the log.
fn logged_space_spec(project: &Project, csv: &Csv) -> Result<TuningSpec, String> {
    // exact match against the log's parameter columns, not a subset
    // check: a merged log's shared columns would otherwise let the flat
    // global spec shadow the merged space and silently drop every tuned
    // `@workload` dim from the reconstruction
    // `fidelity` is the racing runs' trailing evidence-tier column —
    // never a tuned dimension, so it must not count as a param column
    let fixed = ["iter", "optimizer", "runtime_s", "best_so_far", "fidelity"];
    let param_cols = csv
        .header
        .iter()
        .filter(|h| !fixed.contains(&h.as_str()))
        .count();
    let covers = |spec: &TuningSpec| {
        spec.ranges.len() == param_cols
            && spec
                .ranges
                .iter()
                .all(|r| csv.col_index(r.name()).is_some())
    };
    if let Some(spec) = &project.spec {
        if spec.dims() > 0 && covers(spec) {
            return Ok(spec.clone());
        }
    }
    if let (Some(scoped), false) = (&project.scoped, project.jobs.is_empty()) {
        // workflow syntax (trailing `after=` clauses) is a superset of
        // the plain jobs.list grammar, so it parses both kinds of lines
        let names: Vec<String> = project
            .jobs
            .iter()
            .filter_map(|l| crate::catla::workflow::parse_workflow_line(l).ok())
            .map(|j| j.job.workload.name)
            .collect();
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let merged = scoped.merge(&refs)?;
        if covers(&merged.spec) {
            return Ok(merged.spec);
        }
    }
    Err("tuning log columns match neither this project's spec nor its merged workflow space"
        .into())
}

/// Reconstruct the best evaluated configuration recorded in a project's
/// tuning log, against the exact space the run tuned (flat or merged —
/// see [`logged_space_spec`]) with the same decode-time constraint
/// repair, so the rebuilt config is byte-identical to the one the run
/// evaluated. `Ok(None)` when the project has no usable log.
pub fn best_logged_config(project: &Project) -> Result<Option<HadoopConfig>, String> {
    let Ok(history) = History::open(&project.dir) else {
        return Ok(None);
    };
    // tolerant: a log with a torn final line (killed mid-write) still
    // yields its clean prefix — this helper is opportunistic, so an
    // unreadable log degrades to None rather than an error
    let Ok((csv, _torn)) = history.load_tuning_log_tolerant() else {
        return Ok(None);
    };
    let spec = logged_space_spec(project, &csv)?;
    let space = ParamSpace::new(spec.clone(), project.base_config()?);
    let prior = PriorRuns::from_log(&csv, &spec)?;
    Ok(prior.best().map(|(xs, _, _)| {
        let mut cfg = space.base.clone();
        for (r, x) in spec.ranges.iter().zip(xs) {
            cfg.set(r.index, *x);
        }
        spec.repair(&mut cfg.values); // match decode exactly
        cfg
    }))
}

/// Resume a tuning project. `budget` is the TOTAL budget including prior
/// evaluations; returns an outcome covering prior + new evaluations. A
/// budget at or below the logged evaluation count means "exhausted":
/// everything is replayed and nothing new runs — logged evaluations are
/// never dropped (the tuning log is rewritten from the outcome, so
/// truncating the replay would destroy history).
pub fn resume_tuning(
    cluster: &mut SimCluster,
    project: &Project,
    budget: usize,
) -> Result<TuningOutcome, String> {
    let spec = project.spec.clone().ok_or("not a tuning project")?;
    if spec.dims() == 0 {
        return Err(format!(
            "params.spec declares no parameters for workload {:?}",
            project.workload()?.name
        ));
    }
    let history = History::open(&project.dir).map_err(|e| e.to_string())?;
    let log_path = history.dir.join(crate::catla::history::TUNING_CSV);
    // crash-tolerant prefix replay: a torn final line (the writer was
    // killed mid-append) is dropped with a warning and the clean prefix
    // resumes; anything structurally wrong INSIDE the log is mid-file
    // corruption — a hard, explicit error, never a silent restart
    let prior = if log_path.is_file() {
        let (csv, torn) = history.load_tuning_log_tolerant().map_err(|e| {
            format!(
                "{}: {e} — corrupt tuning log; inspect it or run `catla fsck {}`",
                log_path.display(),
                project.dir.display()
            )
        })?;
        if let Some(w) = torn {
            eprintln!("warning: {w}");
        }
        PriorRuns::from_log(&csv, &spec)?
    } else {
        PriorRuns::default()
    };
    // one parser for tuning.properties everywhere: the resumed run
    // honors the same optimizer/seed/batch.chunk as the original, and a
    // malformed value errors here exactly like it does on a fresh run
    let settings = TuningSettings::from_project(project)?;
    let optimizer = settings.optimizer.clone();
    let workload = project.workload()?;
    let space = ParamSpace::new(spec.clone(), project.base_config()?);
    let records = prior.to_records(&space)?;

    // replay the checkpoint into a fresh optimizer, then keep driving;
    // the driver truncates replay to its budget, so clamp the total up
    // to the log size — a too-small budget must not drop history
    let total = budget.max(records.len());
    let mut opt = Method::from_name(&optimizer, settings.seed)?.build();
    let cluster_spec = cluster.spec.clone();
    let inner = ClusterObjective::new(cluster, &workload, 1);
    // a resumed run honors the original run's racing discipline: new
    // slices race through the same tiers (replayed evaluations keep the
    // fidelity the log recorded for them and are never re-raced)
    let mut plain;
    let mut raced;
    let obj: &mut dyn BatchObjective = if settings.racing.enabled {
        let tier0: Option<Box<dyn CandidateScorer>> =
            if cost_model_blind_params(&spec).is_empty() {
                Some(Box::new(NativeScorer {
                    workload: workload.clone(),
                    cluster: cluster_spec,
                }))
            } else {
                None
            };
        raced = RacingObjective::new(inner, settings.racing, tier0);
        &mut raced
    } else {
        plain = inner;
        &mut plain
    };
    let mut outcome = Driver::new(total)
        .chunk(settings.batch_chunk)
        .run_with_history(opt.as_mut(), &space, obj, &records)?;

    outcome.optimizer = if records.len() >= budget {
        format!("{optimizer}[resumed,exhausted]")
    } else {
        format!("{optimizer}[resumed@{}]", records.len())
    };

    history.write_tuning_log(&spec, &outcome)?;
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::optimizer_runner::OptimizerRunner;
    use crate::catla::project::{create_template, ProjectKind};
    use crate::hadoop::ClusterSpec;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-resume-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn tuning_project(name: &str, optimizer: &str, budget: usize) -> PathBuf {
        let dir = tmp(name);
        create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
        std::fs::write(
            dir.join("params.spec"),
            "param mapreduce.job.reduces int 2 32 step 2\n\
             param mapreduce.task.io.sort.mb int 50 800 step 150\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("tuning.properties"),
            format!("optimizer={optimizer}\nbudget={budget}\nseed=3\n"),
        )
        .unwrap();
        dir
    }

    #[test]
    fn grid_resume_skips_done_points_and_finishes() {
        let dir = tuning_project("grid", "grid", 10);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        // phase 1: interrupted after 10 grid evals
        let first = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        assert_eq!(first.outcome.evals(), 10);
        // phase 2: resume up to the full 96-point grid
        let full = 16 * 6;
        let resumed = resume_tuning(&mut cluster, &project, full).unwrap();
        assert_eq!(resumed.evals(), full, "resume did not cover the grid");
        assert!(resumed.optimizer.contains("resumed"));
        // the first 10 rows come from the prior log (replayed, not rerun):
        // their values must match the original log exactly
        for (a, b) in first.outcome.records.iter().zip(&resumed.records) {
            assert!((a.value - b.value).abs() < 1e-3);
        }
        // no duplicate grid points overall
        let mut keys: Vec<String> = resumed
            .records
            .iter()
            .map(|r| format!("{:?}", r.config.values))
            .collect();
        keys.sort();
        let n = keys.len();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate grid evaluations after resume");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dfo_resume_seeds_from_best_prior() {
        let dir = tuning_project("bobyqa", "bobyqa", 15);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let first = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        let resumed = resume_tuning(&mut cluster, &project, 30).unwrap();
        assert_eq!(resumed.evals(), 30);
        // resumed best can only improve on the prior best (1e-3: the
        // tuning log stores runtimes rounded to 3 decimals)
        assert!(resumed.best_value <= first.outcome.best_value + 1e-3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn best_logged_config_rebuilds_the_runs_best_byte_for_byte() {
        let dir = tuning_project("bestlog", "bobyqa", 14);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let first = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        let rebuilt = best_logged_config(&project).unwrap().expect("log exists");
        assert_eq!(rebuilt, first.outcome.best_config);
        // a project without history reconstructs nothing
        let bare = tuning_project("bestlog-bare", "bobyqa", 5);
        let rebuilt = best_logged_config(&Project::load(&bare).unwrap()).unwrap();
        assert!(rebuilt.is_none());
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&bare).unwrap();
    }

    #[test]
    fn racing_resume_replays_fidelities_and_keeps_racing() {
        let dir = tmp("racing");
        create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
        std::fs::write(
            dir.join("params.spec"),
            "param mapreduce.job.reduces int 2 32 step 2\n\
             param mapreduce.task.io.sort.mb int 50 800 step 150\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=16\nseed=3\nracing.enabled=true\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let first = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        assert!(
            first.outcome.records.iter().any(|r| !r.fidelity.is_full()),
            "racing run produced no raced-out records"
        );
        let resumed = resume_tuning(&mut cluster, &project, 32).unwrap();
        assert_eq!(resumed.evals(), 32);
        // the replayed prefix keeps each record's logged fidelity tier
        // (values to 1e-3: the tuning log rounds runtimes to 3 decimals)
        for (a, b) in first.outcome.records.iter().zip(&resumed.records) {
            assert_eq!(a.fidelity, b.fidelity, "replay changed a fidelity tier");
            assert!((a.value - b.value).abs() < 1e-3);
        }
        // the resumed run's NEW slices race too
        assert!(
            resumed.records[first.outcome.evals()..]
                .iter()
                .any(|r| !r.fidelity.is_full()),
            "resumed slices did not race"
        );
        // best only ever comes from a full-fidelity measurement
        let best_full = resumed
            .records
            .iter()
            .filter(|r| r.fidelity.is_full())
            .map(|r| r.value)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(resumed.best_value.to_bits(), best_full.to_bits());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn exhausted_budget_replays_only() {
        let dir = tuning_project("done", "bobyqa", 12);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        let before = cluster.jobs_completed();
        let resumed = resume_tuning(&mut cluster, &project, 12).unwrap();
        assert_eq!(resumed.evals(), 12);
        assert_eq!(cluster.jobs_completed(), before, "exhausted resume ran jobs");
        assert!(resumed.optimizer.contains("exhausted"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn smaller_budget_never_drops_logged_evaluations() {
        // the outcome rewrites tuning_log.csv, so truncating the replay
        // would permanently destroy history (and possibly the true best)
        let dir = tuning_project("shrink", "bobyqa", 12);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let first = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        let logged = first.outcome.evals();
        let resumed = resume_tuning(&mut cluster, &project, logged - 4).unwrap();
        assert_eq!(resumed.evals(), logged, "resume dropped logged evaluations");
        assert!(resumed.optimizer.contains("exhausted"));
        // best can only match the full prior log (1e-3: log rounding)
        assert!(resumed.best_value <= first.outcome.best_value + 1e-3);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Multi-job tuning (extension, DESIGN.md §7): find ONE Hadoop
//! configuration that minimizes the aggregate running time of a whole job
//! group — the realistic shared-cluster scenario where `mapred-site.xml`
//! is set once for a mixed workload, not per job.
//!
//! With `workload { ... }` blocks in `params.spec` the "one
//! configuration" generalizes to one *merged-space point*: shared dims
//! are still set once for every job, while each workload's scoped dims
//! apply only to its own jobs ([`MergedSpace::job_config`] does the
//! routing). Flat specs behave exactly as before.

use crate::catla::history::History;
use crate::catla::project::Project;
use crate::catla::project_runner::{parse_job_line, GroupJob};
use crate::config::params::HadoopConfig;
use crate::config::scope::MergedSpace;
use crate::hadoop::{JobSubmission, SimCluster};
use crate::optim::core::{Driver, FnObjective};
use crate::optim::{Method, ParamSpace, TuningOutcome};

/// How per-job runtimes combine into one objective value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupMetric {
    /// Total cluster seconds (throughput view).
    Sum,
    /// Worst job (tail/SLO view).
    Max,
}

impl GroupMetric {
    pub fn from_name(s: &str) -> Result<GroupMetric, String> {
        match s {
            "sum" | "total" => Ok(GroupMetric::Sum),
            "max" | "worst" => Ok(GroupMetric::Max),
            other => Err(format!("unknown group.metric {other:?} (sum|max)")),
        }
    }

    fn combine(&self, runtimes: &[f64]) -> f64 {
        match self {
            GroupMetric::Sum => runtimes.iter().sum(),
            GroupMetric::Max => runtimes.iter().cloned().fold(0.0, f64::max),
        }
    }
}

/// Objective over a job group: run every job with its projection of the
/// candidate merged configuration (for flat specs the projection is the
/// identity — every job gets the candidate itself, as before).
pub fn group_objective<'a>(
    cluster: &'a mut SimCluster,
    jobs: &'a [GroupJob],
    metric: GroupMetric,
    merged: &'a MergedSpace,
) -> impl FnMut(&HadoopConfig) -> f64 + 'a {
    move |cfg: &HadoopConfig| {
        let runtimes: Vec<f64> = jobs
            .iter()
            .map(|j| {
                cluster
                    .run_job(&JobSubmission {
                        name: j.name.clone(),
                        workload: j.workload.clone(),
                        config: merged.job_config(cfg, &j.workload.name),
                    })
                    .runtime_s
            })
            .collect();
        metric.combine(&runtimes)
    }
}

/// Tune one shared configuration (one merged-space point, for scoped
/// specs) for a project's whole `jobs.list`. Requires both `jobs.list`
/// and `params.spec` in the project folder; `tuning.properties` may set
/// `group.metric=sum|max`. The tuning log / summary are written against
/// the merged spec, so scoped dims appear as `<param>@<workload>`
/// columns and resume-style reconstruction can rebuild the exact space.
pub fn tune_group(
    cluster: &mut SimCluster,
    project: &Project,
) -> Result<TuningOutcome, String> {
    if project.jobs.is_empty() {
        return Err("multi-job tuning needs a jobs.list".into());
    }
    let scoped = project
        .scoped
        .clone()
        .ok_or("multi-job tuning needs params.spec")?;
    let jobs: Vec<GroupJob> = project
        .jobs
        .iter()
        .map(|l| parse_job_line(l))
        .collect::<Result<_, _>>()?;
    let names: Vec<&str> = jobs.iter().map(|j| j.workload.name.as_str()).collect();
    let merged = scoped.merge(&names)?;

    let (optimizer, budget, seed, metric) = match &project.tuning {
        Some(t) => (
            t.get("optimizer").unwrap_or("bobyqa").to_string(),
            t.get("budget").and_then(|s| s.parse().ok()).unwrap_or(40),
            t.get("seed").and_then(|s| s.parse().ok()).unwrap_or(7),
            GroupMetric::from_name(t.get("group.metric").unwrap_or("sum"))?,
        ),
        None => ("bobyqa".to_string(), 40, 7, GroupMetric::Sum),
    };

    let space = ParamSpace::new(merged.spec.clone(), project.base_config()?);
    let mut opt = Method::from_name(&optimizer, seed)?.build();
    let mut outcome = {
        let mut obj = FnObjective(group_objective(cluster, &jobs, metric, &merged));
        Driver::new(budget).run(opt.as_mut(), &space, &mut obj)?
    };
    outcome.optimizer = format!("{}[group-{:?}x{}]", outcome.optimizer, metric, jobs.len());

    let history = History::open(&project.dir).map_err(|e| e.to_string())?;
    history.write_tuning_log(&merged.spec, &outcome)?;
    history.append_summary(&merged.spec, &outcome)?;
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::project::{create_template, ProjectKind};
    use crate::hadoop::ClusterSpec;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-multi-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn group_project(name: &str, metric: &str) -> PathBuf {
        let dir = tmp(name);
        create_template(&dir, ProjectKind::Tuning, "wordcount", 2048.0).unwrap();
        std::fs::write(
            dir.join("jobs.list"),
            "wc wordcount 2048\nsort terasort 2048\ngrep grep 2048\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("tuning.properties"),
            format!("optimizer=bobyqa\nbudget=20\nseed=3\ngroup.metric={metric}\n"),
        )
        .unwrap();
        dir
    }

    #[test]
    fn metric_combinators() {
        assert_eq!(GroupMetric::Sum.combine(&[1.0, 2.0, 3.0]), 6.0);
        assert_eq!(GroupMetric::Max.combine(&[1.0, 5.0, 3.0]), 5.0);
        assert!(GroupMetric::from_name("median").is_err());
    }

    #[test]
    fn tunes_shared_config_over_group() {
        let dir = group_project("sum", "sum");
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = tune_group(&mut cluster, &project).unwrap();
        assert!(out.optimizer.contains("group-Sum"));
        assert!(out.evals() <= 20);
        // shared tuned config must beat defaults on the group objective
        let jobs: Vec<GroupJob> = project
            .jobs
            .iter()
            .map(|l| parse_job_line(l).unwrap())
            .collect();
        let names: Vec<&str> = jobs.iter().map(|j| j.workload.name.as_str()).collect();
        let merged = project.scoped.clone().unwrap().merge(&names).unwrap();
        let mut verify = SimCluster::new(ClusterSpec::default());
        let avg = |cluster: &mut SimCluster, cfg: &HadoopConfig| -> f64 {
            let mut obj = group_objective(cluster, &jobs, GroupMetric::Sum, &merged);
            (0..5).map(|_| obj(cfg)).sum::<f64>() / 5.0
        };
        let tuned = avg(&mut verify, &out.best_config);
        let default = avg(&mut verify, &HadoopConfig::default());
        assert!(tuned < default, "group-tuned {tuned:.1} vs default {default:.1}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn max_metric_runs() {
        let dir = group_project("max", "max");
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = tune_group(&mut cluster, &project).unwrap();
        assert!(out.optimizer.contains("group-Max"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scoped_group_tunes_the_merged_space_and_logs_alias_columns() {
        let dir = tmp("scoped");
        create_template(&dir, ProjectKind::Tuning, "wordcount", 1024.0).unwrap();
        std::fs::write(
            dir.join("jobs.list"),
            "wc wordcount 1024\nsort terasort 1024\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("params.spec"),
            "param mapreduce.job.reduces int 2 32\n\
             workload terasort {\n\
               param mapreduce.reduce.shuffle.parallelcopies int 1 64\n\
             }\n\
             workload wordcount {\n\
               param mapreduce.map.memory.mb int 512 4096\n\
             }\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=8\nseed=5\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = tune_group(&mut cluster, &project).unwrap();
        assert_eq!(out.best_config.len(), crate::config::params::N_AOT_PARAMS + 2);
        let csv = crate::catla::history::History::open(&dir)
            .unwrap()
            .load_tuning_log()
            .unwrap();
        assert!(csv
            .header
            .contains(&"mapreduce.reduce.shuffle.parallelcopies@terasort".to_string()));
        assert!(csv
            .header
            .contains(&"mapreduce.map.memory.mb@wordcount".to_string()));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn requires_jobs_list() {
        let dir = tmp("nojobs");
        create_template(&dir, ProjectKind::Tuning, "wordcount", 512.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        assert!(tune_group(&mut cluster, &project).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

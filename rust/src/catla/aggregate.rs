//! Log (re-)aggregation — "When the tuning process is stopped in the
//! middle of tuning, the log aggregation is not finished. Therefore, the
//! user can start this command to re-aggregate existing logs from
//! /history folder." (§II.C.4)
//!
//! Scans a project folder for every downloaded `*.history.json`
//! (including per-job subfolders left by the Project Runner), rebuilds
//! `history/jobs.csv` from scratch, and reconciles the tuning log's
//! best-so-far column.

use std::path::{Path, PathBuf};

use crate::catla::history::History;
use crate::catla::metrics::JobMetrics;
use crate::util::csv::Csv;

/// What re-aggregation found and rebuilt.
#[derive(Debug, Default)]
pub struct AggregateReport {
    pub histories_found: usize,
    pub jobs_csv_rows: usize,
    pub tuning_rows_repaired: usize,
}

/// Recursively collect `*.history.json` under `dir`.
fn find_histories(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            find_histories(&p, out);
        } else if p
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.ends_with("history.json"))
            .unwrap_or(false)
        {
            out.push(p);
        }
    }
}

/// Re-aggregate a project folder.
pub fn aggregate(project_dir: &Path) -> Result<AggregateReport, String> {
    let mut report = AggregateReport::default();
    let results = project_dir.join("downloaded_results");
    let mut histories = Vec::new();
    if results.is_dir() {
        find_histories(&results, &mut histories);
    }
    report.histories_found = histories.len();

    // rebuild jobs.csv from scratch so partial rows never duplicate
    let history = History::open(project_dir).map_err(|e| e.to_string())?;
    let jobs_path = history.dir.join(crate::catla::history::JOBS_CSV);
    if jobs_path.is_file() {
        std::fs::remove_file(&jobs_path).map_err(|e| e.to_string())?;
    }
    for h in &histories {
        let m = JobMetrics::from_file(h)?;
        history.append_job(&m)?;
        report.jobs_csv_rows += 1;
    }

    // repair the tuning log's best_so_far column if one exists
    let tuning_path = history.dir.join(crate::catla::history::TUNING_CSV);
    if tuning_path.is_file() {
        let mut csv = Csv::load(&tuning_path)?;
        let vi = csv
            .col_index("runtime_s")
            .ok_or("tuning log missing runtime_s")?;
        let bi = csv
            .col_index("best_so_far")
            .ok_or("tuning log missing best_so_far")?;
        let mut best = f64::INFINITY;
        for row in csv.rows.iter_mut() {
            let v: f64 = row[vi].parse().map_err(|_| "bad runtime cell")?;
            best = best.min(v);
            let fixed = format!("{best:.3}");
            if row[bi] != fixed {
                row[bi] = fixed;
                report.tuning_rows_repaired += 1;
            }
        }
        csv.save(&tuning_path).map_err(|e| e.to_string())?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::project::{create_template, Project, ProjectKind};
    use crate::catla::task_runner::TaskRunner;
    use crate::hadoop::{ClusterSpec, SimCluster};

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-agg-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn rebuilds_jobs_csv_idempotently() {
        let dir = tmp("rebuild");
        create_template(&dir, ProjectKind::Task, "wordcount", 1024.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut tr = TaskRunner::new(&mut cluster);
        tr.run(&project).unwrap();
        tr.run(&project).unwrap();

        let r1 = aggregate(&dir).unwrap();
        assert_eq!(r1.histories_found, 2);
        assert_eq!(r1.jobs_csv_rows, 2);
        // idempotent: re-running does not duplicate
        let r2 = aggregate(&dir).unwrap();
        assert_eq!(r2.jobs_csv_rows, 2);
        let h = History::open(&dir).unwrap();
        assert_eq!(h.load_jobs().unwrap().rows.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repairs_corrupted_best_so_far() {
        let dir = tmp("repair");
        create_template(&dir, ProjectKind::Task, "grep", 256.0).unwrap();
        let history = History::open(&dir).unwrap();
        // simulate an interrupted tuning log with a broken best column
        let csv_text = "iter,optimizer,runtime_s,best_so_far,mapreduce.job.reduces\n\
                        1,bobyqa,120.000,120.000,4\n\
                        2,bobyqa,100.000,999.000,8\n\
                        3,bobyqa,110.000,0.000,12\n";
        std::fs::write(history.dir.join("tuning_log.csv"), csv_text).unwrap();
        let report = aggregate(&dir).unwrap();
        assert_eq!(report.tuning_rows_repaired, 2);
        let csv = history.load_tuning_log().unwrap();
        assert_eq!(csv.col_f64("best_so_far").unwrap(), vec![120.0, 100.0, 100.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_project_reports_zero() {
        let dir = tmp("empty");
        create_template(&dir, ProjectKind::Task, "join", 128.0).unwrap();
        let r = aggregate(&dir).unwrap();
        assert_eq!(r.histories_found, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Optimizer Runner — "creates a series of MapReduce jobs with different
//! combinations of parameter values according to parameter configuration
//! files and obtains the optimal parameter value sets with minimum
//! running time after the tuning process is finished." (§II.A)
//!
//! Reads `params.spec` + `tuning.properties` from a tuning project,
//! builds the chosen ask/tell method through the `Method` registry,
//! drives it with the shared `optim::core::Driver` against the batched
//! cluster objective, and records the per-iteration log + summary into
//! `/history`.

use crate::catla::history::History;
use crate::catla::project::Project;
use crate::config::params::N_AOT_PARAMS;
use crate::config::spec::TuningSpec;
use crate::hadoop::{costmodel, SimCluster};
use crate::optim::core::{
    BatchObjective, ClusterObjective, Driver, EarlyStop, DEFAULT_BATCH_CHUNK,
};
use crate::optim::racing::{RacingObjective, RacingSettings};
use crate::optim::surrogate::{CandidateScorer, NativeScorer, Prescreen};
use crate::optim::{EvalRecord, Method, ParamSpace, TuningOutcome};

/// Parsed tuning settings (from `tuning.properties`).
#[derive(Clone, Debug)]
pub struct TuningSettings {
    pub optimizer: String,
    pub budget: usize,
    pub repeats: usize,
    pub seed: u64,
    /// Prescreen cluster starts with the surrogate model ("auto" | "off").
    pub prescreen: bool,
    /// Early stop after this many non-improving evaluations (0 = off;
    /// `early.patience` in tuning.properties).
    pub early_patience: usize,
    /// Relative improvement threshold for early stopping (`early.tol`).
    pub early_tol: f64,
    /// Streaming chunk (`batch.chunk`): streaming methods (grid) propose
    /// at most this many candidates per ask and the driver evaluates
    /// ask-batches in slices of this size. Outcomes are byte-identical
    /// under any chunk — this only bounds working memory.
    pub batch_chunk: usize,
    /// LRU cap this project requests for the serve daemon's global
    /// simulation memo-cache (`serve.cache_entries`). `None` leaves the
    /// daemon's current cap alone; ignored outside `catla serve`.
    pub cache_entries: Option<usize>,
    /// Retry budget for transient evaluation failures in the serve
    /// daemon (`serve.retry.max`): a panicking evaluation is re-run up
    /// to this many times before the owning session moves to its
    /// `Failed` terminal state. Ignored outside `catla serve`.
    pub retry_max: usize,
    /// Base backoff between serve retries in milliseconds
    /// (`serve.retry.backoff_ms`), scaled linearly by retry number —
    /// bounded and deterministic. 0 (the default) retries immediately.
    pub retry_backoff_ms: u64,
    /// Multi-fidelity racing knobs (`racing.{enabled,eta,min_tier_evals}`).
    /// Off by default — outcomes are then byte-identical to a driver
    /// without the racing layer.
    pub racing: RacingSettings,
}

impl TuningSettings {
    pub fn from_project(project: &Project) -> Result<TuningSettings, String> {
        let t = project
            .tuning
            .as_ref()
            .ok_or("not a tuning project (missing tuning.properties)")?;
        let parse_usize = |k: &str, d: usize| -> Result<usize, String> {
            match t.get(k) {
                None => Ok(d),
                Some(s) => s.parse().map_err(|_| format!("bad {k}={s:?}")),
            }
        };
        let parse_f64 = |k: &str, d: f64| -> Result<f64, String> {
            match t.get(k) {
                None => Ok(d),
                Some(s) => s.parse().map_err(|_| format!("bad {k}={s:?}")),
            }
        };
        Ok(TuningSettings {
            optimizer: t.get("optimizer").unwrap_or("bobyqa").to_string(),
            budget: parse_usize("budget", 60)?,
            repeats: parse_usize("repeats", 1)?,
            seed: t
                .get("seed")
                .map(|s| s.parse().map_err(|_| format!("bad seed={s:?}")))
                .transpose()?
                .unwrap_or(7),
            prescreen: t.get("prescreen").map(|v| v == "auto").unwrap_or(false),
            early_patience: parse_usize("early.patience", 0)?,
            early_tol: parse_f64("early.tol", 1e-3)?,
            batch_chunk: parse_usize("batch.chunk", DEFAULT_BATCH_CHUNK)?.max(1),
            cache_entries: t
                .get("serve.cache_entries")
                .map(|s| {
                    s.parse()
                        .map_err(|_| format!("bad serve.cache_entries={s:?}"))
                })
                .transpose()?,
            retry_max: parse_usize("serve.retry.max", 2)?,
            retry_backoff_ms: parse_usize("serve.retry.backoff_ms", 0)? as u64,
            racing: {
                let d = RacingSettings::default();
                let racing = RacingSettings {
                    enabled: t.get("racing.enabled").map(|v| v == "true").unwrap_or(d.enabled),
                    eta: parse_usize("racing.eta", d.eta)?,
                    min_tier_evals: parse_usize("racing.min_tier_evals", d.min_tier_evals)?,
                };
                racing.validate()?;
                racing
            },
        })
    }

    /// Build the shared tuning loop these settings describe (budget,
    /// early stopping, CATLA_TRACE observer) — also used by the
    /// workflow tuner so every entry point honors the same properties.
    pub fn driver<'a>(&self) -> Driver<'a> {
        let mut driver = Driver::new(self.budget).chunk(self.batch_chunk);
        if self.early_patience > 0 {
            driver = driver.early_stop(EarlyStop {
                patience: self.early_patience,
                min_rel: self.early_tol,
            });
        }
        // detlint: allow(ambient-entropy) -- opt-in stderr trace observer;
        // attaches a printer only, never alters tuning decisions
        if std::env::var("CATLA_TRACE").is_ok() {
            driver = driver.observe(|r: &EvalRecord| {
                eprintln!(
                    "eval {:>4}: {:8.1}s (best so far {:8.1}s)",
                    r.iter, r.value, r.best_so_far
                );
            });
        }
        driver
    }
}

/// Tuned parameters the analytic cost model is genuinely blind to.
///
/// The stable [`N_AOT_PARAMS`]-slot AOT prefix is always covered, and
/// [`costmodel::extended_param_mapped`] whitelists the post-prefix
/// extras the model maps by name (codec choice, shuffle input buffer
/// percent). Only spec-declared dims in neither set are listed — those
/// never move a prediction, so the surrogate prescreen ignores them and
/// multi-fidelity racing refuses its tier-0 model pass (falling back to
/// tier 1, one DES seed) whenever any appear in the spec.
pub fn cost_model_blind_params(spec: &TuningSpec) -> Vec<&str> {
    spec.ranges
        .iter()
        .filter(|r| r.index >= N_AOT_PARAMS && !costmodel::extended_param_mapped(&r.def))
        .map(|r| r.name())
        .collect()
}

/// Outcome + where the logs went.
#[derive(Debug)]
pub struct TuningRunOutcome {
    pub outcome: TuningOutcome,
    pub cluster_evals: usize,
    pub log_path: std::path::PathBuf,
}

pub struct OptimizerRunner<'a> {
    pub cluster: &'a mut SimCluster,
    /// Optional surrogate scorer for prescreen=auto projects.
    pub scorer: Option<&'a mut dyn CandidateScorer>,
}

impl<'a> OptimizerRunner<'a> {
    pub fn new(cluster: &'a mut SimCluster) -> Self {
        Self {
            cluster,
            scorer: None,
        }
    }

    pub fn with_scorer(cluster: &'a mut SimCluster, scorer: &'a mut dyn CandidateScorer) -> Self {
        Self {
            cluster,
            scorer: Some(scorer),
        }
    }

    /// Run the tuning project end to end.
    pub fn run(&mut self, project: &Project) -> Result<TuningRunOutcome, String> {
        let settings = TuningSettings::from_project(project)?;
        let spec = project
            .spec
            .clone()
            .ok_or("tuning project missing params.spec")?;
        let workload = project.workload()?;
        if spec.dims() == 0 {
            return Err(format!(
                "params.spec declares no parameters for workload {:?} \
                 (only workload blocks for other suites)",
                workload.name
            ));
        }
        // satellite guard: one precise note per run, only for params the
        // model truly cannot map, only when something consumes the model
        let blind = cost_model_blind_params(&spec);
        if !blind.is_empty() && (settings.prescreen || settings.racing.enabled) {
            eprintln!(
                "note: the analytic cost model cannot map spec-declared parameter(s) {} — \
                 surrogate prescreen predictions never react to them, and multi-fidelity \
                 racing skips its tier-0 model pass (tier 1, one DES seed, becomes the \
                 cheapest fidelity)",
                blind.join(", ")
            );
        }
        let base = project.base_config()?;
        let space = ParamSpace::new(spec.clone(), base);
        let cluster_spec = self.cluster.spec.clone();

        let outcome = {
            let inner = ClusterObjective::new(self.cluster, &workload, settings.repeats);
            let mut plain;
            let mut raced;
            let obj: &mut dyn BatchObjective = if settings.racing.enabled {
                // tier 0 only when every tuned param is model-visible;
                // otherwise the race starts at one-seed fidelity
                let tier0: Option<Box<dyn CandidateScorer>> = if blind.is_empty() {
                    Some(Box::new(NativeScorer {
                        workload: workload.clone(),
                        cluster: cluster_spec,
                    }))
                } else {
                    None
                };
                raced = RacingObjective::new(inner, settings.racing, tier0);
                &mut raced
            } else {
                plain = inner;
                &mut plain
            };
            let mut driver = settings.driver();
            if settings.prescreen {
                let scorer = self
                    .scorer
                    .as_deref_mut()
                    .ok_or("prescreen=auto but no surrogate scorer attached")?;
                match settings.optimizer.as_str() {
                    // only DFO benefits from a seeded start; direct search
                    // ignores prescreening
                    "bobyqa" => {
                        let mut p = Prescreen::new(scorer);
                        p.seed = settings.seed;
                        p.prime(&space)?;
                        driver.run(&mut p, &space, obj)?
                    }
                    other => {
                        let mut opt = Method::from_name(other, settings.seed)?.build();
                        driver.run(opt.as_mut(), &space, obj)?
                    }
                }
            } else {
                let mut opt = Method::from_name(&settings.optimizer, settings.seed)?.build();
                driver.run(opt.as_mut(), &space, obj)?
            }
        };

        let history = History::open(&project.dir).map_err(|e| e.to_string())?;
        let log_path = history.write_tuning_log(&spec, &outcome)?;
        history.append_summary(&spec, &outcome)?;
        // DES runs actually spent: with racing, pruned candidates cost
        // fewer (or zero) simulations than `repeats`
        let cluster_evals = outcome
            .records
            .iter()
            .map(|r| r.fidelity.sims(settings.repeats))
            .sum();
        Ok(TuningRunOutcome {
            outcome,
            cluster_evals,
            log_path,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::project::{create_template, ProjectKind};
    use crate::hadoop::ClusterSpec;
    use crate::optim::surrogate::NativeScorer;
    use crate::workloads::wordcount;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-opt-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn make_tuning_project(name: &str, optimizer: &str, budget: usize) -> PathBuf {
        let dir = tmp(name);
        create_template(&dir, ProjectKind::Tuning, "wordcount", 2048.0).unwrap();
        let tp = dir.join("tuning.properties");
        std::fs::write(
            &tp,
            format!("optimizer={optimizer}\nbudget={budget}\nrepeats=1\nseed=5\n"),
        )
        .unwrap();
        dir
    }

    #[test]
    fn bobyqa_tuning_project_end_to_end() {
        let dir = make_tuning_project("bobyqa", "bobyqa", 25);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        assert!(out.outcome.evals() <= 25);
        assert!(out.log_path.is_file());
        // tuning log has one row per evaluation
        let h = History::open(&dir).unwrap();
        assert_eq!(h.load_tuning_log().unwrap().rows.len(), out.outcome.evals());
        // best-so-far column is monotone non-increasing
        let conv =
            History::convergence_from_log(&h.load_tuning_log().unwrap()).unwrap();
        for w in conv.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tuning_improves_over_first_sample() {
        let dir = make_tuning_project("improve", "bobyqa", 40);
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        let first = out.outcome.records[0].value;
        assert!(
            out.outcome.best_value < first,
            "no improvement: best {} vs first {first}",
            out.outcome.best_value
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prescreen_requires_scorer() {
        let dir = make_tuning_project("prescreen-miss", "bobyqa", 10);
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=bobyqa\nbudget=10\nprescreen=auto\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        assert!(OptimizerRunner::new(&mut cluster).run(&project).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prescreen_with_native_scorer_runs() {
        let dir = make_tuning_project("prescreen", "bobyqa", 15);
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=bobyqa\nbudget=15\nprescreen=auto\nseed=5\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut scorer = NativeScorer {
            workload: wordcount(2048.0),
            cluster: ClusterSpec::default(),
        };
        let out = OptimizerRunner::with_scorer(&mut cluster, &mut scorer)
            .run(&project)
            .unwrap();
        assert!(out.outcome.optimizer.contains("prescreen"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cost_model_blind_params_names_exactly_the_unmappable_dims() {
        // codec choice and shuffle buffer percent are post-prefix but
        // model-mapped now; only the made-up param is truly blind
        let spec = crate::config::spec::TuningSpec::parse(
            "param mapreduce.task.io.sort.mb int 64 1024\n\
             param x.shuffle.buffer.kb int 32 4096\n\
             param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
             param mapreduce.reduce.shuffle.input.buffer.percent float 0.1 0.9\n",
        )
        .unwrap();
        assert_eq!(cost_model_blind_params(&spec), vec!["x.shuffle.buffer.kb"]);
        // a codec list with an unknown label cannot be mapped
        let spec = crate::config::spec::TuningSpec::parse(
            "param mapreduce.map.output.compress.codec cat none,brotli\n",
        )
        .unwrap();
        assert_eq!(
            cost_model_blind_params(&spec),
            vec!["mapreduce.map.output.compress.codec"]
        );
        assert!(cost_model_blind_params(&crate::config::spec::TuningSpec::fig3()).is_empty());
    }

    #[test]
    fn racing_settings_parse_and_validate() {
        let dir = make_tuning_project("racing-parse", "random", 8);
        let project = Project::load(&dir).unwrap();
        let s = TuningSettings::from_project(&project).unwrap();
        assert!(!s.racing.enabled, "racing must default off");
        assert_eq!(s.racing.eta, 4);
        assert_eq!(s.racing.min_tier_evals, 2);
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=8\nracing.enabled=true\nracing.eta=3\nracing.min_tier_evals=1\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let s = TuningSettings::from_project(&project).unwrap();
        assert!(s.racing.enabled && s.racing.eta == 3 && s.racing.min_tier_evals == 1);
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=8\nracing.enabled=true\nracing.eta=1\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        assert!(TuningSettings::from_project(&project).is_err(), "eta=1 must be rejected");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn racing_run_spends_fewer_cluster_evals_and_keeps_a_full_best() {
        let dir = make_tuning_project("racing-run", "random", 24);
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=24\nrepeats=3\nseed=5\nracing.enabled=true\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        assert!(
            out.cluster_evals < out.outcome.evals() * 3,
            "racing spent full fidelity everywhere: {} sims for {} evals",
            out.cluster_evals,
            out.outcome.evals()
        );
        // the declared winner is always full-fidelity evidence
        let best_full = out
            .outcome
            .records
            .iter()
            .filter(|r| r.fidelity.is_full())
            .map(|r| r.value)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(out.outcome.best_value, best_full);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn grid_method_also_supported() {
        let dir = make_tuning_project("grid", "grid", 30);
        // fig3 spec has no steps -> default grids; budget caps at 30
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        assert_eq!(out.outcome.evals(), 30);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn serve_retry_settings_parse_with_defaults() {
        let dir = make_tuning_project("retry", "random", 4);
        let project = Project::load(&dir).unwrap();
        let s = TuningSettings::from_project(&project).unwrap();
        assert_eq!(s.retry_max, 2, "default serve.retry.max");
        assert_eq!(s.retry_backoff_ms, 0, "default serve.retry.backoff_ms");
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=4\nserve.retry.max=5\nserve.retry.backoff_ms=7\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let s = TuningSettings::from_project(&project).unwrap();
        assert_eq!(s.retry_max, 5);
        assert_eq!(s.retry_backoff_ms, 7);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn early_stop_settings_cap_the_run() {
        let dir = make_tuning_project("earlystop", "random", 400);
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=random\nbudget=400\nseed=5\nearly.patience=10\nearly.tol=0.01\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        let settings = TuningSettings::from_project(&project).unwrap();
        assert_eq!(settings.early_patience, 10);
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = OptimizerRunner::new(&mut cluster).run(&project).unwrap();
        assert!(
            out.outcome.evals() < 400,
            "early stop never fired: {} evals",
            out.outcome.evals()
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

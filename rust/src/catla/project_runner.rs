//! Project Runner — "submits a group of MapReduce jobs in an organized
//! project folder and monitors the status of its running until job
//! completion; eventually, all analyzing results and their logs ... are
//! downloaded and organized to specified location in its project folder."
//! (§II.A)
//!
//! Jobs come from `jobs.list`: `<name> <workload> <input_mb>
//! [conf.param=value ...]`, one per line.

use crate::catla::history::History;
use crate::catla::metrics::JobMetrics;
use crate::catla::project::Project;
use crate::catla::task_runner::TaskRunner;
use crate::config::params::HadoopConfig;
use crate::hadoop::{Cluster, JobSubmission, JobStatus};
use crate::util::durable::atomic_write;
use crate::workloads::{self, WorkloadSpec};

/// One parsed `jobs.list` entry.
#[derive(Clone, Debug)]
pub struct GroupJob {
    pub name: String,
    pub workload: WorkloadSpec,
    pub config: HadoopConfig,
}

/// Parse a `jobs.list` line.
pub fn parse_job_line(line: &str) -> Result<GroupJob, String> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    if toks.len() < 3 {
        return Err(format!("jobs.list line {line:?}: expected <name> <workload> <input_mb>"));
    }
    let input_mb: f64 = toks[2]
        .parse()
        .map_err(|_| format!("bad input_mb {:?}", toks[2]))?;
    let workload = workloads::by_name(toks[1], input_mb)
        .ok_or_else(|| format!("unknown workload {:?}", toks[1]))?;
    let mut config = HadoopConfig::default();
    for t in &toks[3..] {
        let (k, v) = t
            .split_once('=')
            .ok_or_else(|| format!("bad override {t:?}"))?;
        let param = k
            .strip_prefix("conf.")
            .ok_or_else(|| format!("override {t:?} must start with conf."))?;
        config.set_by_name(param, v.parse().map_err(|_| format!("bad value {v:?}"))?)?;
    }
    Ok(GroupJob {
        name: toks[0].to_string(),
        workload,
        config,
    })
}

/// Result of running a whole project folder.
#[derive(Clone, Debug)]
pub struct ProjectRunOutcome {
    pub jobs: Vec<(String, JobMetrics)>, // (group name, metrics)
}

pub struct ProjectRunner<'a, C: Cluster> {
    pub cluster: &'a mut C,
}

impl<'a, C: Cluster> ProjectRunner<'a, C> {
    pub fn new(cluster: &'a mut C) -> Self {
        Self { cluster }
    }

    /// Submit every job in the group, monitor to completion, download
    /// all artifacts into per-job subfolders of `downloaded_results/`.
    pub fn run(&mut self, project: &Project) -> Result<ProjectRunOutcome, String> {
        if project.jobs.is_empty() {
            return Err("project has no jobs.list entries".into());
        }
        let group: Vec<GroupJob> = project
            .jobs
            .iter()
            .map(|l| parse_job_line(l))
            .collect::<Result<_, _>>()?;

        // submit all up front (the paper's runner monitors a batch)
        let mut submitted: Vec<(String, String)> = Vec::new(); // (group name, job id)
        for j in &group {
            let id = self.cluster.submit_job(JobSubmission {
                name: j.name.clone(),
                workload: j.workload.clone(),
                config: j.config.clone(),
            })?;
            submitted.push((j.name.clone(), id));
        }

        // monitor until every job completes
        let mut done: Vec<bool> = vec![false; submitted.len()];
        let mut guard = 0u32;
        while done.iter().any(|d| !d) {
            guard += 1;
            if guard > 100_000 {
                return Err("project monitor exceeded poll budget".into());
            }
            for (i, (_, id)) in submitted.iter().enumerate() {
                if done[i] {
                    continue;
                }
                match self.cluster.poll(id)? {
                    JobStatus::Running { .. } => {}
                    JobStatus::Failed { reason } => {
                        return Err(format!("job {id} failed: {reason}"))
                    }
                    JobStatus::Succeeded { .. } => done[i] = true,
                }
            }
        }

        // download + organize per job
        let history = History::open(&project.dir).map_err(|e| e.to_string())?;
        let mut jobs = Vec::new();
        for (name, id) in &submitted {
            let job_dir = project.results_dir().join(name);
            let logs_dir = job_dir.join("logs");
            std::fs::create_dir_all(&logs_dir).map_err(|e| e.to_string())?;
            let artifacts = self.cluster.fetch_artifacts(id)?;
            let hist_path = job_dir.join(format!("{id}.history.json"));
            atomic_write(&hist_path, artifacts.history_json.as_bytes())
                .map_err(|e| e.to_string())?;
            for (fname, content) in &artifacts.container_logs {
                atomic_write(&logs_dir.join(fname), content.as_bytes())
                    .map_err(|e| e.to_string())?;
            }
            for (fname, content) in &artifacts.outputs {
                atomic_write(&job_dir.join(fname), content.as_bytes())
                    .map_err(|e| e.to_string())?;
            }
            let metrics = JobMetrics::from_file(&hist_path)?;
            history.append_job(&metrics)?;
            jobs.push((name.clone(), metrics));
        }
        Ok(ProjectRunOutcome { jobs })
    }
}

/// Convenience: run a single-job project through the Task Runner (used
/// by the CLI when a project folder turns out to be a task template).
pub fn run_as_task<C: Cluster>(
    cluster: &mut C,
    project: &Project,
) -> Result<ProjectRunOutcome, String> {
    let mut tr = TaskRunner::new(cluster);
    let out = tr.run(project)?;
    Ok(ProjectRunOutcome {
        jobs: vec![("task".into(), out.metrics)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::project::{create_template, ProjectKind};
    use crate::hadoop::{ClusterSpec, SimCluster};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-proj-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parse_job_line_full() {
        let j = parse_job_line("wc-a wordcount 2048 conf.mapreduce.job.reduces=8").unwrap();
        assert_eq!(j.name, "wc-a");
        assert_eq!(j.workload.input_mb, 2048.0);
        assert_eq!(j.config.get(crate::config::params::P_REDUCES), 8.0);
    }

    #[test]
    fn parse_job_line_rejects_malformed() {
        assert!(parse_job_line("only-two args").is_err());
        assert!(parse_job_line("n wordcount notanumber").is_err());
        assert!(parse_job_line("n wordcount 100 reduces=8").is_err());
        assert!(parse_job_line("n mystery 100").is_err());
    }

    #[test]
    fn group_run_downloads_everything() {
        let dir = tmp("group");
        create_template(&dir, ProjectKind::Project, "wordcount", 2048.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let out = ProjectRunner::new(&mut cluster).run(&project).unwrap();
        assert_eq!(out.jobs.len(), 2);
        for (name, m) in &out.jobs {
            assert!(m.runtime_s > 0.0);
            let jd = project.results_dir().join(name);
            assert!(jd.is_dir(), "missing {}", jd.display());
            assert!(jd.join("logs").is_dir());
        }
        // both jobs in jobs.csv
        let h = History::open(&dir).unwrap();
        assert_eq!(h.load_jobs().unwrap().rows.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_project_is_error() {
        let dir = tmp("empty");
        create_template(&dir, ProjectKind::Task, "grep", 64.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        assert!(ProjectRunner::new(&mut cluster).run(&project).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
